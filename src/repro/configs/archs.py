"""The 10 assigned architectures (exact configs from the assignment sheet).

Each also ships a `smoke()` reduction: same family / wiring, tiny dims, so a
single forward/train step runs on CPU in tests.
"""
from __future__ import annotations

from .base import ModelConfig, MoEConfig, SSMConfig

# --- mixtral-8x22b [arXiv:2401.04088] -------------------------------------
MIXTRAL_8X22B = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=32768, head_dim=128,
    sliding_window=4096,  # per assignment: SWA
    rope_theta=1e6,
    moe=MoEConfig(num_experts=8, num_experts_per_tok=2),
)

# --- moonshot-v1-16b-a3b (Moonlight) [hf:moonshotai/Moonlight-16B-A3B] -----
MOONSHOT_16B_A3B = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=163840, head_dim=128,
    rope_theta=50000.0,
    moe=MoEConfig(num_experts=64, num_experts_per_tok=6,
                  num_shared_experts=2, first_k_dense=1, dense_d_ff=11264),
)

# --- phi3-medium-14b [arXiv:2404.14219] ------------------------------------
PHI3_MEDIUM = ModelConfig(
    name="phi3-medium-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10,
    d_ff=17920, vocab_size=100352, head_dim=128,
    rope_theta=10000.0,
)

# --- yi-6b [arXiv:2403.04652] ----------------------------------------------
YI_6B = ModelConfig(
    name="yi-6b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=4,
    d_ff=11008, vocab_size=64000, head_dim=128,
    rope_theta=5e6,
)

# --- chatglm3-6b [arXiv:2406.12793] ----------------------------------------
CHATGLM3_6B = ModelConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=65024, head_dim=128,
    rope_theta=10000.0, rope_fraction=0.5, rope_interleaved=True,  # 2d RoPE
)

# --- gemma3-1b [hf:google/gemma-3-1b-pt] ------------------------------------
GEMMA3_1B = ModelConfig(
    name="gemma3-1b", family="dense",
    num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1,
    d_ff=6912, vocab_size=262144, head_dim=256,
    sliding_window=512, local_global_ratio=5,   # 5 local : 1 global
    rope_theta=1e6, qk_norm=True,
    tie_embeddings=True, embedding_scale=True,
)

# --- internvl2-76b [arXiv:2404.16821]: ViT stub + LLaMA3-70B-like backbone --
INTERNVL2_76B = ModelConfig(
    name="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256, head_dim=128,
    rope_theta=5e5,
    frontend="vision_patches", frontend_tokens=1024,
)

# --- mamba2-2.7b [arXiv:2405.21060] -----------------------------------------
MAMBA2_2P7B = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    head_dim=1,  # unused for ssm
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256, ngroups=1),
    norm_type="rmsnorm",
)

# --- zamba2-7b [arXiv:2411.15242] -------------------------------------------
ZAMBA2_7B = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, head_dim=112,
    hybrid_attn_every=6,   # shared attn block after every 6 mamba2 blocks
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256, ngroups=1),
)

# --- seamless-m4t-large-v2 [arXiv:2308.11596] --------------------------------
SEAMLESS_M4T_V2 = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=256206, head_dim=64,
    encoder_layers=24,
    norm_type="layernorm",
    frontend="audio_frames", frontend_tokens=0,  # encoder input IS frames
)

ARCHS = {
    c.name: c for c in [
        MIXTRAL_8X22B, MOONSHOT_16B_A3B, PHI3_MEDIUM, YI_6B, CHATGLM3_6B,
        GEMMA3_1B, INTERNVL2_76B, MAMBA2_2P7B, ZAMBA2_7B, SEAMLESS_M4T_V2,
    ]
}


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 4),
        d_model=64,
        vocab_size=256,
        head_dim=16,
    )
    if cfg.family == "ssm" or cfg.family == "hybrid":
        kw["ssm"] = SSMConfig(state_dim=16, head_dim=16, expand=2,
                              conv_width=4, chunk_size=16, ngroups=1)
    if cfg.family != "ssm":
        kw["num_heads"] = 4
        kw["num_kv_heads"] = min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0
        kw["d_ff"] = 128
    if cfg.family == "moe":
        kw["moe"] = MoEConfig(
            num_experts=4, num_experts_per_tok=2,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            first_k_dense=min(cfg.moe.first_k_dense, 1),
            dense_d_ff=160 if cfg.moe.dense_d_ff else 0)
    if cfg.sliding_window:
        kw["sliding_window"] = 8
    if cfg.hybrid_attn_every:
        kw["hybrid_attn_every"] = 2
        kw["num_layers"] = 4
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
        kw["num_layers"] = 2
    if cfg.frontend_tokens:
        kw["frontend_tokens"] = 4
    return cfg.replace(**kw)
