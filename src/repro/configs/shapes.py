"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

No device allocation — these feed jit(...).lower() in the dry-run and the
shardings resolver. Modality frontends are stubs: VLM cells get patch
embeddings, audio cells get frame embeddings, per the assignment.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .base import ModelConfig, ShapeConfig
from ..models.api import build_model

# seamless decode cells: fixed encoder context length
ENCDEC_SRC_LEN = 4096


def train_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    if cfg.family == "encdec":
        T = S // 2
        return {
            "src_embeds": jax.ShapeDtypeStruct((B, T, cfg.d_model), bf16),
            "tokens": jax.ShapeDtypeStruct((B, S - T), i32),
            "targets": jax.ShapeDtypeStruct((B, S - T), i32),
        }
    P = cfg.frontend_tokens
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S - P), i32),
        "targets": jax.ShapeDtypeStruct((B, S), i32),
    }
    if P:
        specs["frontend_embeds"] = jax.ShapeDtypeStruct((B, P, cfg.d_model), bf16)
    return specs


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    if cfg.family == "encdec":
        return {
            "src_embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16),
            "tokens": jax.ShapeDtypeStruct((B, 8), i32),  # primer prefix
        }
    P = cfg.frontend_tokens
    specs = {"tokens": jax.ShapeDtypeStruct((B, S - P), i32)}
    if P:
        specs["frontend_embeds"] = jax.ShapeDtypeStruct((B, P, cfg.d_model), bf16)
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[Any, Any]:
    """Returns (cache_specs, token_specs) for one decode step at kv=seq_len."""
    B, S = shape.global_batch, shape.seq_len
    model = build_model(cfg)
    kw = {"src_len": ENCDEC_SRC_LEN} if cfg.family == "encdec" else {}
    cache = model.cache_specs(B, S, jnp.bfloat16, **kw)
    tokens = jax.ShapeDtypeStruct((B,), jnp.int32)
    return cache, tokens


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    if shape.kind == "train":
        return {"batch": train_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_specs(cfg, shape)}
    cache, tokens = decode_specs(cfg, shape)
    return {"cache": cache, "tokens": tokens}
