"""Assigned architecture config (see archs.py for the literal)."""
from .archs import PHI3_MEDIUM as CONFIG
from .archs import smoke

SMOKE = smoke(CONFIG)
