"""Assigned architecture config (see archs.py for the literal)."""
from .archs import MAMBA2_2P7B as CONFIG
from .archs import smoke

SMOKE = smoke(CONFIG)
