"""Assigned architecture config (see archs.py for the literal)."""
from .archs import MOONSHOT_16B_A3B as CONFIG
from .archs import smoke

SMOKE = smoke(CONFIG)
