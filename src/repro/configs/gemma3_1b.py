"""Assigned architecture config (see archs.py for the literal)."""
from .archs import GEMMA3_1B as CONFIG
from .archs import smoke

SMOKE = smoke(CONFIG)
