"""Assigned architecture config (see archs.py for the literal)."""
from .archs import SEAMLESS_M4T_V2 as CONFIG
from .archs import smoke

SMOKE = smoke(CONFIG)
