from .base import (ModelConfig, MoEConfig, SSMConfig, ShapeConfig,
                   SHAPES, SHAPES_BY_NAME, TRAIN_4K, PREFILL_32K,
                   DECODE_32K, LONG_500K, long_context_ok)
from .archs import ARCHS, smoke


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return smoke(ARCHS[name[: -len("-smoke")]])
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]
