"""Assigned architecture config (see archs.py for the literal)."""
from .archs import MIXTRAL_8X22B as CONFIG
from .archs import smoke

SMOKE = smoke(CONFIG)
