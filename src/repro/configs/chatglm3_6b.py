"""Assigned architecture config (see archs.py for the literal)."""
from .archs import CHATGLM3_6B as CONFIG
from .archs import smoke

SMOKE = smoke(CONFIG)
