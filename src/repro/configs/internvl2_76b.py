"""Assigned architecture config (see archs.py for the literal)."""
from .archs import INTERNVL2_76B as CONFIG
from .archs import smoke

SMOKE = smoke(CONFIG)
