"""Assigned architecture config (see archs.py for the literal)."""
from .archs import ZAMBA2_7B as CONFIG
from .archs import smoke

SMOKE = smoke(CONFIG)
