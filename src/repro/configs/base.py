"""Model configuration schema shared by every assigned architecture.

Every field is plain data so configs hash/serialize cleanly (used as jit
static args and checkpoint metadata).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    num_experts_per_tok: int = 0
    # DeepSeek/Moonlight-style extras
    num_shared_experts: int = 0
    first_k_dense: int = 0          # first k layers use a dense FFN
    dense_d_ff: int = 0             # d_ff of those dense layers (0 -> d_ff)
    router_jitter: float = 0.0
    capacity_factor: float = 0.0    # 0 -> dropless dense dispatch


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 0              # N in Mamba2 / SSD
    head_dim: int = 64              # P
    expand: int = 2                 # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256           # SSD block size
    ngroups: int = 1                # B/C groups (like GQA for SSM)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # --- attention pattern ---
    sliding_window: int = 0         # 0 -> full attention
    local_global_ratio: int = 0     # gemma3: N local layers per 1 global
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0      # chatglm3: rotary applied to a fraction
    rope_interleaved: bool = False  # pairwise (GLM/NeoX-2d) vs half-split
    attn_logit_softcap: float = 0.0
    qk_norm: bool = False

    # --- FFN ---
    mlp_activation: str = "silu"    # silu (SwiGLU) | gelu (GeGLU)

    # --- norms / embeddings ---
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    embedding_scale: bool = False   # gemma: scale embeds by sqrt(d)

    # --- sub-configs ---
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)

    # --- hybrid (zamba2-style): shared attention block cadence ---
    hybrid_attn_every: int = 0      # insert shared attn block every N ssm layers

    # --- encoder-decoder (seamless) ---
    encoder_layers: int = 0         # >0 -> enc-dec; num_layers = decoder layers

    # --- modality frontend stubs ---
    frontend: str = "none"          # none | vision_patches | audio_frames
    frontend_tokens: int = 0        # patches/frames supplied by input_specs

    # --- numerics ---
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    # ---- derived quantities ----------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def num_params(self) -> int:
        """Analytic total parameter count (matches init'd pytree)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        emb = V * d + (0 if self.tie_embeddings else V * d)

        def attn_p():
            return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d

        def dense_ffn(f):
            return 3 * d * f  # gate, up, down (SwiGLU)

        def norms():
            return 2 * d

        if self.family == "ssm":
            p = 0
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            conv_dim = d_in + 2 * s.ngroups * s.state_dim
            per = (d * (2 * d_in + 2 * s.ngroups * s.state_dim + nheads)  # in_proj
                   + conv_dim * s.conv_width + conv_dim                   # conv + bias
                   + 2 * nheads                                           # A_log, D
                   + nheads                                               # dt_bias
                   + d_in                                                 # norm gate
                   + d_in * d + d)                                        # out_proj + norm
            p = per * self.num_layers
            return p + emb + d
        # transformer-ish
        per_layer = attn_p() + norms()
        if self.family in ("moe",):
            m = self.moe
            moe_layers = self.num_layers - m.first_k_dense
            e_ff = ff
            p = 0
            p += m.first_k_dense * dense_ffn(m.dense_d_ff or ff)
            p += moe_layers * (m.num_experts * dense_ffn(e_ff)
                               + m.num_shared_experts * dense_ffn(e_ff)
                               + d * m.num_experts)  # router
            p += self.num_layers * per_layer
        elif self.family == "hybrid":
            # zamba2: ssm blocks + one shared attn/ffn block
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            conv_dim = d_in + 2 * s.ngroups * s.state_dim
            per_ssm = (d * (2 * d_in + 2 * s.ngroups * s.state_dim + nheads)
                       + conv_dim * s.conv_width + conv_dim
                       + 2 * nheads + nheads + d_in + d_in * d + d)
            p = per_ssm * self.num_layers
            p += attn_p() + dense_ffn(ff) + norms()  # single shared block
        else:
            p = self.num_layers * (per_layer + dense_ffn(ff))
        if self.is_encdec:
            # encoder layers: self-attn + ffn; decoder adds cross-attn
            p += self.encoder_layers * (attn_p() + dense_ffn(ff) + norms())
            p += self.num_layers * attn_p()  # cross-attention
        p += emb + d  # final norm
        return p

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """KV-cache bytes per sequence token (the DistServe transfer unit)."""
        if self.family == "ssm":
            return 0  # constant state, not per-token
        layers = self.num_layers
        if self.family == "hybrid":
            layers = self.num_layers // max(self.hybrid_attn_every, 1)
        return layers * 2 * self.kv_dim * dtype_bytes

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Shape regimes (assigned): every LM arch pairs with these four.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def long_context_ok(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic archs (SSM/hybrid/SWA)."""
    if cfg.family in ("ssm", "hybrid"):
        return True
    return cfg.sliding_window > 0  # SWA / local-global bound the KV
