"""Assigned architecture config (see archs.py for the literal)."""
from .archs import YI_6B as CONFIG
from .archs import smoke

SMOKE = smoke(CONFIG)
