"""Deterministic synthetic token pipeline (no datasets ship offline).

Produces next-token-prediction batches with document boundaries, sharded
by host and seeded per step, so restarts resume the exact stream
(fault-tolerant data order)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 1234
    mean_doc_len: int = 512


class SyntheticTokens:
    """Markov-ish synthetic stream: documents of geometric length, token
    correlations so the loss signal is learnable (not pure noise)."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, self.host_id))
        b = cfg.batch // self.num_hosts
        base = rng.integers(0, cfg.vocab_size, size=(b, cfg.seq_len + 1),
                            dtype=np.int64)
        # correlate: with p=0.5 a token repeats (t-1) + 1 mod V (learnable)
        rep = rng.random((b, cfg.seq_len)) < 0.5
        nxt = (base[:, :-1] + 1) % cfg.vocab_size
        base[:, 1:][rep] = nxt[rep]
        # document boundaries
        eod = rng.random((b, cfg.seq_len + 1)) < 1.0 / cfg.mean_doc_len
        base[eod] = 0
        return {"tokens": base[:, :-1].astype(np.int32),
                "targets": base[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
