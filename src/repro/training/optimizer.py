"""AdamW + global-norm clipping in pure JAX (optax is not in this env).

Optimizer state mirrors the param tree, so it inherits param shardings
(FSDP/TP) with no extra rules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads, max_norm):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def _lr_at(step, cfg: AdamWConfig):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def adamw_update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = _lr_at(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        pf = p.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        pf = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    flat = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_p = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v, "step": step}
