"""Cross-entropy train step with configurable remat + mixed precision.

Params live in f32 (with f32 Adam moments); compute casts to bf16 at the
top of the loss (cast-before-use keeps FSDP all-gathers in bf16 after XLA
sinks the convert — verified in the dry-run HLO)."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .optimizer import AdamWConfig, adamw_update, clip_by_global_norm


def cross_entropy(logits, targets):
    """logits: (B, S, V) any float dtype; targets: (B, S) i32."""
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    picked = jnp.take_along_axis(logits, targets[..., None],
                                 axis=-1)[..., 0].astype(jnp.float32)
    return jnp.mean(lse - picked)


def make_loss_fn(model, *, remat: bool = True, compute_dtype=jnp.bfloat16,
                 aux_weight: float = 0.01, attn_blocks=(512, 512)):
    def loss_fn(params, batch):
        pc = model.cast(params, compute_dtype)
        logits, aux = model.forward(pc, batch, remat=remat,
                                    attn_blocks=attn_blocks)
        loss = cross_entropy(logits, batch["targets"])
        total = loss + aux_weight * aux
        return total, {"loss": loss, "aux": aux}

    return loss_fn


def make_train_step(model, opt_cfg: AdamWConfig = AdamWConfig(), *,
                    remat: bool = True, compute_dtype=jnp.bfloat16,
                    attn_blocks=(512, 512)):
    loss_fn = make_loss_fn(model, remat=remat, compute_dtype=compute_dtype,
                           attn_blocks=attn_blocks)

    def train_step(params, opt_state, batch):
        (total, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, total=total, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step
