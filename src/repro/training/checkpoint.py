"""Sharded checkpointing without orbax: msgpack index + zstd-compressed
raw tensor blobs, one file per (host-local) leaf. Restore re-shards onto
whatever mesh is active — the elastic-rescale path (node failure or scale
change restarts on a different topology from the same checkpoint).
"""
from __future__ import annotations

import dataclasses
import io
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:                                    # optional deps: fall back to stdlib
    import msgpack
except ImportError:                     # pragma: no cover - env dependent
    msgpack = None
try:
    import zstandard as zstd
except ImportError:                     # pragma: no cover - env dependent
    zstd = None
import json
import zlib


class _ZlibCodec:
    """Stdlib stand-in with the zstd compressor/decompressor interface."""

    def __init__(self, level: int = 6):
        self.level = level

    def compress(self, data: bytes) -> bytes:
        return zlib.compress(data, self.level)

    def decompress(self, data: bytes) -> bytes:
        return zlib.decompress(data)


def _compressor(level: int):
    if zstd is not None:
        return zstd.ZstdCompressor(level=level), "zstd"
    return _ZlibCodec(min(level * 2, 9)), "zlib"


def _decompressor(codec: str):
    if codec == "zstd":
        if zstd is None:
            raise RuntimeError("checkpoint was written with zstd, which is "
                               "not installed")
        return zstd.ZstdDecompressor()
    return _ZlibCodec()


def _pack_index(index: Dict) -> bytes:
    if msgpack is not None:
        return msgpack.packb(index)
    return json.dumps(index).encode()


def _unpack_index(raw: bytes) -> Dict:
    if raw[:1] == b"{":                 # JSON fallback index
        return json.loads(raw.decode())
    if msgpack is None:
        raise RuntimeError("checkpoint index is msgpack but msgpack is not "
                           "installed")
    return msgpack.unpackb(raw)


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(f"{prefix}/{k}" if prefix else str(k), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(f"{prefix}/{i}", v)
        else:
            flat[prefix] = node
    rec("", tree)
    return flat


def save(path: str, step: int, params, opt_state=None,
         extra: Optional[Dict] = None, level: int = 3):
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    cctx, codec = _compressor(level)
    index = {"step": int(step), "extra": extra or {}, "codec": codec,
             "leaves": {}}
    trees = {"params": params}
    if opt_state is not None:
        trees["opt"] = opt_state
    for tname, tree in trees.items():
        for key, leaf in _flatten(tree).items():
            arr = np.asarray(jax.device_get(leaf))
            fname = f"{tname}__{key.replace('/', '__')}.zst"
            with open(os.path.join(tmp, fname), "wb") as f:
                f.write(cctx.compress(arr.tobytes()))
            index["leaves"][f"{tname}/{key}"] = {
                "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "index.msgpack"), "wb") as f:
        f.write(_pack_index(index))
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = [int(d.split("_")[-1]) for d in os.listdir(root)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(path: str, like_params, like_opt=None, shardings=None,
            opt_shardings=None) -> Tuple[int, Any, Any, Dict]:
    """Restore into the structure of `like_*` (ShapeDtypeStructs or arrays).
    With `shardings`, leaves are placed sharded (elastic re-shard)."""
    with open(os.path.join(path, "index.msgpack"), "rb") as f:
        index = _unpack_index(f.read())
    dctx = _decompressor(index.get("codec", "zstd"))

    def load_tree(tname, like, shards):
        flat_like = _flatten(like)
        flat_shards = _flatten(shards) if shards is not None else None
        out_flat = {}
        for key, leaf in flat_like.items():
            meta = index["leaves"][f"{tname}/{key}"]
            with open(os.path.join(path, meta["file"]), "rb") as f:
                raw = dctx.decompress(f.read())
            arr = np.frombuffer(raw, dtype=meta["dtype"]).reshape(meta["shape"])
            if flat_shards is not None and flat_shards.get(key) is not None:
                out_flat[key] = jax.device_put(arr, flat_shards[key])
            else:
                out_flat[key] = jnp.asarray(arr)
        return _unflatten(out_flat, like)

    params = load_tree("params", like_params, shardings)
    opt = None
    if like_opt is not None:
        opt = load_tree("opt", like_opt, opt_shardings)
    return index["step"], params, opt, index.get("extra", {})


def _unflatten(flat: Dict[str, Any], like) -> Any:
    def rec(prefix, node):
        if isinstance(node, dict):
            return {k: rec(f"{prefix}/{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            vals = [rec(f"{prefix}/{i}", v) for i, v in enumerate(node)]
            return type(node)(vals)
        return flat[prefix]
    return rec("", like)
