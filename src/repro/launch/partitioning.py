"""Logical-axis -> mesh-axis rule sets + pytree sharding resolution.

Three modes:
  train      — FSDP("data") x TP("model"); batch over ("pod","data").
  serve      — TP("model") only; weights replicated over "data"; batch over
               ("pod","data") = replica rows.
  serve_2d   — as serve, plus weights 2D-sharded with d_model over "data"
               (for archs whose weights exceed HBM/16: mixtral, internvl2).

Every rule is a candidate LIST; the resolver (common.ShardingRules) picks
the first axis whose size divides the tensor dim and isn't already used in
the same spec — small archs (gemma3's 4 heads) degrade to replication
per-tensor instead of failing.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.common import ShardingRules

# archs that need 2D weight sharding to fit 16 GB/chip in serving
SERVE_2D_ARCHS = ("mixtral-8x22b", "internvl2-76b")


def _with_pod(mesh, *axes):
    """Prefix ("pod", ...) when a pod axis exists."""
    if "pod" in mesh.shape:
        return (("pod",) + axes,) if axes else ("pod",)
    return (axes,) if axes else ()


def make_rules(mesh, mode: str, opts=()) -> ShardingRules:
    has_pod = "pod" in mesh.shape
    batch_c = [("pod", "data") if has_pod else "data", "data", None]
    if mode == "train":
        rules = {
            # activations
            "batch": batch_c,
            "embed_act": [None],
            "heads": ["model", None],
            "kv_heads": ["model", None],
            "vocab": ["model", None],
            "kv_seq": [None],
            # params: FSDP on data, TP on model
            "embed": [("pod", "data") if has_pod else "data", "data", None],
            "kv_embed": [("pod", "data") if has_pod else "data", "data", None],
            "kv_batch": batch_c,
            "mlp": ["model", None],
            "expert": ["model", None],
            "ssm_inner": ["model", None],
            "state": [None],
            "layers": [None], "groups": [None],
        }
    elif mode in ("serve", "serve_2d"):
        # decode_weight_stationary: replicate the (tiny) decode activations
        # instead of sharding their batch, so 2D-sharded weights stay put
        # and each matmul reduces small partials — kills the per-step
        # per-layer weight all-gathers of serve_2d (beyond-paper).
        act_batch = [None] if "decode_weight_stationary" in opts else batch_c
        rules = {
            "batch": act_batch,
            "kv_batch": batch_c,
            "embed_act": [None],
            "heads": ["model", None],
            "kv_heads": ["model", None],
            "vocab": ["model", None],
            # KV sequence parallelism (beyond-paper, default-on): falls to
            # the data axis for B=1 long-context cells, and to the model
            # axis for small-kv-head archs whose cache would otherwise
            # replicate across it (flash-decoding-style partial softmax).
            # --opt kv_seq_data_only restores the paper-faithful baseline.
            "kv_seq": (["data", None] if "kv_seq_data_only" in opts
                       else ["data", "model", None]),
            "embed": (["data", None] if mode == "serve_2d" else [None]),
            # KV projections of small-kv-head archs would replicate on the
            # model axis; shard their input dim on data instead
            "kv_embed": ["data", None],
            # 2D ff sharding (TP=256 for the FFN): the only way mixtral's
            # 282 GB of expert weights fit at decode without per-step weight
            # gathers; psum of tiny decode activations is the cost
            "mlp": [("data", "model"), "model", None],
            "expert": ["model", None],
            "ssm_inner": ["model", None],
            "state": [None],
            "layers": [None], "groups": [None],
        }
    else:
        raise ValueError(mode)
    out = ShardingRules(mesh, rules)
    for o in opts:
        setattr(out, o, True)
    return out


def tree_shardings(rules: ShardingRules, shapes_tree, axes_tree):
    """NamedShardings for a pytree given ShapeDtypeStructs + logical axes."""

    def one(shape_struct, axes):
        spec = rules.resolve(axes, shape_struct.shape)
        return NamedSharding(rules.mesh, spec)

    return jax.tree.map(one, shapes_tree, axes_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# logical axes for step inputs --------------------------------------------

def batch_logical_axes(cfg, kind: str) -> Dict[str, Any]:
    if kind == "train":
        ax = {"tokens": ("batch", None), "targets": ("batch", None)}
    else:
        ax = {"tokens": ("batch", None)}
    if cfg.family == "encdec":
        ax["src_embeds"] = ("batch", None, "embed_act")
    if cfg.frontend_tokens:
        ax["frontend_embeds"] = ("batch", None, "embed_act")
    return ax
