"""Training launcher: real loop with checkpoint/restart + elastic restore.

CPU demo:  PYTHONPATH=src python -m repro.launch.train --arch yi-6b-smoke \
               --steps 20 --batch 8 --seq 64 --ckpt-dir /tmp/ck --ckpt-every 10
Production mesh flags (--mesh pod1|pod2) lower the same step via pjit.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models.api import build_model
from ..models.common import sharding_ctx
from ..training import checkpoint as ckpt
from ..training.data import DataConfig, SyntheticTokens
from ..training.optimizer import AdamWConfig, adamw_init
from ..training.train_step import make_train_step
from .mesh import make_debug_mesh, make_production_mesh
from .partitioning import make_rules, tree_shardings


def run(arch: str, steps: int, batch: int, seq: int, ckpt_dir: str | None,
        ckpt_every: int, mesh_kind: str = "debug", lr: float = 3e-4,
        remat: bool = False, resume: bool = True, log_every: int = 1):
    cfg = get_config(arch)
    model = build_model(cfg)
    if mesh_kind == "debug":
        mesh = make_debug_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "pod2"))
    rules = make_rules(mesh, "train")
    opt_cfg = AdamWConfig(lr=lr)
    step_fn = make_train_step(model, opt_cfg, remat=remat,
                              attn_blocks=(min(64, seq), min(64, seq)))

    param_shapes, param_axes = model.param_axes()
    p_shard = tree_shardings(rules, param_shapes, param_axes)
    with mesh, sharding_ctx(rules):
        params = jax.jit(model.init, out_shardings=p_shard)(
            jax.random.PRNGKey(0))
        opt_state = jax.jit(adamw_init)(params)
        start = 0
        if ckpt_dir and resume:
            last = ckpt.latest_step(ckpt_dir)
            if last is not None:
                opt_shard = jax.tree.map(lambda x: x.sharding, opt_state)
                start, params, opt_state, _ = ckpt.restore(
                    f"{ckpt_dir}/step_{last}", params, opt_state,
                    shardings=jax.tree.map(lambda x: x.sharding, params),
                    opt_shardings=opt_shard)
                print(f"[train] resumed from step {start}")

        data = SyntheticTokens(DataConfig(cfg.vocab_size, batch, seq))
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
        losses = []
        for step in range(start, steps):
            np_batch = data.batch_at(step)
            jb = {k: jnp.asarray(v) for k, v in np_batch.items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = jit_step(params, opt_state, jb)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            losses.append(loss)
            if step % log_every == 0:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} ({dt*1e3:.0f} ms)",
                      flush=True)
            if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
                ckpt.save(f"{ckpt_dir}/step_{step + 1}", step + 1, params,
                          opt_state)
                print(f"[train] checkpointed step {step + 1}", flush=True)
        return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b-smoke")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--mesh", default="debug", choices=["debug", "pod1", "pod2"])
    ap.add_argument("--remat", action="store_true")
    args = ap.parse_args()
    run(args.arch, args.steps, args.batch, args.seq, args.ckpt_dir,
        args.ckpt_every, args.mesh, args.lr, args.remat)


if __name__ == "__main__":
    main()
