"""Production mesh construction.

A function (not a module constant) so importing never touches jax device
state. Single pod: 16x16 = 256 chips (v5e pod). Multi-pod: 2 pods x 256.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over however many real devices exist (tests)."""
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
