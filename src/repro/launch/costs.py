"""Trip-count-aware cost analysis.

XLA's HloCostAnalysis counts a while/scan body ONCE, so for scan-over-layers
programs `compiled.cost_analysis()` under-reports FLOPs/bytes by the trip
count, and the same for collectives that live inside the loop body. Two
correctors:

  * `jaxpr_costs(fn, *args)` — walks the closed jaxpr, counting dot FLOPs
    exactly and structural memory traffic (dot/gather/scatter/slice operands
    + outputs; elementwise assumed fused), multiplying scan bodies by their
    trip counts. These are GLOBAL (pre-SPMD) numbers.
  * `collectives_with_trips(hlo_text)` — the per-device HLO parse from
    dryrun, with each collective weighted by the product of trip counts of
    the while loops enclosing its computation.
"""
from __future__ import annotations

import math
import re
from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.extend import core as jcore


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:  # noqa: BLE001
        return 0


def _dot_flops(eqn) -> int:
    d = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = d
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = math.prod(lhs.shape[i] for i in lb) if lb else 1
    contract = math.prod(lhs.shape[i] for i in lc) if lc else 1
    lhs_free = math.prod(lhs.shape[i] for i in range(len(lhs.shape))
                         if i not in lc and i not in lb)
    rhs_free = math.prod(rhs.shape[i] for i in range(len(rhs.shape))
                         if i not in rc and i not in rb)
    return 2 * batch * contract * lhs_free * rhs_free


def _mem_bytes(eqn) -> int:
    """HBM-traffic model per primitive: reads/writes actually touched, not
    full operand sizes (a dynamic_slice of a huge array only reads the
    slice; a scatter only writes the updates)."""
    name = eqn.primitive.name
    out_b = sum(_aval_bytes(v.aval) for v in eqn.outvars)
    in_b = sum(_aval_bytes(v.aval) for v in eqn.invars)
    if name in ("dynamic_slice", "gather", "take", "transpose"):
        return 2 * out_b                       # read slice + write out
    if name in ("dynamic_update_slice",):
        upd = _aval_bytes(eqn.invars[1].aval)
        return 2 * upd                         # read update + write window
    if name.startswith("scatter"):
        upd = _aval_bytes(eqn.invars[-1].aval)
        return 2 * upd
    if name in ("reduce_sum", "reduce_max", "reduce_min", "reduce_and",
                "reduce_or", "argmax", "argmin", "cumsum", "cumlogsumexp"):
        return in_b + out_b
    if name in ("concatenate", "sort", "conv_general_dilated"):
        return in_b + out_b
    return 0


_MEM_PRIMS = {
    "dot_general", "gather", "scatter", "scatter-add", "scatter_add",
    "dynamic_slice", "dynamic_update_slice", "concatenate",
    "conv_general_dilated", "reduce_sum", "reduce_max", "reduce_min",
    "cumsum", "cumlogsumexp", "sort", "take", "transpose", "argmax",
    "argmin",
}


def _walk(jaxpr, mult: float, acc: Dict[str, float]):
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            acc["flops"] += mult * _dot_flops(eqn)
            io = (sum(_aval_bytes(v.aval) for v in eqn.invars)
                  + sum(_aval_bytes(v.aval) for v in eqn.outvars))
            acc["bytes"] += mult * io
        elif name == "scan":
            trips = eqn.params["length"]
            inner = eqn.params["jaxpr"].jaxpr
            # carry+xs read and ys written each trip
            io = (sum(_aval_bytes(v.aval) for v in eqn.invars)
                  + sum(_aval_bytes(v.aval) for v in eqn.outvars))
            acc["bytes"] += mult * io  # xs/ys are consumed once in total
            _walk(inner, mult * trips, acc)
        elif name == "shard_map":
            # body runs once per manual shard with LOCAL shapes; scale back
            # to global totals
            m = eqn.params["mesh"]
            shards = 1
            for a in eqn.params["manual_axes"]:
                shards *= dict(m.shape)[a]
            _walk(eqn.params["jaxpr"], mult * shards, acc)
        elif name == "cond":
            for br in eqn.params["branches"]:
                _walk(br.jaxpr if hasattr(br, "jaxpr") else br, mult, acc)
                break  # first branch as representative
        else:
            descended = False
            for v in eqn.params.values():
                if isinstance(v, jcore.ClosedJaxpr):
                    _walk(v.jaxpr, mult, acc)
                    descended = True
                elif isinstance(v, jcore.Jaxpr):
                    _walk(v, mult, acc)
                    descended = True
            if not descended and name in _MEM_PRIMS:
                acc["bytes"] += mult * _mem_bytes(eqn)
    return acc


def jaxpr_costs(fn, *args, **kw) -> Dict[str, float]:
    closed = jax.make_jaxpr(fn)(*args, **kw)
    acc = {"flops": 0.0, "bytes": 0.0}
    _walk(closed.jaxpr, 1.0, acc)
    # argument reads count once (params, caches)
    acc["arg_bytes"] = float(sum(_aval_bytes(v.aval)
                                 for v in closed.jaxpr.invars))
    return acc


# ---------------------------------------------------------------------------
# HLO while-loop trip-count multipliers for collectives
# ---------------------------------------------------------------------------

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"=\s*(?:\()?[^=\n]*while\([^)]*\),\s*condition=%?([\w\.\-]+),\s*"
    r"body=%?([\w\.\-]+)([^\n]*)")
_TRIP_RE = re.compile(r'"known_trip_count":\s*\{\s*"n":\s*"(\d+)"')
_CONST_RE = re.compile(r"constant\((\d+)\)")


def split_computations(hlo: str) -> Dict[str, str]:
    comps: Dict[str, str] = {}
    name, buf = None, []
    for ln in hlo.splitlines():
        if name is None:
            m = _COMP_HDR.match(ln)
            if m and ln.rstrip().endswith("{"):
                name = m.group(2)
                buf = [ln]
        else:
            buf.append(ln)
            if ln.startswith("}"):
                comps[name] = "\n".join(buf)
                name, buf = None, []
    if name is not None:
        comps[name] = "\n".join(buf)
    return comps


def _trip_count(while_line_rest: str, cond_body: str) -> int:
    m = _TRIP_RE.search(while_line_rest)
    if m:
        return int(m.group(1))
    consts = [int(c) for c in _CONST_RE.findall(cond_body)]
    consts = [c for c in consts if c > 0]
    return max(consts) if consts else 1


def computation_multipliers(hlo: str) -> Dict[str, float]:
    """Product of enclosing while trip counts per computation name."""
    comps = split_computations(hlo)
    # map body computation -> (caller computation, trip)
    called_by: Dict[str, Tuple[str, int]] = {}
    for cname, body in comps.items():
        for m in _WHILE_RE.finditer(body):
            cond, wbody, rest = m.group(1), m.group(2), m.group(3)
            trip = _trip_count(rest or "", comps.get(cond, ""))
            called_by[wbody] = (cname, trip)
            called_by[cond] = (cname, trip)
        # plain calls / fusions inherit multiplier
        for m in re.finditer(r"(?:calls|to_apply|fusion)=%?([\w\.\-_]+)", body):
            called_by.setdefault(m.group(1), (cname, 1))

    mult: Dict[str, float] = {}

    def resolve(c: str, depth=0) -> float:
        if c in mult:
            return mult[c]
        if depth > 50 or c not in called_by:
            mult[c] = 1.0
            return 1.0
        caller, trip = called_by[c]
        m = resolve(caller, depth + 1) * trip
        mult[c] = m
        return m

    for c in comps:
        resolve(c)
    return mult


def collectives_with_trips(hlo: str, parse_fn, n_pod_boundary: int = 256
                           ) -> Dict[str, Any]:
    """Re-run the dryrun collective parse per computation, weighted by the
    enclosing while trip product."""
    comps = split_computations(hlo)
    mults = computation_multipliers(hlo)
    total = {"ici_bytes": 0.0, "dcn_bytes": 0.0, "by_kind": {}, "n_ops": 0}
    for cname, body in comps.items():
        sub = parse_fn(body, n_pod_boundary)
        m = mults.get(cname, 1.0)
        total["ici_bytes"] += sub["ici_bytes"] * m
        total["dcn_bytes"] += sub["dcn_bytes"] * m
        total["n_ops"] += sub["n_ops"]
        for k, v in sub["by_kind"].items():
            total["by_kind"][k] = total["by_kind"].get(k, 0.0) + v * m
    return total
