import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs (no allocation), then report memory analysis, HLO
cost analysis, and parsed collective traffic for the roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k \
      [--multi-pod] [--mode serve|serve_2d|train] [--out out.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all   # every cell, both meshes
"""
import argparse
import json
import re
import sys
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs import (ARCHS, SHAPES, get_config, get_shape, long_context_ok)
from ..configs.shapes import input_specs
from ..models.api import build_model
from ..models.common import sharding_ctx
from ..training.optimizer import AdamWConfig, adamw_init
from ..training.train_step import make_train_step
from .mesh import make_production_mesh
from .partitioning import (SERVE_2D_ARCHS, batch_logical_axes, make_rules,
                           tree_shardings)

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "c64": 8,
               "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*\(?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_ITOA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def parse_collectives(hlo: str, n_pod_boundary: int = 256) -> Dict[str, Any]:
    """Estimate per-chip wire bytes per collective kind from optimized HLO.

    The post-SPMD module is the per-device program, so result shapes are
    per-device. Wire-bytes model (ring algorithms):
      all-gather:          ~result bytes received
      collective-permute:  result bytes
      all-to-all:          ~result bytes
      all-reduce:          ~2x bytes (reduce-scatter + all-gather phases)
      reduce-scatter:      ~(g-1) x result bytes (g = group size)
    Group membership spanning a pod boundary is attributed to DCN.
    """
    out = {"ici_bytes": 0.0, "dcn_bytes": 0.0, "ops": []}
    for m in _COLL_RE.finditer(hlo):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        if dt not in DTYPE_BYTES:
            continue
        nelem = 1
        for d in dims.split(","):
            if d:
                nelem *= int(d)
        nbytes = nelem * DTYPE_BYTES[dt]
        # group size / span from the first replica group on the same line
        line_end = hlo.find("\n", m.end())
        line = hlo[m.start():line_end if line_end > 0 else len(hlo)]
        gm = _GROUPS_RE.search(line)
        gsize, dcn = 1, False
        if gm:
            ids = [int(x) for x in gm.group(1).split(",") if x.strip()]
            gsize = max(len(ids), 1)
            if ids:
                dcn = (max(ids) // n_pod_boundary) != (min(ids) // n_pod_boundary)
        else:
            gi = _GROUPS_ITOA_RE.search(line)
            if gi:
                gsize = int(gi.group(2))
                ngroups = int(gi.group(1))
                # iota groups [G,g]: contiguous by construction; crosses pod
                # boundary iff stride pattern spans it
                dcn = gsize > n_pod_boundary
        if kind == "all-reduce":
            wire = 2.0 * nbytes * max(gsize - 1, 1) / max(gsize, 1)
        elif kind == "reduce-scatter":
            wire = nbytes * max(gsize - 1, 1)
        elif kind == "all-gather":
            wire = nbytes * max(gsize - 1, 1) / max(gsize, 1)
        else:
            wire = float(nbytes)
        out["dcn_bytes" if dcn else "ici_bytes"] += wire
        out["ops"].append({"kind": kind, "bytes": nbytes, "group": gsize,
                           "dcn": dcn, "wire": wire})
    agg: Dict[str, float] = {}
    for op in out["ops"]:
        agg[op["kind"]] = agg.get(op["kind"], 0.0) + op["wire"]
    out["by_kind"] = agg
    out["n_ops"] = len(out["ops"])
    del out["ops"]
    return out


def pick_mode(arch: str, shape_kind: str) -> str:
    if shape_kind == "train":
        return "train"
    # 2D weight sharding only where weights exceed HBM/16 AND the step
    # amortizes the per-layer weight gathers (prefill); decode runs pure TP
    # with the KV cache sharded over (data x model) instead (§Perf).
    if arch in SERVE_2D_ARCHS and shape_kind == "prefill":
        return "serve_2d"
    return "serve"


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               mode: str | None = None, attn_blocks=(512, 512),
               opts: tuple = (), extras: Dict[str, Any] | None = None):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if shape_name == "long_500k" and not long_context_ok(cfg):
        return {"arch": arch, "shape": shape_name,
                "multi_pod": multi_pod, "status": "skipped",
                "reason": "pure full-attention arch; long_500k requires "
                          "sub-quadratic attention (see DESIGN.md)"}
    mode = mode or pick_mode(arch, shape.kind)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, mode, opts=opts)
    # MoE dispatch defaults (§Perf): shard-local shard_map dispatch for
    # PREFILL (per-layer weight gathers amortize over 32k tokens; 5.3-8x on
    # the dominant collective term). Decode keeps pjit dispatch — its token
    # traffic is tiny and the weight gathers would dominate. Training keeps
    # pjit (XLA-CPU AD crash; --opt moe_grouped for the portable variant).
    if shape.kind == "prefill" and "moe_pjit" not in opts:
        rules.moe_shard_map = True
    model = build_model(cfg)
    param_shapes, param_axes = model.param_axes()
    if shape.kind != "train":
        param_shapes = jax.eval_shape(
            lambda p: model.cast(p, jnp.bfloat16), param_shapes)
    p_shard = tree_shardings(rules, param_shapes, param_axes)
    specs = input_specs(cfg, shape)

    t0 = time.time()
    with mesh, sharding_ctx(rules):
        if shape.kind == "train":
            step = make_train_step(model, AdamWConfig(),
                                   attn_blocks=attn_blocks)
            opt_shapes = jax.eval_shape(adamw_init, param_shapes)
            opt_shard = {"m": p_shard, "v": p_shard,
                         "step": jax.sharding.NamedSharding(
                             mesh, jax.sharding.PartitionSpec())}
            b_axes = batch_logical_axes(cfg, "train")
            b_shard = tree_shardings(rules, specs["batch"],
                                     _pad_axes(specs["batch"], b_axes))
            fn = jax.jit(step,
                         in_shardings=(p_shard, opt_shard, b_shard),
                         donate_argnums=(0, 1))
            lowered = fn.lower(param_shapes, opt_shapes, specs["batch"])
        elif shape.kind == "prefill":
            def prefill_fn(params, batch):
                return model.prefill(params, batch, max_len=shape.seq_len,
                                     attn_blocks=attn_blocks)
            b_axes = batch_logical_axes(cfg, "prefill")
            b_shard = tree_shardings(rules, specs["batch"],
                                     _pad_axes(specs["batch"], b_axes))
            fn = jax.jit(prefill_fn, in_shardings=(p_shard, b_shard))
            lowered = fn.lower(param_shapes, specs["batch"])
        else:  # decode
            def decode_fn(params, cache, tokens):
                return model.decode_step(params, cache, tokens)
            c_axes = model.cache_logical_axes()
            c_shard = tree_shardings(rules, specs["cache"], c_axes)
            t_shard = jax.sharding.NamedSharding(
                mesh, rules.resolve(("batch",), specs["tokens"].shape))
            fn = jax.jit(decode_fn,
                         in_shardings=(p_shard, c_shard, t_shard),
                         donate_argnums=(1,))
            lowered = fn.lower(param_shapes, specs["cache"], specs["tokens"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    coll = parse_collectives(hlo_text)
    # trip-count-corrected analysis (XLA counts while bodies once)
    from .costs import collectives_with_trips, jaxpr_costs
    coll_trip = collectives_with_trips(hlo_text, parse_collectives)
    with mesh, sharding_ctx(rules):
        if shape.kind == "train":
            jc = jaxpr_costs(step, param_shapes, opt_shapes, specs["batch"])
        elif shape.kind == "prefill":
            jc = jaxpr_costs(prefill_fn, param_shapes, specs["batch"])
        else:
            jc = jaxpr_costs(decode_fn, param_shapes, specs["cache"],
                             specs["tokens"])
    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "mode": mode, "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
            "transcendentals": cost.get("transcendentals"),
        },
        "cost_corrected": {        # global (pre-SPMD), trip-count exact
            "dot_flops": jc["flops"],
            "struct_bytes": jc["bytes"],
            "arg_bytes": jc["arg_bytes"],
        },
        "collectives": coll,
        "collectives_corrected": coll_trip,   # per-chip wire bytes x trips
        "n_devices": mesh.devices.size,
    }
    if extras:
        rec.update(extras)
    return rec


def _pad_axes(specs_tree, axes_map):
    """Match axes dict to the spec tree (some entries optional)."""
    return {k: axes_map.get(k, tuple(None for _ in v.shape))
            for k, v in specs_tree.items()}


def iter_cells():
    for arch in ARCHS:
        for shape in SHAPES:
            yield arch, shape.name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default=None)
    ap.add_argument("--attn-block", type=int, default=512)
    ap.add_argument("--opt", default="",
                    help="comma list of optimization flags set on the rules "
                         "(e.g. moe_shard_map)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    if args.all:
        cells = [(a, s, mp) for a, s in iter_cells() for mp in (False, True)]
    else:
        cells = [(args.arch, args.shape, args.multi_pod)]

    results = []
    for arch, shape, mp in cells:
        tag = f"{arch} x {shape} {'pod2' if mp else 'pod1'}"
        try:
            opts = tuple(o for o in args.opt.split(",") if o)
            rec = lower_cell(arch, shape, multi_pod=mp, mode=args.mode,
                             attn_blocks=(args.attn_block, args.attn_block),
                             opts=opts, extras={"opts": list(opts)} if opts else None)
            print(f"[dryrun] {tag}: {rec['status']} "
                  f"(lower {rec.get('lower_s', '-')}s, "
                  f"compile {rec.get('compile_s', '-')}s)", flush=True)
        except Exception as e:  # noqa: BLE001 - report and continue
            rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                   "status": "error", "error": f"{type(e).__name__}: {e}"}
            print(f"[dryrun] {tag}: ERROR {rec['error'][:500]}", flush=True)
        results.append(rec)

    out = args.out or "experiments/dryrun.json"
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(results if len(results) > 1 else results[0], f, indent=1)
    print(f"[dryrun] wrote {out}")
    bad = [r for r in results if r["status"] == "error"]
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
