"""Serving launcher.

Production path (TPU): run the placement search for the target arch +
workload, then instantiate the disaggregated cluster with the chosen
parallelism per phase. On this CPU host the same entrypoint drives the
smoke-scale live cluster; the full-scale engine programs are validated via
`repro.launch.dryrun` (lower+compile on the production mesh).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
        --workload sharegpt --rate 8 [--live]
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from ..configs import get_config
from ..core import hw
from ..core.latency_model import LatencyModel
from ..core.placement import algo1_high_affinity, algo2_low_affinity
from ..core.workload import WORKLOADS, Request, derive_slos, sample_requests
from ..models.api import build_model
from ..serving.cluster import DisaggCluster


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--workload", default="sharegpt",
                    choices=list(WORKLOADS))
    ap.add_argument("--rate", type=float, default=8.0)
    ap.add_argument("--algo", default="low", choices=["low", "high"])
    ap.add_argument("--n-node", type=int, default=2)
    ap.add_argument("--m-per-node", type=int, default=8)
    ap.add_argument("--n-requests", type=int, default=200)
    ap.add_argument("--live", action="store_true",
                    help="also serve a trace on the smoke-scale live cluster")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    lm = LatencyModel(cfg, hw.V5E)
    spec = derive_slos(WORKLOADS[args.workload], lm)
    search = algo2_low_affinity if args.algo == "low" else algo1_high_affinity
    placement = search(lm, spec, rate=args.rate, n_node=args.n_node,
                       m_per_node=args.m_per_node,
                       n_requests=args.n_requests)
    print(json.dumps(placement.summary(), indent=1))

    if args.live:
        smoke = get_config(args.arch + "-smoke")
        params = build_model(smoke).init(jax.random.PRNGKey(0))
        cluster = DisaggCluster(
            smoke, params,
            n_prefill=min(placement.n_prefill, 2),
            n_decode=min(placement.n_decode, 2),
            max_batch=4, max_len=96, lm_tokens=64)
        trace = [Request(r.rid, r.arrive, min(r.in_len, 48),
                         min(r.out_len, 8))
                 for r in sample_requests(spec, 20.0, 12, seed=0)]
        res = cluster.run(trace)
        ttfts = sorted(r.ttft for r in res.values())
        print(f"[live] served {len(res)} requests; "
              f"median ttft {ttfts[len(ttfts) // 2] * 1e3:.0f} ms; "
              f"KV migrated {cluster.tx.total_bytes / 1e6:.1f} MB")


if __name__ == "__main__":
    main()
