import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Dump the largest trip-weighted collectives of a cell with their source
op names (hillclimb profiling aid), or — with ``--serve-metrics`` — a
Prometheus-style metrics snapshot from a smoke serving run (queue depths,
page-pool occupancy, transfer totals, TTFT/TPOT histograms).

  PYTHONPATH=src python -m repro.launch.diagnose --arch gemma3-1b \
      --shape decode_32k [--opt ...] [--top 15]
  PYTHONPATH=src python -m repro.launch.diagnose --arch yi-6b-smoke \
      --serve-metrics
"""
import argparse
import re

from .costs import computation_multipliers, split_computations
from .dryrun import DTYPE_BYTES, lower_cell

_OP_RE = re.compile(
    r"=\s*\(?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_NAME_RE = re.compile(r'op_name="([^"]+)"')


def serve_metrics(arch: str, replicas: int = 2) -> None:
    """Smoke fleet serving run with metrics registries attached; dumps the
    router's Prometheus snapshot (queue depth, shed count, per-replica
    inflight) followed by per-replica snapshots prefixed ``replicaN.`` and
    their ``fleet.``-summed totals (engine/queue/transfer pull-collectors
    plus the request counters and latency histograms)."""
    import jax
    import numpy as np
    from ..configs import get_config
    from ..core.telemetry import MetricsRegistry
    from ..core.workload import Request
    from ..models.api import build_model
    from ..serving.cluster import DisaggCluster
    from ..serving.router import (FleetRouter, OverloadDetector,
                                  aggregate_snapshots)

    cfg = get_config(arch)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    regs = [MetricsRegistry() for _ in range(replicas)]
    backends = [DisaggCluster(cfg, params, n_prefill=1, n_decode=1,
                              max_batch=4, max_len=96, lm_tokens=64,
                              metrics=regs[i], seed=i)
                for i in range(replicas)]
    router_metrics = MetricsRegistry()
    # tight gates so the smoke burst exercises router queueing + shedding
    router = FleetRouter(backends, policy="shortest_queue",
                         detector=OverloadDetector(max_inflight=2,
                                                   max_queue=4),
                         metrics=router_metrics)
    rng = np.random.default_rng(0)
    for i in range(10):
        router.submit(Request(i, i * 0.005, int(rng.integers(8, 40)),
                              int(rng.integers(4, 8))))
    router.drain()
    print(router_metrics.prometheus(), end="")
    agg = aggregate_snapshots({f"replica{i}": regs[i].snapshot()
                               for i in range(replicas)})
    fleet = MetricsRegistry()
    for k, v in agg.items():
        fleet.gauge(k, v)
    print(fleet.prometheus(), end="")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default=None)
    ap.add_argument("--opt", default="")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--serve-metrics", action="store_true",
                    help="run a smoke fleet serving workload and dump "
                         "Prometheus-style metrics snapshots (router + "
                         "per-replica + fleet-summed) instead of the "
                         "collectives report")
    ap.add_argument("--replicas", type=int, default=2,
                    help="fleet size for --serve-metrics")
    args = ap.parse_args()

    if args.serve_metrics:
        serve_metrics(args.arch, replicas=args.replicas)
        return
    if not args.shape:
        ap.error("--shape is required unless --serve-metrics is given")

    opts = tuple(o for o in args.opt.split(",") if o)
    import repro.launch.dryrun as dr
    hlo_box = {}
    orig = dr.parse_collectives

    # capture the HLO text by hooking lower_cell's parse call
    def hook(hlo, n_pod_boundary=256):
        hlo_box.setdefault("text", hlo)
        return orig(hlo, n_pod_boundary)
    dr.parse_collectives = hook
    rec = dr.lower_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                        mode=args.mode, opts=opts)
    dr.parse_collectives = orig
    hlo = hlo_box["text"]

    comps = split_computations(hlo)
    mults = computation_multipliers(hlo)
    rows = []
    for cname, body in comps.items():
        m = mults.get(cname, 1.0)
        for mm in _OP_RE.finditer(body):
            dt, dims, kind = mm.group(1), mm.group(2), mm.group(3)
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes = n * DTYPE_BYTES[dt]
            line_end = body.find("\n", mm.end())
            line = body[mm.start():line_end]
            nm = _NAME_RE.search(line)
            rows.append((nbytes * m, kind, dt, dims, m,
                         (nm.group(1) if nm else "?")[:140]))
    rows.sort(reverse=True)
    print(f"status={rec['status']} total_coll_ici="
          f"{rec['collectives_corrected']['ici_bytes']/1e9:.1f}GB")
    for b, kind, dt, dims, m, name in rows[:args.top]:
        print(f"{b/1e9:9.2f}GB x{m:5.0f} {kind:18s} {dt}[{dims}] :: {name}")


if __name__ == "__main__":
    main()
