"""Pallas TPU paged decode-attention kernel (PagedAttention, TPU-native).

One new token per sequence attends to a paged KV cache. The block table
rides in scalar-prefetch (SMEM) so the k/v BlockSpec index_map can chase
page indirections while the pipeline prefetches the next page HBM->VMEM —
the TPU analogue of vLLM's per-CTA page walk. Pages are the innermost
sequential grid axis; the flash-decoding running (m, l, acc) state lives in
VMEM scratch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import CompilerParams

NEG_INF = -2.3819763e38


def _kernel(table_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, page_size: int, groups: int,
            scale: float, softcap: float):
    b = pl.program_id(0)
    p = pl.program_id(1)
    np_ = pl.num_programs(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = lens_ref[b]
    base = p * page_size

    @pl.when(base < seq_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                # (H, hd)
        k = k_ref[0].astype(jnp.float32)                # (page, Hkv, hd)
        v = v_ref[0].astype(jnp.float32)
        H, hd = q.shape
        Hkv = k.shape[1]
        valid = (base + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
                 ) < seq_len                            # (1, page)

        m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
        s_rows = []
        for kv in range(Hkv):
            qg = jax.lax.dynamic_slice_in_dim(q, kv * groups, groups, 0)
            s_kv = jax.lax.dot_general(qg, k[:, kv],
                                       (((1,), (1,)), ((), ())),
                                       preferred_element_type=jnp.float32)
            s_rows.append(s_kv * scale)                 # (G, page)
        s = jnp.concatenate(s_rows, axis=0)             # (H, page)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(valid, s, NEG_INF)

        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        pexp = jnp.exp(s - m_new[:, None])              # (H, page)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(pexp, axis=1)
        pv_rows = []
        for kv in range(Hkv):
            pg = jax.lax.dynamic_slice_in_dim(pexp, kv * groups, groups, 0)
            pv_kv = jax.lax.dot_general(pg, v[:, kv],
                                        (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)
            pv_rows.append(pv_kv)                       # (G, hd)
        pv = jnp.concatenate(pv_rows, axis=0)           # (H, hd)
        acc_ref[...] = acc_prev * corr[:, None] + pv
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(p == np_ - 1)
    def _fin():
        den = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0] = (acc_ref[...] / den[:, None]).astype(o_ref.dtype)


def _dbuf_kernel(table_ref, lens_ref, q_ref, kp_ref, vp_ref, o_ref,
                 k_buf, v_buf, sem, *, page_size: int, groups: int,
                 scale: float, softcap: float):
    """Double-buffered page walk: the pools stay in compiler-chosen (HBM)
    memory and each page is DMA'd into one of two VMEM slots with
    `make_async_copy`, so page i+1's copy overlaps page i's flash step —
    the manual analogue of the BlockSpec pipeline in `_kernel`, without
    round-tripping the block table through an index_map."""
    b = pl.program_id(0)
    seq_len = lens_ref[b]
    n_used = (seq_len + page_size - 1) // page_size

    def dma(slot, i, buf, pool, ax):
        return pltpu.make_async_copy(pool.at[table_ref[b, i]],
                                     buf.at[slot], sem.at[slot, ax])

    @pl.when(n_used > 0)
    def _warm():
        dma(0, 0, k_buf, kp_ref, 0).start()
        dma(0, 0, v_buf, vp_ref, 1).start()

    q = q_ref[0].astype(jnp.float32)                    # (H, hd)
    H, hd = q.shape
    Hkv = k_buf.shape[2]

    def step(i, carry):
        m_prev, l_prev, acc_prev = carry
        slot = jax.lax.rem(i, 2)
        nxt = jax.lax.rem(i + 1, 2)

        @pl.when(i + 1 < n_used)
        def _prefetch():
            dma(nxt, i + 1, k_buf, kp_ref, 0).start()
            dma(nxt, i + 1, v_buf, vp_ref, 1).start()

        dma(slot, i, k_buf, kp_ref, 0).wait()
        dma(slot, i, v_buf, vp_ref, 1).wait()
        k = k_buf[slot].astype(jnp.float32)             # (page, Hkv, hd)
        v = v_buf[slot].astype(jnp.float32)
        base = i * page_size
        valid = (base + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
                 ) < seq_len
        s_rows = []
        for kv in range(Hkv):
            qg = jax.lax.dynamic_slice_in_dim(q, kv * groups, groups, 0)
            s_kv = jax.lax.dot_general(qg, k[:, kv],
                                       (((1,), (1,)), ((), ())),
                                       preferred_element_type=jnp.float32)
            s_rows.append(s_kv * scale)
        s = jnp.concatenate(s_rows, axis=0)             # (H, page)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        pexp = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(pexp, axis=1)
        pv_rows = []
        for kv in range(Hkv):
            pg = jax.lax.dynamic_slice_in_dim(pexp, kv * groups, groups, 0)
            pv_rows.append(jax.lax.dot_general(
                pg, v[:, kv], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32))
        pv = jnp.concatenate(pv_rows, axis=0)
        return m_new, l_new, acc_prev * corr[:, None] + pv

    m0 = jnp.full((H,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((H,), jnp.float32)
    acc0 = jnp.zeros((H, hd), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_used, step, (m0, l0, acc0))
    den = jnp.maximum(l, 1e-37)
    o_ref[0] = (acc / den[:, None]).astype(o_ref.dtype)


def paged_decode(q, k_pages, v_pages, block_table, lens, *,
                 scale=None, softcap: float = 0.0, dbuf: bool = False,
                 interpret: bool = False):
    """q: (B, H, hd); k/v_pages: (num_pages, page, Hkv, hd);
    block_table: (B, pages_per_seq) i32; lens: (B,) i32 -> (B, H, hd).
    With `dbuf`, pages are prefetched via explicit async-copy double
    buffering instead of the BlockSpec pipeline."""
    B, H, hd = q.shape
    num_pages, page_size, Hkv, _ = k_pages.shape
    pages_per_seq = block_table.shape[1]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    if dbuf:
        any_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        return pl.pallas_call(
            functools.partial(_dbuf_kernel, page_size=page_size, groups=G,
                              scale=scale, softcap=softcap),
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=2,
                grid=(B,),
                in_specs=[
                    pl.BlockSpec((1, H, hd), lambda b, table, lens: (b, 0, 0)),
                    any_spec, any_spec,
                ],
                out_specs=pl.BlockSpec((1, H, hd),
                                       lambda b, table, lens: (b, 0, 0)),
                scratch_shapes=[
                    pltpu.VMEM((2, page_size, Hkv, hd), k_pages.dtype),
                    pltpu.VMEM((2, page_size, Hkv, hd), v_pages.dtype),
                    pltpu.SemaphoreType.DMA((2, 2)),
                ],
            ),
            out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
            interpret=interpret,
            compiler_params=CompilerParams(
                dimension_semantics=("arbitrary",)),
        )(block_table, lens, q, k_pages, v_pages)

    grid = (B, pages_per_seq)
    kv_spec = pl.BlockSpec(
        (1, page_size, Hkv, hd),
        lambda b, p, table, lens: (table[b, p], 0, 0, 0))
    out = pl.pallas_call(
        functools.partial(_kernel, page_size=page_size, groups=G,
                          scale=scale, softcap=softcap),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, H, hd), lambda b, p, table, lens: (b, 0, 0)),
                kv_spec, kv_spec,
            ],
            out_specs=pl.BlockSpec((1, H, hd),
                                   lambda b, p, table, lens: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H,), jnp.float32),
                pltpu.VMEM((H,), jnp.float32),
                pltpu.VMEM((H, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(block_table, lens, q, k_pages, v_pages)
    return out


def _insert_kernel(pidx_ref, off_ref, knew_ref, vnew_ref, kin_ref, vin_ref,
                   kout_ref, vout_ref, *, page_size: int):
    b = pl.program_id(0)
    off = off_ref[b]
    sel = jax.lax.broadcasted_iota(jnp.int32, (page_size, 1, 1), 0) == off
    kout_ref[0] = jnp.where(sel, knew_ref[0][None].astype(kout_ref.dtype),
                            kin_ref[0])
    vout_ref[0] = jnp.where(sel, vnew_ref[0][None].astype(vout_ref.dtype),
                            vin_ref[0])


def paged_insert(k_pages, v_pages, k_new, v_new, page_idx, offset, *,
                 interpret: bool = False):
    """In-place page-pool splice of one new token per sequence.

    k/v_pages: (num_pages, page, Hkv, hd); k/v_new: (B, Hkv, hd);
    page_idx/offset: (B,) i32 -> updated (k_pages, v_pages).

    The grid visits only each sequence's target page (page_idx rides in
    scalar-prefetch so the BlockSpec walks the indirection) and the pools
    are donated via input_output_aliases — untouched pages are never read
    or written, so the splice costs O(B * page) HBM traffic instead of
    O(num_pages * page).
    """
    num_pages, page_size, Hkv, hd = k_pages.shape
    B = k_new.shape[0]
    grid = (B,)
    new_spec = pl.BlockSpec((1, Hkv, hd), lambda b, pidx, off: (b, 0, 0))
    pool_spec = pl.BlockSpec((1, page_size, Hkv, hd),
                             lambda b, pidx, off: (pidx[b], 0, 0, 0))
    return pl.pallas_call(
        functools.partial(_insert_kernel, page_size=page_size),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[new_spec, new_spec, pool_spec, pool_spec],
            out_specs=[pool_spec, pool_spec],
        ),
        out_shape=[jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
                   jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype)],
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(page_idx, offset, k_new, v_new, k_pages, v_pages)
