"""Jitted wrapper for paged decode attention."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import paged_decode
from .ref import paged_decode_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("scale", "softcap", "impl"))
def paged_decode_op(q, k_pages, v_pages, block_table, lens, *,
                    scale: float = None, softcap: float = 0.0,
                    impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return paged_decode_ref(q, k_pages, v_pages, block_table, lens,
                                scale=scale, softcap=softcap)
    return paged_decode(q, k_pages, v_pages, block_table, lens,
                        scale=scale, softcap=softcap,
                        interpret=(impl == "interpret"))
