"""Jitted wrapper for paged decode attention."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import paged_decode, paged_insert
from .ref import paged_decode_ref, paged_insert_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("scale", "softcap", "impl", "dbuf"))
def paged_decode_op(q, k_pages, v_pages, block_table, lens, *,
                    scale: float = None, softcap: float = 0.0,
                    impl: str = "auto", dbuf: bool = False):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return paged_decode_ref(q, k_pages, v_pages, block_table, lens,
                                scale=scale, softcap=softcap)
    return paged_decode(q, k_pages, v_pages, block_table, lens,
                        scale=scale, softcap=softcap, dbuf=dbuf,
                        interpret=(impl == "interpret"))


@partial(jax.jit, static_argnames=("impl",))
def paged_insert_op(k_pages, v_pages, k_new, v_new, page_idx, offset, *,
                    impl: str = "auto"):
    """Splice one new token per sequence into the paged pools. The pallas
    path aliases the pools in place (input_output_aliases); the ref path
    relies on XLA's in-place scatter inside the enclosing jit."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return paged_insert_ref(k_pages, v_pages, k_new, v_new,
                                page_idx, offset)
    return paged_insert(k_pages, v_pages, k_new, v_new, page_idx, offset,
                        interpret=(impl == "interpret"))
