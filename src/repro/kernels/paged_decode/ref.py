"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def paged_decode_ref(q, k_pages, v_pages, block_table, lens, *,
                     scale=None, softcap: float = 0.0):
    B, H, hd = q.shape
    num_pages, page_size, Hkv, _ = k_pages.shape
    pages_per_seq = block_table.shape[1]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    # gather each sequence's pages -> contiguous (B, S, Hkv, hd)
    k_seq = k_pages[block_table].reshape(B, pages_per_seq * page_size, Hkv, hd)
    v_seq = v_pages[block_table].reshape(B, pages_per_seq * page_size, Hkv, hd)
    kr = jnp.repeat(k_seq, G, axis=2)
    vr = jnp.repeat(v_seq, G, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    pos = jnp.arange(pages_per_seq * page_size)
    mask = pos[None, :] < lens[:, None]
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhs,bshd->bhd", p, vr.astype(jnp.float32))
    return o.astype(q.dtype)


def paged_insert_ref(k_pages, v_pages, k_new, v_new, page_idx, offset):
    """Scatter one new token per sequence into its page: k/v_pages
    (num_pages, page, Hkv, hd); k/v_new (B, Hkv, hd); page_idx/offset (B,)
    i32. Returns the updated (k_pages, v_pages).

    This is byte-identical to the dense `.at[pidx, off].set(...)` splice
    the model used before the kernel existed — the decode-token equality
    tests pin that.
    """
    k_pages = k_pages.at[page_idx, offset].set(k_new.astype(k_pages.dtype))
    v_pages = v_pages.at[page_idx, offset].set(v_new.astype(v_pages.dtype))
    return k_pages, v_pages
