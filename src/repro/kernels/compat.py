"""Version compatibility shims for Pallas TPU.

`pltpu.CompilerParams` was renamed from `TPUCompilerParams` across JAX
releases; resolve whichever this JAX ships so the kernels lower on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
