"""Pallas TPU kernel for the Mamba2 SSD chunked scan [arXiv:2405.21060].

Grid = (batch, heads, chunks) with chunks innermost/sequential: the
inter-chunk recurrent state (hd x N) lives in VMEM scratch, so the
recurrence never round-trips HBM — the TPU analogue of the paper's
"state passing" block decomposition. Within a chunk everything is dense
(chunk x chunk) / (chunk x N) matmuls on the MXU; chunk=128..256 and
N=64..128 align the 128-lane requirement.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import CompilerParams


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, hout_ref,
            h_ref, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)              # (Q, hd)
    dt = dt_ref[0, :, 0].astype(jnp.float32)            # (Q,)
    Bm = b_ref[0, :, 0].astype(jnp.float32)             # (Q, N)
    Cm = c_ref[0, :, 0].astype(jnp.float32)             # (Q, N)
    A = a_ref[0, 0]                                     # scalar
    D = d_ref[0, 0]

    a = dt * A                                          # (Q,)
    a_cum = jnp.cumsum(a)                               # (Q,)
    xdt = x * dt[:, None]                               # (Q, hd)

    # within-chunk decay L[t, s] = exp(sum_{s<r<=t} a_r), tril
    diff = a_cum[:, None] - a_cum[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(row >= col, jnp.exp(diff), 0.0)

    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    y_diag = jax.lax.dot_general(CB * L, xdt, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    h_prev = h_ref[...]                                 # (hd, N)
    state_decay = jnp.exp(a_cum)                        # (Q,)
    y_off = jax.lax.dot_general(Cm, h_prev, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_off = y_off * state_decay[:, None]                # (Q, hd)

    y_ref[0, :, 0] = (y_diag + y_off + D * x).astype(y_ref.dtype)

    # chunk-end state: h' = h * exp(sum a) + xdt^T @ (B * decay_to_end)
    decay_to_end = jnp.exp(a_cum[-1] - a_cum)           # (Q,)
    Bw = Bm * decay_to_end[:, None]                     # (Q, N)
    s_c = jax.lax.dot_general(xdt, Bw, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (hd, N)
    h_ref[...] = h_prev * jnp.exp(a_cum[-1]) + s_c

    @pl.when(ci == nc - 1)
    def _fin():
        hout_ref[0, 0] = h_ref[...].astype(hout_ref.dtype)


def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 128, interpret: bool = False):
    """x: (b, S, nh, hd); dt: (b, S, nh); A, D: (nh,); B, C: (b, S, G, N).
    Returns (y (b, S, nh, hd), h_final (b, nh, hd, N))."""
    b, S, nh, hd = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = nh // G
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    A2 = A.reshape(nh, 1).astype(jnp.float32)
    D2 = D.reshape(nh, 1).astype(jnp.float32)

    grid = (b, nh, nc)
    y, h = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda bi, h, c: (bi, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, h, c: (bi, c, h)),
            pl.BlockSpec((1, chunk, 1, N), lambda bi, h, c: (bi, c, h // rep, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda bi, h, c: (bi, c, h // rep, 0)),
            pl.BlockSpec((1, 1), lambda bi, h, c: (h, 0)),
            pl.BlockSpec((1, 1), lambda bi, h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda bi, h, c: (bi, c, h, 0)),
            pl.BlockSpec((1, 1, hd, N), lambda bi, h, c: (bi, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, S, nh, hd), x.dtype),
            jax.ShapeDtypeStruct((b, nh, hd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, dt, B, C, A2, D2)
    return y, h
