"""Jitted wrapper for the SSD scan."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import ssd_scan
from .ref import ssd_scan_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd_scan_op(x, dt, A, B, C, D, *, chunk: int = 128, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return ssd_scan_ref(x, dt, A, B, C, D, chunk=chunk)
    return ssd_scan(x, dt, A, B, C, D, chunk=chunk,
                    interpret=(impl == "interpret"))
