"""Pure-jnp oracle for the SSD scan kernel — delegates to the model-side
chunked implementation (itself validated against the sequential
recurrence in tests)."""
from __future__ import annotations

from ...models.ssd import ssd_chunked, ssd_reference


def ssd_scan_ref(x, dt, A, B, C, D, *, chunk: int = 128):
    return ssd_chunked(x, dt, A, B, C, D, chunk)


def ssd_scan_sequential(x, dt, A, B, C, D):
    return ssd_reference(x, dt, A, B, C, D)
