"""Pallas TPU flash-attention prefill kernel (causal, GQA, sliding window).

TPU mapping of the FlashAttention tiling: grid = (batch, q_heads, q_blocks,
kv_blocks) with the kv_blocks axis innermost/sequential ("arbitrary"), so
the online-softmax running state (m, l, acc) lives in VMEM scratch across
kv iterations. Block shapes are (block_q x head_dim) / (block_kv x
head_dim) — head_dim 64/128 aligns the MXU lane dimension; block_q/kv
default 128/256 to fill 128x128 MXU tiles while keeping
q+k+v+acc < 2 MB VMEM per step.

GQA is handled in the k/v BlockSpec index_map (kv head = q_head // group),
so no KV duplication is materialized in HBM or VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import CompilerParams

NEG_INF = -2.3819763e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_kv: int, seq_q: int, seq_kv: int, softcap: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bkv, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    kpos = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    mask = kpos < seq_kv
    if causal:
        mask &= kpos <= (seq_kv - seq_q) + qpos
    if window:
        mask &= kpos > (seq_kv - seq_q) + qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    v = v_ref[0, 0].astype(jnp.float32)                 # (bkv, hd)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _fin():
        den = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / den[:, None]).astype(o_ref.dtype)


def flash_prefill(q, k, v, *, causal=True, window: int = 0,
                  block_q: int = 128, block_kv: int = 256,
                  scale=None, softcap: float = 0.0, interpret: bool = False):
    """q: (B, H, Sq, hd); k, v: (B, Hkv, Skv, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    _, Hkv, Skv, _ = k.shape
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    pq = (-Sq) % block_q
    pkv = (-Skv) % block_kv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pkv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pkv), (0, 0)))
    nq = (Sq + pq) // block_q
    nk = (Skv + pkv) // block_kv

    grid = (B, H, nq, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          block_q=block_q, block_kv=block_kv, seq_q=Sq,
                          seq_kv=Skv, softcap=softcap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q, k, v)
    return out[:, :, :Sq]
