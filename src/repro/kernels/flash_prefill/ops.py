"""Jitted public wrapper: picks the Pallas kernel on TPU, interpret-mode
kernel when requested, and the jnp oracle elsewhere."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_prefill
from .ref import flash_prefill_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_kv",
                                   "softcap", "impl"))
def flash_prefill_op(q, k, v, *, causal: bool = True, window: int = 0,
                     block_q: int = 128, block_kv: int = 256,
                     softcap: float = 0.0, impl: str = "auto"):
    """Layout: model-side (B, S, H, hd) in/out; kernel runs (B, H, S, hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        ot = flash_prefill_ref(qt, kt, vt, causal=causal, window=window,
                               softcap=softcap)
    else:
        ot = flash_prefill(qt, kt, vt, causal=causal, window=window,
                           block_q=block_q, block_kv=block_kv,
                           softcap=softcap, interpret=(impl == "interpret"))
    return ot.transpose(0, 2, 1, 3)
