"""Pure-jnp oracle for the flash prefill kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def flash_prefill_ref(q, k, v, *, causal=True, window: int = 0,
                      scale=None, softcap: float = 0.0):
    """q: (B, H, Sq, hd); k, v: (B, Hkv, Skv, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    _, Hkv, Skv, _ = k.shape
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kr = jnp.repeat(k, G, axis=1)
    vr = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= (Skv - Sq) + qpos
    if window:
        mask &= kpos > (Skv - Sq) + qpos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return o.astype(q.dtype)
