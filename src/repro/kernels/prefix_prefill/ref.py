"""Pure-jnp oracle for fused suffix-prefill over paged prefix KV.

Suffix queries attend over (a) a shared prefix that lives in the paged KV
pool, addressed through a block table, and (b) their own fresh suffix KV,
with the causal mask offset by the prefix length. The reference gathers the
prefix pages densely (exactly what the kernel must avoid) and runs a masked
softmax in f32 — it is the numeric ground truth for interpret-mode parity.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

NEG_INF = -2.3819763e38


def prefix_prefill_ref(q, k_suf, v_suf, k_pages, v_pages, prefix_table,
                       prefix_lens, suffix_lens=None, *, scale=None,
                       softcap: float = 0.0):
    """q: (B, H, Sq, hd); k/v_suf: (B, Hkv, Sq, hd);
    k/v_pages: (num_pages, page, Hkv, hd); prefix_table: (B, npp) i32;
    prefix_lens: (B,) i32 — valid prefix tokens per sequence (rest of the
    gathered pages, incl. trash-padded table slots, is masked);
    suffix_lens: (B,) i32 or None — valid suffix tokens (default Sq).
    Returns (B, H, Sq, hd).
    """
    B, H, Sq, hd = q.shape
    Hkv = k_suf.shape[1]
    page = k_pages.shape[1]
    npp = prefix_table.shape[1]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    if suffix_lens is None:
        suffix_lens = jnp.full((B,), Sq, jnp.int32)

    # dense gather of the paged prefix: (B, npp*page, Hkv, hd)
    kp = k_pages[prefix_table].reshape(B, npp * page, Hkv, hd)
    vp = v_pages[prefix_table].reshape(B, npp * page, Hkv, hd)
    # (B, Hkv, P + Sq, hd)
    k = jnp.concatenate([kp.transpose(0, 2, 1, 3), k_suf], axis=2)
    v = jnp.concatenate([vp.transpose(0, 2, 1, 3), v_suf], axis=2)
    if G > 1:
        k = jnp.repeat(k, G, axis=1)
        v = jnp.repeat(v, G, axis=1)

    P = npp * page
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    kpos = jnp.arange(P + Sq)[None, None, None, :]
    qpos = jnp.arange(Sq)[None, None, :, None]
    plen = prefix_lens[:, None, None, None]
    slen = suffix_lens[:, None, None, None]
    in_prefix = kpos < P
    mask = jnp.where(in_prefix, kpos < plen,
                     ((kpos - P) <= qpos) & ((kpos - P) < slen))
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-37)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
