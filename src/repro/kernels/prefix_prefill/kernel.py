"""Pallas TPU fused suffix-prefill over paged prefix KV.

A flash-prefill variant for the prefix-cache hot path: suffix queries
attend over `n_prefix_pages` of shared prefix KV read *straight from the
paged pool* (block-table-indexed BlockSpecs, same scalar-prefetch page walk
as `paged_decode`) followed by their own fresh suffix KV with the offset
causal mask. The dense `(B, P, Hkv, hd)` prefix gather the engine used to
materialize never exists: the kv grid axis first walks the prefix pages,
then the suffix blocks, carrying one online-softmax state (m, l, acc) in
VMEM scratch across both phases.

Grid: (batch, q_heads, q_blocks, n_prefix_pages + suffix_kv_blocks) with
the combined kv axis innermost/sequential ("arbitrary"). The block table
and the per-sequence prefix/suffix lengths ride in scalar-prefetch (SMEM)
so the page indirection is resolved during pipelining and ragged lengths
(including trash-padded table slots) are masked, not branched.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import CompilerParams

NEG_INF = -2.3819763e38


def _kernel(tab_ref, plen_ref, slen_ref, q_ref, ks_ref, vs_ref,
            kp_ref, vp_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, softcap: float, page_size: int, block_q: int,
            block_kv: int, n_prefix_pages: int):
    b = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (bq, hd)

    def _accum(k, v, mask):
        """One online-softmax step over a (bq, bkv) score tile."""
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(ik < n_prefix_pages)
    def _prefix():
        k = kp_ref[0, :, 0].astype(jnp.float32)         # (page, hd)
        v = vp_ref[0, :, 0].astype(jnp.float32)
        # global prefix position vs ragged prefix length: masks both the
        # tail of a partially-filled page and trash-padded table slots
        kpos = ik * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, page_size), 1)
        _accum(k, v, kpos < plen_ref[b])

    @pl.when(ik >= n_prefix_pages)
    def _suffix():
        k = ks_ref[0, 0].astype(jnp.float32)            # (bkv, hd)
        v = vs_ref[0, 0].astype(jnp.float32)
        j = ik - n_prefix_pages
        qpos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        kpos = j * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        # suffix-local causal: every suffix query already sees the whole
        # prefix, so the offset cancels and the mask is purely local
        _accum(k, v, (kpos <= qpos) & (kpos < slen_ref[b]))

    @pl.when(ik == nk - 1)
    def _fin():
        den = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / den[:, None]).astype(o_ref.dtype)


def _dbuf_kernel(tab_ref, plen_ref, slen_ref, q_ref, ks_ref, vs_ref,
                 kp_ref, vp_ref, o_ref, m_ref, l_ref, acc_ref,
                 k_buf, v_buf, sem, *, scale: float, softcap: float,
                 page_size: int, block_q: int, block_kv: int,
                 n_prefix_pages: int, groups: int):
    """`_kernel` with the paged-prefix loads double-buffered by hand: the
    pools stay in compiler-chosen (HBM) memory and page ik+1's async copy
    is started before page ik's flash step runs, carried across the
    sequential kv grid axis in two VMEM slots. Suffix KV keeps the regular
    BlockSpec pipeline (it is dense and local to the batch row)."""
    b = pl.program_id(0)
    h = pl.program_id(1)
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)
    npp = n_prefix_pages
    hkv = h // groups

    def dma(slot, i, buf, pool, ax):
        return pltpu.make_async_copy(pool.at[tab_ref[b, i]],
                                     buf.at[slot], sem.at[slot, ax])

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        dma(0, 0, k_buf, kp_ref, 0).start()
        dma(0, 0, v_buf, vp_ref, 1).start()

    q = q_ref[0, 0].astype(jnp.float32)                 # (bq, hd)

    def _accum(k, v, mask):
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(ik < npp)
    def _prefix():
        slot = jax.lax.rem(ik, 2)
        nxt = jax.lax.rem(ik + 1, 2)

        @pl.when(ik + 1 < npp)
        def _prefetch():
            dma(nxt, ik + 1, k_buf, kp_ref, 0).start()
            dma(nxt, ik + 1, v_buf, vp_ref, 1).start()

        dma(slot, ik, k_buf, kp_ref, 0).wait()
        dma(slot, ik, v_buf, vp_ref, 1).wait()
        k = jax.lax.dynamic_index_in_dim(
            k_buf[slot], hkv, axis=1, keepdims=False).astype(jnp.float32)
        v = jax.lax.dynamic_index_in_dim(
            v_buf[slot], hkv, axis=1, keepdims=False).astype(jnp.float32)
        kpos = ik * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, page_size), 1)
        _accum(k, v, kpos < plen_ref[b])

    @pl.when(ik >= npp)
    def _suffix():
        k = ks_ref[0, 0].astype(jnp.float32)
        v = vs_ref[0, 0].astype(jnp.float32)
        j = ik - npp
        qpos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        kpos = j * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        _accum(k, v, (kpos <= qpos) & (kpos < slen_ref[b]))

    @pl.when(ik == nk - 1)
    def _fin():
        den = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / den[:, None]).astype(o_ref.dtype)


def prefix_prefill(q, k_suf, v_suf, k_pages, v_pages, prefix_table,
                   prefix_lens, suffix_lens=None, *, scale=None,
                   softcap: float = 0.0, block_q: int = 128,
                   block_kv: int = 256, dbuf: bool = False,
                   interpret: bool = False):
    """q: (B, H, Sq, hd); k/v_suf: (B, Hkv, Sq, hd);
    k/v_pages: (num_pages, page, Hkv, hd); prefix_table: (B, npp) i32;
    prefix_lens: (B,) i32; suffix_lens: (B,) i32 or None -> (B, H, Sq, hd).
    """
    B, H, Sq, hd = q.shape
    _, Hkv, _, _ = k_suf.shape
    page_size = k_pages.shape[1]
    npp = prefix_table.shape[1]
    assert npp >= 1, "prefix_prefill needs >= 1 prefix page (else use flash)"
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    if suffix_lens is None:
        suffix_lens = jnp.full((B,), Sq, jnp.int32)

    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Sq)
    pq = (-Sq) % block_q
    pkv = (-Sq) % block_kv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pkv:
        k_suf = jnp.pad(k_suf, ((0, 0), (0, 0), (0, pkv), (0, 0)))
        v_suf = jnp.pad(v_suf, ((0, 0), (0, 0), (0, pkv), (0, 0)))
    nq = (Sq + pq) // block_q
    nsk = (Sq + pkv) // block_kv

    grid = (B, H, nq, npp + nsk)
    # suffix blocks only advance once ik passes the prefix pages; the page
    # index is clamped symmetrically so the inactive branch stays in range
    suf_spec = pl.BlockSpec(
        (1, 1, block_kv, hd),
        lambda b, h, iq, ik, tab, pl_, sl: (
            b, h // G, jnp.clip(ik - npp, 0, nsk - 1), 0))
    q_spec = pl.BlockSpec(
        (1, 1, block_q, hd),
        lambda b, h, iq, ik, tab, pl_, sl: (b, h, iq, 0))
    softmax_scratch = [
        pltpu.VMEM((block_q,), jnp.float32),
        pltpu.VMEM((block_q,), jnp.float32),
        pltpu.VMEM((block_q, hd), jnp.float32),
    ]
    if dbuf:
        any_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        kern = functools.partial(_dbuf_kernel, scale=scale, softcap=softcap,
                                 page_size=page_size, block_q=block_q,
                                 block_kv=block_kv, n_prefix_pages=npp,
                                 groups=G)
        page_specs = [any_spec, any_spec]
        extra_scratch = [
            pltpu.VMEM((2, page_size, Hkv, hd), k_pages.dtype),
            pltpu.VMEM((2, page_size, Hkv, hd), v_pages.dtype),
            pltpu.SemaphoreType.DMA((2, 2)),
        ]
        # the manual DMA chain serializes the q-block walk too (slots are
        # reused across grid steps), so only batch/head stay parallel
        semantics = ("parallel", "parallel", "arbitrary", "arbitrary")
    else:
        kern = functools.partial(_kernel, scale=scale, softcap=softcap,
                                 page_size=page_size, block_q=block_q,
                                 block_kv=block_kv, n_prefix_pages=npp)
        page_spec = pl.BlockSpec(
            (1, page_size, 1, hd),
            lambda b, h, iq, ik, tab, pl_, sl: (
                tab[b, jnp.minimum(ik, npp - 1)], 0, h // G, 0))
        page_specs = [page_spec, page_spec]
        extra_scratch = []
        semantics = ("parallel", "parallel", "parallel", "arbitrary")
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[q_spec, suf_spec, suf_spec, *page_specs],
            out_specs=q_spec,
            scratch_shapes=softmax_scratch + extra_scratch,
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pq, hd), q.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=semantics),
    )(prefix_table, prefix_lens, suffix_lens, q, k_suf, v_suf,
      k_pages, v_pages)
    return out[:, :, :Sq]
