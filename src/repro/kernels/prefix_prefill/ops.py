"""Jitted wrapper for fused suffix-prefill over paged prefix KV.

Takes the model layout — q/k/v as (B, S, heads, hd) — transposes to the
kernel's (B, heads, S, hd) layout, and dispatches: Pallas on TPU, the dense
jnp oracle elsewhere (`impl="interpret"` forces the kernel through the
Pallas interpreter for parity tests).
"""
from __future__ import annotations

from functools import partial

import jax

from .kernel import prefix_prefill
from .ref import prefix_prefill_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("scale", "softcap", "block_q",
                                   "block_kv", "impl", "dbuf"))
def prefix_prefill_op(q, k_suf, v_suf, k_pages, v_pages, prefix_table,
                      prefix_lens, suffix_lens=None, *, scale: float = None,
                      softcap: float = 0.0, block_q: int = 128,
                      block_kv: int = 256, impl: str = "auto",
                      dbuf: bool = False):
    """q: (B, S, H, hd); k/v_suf: (B, S, Hkv, hd);
    k/v_pages: (num_pages, page, Hkv, hd); prefix_table: (B, npp) i32;
    prefix_lens: (B,) i32; suffix_lens: (B,) i32 or None -> (B, S, H, hd).
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    qt = q.transpose(0, 2, 1, 3)
    kt = k_suf.transpose(0, 2, 1, 3)
    vt = v_suf.transpose(0, 2, 1, 3)
    if impl == "ref":
        out = prefix_prefill_ref(qt, kt, vt, k_pages, v_pages, prefix_table,
                                 prefix_lens, suffix_lens, scale=scale,
                                 softcap=softcap)
    else:
        out = prefix_prefill(qt, kt, vt, k_pages, v_pages, prefix_table,
                             prefix_lens, suffix_lens, scale=scale,
                             softcap=softcap, block_q=block_q,
                             block_kv=block_kv, dbuf=dbuf,
                             interpret=(impl == "interpret"))
    return out.transpose(0, 2, 1, 3)
