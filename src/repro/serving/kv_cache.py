"""Block-granular, refcount-sharing KV-cache manager for the live engine
(vLLM-style paging + sglang-style prefix sharing, TetriInfer-style
disaggregated admission).

Page layout
-----------
The device cache owned by `Engine` holds, per attention segment, two pools
shaped ``(layers, num_pages, page_size, num_kv_heads, head_dim)``. A
*page* is ``page_size`` consecutive token positions of one sequence,
replicated across every layer: block tables are per-sequence, not
per-layer, so physical page ``p`` stores the same logical positions in all
layers' pools. Page 0 is reserved as a trash page — freed/idle batch slots
point every block-table entry at it, so their (masked, never attended)
decode writes land harmlessly.

Refcounted sharing
------------------
Every live page carries a reference count. A sequence's `alloc` may name
``shared`` pages (from a `serving.prefix_cache.RadixPrefixCache` match):
those get their refcount bumped instead of being taken off the free list,
so one physical page can appear in many block tables. `free`/`release`
only return a page to the free list when its refcount reaches zero — a
page is never reclaimed while any block table (or the prefix tree) still
references it. Writing into a shared page is forbidden; `cow` is the
copy-on-write escape hatch that gives a sequence a private replacement
page id (the caller copies the device bytes).

Admission semantics
-------------------
Admission reserves ``ceil(tokens / page_size)`` pages up front for the
sequence's full lifetime (prompt + all decode positions, clamped to the
engine's ``max_len``), minus any shared prefix pages — exactly the
pull-based admission signal the paper's burstiness argument assumes: a
decode instance admits a parked prefill iff `can_admit` says the whole
residency fits. Inserting a transferred prefill is a *splice*: the dense
(layers, 1, S, Hkv, hd) blob is chunked into pages and scattered into the
pools at the freshly allocated page ids — O(pages written), never a
full-cache rewrite — and the device block-table row for the sequence's
batch slot is overwritten with shared + fresh ids.

Follow-on work (see ROADMAP): preemption (page stealing with
re-prefill). Per-layer streaming admission landed with the fused
prefix-prefill PR (core/kv_transfer.pull_layered).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

TRASH_PAGE = 0


class KVCacheManager:
    """Free list + refcounts + per-sequence block tables over a fixed pool.

    The same counters the scheduler admits against (`free_pages`,
    `used_pages`, `peak_used_pages`) are maintained here; the simulator's
    decode instances use the byte-denominated `core.scheduler.PagePool`
    twin for the same accounting.
    """

    def __init__(self, num_pages: int, page_size: int, max_len: int):
        assert num_pages >= 2, "need at least the trash page + one real page"
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_len = max_len
        self.max_pages_per_seq = -(-max_len // page_size)
        # page 0 is the reserved trash page, never handed out
        self._free: List[int] = list(range(1, num_pages))
        self._refcnt: Dict[int, int] = {}        # page id -> count (> 0)
        self._tables: Dict[int, List[int]] = {}
        self.peak_used = 0
        self._reserved = 0                       # streaming-admission holds

    # ---- capacity ----------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free) - self._reserved

    # ---- reservations -------------------------------------------------
    def reserve(self, n_pages: int):
        """Hold `n_pages` off the admission signal without naming page ids
        (streamed chunked-prefill admission: decode grants a still-
        prefilling request its residency so the wire can start early; the
        actual `alloc` happens at insert time, after `unreserve`)."""
        assert 0 <= n_pages <= self.free_pages, (n_pages, self.free_pages)
        self._reserved += n_pages

    def unreserve(self, n_pages: int):
        assert 0 <= n_pages <= self._reserved, (n_pages, self._reserved)
        self._reserved -= n_pages

    @property
    def reserved_pages(self) -> int:
        return self._reserved

    @property
    def used_pages(self) -> int:
        return self.num_pages - 1 - len(self._free)

    @property
    def peak_used_pages(self) -> int:
        return self.peak_used

    def stats(self) -> Dict[str, float]:
        """Pull-collector snapshot for a `MetricsRegistry`: occupancy,
        reservations, and sharing (refcount > 1 means a page appears in
        several block tables / the prefix tree)."""
        shared = sum(1 for c in self._refcnt.values() if c > 1)
        return {"kv.num_pages": self.num_pages,
                "kv.used_pages": self.used_pages,
                "kv.free_pages": self.free_pages,
                "kv.peak_used_pages": self.peak_used,
                "kv.reserved_pages": self._reserved,
                "kv.shared_pages": shared,
                "kv.tables": len(self._tables)}

    def pages_for(self, n_tokens: int) -> int:
        """Whole pages covering `n_tokens` positions (clamped to max_len)."""
        n = min(max(n_tokens, 1), self.max_len)
        return max(-(-n // self.page_size), 1)

    def can_admit(self, n_tokens: int, n_shared: int = 0) -> bool:
        """True iff the residency fits: only the non-shared tail needs
        fresh pages."""
        return self.pages_for(n_tokens) - n_shared <= self.free_pages

    # ---- refcounts ----------------------------------------------------
    def ref(self, page: int) -> int:
        return self._refcnt.get(page, 0)

    def acquire(self, pages: Iterable[int]):
        """Take one reference on each (already-live) page."""
        for p in pages:
            assert self._refcnt.get(p, 0) > 0, f"acquire of dead page {p}"
            self._refcnt[p] += 1

    def release(self, pages: Iterable[int]) -> int:
        """Drop one reference per page; pages reaching zero return to the
        free list (never earlier). Returns the number of pages freed."""
        freed = 0
        for p in pages:
            c = self._refcnt[p] - 1
            if c == 0:
                del self._refcnt[p]
                self._free.append(p)
                freed += 1
            else:
                self._refcnt[p] = c
        return freed

    # ---- allocation ---------------------------------------------------
    def alloc(self, rid: int, n_tokens: int,
              shared: Sequence[int] = ()) -> List[int]:
        """Reserve the block table for a sequence's full residency.

        `shared` pages (a prefix-cache match, in prefix order) are
        acquired — refcount bumped, not taken from the free list; only the
        remainder comes off the free list with refcount 1."""
        assert rid not in self._tables, rid
        need = self.pages_for(n_tokens)
        assert len(shared) <= need, (rid, len(shared), need)
        fresh_n = need - len(shared)
        assert fresh_n <= self.free_pages, (rid, fresh_n, self.free_pages)
        self.acquire(shared)
        fresh = self._free[:fresh_n]
        del self._free[:fresh_n]
        for p in fresh:
            self._refcnt[p] = 1
        self._tables[rid] = list(shared) + fresh
        self.peak_used = max(self.peak_used, self.used_pages)
        return self._tables[rid]

    def block_table(self, rid: int) -> List[int]:
        return self._tables[rid]

    def free(self, rid: int) -> int:
        """Release one reference on each of a sequence's pages; only pages
        nobody else references return to the pool."""
        return self.release(self._tables.pop(rid))

    def cow(self, rid: int, idx: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write: give `rid` a private replacement for block-table
        entry `idx` if that page is shared. Returns (old, new) page ids —
        the caller must copy the device bytes old -> new — or None when
        the page was already private (write in place)."""
        table = self._tables[rid]
        old = table[idx]
        if self._refcnt[old] <= 1:
            return None
        assert self.free_pages >= 1, "cow needs a free page"
        new = self._free.pop(0)
        self._refcnt[new] = 1
        table[idx] = new
        self.release([old])
        self.peak_used = max(self.peak_used, self.used_pages)
        return old, new

    def padded_table(self, rid: int) -> List[int]:
        """Block table padded with the trash page to max_pages_per_seq."""
        t = self._tables[rid]
        return t + [TRASH_PAGE] * (self.max_pages_per_seq - len(t))
