"""Block-granular KV-cache manager for the live engine (vLLM-style paging,
TetriInfer-style disaggregated admission).

Page layout
-----------
The device cache owned by `Engine` holds, per attention segment, two pools
shaped ``(layers, num_pages, page_size, num_kv_heads, head_dim)``. A
*page* is ``page_size`` consecutive token positions of one sequence,
replicated across every layer: block tables are per-sequence, not
per-layer, so physical page ``p`` stores the same logical positions in all
layers' pools. Page 0 is reserved as a trash page — freed/idle batch slots
point every block-table entry at it, so their (masked, never attended)
decode writes land harmlessly.

Block-table semantics
---------------------
`KVCacheManager` is the host-side allocator: a free list of physical page
ids plus one block table (a list of page ids) per resident sequence.
Admission reserves ``ceil(tokens / page_size)`` pages up front for the
sequence's full lifetime (prompt + all decode positions, clamped to the
engine's ``max_len``), which is exactly the pull-based admission signal the
paper's burstiness argument assumes: a decode instance admits a parked
prefill iff `can_admit` says the whole residency fits. Inserting a
transferred prefill is a *splice*: the dense (layers, 1, S, Hkv, hd) blob
is chunked into pages and scattered into the pools at the allocated page
ids — O(pages written), never a full-cache rewrite — and the device block
table row for the sequence's batch slot is overwritten with the new ids.

Follow-on work (see ROADMAP): prefix-cache page sharing (refcounted pages
keyed by token-prefix hash) and preemption (page stealing with re-prefill).
"""
from __future__ import annotations

from typing import Dict, List

from ..core.scheduler import PagePool

TRASH_PAGE = 0


class KVCacheManager:
    """Free list + per-sequence block tables over a fixed page pool.

    Capacity accounting (used/free/peak, per-rid reservations) is the
    shared `core.scheduler.PagePool` — the same counter the simulator's
    decode instances admit against — with the physical page-id free list
    and the max_len residency clamp layered on top.
    """

    def __init__(self, num_pages: int, page_size: int, max_len: int):
        assert num_pages >= 2, "need at least the trash page + one real page"
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_len = max_len
        self.max_pages_per_seq = -(-max_len // page_size)
        # page 0 is the reserved trash page, never handed out
        self.pool = PagePool(num_pages - 1, unit=page_size)
        self._free: List[int] = list(range(1, num_pages))
        self._tables: Dict[int, List[int]] = {}

    # ---- capacity ----------------------------------------------------
    @property
    def free_pages(self) -> int:
        return self.pool.free_pages

    @property
    def used_pages(self) -> int:
        return self.pool.used

    @property
    def peak_used_pages(self) -> int:
        return self.pool.peak_used

    def pages_for(self, n_tokens: int) -> int:
        """Whole pages covering `n_tokens` positions (clamped to max_len)."""
        return self.pool.pages_for(min(max(n_tokens, 1), self.max_len))

    def can_admit(self, n_tokens: int) -> bool:
        return self.pool.can_alloc(self.pages_for(n_tokens))

    # ---- allocation ---------------------------------------------------
    def alloc(self, rid: int, n_tokens: int) -> List[int]:
        """Reserve the block table for a sequence's full residency."""
        need = self.pages_for(n_tokens)
        self.pool.alloc(rid, need)
        pages = self._free[:need]
        del self._free[:need]
        self._tables[rid] = pages
        return pages

    def block_table(self, rid: int) -> List[int]:
        return self._tables[rid]

    def free(self, rid: int) -> int:
        """Release a sequence's pages back to the pool."""
        n = self.pool.free(rid)
        self._free.extend(self._tables.pop(rid))
        return n

    def padded_table(self, rid: int) -> List[int]:
        """Block table padded with the trash page to max_pages_per_seq."""
        t = self._tables[rid]
        return t + [TRASH_PAGE] * (self.max_pages_per_seq - len(t))
