"""Live disaggregated cluster (DistServe runtime, Fig. 6) and the colocated
baseline, on real JAX engines with virtual-clock concurrency emulation.

Controller: FCFS arrival queue -> shortest-queue prefill dispatch ->
pull-based KV migration -> least-loaded decode dispatch. Fault injection
hooks exercise the failover paths in core.fault.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.fault import HeartbeatMonitor, plan_failover
from ..core.kv_transfer import TransferManager, kv_bytes
from ..core.scheduler import FCFSQueue, least_loaded, shortest_queue
from ..core.workload import Request
from .engine import Engine, Sequence


@dataclasses.dataclass
class ServedResult:
    rid: int
    tokens: List[int]
    ttft: float
    tpot: float
    finish: float


class DisaggCluster:
    """n_prefill + n_decode live engines; virtual-clock event loop."""

    def __init__(self, cfg, params, *, n_prefill: int = 1, n_decode: int = 1,
                 max_batch: int = 8, max_len: int = 256,
                 transfer_bandwidth: float = 50e9, lm_tokens: int = 256,
                 attn_blocks=(64, 64)):
        self.cfg = cfg
        self.prefill = [Engine(cfg, params, max_batch=1, max_len=max_len,
                               attn_blocks=attn_blocks)
                        for _ in range(n_prefill)]
        self.decode = [Engine(cfg, params, max_batch=max_batch,
                              max_len=max_len, attn_blocks=attn_blocks)
                       for _ in range(n_decode)]
        self.queues = [FCFSQueue(token_of=lambda s: len(s.tokens))
                       for _ in range(n_prefill)]
        self.tx = TransferManager(transfer_bandwidth)
        self.lm_tokens = lm_tokens
        self.monitor = HeartbeatMonitor(timeout=1e9)
        for i in range(n_prefill):
            self.monitor.register(f"prefill{i}")
        for i in range(n_decode):
            self.monitor.register(f"decode{i}")
        self.failed_prefill: set = set()
        self.failed_decode: set = set()

    # -- fault injection ------------------------------------------------
    def fail_decode(self, idx: int) -> List[int]:
        """Kill a decode instance; returns rids needing re-prefill."""
        self.monitor.mark_failed(f"decode{idx}")
        self.failed_decode.add(idx)
        lost = [s.rid for s in getattr(self.decode[idx], "_active", [])]
        return lost

    def fail_prefill(self, idx: int) -> List[int]:
        self.monitor.mark_failed(f"prefill{idx}")
        self.failed_prefill.add(idx)
        return [s.rid for s in self.queues[idx].items]

    # -- main loop --------------------------------------------------------
    def run(self, requests: List[Request],
            fail_decode_at: Optional[Tuple[float, int]] = None
            ) -> Dict[int, ServedResult]:
        """Drive all requests to completion on the virtual clock."""
        rng = np.random.default_rng(0)
        seqs: Dict[int, Sequence] = {}
        for r in requests:
            toks = rng.integers(1, self.cfg.vocab_size,
                                size=r.in_len).tolist()
            seqs[r.rid] = Sequence(r.rid, toks, r.out_len)

        evq: List[Tuple[float, int, str, Any]] = []
        ctr = itertools.count()

        def push(t, kind, payload):
            heapq.heappush(evq, (t, next(ctr), kind, payload))

        for r in requests:
            push(r.arrive, "arrive", r)
        if fail_decode_at is not None:
            push(fail_decode_at[0], "fail_decode", fail_decode_at[1])

        # per-engine virtual clocks
        p_free = [0.0] * len(self.prefill)
        d_free = [0.0] * len(self.decode)
        d_active: List[List[Sequence]] = [[] for _ in self.decode]
        d_ready: List[List[Tuple[Request, Any]]] = [[] for _ in self.decode]
        results: Dict[int, ServedResult] = {}

        def healthy_p(i):
            return i not in self.failed_prefill

        def healthy_d(i):
            return i not in self.failed_decode

        def start_prefill(i, now):
            if not healthy_p(i) or not self.queues[i].items or p_free[i] > now:
                return
            batch = self.queues[i].form_batch(self.lm_tokens, max_batch=1)
            for seq in batch:
                req = seq._req
                first, blob, dt = self.prefill[i].prefill_request(seq)
                seq.tokens.append(first)
                seq.produced += 1
                req.first_token = now + dt
                if seq.produced >= seq.out_len:
                    seq.done = True
                    req.finish = now + dt
                    _finish(req, seq, now + dt)
                else:
                    nbytes = kv_bytes(self.cfg, len(seq.tokens) - 1)
                    self.tx.park(seq.rid, blob, nbytes, now + dt)
                    push(now + dt, "dispatch_decode", (req, seq))
                p_free[i] = now + dt
                push(now + dt, "poke_prefill", i)

        def _finish(req, seq, t):
            ttft = req.first_token - req.arrive
            tpot = ((req.finish - req.first_token) / max(seq.out_len - 1, 1))
            results[req.rid] = ServedResult(req.rid, seq.tokens, ttft, tpot,
                                            req.finish)

        def start_decode(i, now):
            if not healthy_d(i) or d_free[i] > now:
                return
            d = self.decode[i]
            # pull-based admission
            while d_ready[i] and d.has_slot():
                req, seq = d_ready[i].pop(0)
                blob, t_done = self.tx.pull(seq.rid, now)
                d.insert_kv(seq, blob)
                seq._req.decode_admit = max(now, t_done)
                d_active[i].append(seq)
            d._active = d_active[i]
            if not d_active[i]:
                return
            dt = d.decode_step(d_active[i])
            done_t = now + dt
            d_free[i] = done_t
            still = []
            for seq in d_active[i]:
                if seq.done:
                    seq._req.finish = done_t
                    _finish(seq._req, seq, done_t)
                    d.release(seq)
                else:
                    still.append(seq)
            d_active[i] = still
            push(done_t, "poke_decode", i)

        while evq:
            t, _, kind, payload = heapq.heappop(evq)
            if kind == "arrive":
                r = payload
                seq = seqs[r.rid]
                seq._req = r
                alive = [i for i in range(len(self.queues)) if healthy_p(i)]
                qi = min(alive, key=lambda i: self.queues[i].queued_tokens)
                self.queues[qi].push(seq)
                start_prefill(qi, max(t, p_free[qi]))
            elif kind == "poke_prefill":
                start_prefill(payload, t)
            elif kind == "dispatch_decode":
                req, seq = payload
                alive = [i for i in range(len(self.decode)) if healthy_d(i)]
                di = min(alive, key=lambda i: len(d_active[i]) + len(d_ready[i]))
                d_ready[di].append((req, seq))
                start_decode(di, max(t, d_free[di]))
            elif kind == "poke_decode":
                start_decode(payload, t)
            elif kind == "fail_decode":
                idx = payload
                lost = self.fail_decode(idx)
                # failover: re-prefill lost requests (keep generated tokens)
                for rid in lost:
                    seq = seqs[rid]
                    self.decode[idx].release(seq)
                    seq.done = False
                    alive = [i for i in range(len(self.queues)) if healthy_p(i)]
                    qi = min(alive, key=lambda i: self.queues[i].queued_tokens)
                    self.queues[qi].push(seq)
                    push(t, "poke_prefill", qi)
                # also re-route ready-but-unpulled requests
                moved = d_ready[idx]
                d_ready[idx] = []
                for req, seq in moved:
                    push(t, "dispatch_decode", (req, seq))
        return results


class ColocatedCluster:
    """vLLM-like baseline: each engine runs prefill + decode interleaved
    with prefill priority (iteration-level batching)."""

    def __init__(self, cfg, params, *, n_engines: int = 1, max_batch: int = 8,
                 max_len: int = 256, max_prefill_tokens: int = 512,
                 attn_blocks=(64, 64)):
        self.cfg = cfg
        self.engines = [Engine(cfg, params, max_batch=max_batch,
                               max_len=max_len, attn_blocks=attn_blocks)
                        for _ in range(n_engines)]
        self.max_prefill_tokens = max_prefill_tokens

    def run(self, requests: List[Request]) -> Dict[int, ServedResult]:
        rng = np.random.default_rng(0)
        results: Dict[int, ServedResult] = {}
        evq: List[Tuple[float, int, str, Any]] = []
        ctr = itertools.count()

        def push(t, kind, payload):
            heapq.heappush(evq, (t, next(ctr), kind, payload))

        waiting: List[List[Tuple[Request, Sequence]]] = [[] for _ in self.engines]
        active: List[List[Sequence]] = [[] for _ in self.engines]
        free_at = [0.0] * len(self.engines)

        for r in requests:
            toks = rng.integers(1, self.cfg.vocab_size, size=r.in_len).tolist()
            s = Sequence(r.rid, toks, r.out_len)
            s._req = r
            push(r.arrive, "arrive", (r, s))

        def _finish(req, seq, t):
            req.finish = t
            ttft = req.first_token - req.arrive
            tpot = (req.finish - req.first_token) / max(seq.out_len - 1, 1)
            results[req.rid] = ServedResult(req.rid, seq.tokens, ttft, tpot, t)

        def step(i, now):
            if free_at[i] > now:
                return
            e = self.engines[i]
            if waiting[i] and e.has_slot():
                req, seq = waiting[i].pop(0)
                first, blob, dt = e.prefill_request(seq)
                seq.tokens.append(first)
                seq.produced += 1
                req.first_token = now + dt
                e.insert_kv(seq, blob)
                if seq.produced >= seq.out_len:
                    seq.done = True
                    e.release(seq)
                    _finish(req, seq, now + dt)
                else:
                    active[i].append(seq)
                free_at[i] = now + dt
                push(now + dt, "poke", i)
                return
            if active[i]:
                dt = e.decode_step(active[i])
                done_t = now + dt
                still = []
                for seq in active[i]:
                    if seq.done:
                        e.release(seq)
                        _finish(seq._req, seq, done_t)
                    else:
                        still.append(seq)
                active[i] = still
                free_at[i] = done_t
                push(done_t, "poke", i)

        while evq:
            t, _, kind, payload = heapq.heappop(evq)
            if kind == "arrive":
                r, s = payload
                i = min(range(len(self.engines)),
                        key=lambda j: len(waiting[j]) + len(active[j]))
                waiting[i].append((r, s))
                step(i, max(t, free_at[i]))
            elif kind == "poke":
                step(payload, t)
        return results
