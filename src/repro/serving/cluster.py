"""Live disaggregated cluster (DistServe runtime, Fig. 6) and the colocated
baseline, on real JAX engines with virtual-clock concurrency emulation.

Both clusters implement the `serving.api.ServingBackend` protocol: arrivals
are external submissions (`submit` returns a `ServeHandle` with streaming
token events and `.cancel()`), the event loop advances via `step` /
`run_until(t)` / `drain()`, and every request walks the
`RequestStatus` state machine (QUEUED -> PREFILLING -> MIGRATING ->
PENDING_ADMIT -> DECODING -> FINISHED | CANCELLED | FAILED).  The legacy
closed-world `run(requests)` is a thin submit-all-then-drain shim kept for
compatibility (it resets the loop + token rng, so repeated runs replay
identically).

Controller: FCFS arrival queue -> shortest-queue prefill dispatch ->
pull-based, page-granular KV migration -> least-loaded decode dispatch.
All dispatch decisions and batch formation go through the shared scheduler
core in `core.scheduler` (the same code the discrete-event simulator
runs), and decode admission is gated on free KV *pages*, not whole slots.
Cancellation at any stage releases pages, prefix pins, and parked
transfer bytes without leaking.  Fault injection hooks exercise the
failover paths in core.fault.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.fault import HeartbeatMonitor, plan_failover
from ..core.kv_transfer import TransferManager, kv_bytes, pipelined_finish
from ..core.scheduler import DisaggDispatcher, FCFSQueue, least_loaded
from ..core.workload import Request
from .api import (FINISH_FAILED, GREEDY, BackendBase, RequestState,
                  RequestStatus, ServedResult, sequence_tokens)
from .engine import Engine, KVBlob, Sequence, release_blob

__all__ = ["DisaggCluster", "ColocatedCluster", "ServedResult"]


def _page_bytes(cfg, page_size: int, dtype_bytes: int = 2) -> Optional[int]:
    per_tok = cfg.kv_bytes_per_token(dtype_bytes)
    return per_tok * page_size if per_tok else None


def _slice_blob(blob, skip_tokens: int):
    """Drop the first `skip_tokens` positions from a migration blob — the
    decode side already holds that prefix, so only the suffix ships."""
    cache, n_tok = blob
    if not skip_tokens:
        return blob
    sliced = {k: ({"k": v["k"][:, :, skip_tokens:],
                   "v": v["v"][:, :, skip_tokens:]}
                  if k.startswith("seg") else v)
              for k, v in cache.items()}
    return sliced, n_tok


class _LiveBackend(BackendBase):
    """Sequence construction shared by both live clusters (previously
    copied between the two `run` loops with a hardcoded rng seed)."""

    def _init_live(self, cfg, seed: int, tracker=None, tracer=None,
                   metrics=None):
        self.cfg = cfg
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        self._init_backend(tracker=tracker, tracer=tracer, metrics=metrics)

    def _reset_loop(self):
        """Fresh event loop, virtual clocks, and token rng (the legacy
        `run` contract: every replay of the same trace restarts at t=0
        and derives identical token streams)."""
        self._rng = np.random.default_rng(self.seed)
        self._init_backend(tracker=self.tracker,
                           tracer=self.tracer or None, metrics=self.metrics)
        self._reset_clocks()

    def _reset_clocks(self):
        raise NotImplementedError

    def _make_sequence(self, state: RequestState) -> Sequence:
        r, sp = state.request, state.sampling
        seq = Sequence(r.rid, sequence_tokens(self.cfg, r, self._rng),
                       sp.out_len(r.out_len),
                       sampling=None if sp == GREEDY else sp)
        state.seq = seq
        return seq


class DisaggCluster(_LiveBackend):
    """n_prefill + n_decode live engines; virtual-clock event loop."""

    def __init__(self, cfg, params, *, n_prefill: int = 1, n_decode: int = 1,
                 max_batch: int = 8, max_len: int = 256,
                 transfer_bandwidth: float = 50e9, lm_tokens: int = 256,
                 attn_blocks=(64, 64), page_size: int = 16,
                 decode_num_pages: Optional[int] = None,
                 paged: Optional[bool] = None,
                 prefix_cache: bool = False,
                 prefill_num_pages: Optional[int] = None,
                 fused_prefix: Optional[bool] = None,
                 chunk_tokens: Optional[int] = None,
                 seed: int = 0, tracker=None, tracer=None,
                 charge=None, metrics=None):
        self._init_live(cfg, seed, tracker=tracker, tracer=tracer,
                        metrics=metrics)
        # optional deterministic charge model: replace measured kernel
        # times with `core.latency_model.EngineCharge` analytic times, so
        # the live event timeline (and trace) is float-identical to the
        # simulator's on the same request trace
        self.charge = charge
        if (prefix_cache or chunk_tokens) and prefill_num_pages is None:
            # a prefill engine's default pool (one resident sequence) has
            # no room to retain prefixes or to hold several chunked
            # prompts' reserved residencies; keep a few sequences' worth
            prefill_num_pages = 8 * -(-max_len // page_size) + 1
        self.prefix_cache = prefix_cache
        self.prefill = [Engine(cfg, params, max_batch=1, max_len=max_len,
                               attn_blocks=attn_blocks, paged=paged,
                               page_size=page_size,
                               num_pages=prefill_num_pages,
                               prefix_cache=prefix_cache,
                               fused_prefix=fused_prefix)
                        for _ in range(n_prefill)]
        self.decode = [Engine(cfg, params, max_batch=max_batch,
                              max_len=max_len, attn_blocks=attn_blocks,
                              paged=paged, page_size=page_size,
                              num_pages=decode_num_pages,
                              prefix_cache=prefix_cache)
                       for _ in range(n_decode)]
        # chunked prefill needs the paged runtime (in-place page writes)
        self.chunk_tokens = (chunk_tokens if chunk_tokens
                             and self.prefill[0].paged else None)
        # queue load = tokens still to prefill (partial prompts re-queue
        # with their remaining suffix only)
        self.queues = [FCFSQueue(
            token_of=lambda s: max(len(s.tokens) - s.prefilled, 0))
            for _ in range(n_prefill)]
        self.dispatcher = DisaggDispatcher()
        self.tx = TransferManager(transfer_bandwidth,
                                  page_bytes=_page_bytes(cfg, page_size),
                                  n_layers=cfg.num_layers)
        self.lm_tokens = lm_tokens
        self.monitor = HeartbeatMonitor(timeout=1e9)
        for i in range(n_prefill):
            self.monitor.register(f"prefill{i}")
        for i in range(n_decode):
            self.monitor.register(f"decode{i}")
        self.failed_prefill: set = set()
        self.failed_decode: set = set()
        self._p_free = [0.0] * n_prefill
        self._d_free = [0.0] * n_decode
        self._d_active: List[List[Sequence]] = [[] for _ in range(n_decode)]
        # (state, skip_tokens, pinned_pages) awaiting decode admission
        self._d_pending: List[List[Tuple[RequestState, int, List[int]]]] = \
            [[] for _ in range(n_decode)]
        # (state, skip, pinned, reserved_pages): streamed chunked prefills
        # whose residency is granted, waiting for the final chunk to land
        self._d_granted: List[List[Tuple[RequestState, int, List[int],
                                         int]]] = [[] for _ in range(n_decode)]
        # rid -> (decode_idx, src_prefill, skip): streamed-migration route
        # chosen at first-chunk completion
        self._stream: Dict[int, Tuple[int, int, int]] = {}
        if self.tracer.enabled:
            self.tx.tracer = self.tracer
            self.dispatcher.tracer = self.tracer
        if metrics is not None:
            metrics.register(self._collect_metrics)

    def _collect_metrics(self) -> Dict[str, float]:
        """Pull-collector for a `MetricsRegistry`: per-engine dispatch and
        page-pool stats, queue depths, transfer-manager totals."""
        out: Dict[str, float] = {}
        for side, engines in (("prefill", self.prefill),
                              ("decode", self.decode)):
            for i, e in enumerate(engines):
                for k, v in e.stats().items():
                    out[f"{side}{i}.{k}"] = v
        for i, q in enumerate(self.queues):
            out[f"queue{i}.depth"] = len(q)
            out[f"queue{i}.tokens"] = q.queued_tokens
        for k, v in self.tx.stats().items():
            out[f"tx.{k}"] = v
        out["decode_pending"] = sum(len(p) for p in self._d_pending)
        out["decode_granted"] = sum(len(g) for g in self._d_granted)
        out["decode_active"] = sum(len(a) for a in self._d_active)
        return out

    # -- fault injection ------------------------------------------------
    def fail_decode(self, idx: int) -> List[int]:
        """Kill a decode instance; returns rids needing re-prefill."""
        self.monitor.mark_failed(f"decode{idx}")
        self.failed_decode.add(idx)
        # `_active` may predate the latest iteration's completion filter —
        # sequences that already finished are not lost
        lost = [s.rid for s in getattr(self.decode[idx], "_active", [])
                if not s.done]
        return lost

    def fail_prefill(self, idx: int) -> List[int]:
        self.monitor.mark_failed(f"prefill{idx}")
        self.failed_prefill.add(idx)
        return [s.rid for s in self.queues[idx].items]

    def _reset_clocks(self):
        self._p_free = [0.0] * len(self.prefill)
        self._d_free = [0.0] * len(self.decode)
        self._d_active = [[] for _ in self.decode]
        self._d_pending = [[] for _ in self.decode]
        self._d_granted = [[] for _ in self.decode]
        self._stream = {}

    def _alive_p(self):
        return [i for i in range(len(self.prefill))
                if i not in self.failed_prefill]

    def _alive_d(self):
        return [i for i in range(len(self.decode))
                if i not in self.failed_decode]

    def _prefill_hits(self, tokens):
        if not self.prefix_cache:
            return None
        return [self.prefill[i].prefix_peek(tokens)
                for i in range(len(self.prefill))]

    # -- ServingBackend hooks -------------------------------------------
    def _do_submit(self, state: RequestState, t: float):
        self._make_sequence(state)
        self._ev.push(t, "arrive", state)

    def _handle(self, t: float, kind: str, payload: Any):
        if kind == "arrive":
            self._on_arrive(payload, t)
        elif kind == "poke_prefill":
            self._poke_prefill(payload, t)
        elif kind == "dispatch_decode":
            self._on_dispatch_decode(payload, t)
        elif kind == "predispatch_decode":
            self._on_predispatch(payload, t)
        elif kind == "finalize_stream":
            self._on_finalize_stream(payload, t)
        elif kind == "poke_decode":
            self._poke_decode(payload, t)
        elif kind == "fail_decode":
            self._on_fail_decode(payload, t)

    # -- event handlers --------------------------------------------------
    def _on_arrive(self, state: RequestState, t: float):
        if state.done:                      # cancelled before arrival
            return
        seq = state.seq
        qi = self.dispatcher.pick_prefill(state.rid, self.queues,
                                          self._alive_p(),
                                          hits=self._prefill_hits(seq.tokens),
                                          now=t)
        self.queues[qi].push(seq)
        state.where = ("prefill", qi)
        if self.tracer.enabled:
            self.tracer.phase(state.rid, "queued", t, f"prefill{qi}")
        self._ev.push(t, "poke_prefill", qi)

    def _poke_prefill(self, i: int, now: float):
        if i in self.failed_prefill or not self.queues[i].items:
            return
        if self._p_free[i] > now:           # busy: come back when free
            self._ev.push(self._p_free[i], "poke_prefill", i)
            return
        if self.chunk_tokens:
            self._prefill_chunk_step(i, now)
            return
        batch = self.queues[i].form_batch(self.lm_tokens, max_batch=1)
        for seq in batch:
            state = self._states[seq.rid]
            state.to_status(RequestStatus.PREFILLING)
            req = state.request
            first, blob, dt = self.prefill[i].prefill_request(seq)
            if self.charge is not None:
                dt = self.charge.prefill([len(seq.tokens) - seq.prefix_hit])
            if self.tracer.enabled:
                self.tracer.phase(seq.rid, "prefilling", now, f"prefill{i}")
                self.tracer.complete(
                    "compute", "prefill_batch", now, now + dt,
                    f"prefill{i}", rid=seq.rid,
                    tokens=len(seq.tokens) - seq.prefix_hit,
                    hit=seq.prefix_hit)
            seq.append_token(first)
            req.first_token = now + dt
            self._emit_token(state, first, now + dt)
            if seq.done:
                release_blob(blob)      # nothing will migrate: drop pins
                self._finish_state(state, now + dt)
            else:
                # decode target (and hence shipped bytes) is chosen at
                # dispatch time, where the decode-side prefix is known
                self._ev.push(now + dt, "dispatch_decode", (state, blob, i))
            self._p_free[i] = now + dt
            self._ev.push(now + dt, "poke_prefill", i)

    def _prefill_chunk_step(self, i: int, now: float):
        """One chunk of the head-of-queue prompt. Unfinished prompts
        re-queue at the tail (chunk-granular round-robin: a long prompt no
        longer head-of-line-blocks short ones), each finished chunk's KV
        is parked as a shippable segment, and the decode target is chosen
        at *first*-chunk completion so the wire can overlap the remaining
        chunks' compute."""
        e = self.prefill[i]
        # a page-blocked *new* head must not strand the resumable partials
        # queued behind it: their reservations free only by finishing, so
        # form_batch may drain them past the head (retry for the head
        # arrives via the poke each pull/finish schedules)
        batch = self.queues[i].form_batch(
            self.lm_tokens, max_batch=1, can_take=e.can_start_chunked,
            chunk_tokens=self.chunk_tokens, resumable=e.has_partial)
        if not batch:
            return
        seq = batch[0]
        state = self._states[seq.rid]
        req = state.request
        state.to_status(RequestStatus.PREFILLING)
        prev = seq.prefilled
        done, first, blob, dt, _c = e.prefill_chunk(seq, self.chunk_tokens)
        if self.charge is not None:
            dt = self.charge.chunk(_c, prev)
        t_end = now + dt
        if self.tracer.enabled:
            self.tracer.phase(seq.rid, "prefilling", now, f"prefill{i}")
            self.tracer.complete("compute", "chunk", now, t_end,
                                 f"prefill{i}", rid=seq.rid,
                                 tokens=_c, ctx=prev)
        state.progress = seq.prefilled
        seg_bytes = kv_bytes(self.cfg, seq.prefilled) - \
            (kv_bytes(self.cfg, prev) if prev else 0)
        self.tx.park_partial(seq.rid, max(seg_bytes, 0), t_end)
        if not done:
            self.queues[i].push(seq)
            if seq.rid not in self._stream:
                self._ev.push(t_end, "predispatch_decode", (state, i))
        else:
            seq.append_token(first)
            req.first_token = t_end
            self._emit_token(state, first, t_end)
            if seq.done:                    # out_len == 1 / instant stop
                release_blob(blob)
                self._drop_stream(state, t_end)
                self.tx.drop_partial(seq.rid)
                self._finish_state(state, t_end)
            elif seq.rid in self._stream:
                self._ev.push(t_end, "finalize_stream", (state, blob))
            else:                           # single-chunk prompt
                self._ev.push(t_end, "dispatch_decode", (state, blob, i))
        self._p_free[i] = t_end
        self._ev.push(t_end, "poke_prefill", i)

    def _on_predispatch(self, payload, t: float):
        """First chunk landed: pick the decode target now so segments can
        be granted pages and start crossing the wire while later chunks
        are still computing."""
        state, src = payload
        if state.done or state.rid in self._stream:
            return
        seq, req = state.seq, state.request
        n_tok = len(seq.tokens)
        alive = self._alive_d()
        loads = [len(self._d_active[i]) + len(self._d_pending[i])
                 + len(self._d_granted[i]) for i in range(len(self.decode))]
        d_hits = None
        if self.prefix_cache:
            d_hits = [self.decode[i].prefix_peek(seq.tokens[:n_tok])
                      for i in range(len(self.decode))]
        di = self.dispatcher.pick_decode(req.rid, loads, alive, hits=d_hits,
                                         now=t)
        skip, pinned = self.decode[di].pin_prefix(seq.tokens[:n_tok])
        self._stream[state.rid] = (di, src, skip)
        self._d_pending[di].append((state, skip, pinned))
        self._ev.push(t, "poke_decode", di)

    def _on_finalize_stream(self, payload, t: float):
        """Final chunk landed: close the stream — park the page-backed
        blob with the decode-side ship size; admission (or the earlier
        grant) pulls the per-segment schedule."""
        state, blob = payload
        if state.done:                      # cancelled mid-final-chunk
            release_blob(blob)
            self.tx.drop_partial(state.rid)
            return
        if state.rid not in self._stream:
            # a decode-failure re-route (_on_fail_decode) reclaimed the
            # stream at this same timestamp and queued a fresh
            # predispatch behind this event; defer until that lands and
            # re-establishes the route
            self._ev.push(t, "finalize_stream", (state, blob))
            return
        di, src, skip = self._stream.pop(state.rid)
        seq = state.seq
        ship = blob.n_tok - skip
        nbytes = kv_bytes(self.cfg, ship) if ship else 0
        self.tx.park(seq.rid, blob, nbytes, t, src=src)
        state.where = ("decode", di)
        state.to_status(RequestStatus.MIGRATING)
        if self.tracer.enabled:
            self.tracer.phase(seq.rid, "migrating", t, f"decode{di}")
        self._ev.push(t, "poke_decode", di)

    def _drop_stream(self, state: RequestState, t: float):
        """Remove every trace of a streamed chunked migration: the chosen
        route, the pending/granted decode-side entry (pins + page
        reservation), and the parked chunk segments."""
        rid = state.rid
        self.tx.drop_partial(rid)
        info = self._stream.pop(rid, None)
        if info is None:
            return
        di, _src, _skip = info
        d = self.decode[di]
        for j, entry in enumerate(self._d_pending[di]):
            if entry[0] is state:
                del self._d_pending[di][j]
                d.unpin(entry[2])
                break
        for j, entry in enumerate(self._d_granted[di]):
            if entry[0] is state:
                del self._d_granted[di][j]
                d.unpin(entry[2])
                if di not in self.failed_decode:
                    d.unreserve(entry[3])
                break
        self._ev.push(t, "poke_decode", di)

    def _on_dispatch_decode(self, payload, t: float):
        state, blob, src = payload
        if state.done:                      # cancelled mid-prefill: the
            release_blob(blob)              # blob is dropped (fused blobs
            return                          # release their prefix pins)
        seq, req = state.seq, state.request
        alive = self._alive_d()
        loads = [len(self._d_active[i]) + len(self._d_pending[i])
                 + len(self._d_granted[i]) for i in range(len(self.decode))]
        n_tok = blob[1]
        d_hits = None
        if self.prefix_cache:
            d_hits = [self.decode[i].prefix_peek(seq.tokens[:n_tok])
                      for i in range(len(self.decode))]
        di = self.dispatcher.pick_decode(req.rid, loads, alive, hits=d_hits,
                                         now=t)
        # pin the decode-resident prefix and ship only the rest
        skip, pinned = self.decode[di].pin_prefix(seq.tokens[:n_tok])
        ship = n_tok - skip
        nbytes = kv_bytes(self.cfg, ship) if ship else 0
        self.tx.park(seq.rid, blob, nbytes, t, src=src)
        self._d_pending[di].append((state, skip, pinned))
        state.where = ("decode", di)
        state.to_status(RequestStatus.MIGRATING)
        if self.tracer.enabled:
            self.tracer.phase(seq.rid, "migrating", t, f"decode{di}")
        self._ev.push(t, "poke_decode", di)

    def _admit_one(self, i: int, state: RequestState, skip: int,
                   pinned: List[int], now: float):
        """Pull one parked request's KV over the wire and splice it in.
        `pull_streamed` charges the per-segment schedule for chunked
        streams and degenerates to the per-layer schedule for whole-blob
        parks."""
        d = self.decode[i]
        seq, req = state.seq, state.request
        src = self.tx.parked[seq.rid].src
        blob, t_first, t_full = self.tx.pull_streamed(seq.rid, now, dst=i)
        if isinstance(blob, KVBlob):
            # page-backed blob: the prefill engine stitches the wire
            # payload from its page pool (and drops its pins)
            wire = blob.owner.materialize_wire(blob, skip)
        else:
            wire = _slice_blob(blob, skip)
        d.insert_kv(seq, wire, shared=pinned, skip_tokens=skip)
        d.unpin(pinned)
        # per-layer streaming: decode starts attending once the first
        # layer of the last chunk lands, not at blob-complete; a granted
        # stream's wire may have finished during prefill (t_full < now),
        # so both marks clamp forward to keep the timeline monotone
        seq.kv_first = max(now, t_first)
        seq.kv_full = max(t_full, seq.kv_first)
        req.decode_admit = seq.kv_first
        req.transfer_done = seq.kv_full
        state.to_status(RequestStatus.DECODING)
        if self.tracer.enabled:
            # decode starts attending at first-layer-landed, the same
            # instant the simulator stamps `decode_admit`
            self.tracer.phase(seq.rid, "decoding", seq.kv_first,
                              f"decode{i}")
        self._d_active[i].append(seq)
        # the pull released prefill-side pages: a stalled chunked prefill
        # may be able to start its next prompt now
        if src < len(self.prefill):
            self._ev.push(now, "poke_prefill", src)

    def _poke_decode(self, i: int, now: float):
        if i in self.failed_decode:
            return
        if self._d_free[i] > now:
            self._ev.push(self._d_free[i], "poke_decode", i)
            return
        d = self.decode[i]
        pending = self._d_pending[i]
        granted = self._d_granted[i]

        # pull-based admission against free KV pages (paper §4.3);
        # shared prefix pages are already resident, so only the
        # suffix needs fresh pages
        def admit_ready():
            # granted streams whose final chunk has landed insert first
            # (their pages are already held; the wire has been moving
            # since the grant)
            progress = True
            while progress:
                progress = False
                for j, (state, skip, pinned, n_res) in enumerate(granted):
                    if self.tx.has_parked(state.rid):
                        del granted[j]
                        d.unreserve(n_res)
                        self._admit_one(i, state, skip, pinned, now)
                        progress = True
                        break
            while pending:
                state, skip, pinned = pending[0]
                if not d.can_admit(state.seq, len(pinned)):
                    break
                pending.pop(0)
                if not self.tx.has_parked(state.rid):
                    # streamed chunked prefill still computing: grant its
                    # residency so parked segments start crossing now
                    n_res = d.reserve_for(state.seq, len(pinned))
                    self.tx.grant(state.rid, now)
                    granted.append((state, skip, pinned, n_res))
                    continue
                self._admit_one(i, state, skip, pinned, now)

        admit_ready()
        if pending and not self._d_active[i] and not granted:
            # liveness fallback: nothing is running (so no future poke
            # will fire) and the head still can't admit — its eviction
            # is blocked by pages pinned for *later* pending requests.
            # Drop every pin (those requests fall back to a full-blob
            # transfer); with no pins and nothing running, the head's
            # residency always fits after LRU eviction.
            for j, (state, _skip, pinned) in enumerate(pending):
                d.unpin(pinned)
                pending[j] = (state, 0, [])
            admit_ready()
        # amortized marking: entries append at the tail, marked ones
        # accumulate at the front (see the simulator twin); streamed
        # entries stay PREFILLING-with-progress until their final chunk
        for state, _skip, _pinned in reversed(pending):
            if state.status is RequestStatus.PENDING_ADMIT:
                break
            if state.status is RequestStatus.MIGRATING:
                state.to_status(RequestStatus.PENDING_ADMIT)
                if self.tracer.enabled:
                    self.tracer.phase(state.rid, "pending_admit", now,
                                      f"decode{i}")
        d._active = self._d_active[i]
        if not self._d_active[i]:
            return
        batch = self._d_active[i]
        ctx_tokens = sum(len(s.tokens) - 1 for s in batch)
        dt = d.decode_step(batch)
        if self.charge is not None:
            dt = self.charge.decode(len(batch), ctx_tokens)
        done_t = now + dt
        for seq in batch:
            if seq.kv_full > now:
                # a member's later layers are still crossing the wire:
                # layer l's attention runs only after layer l lands, so
                # the iteration drains at the pipelined finish time
                done_t = max(done_t, pipelined_finish(
                    now, dt, seq.kv_full, self.tx.n_layers))
            seq.kv_first = seq.kv_full = 0.0
        self._d_free[i] = done_t
        if self.tracer.enabled:
            self.tracer.complete("step", "decode_step", now, done_t,
                                 f"decode{i}", batch=len(batch), compute=dt)
        still = []
        for seq in batch:
            state = self._states[seq.rid]
            self._emit_token(state, seq.tokens[-1], done_t)
            if seq.done:
                self._finish_state(state, done_t)
                d.release(seq)
            else:
                still.append(seq)
        self._d_active[i] = still
        self._ev.push(done_t, "poke_decode", i)

    def _on_fail_decode(self, idx: int, t: float):
        lost = self.fail_decode(idx)
        # failover: re-prefill lost requests (keep generated tokens)
        for rid in lost:
            state = self._states[rid]
            if state.done:
                continue
            seq = state.seq
            self.decode[idx].release(seq)
            seq.done = False
            if not self._alive_p():         # nowhere to recover to
                self._finish_state(state, t, FINISH_FAILED)
                continue
            qi = self.dispatcher.pick_prefill(
                rid, self.queues, self._alive_p(),
                hits=self._prefill_hits(seq.tokens), now=t)
            self.queues[qi].push(seq)
            state.where = ("prefill", qi)
            state.to_status(RequestStatus.QUEUED)
            if self.tracer.enabled:
                self.tracer.phase(rid, "queued", t, f"prefill{qi}")
            self._ev.push(t, "poke_prefill", qi)
        self._d_active[idx] = []
        # also re-route ready-but-unpulled requests (drop the dead
        # instance's prefix pin; the new target re-pins its own)
        moved = [(st, pinned) for st, _skip, pinned in self._d_pending[idx]]
        moved += [(st, pinned) for st, _skip, pinned, _n
                  in self._d_granted[idx]]
        self._d_pending[idx] = []
        self._d_granted[idx] = []
        for state, pinned in moved:
            self.decode[idx].unpin(pinned)
            if self.tx.has_parked(state.rid):
                parked = self.tx.parked.pop(state.rid)
                self.tx._granted.pop(state.rid, None)
                self._ev.push(t, "dispatch_decode",
                              (state, parked.blob, parked.src))
            else:
                # streamed chunked prefill mid-flight: re-route the stream
                _di, src, _skip = self._stream.pop(state.rid)
                self.tx._granted.pop(state.rid, None)
                self._ev.push(t, "predispatch_decode", (state, src))

    # -- cancellation ----------------------------------------------------
    def _do_cancel(self, state: RequestState, t: float):
        """Release whatever this request holds at its current stage:
        QUEUED -> leave the FCFS queue; PREFILLING -> the in-flight
        dispatch event drops the blob; MIGRATING / PENDING_ADMIT ->
        unpark the transfer + drop the decode-side prefix pins;
        DECODING -> free the batch slot and every KV page."""
        seq = state.seq
        if state.status is RequestStatus.QUEUED and state.where is not None:
            _, qi = state.where
            self.queues[qi].remove(seq)
        elif state.status is RequestStatus.PREFILLING \
                and state.where is not None:
            # chunked prefill: the request may sit re-queued between
            # chunks with a reserved residency and a predispatched stream
            _, qi = state.where
            self.queues[qi].remove(seq)
            self.prefill[qi].abort_partial(seq)
            self._drop_stream(state, t)
            self._ev.push(t, "poke_prefill", qi)
        elif state.status in (RequestStatus.MIGRATING,
                              RequestStatus.PENDING_ADMIT):
            _, di = state.where
            pending = self._d_pending[di]
            for j, (st, _skip, pinned) in enumerate(pending):
                if st is state:
                    del pending[j]
                    self.decode[di].cancel(seq, pinned)
                    break
            for j, (st, _skip, pinned, n_res) in \
                    enumerate(self._d_granted[di]):
                if st is state:
                    del self._d_granted[di][j]
                    self.decode[di].unreserve(n_res)
                    self.decode[di].cancel(seq, pinned)
                    break
            p = self.tx.cancel(state.rid)   # drops chunk segments too
            if p is not None:
                release_blob(p.blob)        # drop prefill-side prefix pins
                if p.src < len(self.prefill):
                    self._ev.push(t, "poke_prefill", p.src)
            self._ev.push(t, "poke_decode", di)  # head may admit now
        elif state.status is RequestStatus.DECODING:
            _, di = state.where
            active = self._d_active[di]
            for j, s in enumerate(active):
                if s is seq:
                    del active[j]
                    break
            self.decode[di].cancel(seq)
            self._ev.push(t, "poke_decode", di)  # freed pages may admit

    # -- legacy closed-world shim ----------------------------------------
    def run(self, requests: List[Request],
            fail_decode_at: Optional[Tuple[float, int]] = None
            ) -> Dict[int, ServedResult]:
        """Submit-all-then-drain compatibility shim: drive a whole trace
        to completion on the virtual clock (pre-lifecycle behavior,
        byte-identical results on no-cancel traces)."""
        self._reset_loop()
        for r in requests:
            self.submit(r)
        if fail_decode_at is not None:
            self._ev.push(fail_decode_at[0], "fail_decode", fail_decode_at[1])
        return self.drain()

    # -- prefix-cache stats ----------------------------------------------
    def prefix_stats(self) -> Dict[str, Any]:
        """Aggregate radix-tree stats across the fleet (per-side)."""
        def agg(engines):
            out: Dict[str, float] = {}
            for e in engines:
                if not e.prefix_caching:
                    continue
                for k, v in e.prefix_cache.stats.as_dict().items():
                    out[k] = out.get(k, 0) + v
            return out
        return {"prefill": agg(self.prefill), "decode": agg(self.decode)}


class ColocatedCluster(_LiveBackend):
    """vLLM-like baseline: each engine runs prefill + decode interleaved
    with prefill priority (iteration-level batching).  Implements the
    same `ServingBackend` protocol (statuses skip MIGRATING /
    PENDING_ADMIT — nothing migrates in a colocated engine)."""

    def __init__(self, cfg, params, *, n_engines: int = 1, max_batch: int = 8,
                 max_len: int = 256, max_prefill_tokens: int = 512,
                 attn_blocks=(64, 64), page_size: int = 16,
                 num_pages: Optional[int] = None,
                 paged: Optional[bool] = None,
                 seed: int = 0, tracker=None, tracer=None,
                 charge=None, metrics=None):
        self._init_live(cfg, seed, tracker=tracker, tracer=tracer,
                        metrics=metrics)
        self.charge = charge
        self.engines = [Engine(cfg, params, max_batch=max_batch,
                               max_len=max_len, attn_blocks=attn_blocks,
                               paged=paged, page_size=page_size,
                               num_pages=num_pages)
                        for _ in range(n_engines)]
        self.max_prefill_tokens = max_prefill_tokens
        self._waiting = [FCFSQueue(token_of=lambda s: len(s.tokens))
                         for _ in self.engines]
        self._active: List[List[Sequence]] = [[] for _ in self.engines]
        self._free_at = [0.0] * n_engines
        if metrics is not None:
            metrics.register(self._collect_metrics)

    def _collect_metrics(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for i, e in enumerate(self.engines):
            for k, v in e.stats().items():
                out[f"engine{i}.{k}"] = v
            out[f"queue{i}.depth"] = len(self._waiting[i])
            out[f"queue{i}.tokens"] = self._waiting[i].queued_tokens
            out[f"engine{i}.active"] = len(self._active[i])
        return out

    def _reset_clocks(self):
        self._waiting = [FCFSQueue(token_of=lambda s: len(s.tokens))
                         for _ in self.engines]
        self._active = [[] for _ in self.engines]
        self._free_at = [0.0] * len(self.engines)

    # -- ServingBackend hooks -------------------------------------------
    def _do_submit(self, state: RequestState, t: float):
        self._make_sequence(state)
        self._ev.push(t, "arrive", state)

    def _handle(self, t: float, kind: str, payload: Any):
        if kind == "arrive":
            self._on_arrive(payload, t)
        elif kind == "poke":
            self._step_engine(payload, t)

    def _on_arrive(self, state: RequestState, t: float):
        if state.done:
            return
        i = least_loaded([len(self._waiting[j]) + len(self._active[j])
                          for j in range(len(self.engines))])
        self._waiting[i].push(state.seq)
        state.where = ("engine", i)
        if self.tracer.enabled:
            self.tracer.phase(state.rid, "queued", t, f"engine{i}")
        self._ev.push(t, "poke", i)

    def _step_engine(self, i: int, now: float):
        if self._free_at[i] > now:
            self._ev.push(self._free_at[i], "poke", i)
            return
        e = self.engines[i]
        # prefill priority; page-aware admission via the shared core
        batch = self._waiting[i].form_batch(self.max_prefill_tokens,
                                            max_batch=1, can_take=e.can_admit)
        if batch:
            seq = batch[0]
            state = self._states[seq.rid]
            state.to_status(RequestStatus.PREFILLING)
            req = state.request
            first, blob, dt = e.prefill_request(seq)
            if self.charge is not None:
                dt = self.charge.prefill([len(seq.tokens) - seq.prefix_hit])
            if self.tracer.enabled:
                self.tracer.phase(seq.rid, "prefilling", now, f"engine{i}")
                self.tracer.complete(
                    "compute", "prefill_batch", now, now + dt,
                    f"engine{i}", rid=seq.rid,
                    tokens=len(seq.tokens) - seq.prefix_hit,
                    hit=seq.prefix_hit)
            seq.append_token(first)
            req.first_token = now + dt
            self._emit_token(state, first, now + dt)
            e.insert_kv(seq, blob)
            if seq.done:
                e.release(seq)
                self._finish_state(state, now + dt)
            else:
                state.to_status(RequestStatus.DECODING)
                if self.tracer.enabled:
                    self.tracer.phase(seq.rid, "decoding", now + dt,
                                      f"engine{i}")
                self._active[i].append(seq)
            self._free_at[i] = now + dt
            self._ev.push(now + dt, "poke", i)
            return
        if self._active[i]:
            batch2 = self._active[i]
            ctx_tokens = sum(len(s.tokens) - 1 for s in batch2)
            dt = e.decode_step(batch2)
            if self.charge is not None:
                dt = self.charge.decode(len(batch2), ctx_tokens)
            done_t = now + dt
            if self.tracer.enabled:
                self.tracer.complete("step", "decode_step", now, done_t,
                                     f"engine{i}", batch=len(batch2),
                                     compute=dt)
            still = []
            for seq in batch2:
                state = self._states[seq.rid]
                self._emit_token(state, seq.tokens[-1], done_t)
                if seq.done:
                    e.release(seq)
                    self._finish_state(state, done_t)
                else:
                    still.append(seq)
            self._active[i] = still
            self._free_at[i] = done_t
            self._ev.push(done_t, "poke", i)

    # -- cancellation ----------------------------------------------------
    def _do_cancel(self, state: RequestState, t: float):
        seq = state.seq
        if state.where is None:
            return
        _, i = state.where
        if state.status is RequestStatus.QUEUED:
            self._waiting[i].remove(seq)
            return
        active = self._active[i]
        for j, s in enumerate(active):
            if s is seq:
                del active[j]
                break
        self.engines[i].cancel(seq)
        self._ev.push(t, "poke", i)

    # -- legacy closed-world shim ----------------------------------------
    def run(self, requests: List[Request]) -> Dict[int, ServedResult]:
        self._reset_loop()
        for r in requests:
            self.submit(r)
        return self.drain()
