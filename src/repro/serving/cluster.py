"""Role-unified live serving cluster on real JAX engines with
virtual-clock concurrency emulation (DistServe runtime, Fig. 6, extended
with runtime aggregation<->disaggregation).

`ServingCluster` holds N engine-backed instances, each carrying a *role*
-- ``"prefill"``, ``"decode"`` or ``"mixed"`` -- instead of the role
being baked into the class. A disaggregated deployment is a
prefill+decode role vector; the colocated (vLLM-like) baseline is the
degenerate "all instances mixed" case. `DisaggCluster` /
`ColocatedCluster` remain as thin shims that translate their legacy
constructor signatures into role vectors and produce byte-identical
schedules, token streams and dispatch decisions.

On top of the static roles (mirroring `core.simulator.SimServingBackend`,
the discrete-event twin of this class):

* `set_role(g, role)` flips an instance at runtime. The instance leaves
  the routing views immediately; queued-but-unstarted work is re-routed
  through the shared dispatcher; resident work (running decodes,
  granted/streaming KV, partial chunks) drains in place and the flip
  completes when the instance is idle -- a decode->prefill flip never
  strands or leaks KV pages; a prefill->decode flip drains within one
  batch/chunk time.
* chunked-prefill *absorption*: when every routable prefill queue is
  deeper than ``absorb_tokens``, new prompts spill to a decode/mixed
  instance which prefills them locally in bounded chunks between decode
  iterations (`Engine.prefill_chunk` in-place page writes; the KV never
  crosses the wire).

Both paths implement the `serving.api.ServingBackend` protocol: arrivals
are external submissions (`submit` returns a `ServeHandle`), the event
loop advances via `step` / `run_until(t)` / `drain()`, and every request
walks the `RequestStatus` state machine.  Controller: FCFS arrival queue
-> shortest-queue prefill dispatch -> pull-based, page-granular KV
migration -> least-loaded decode dispatch, all through the shared
scheduler core in `core.scheduler` (the same code the simulator runs).
Cancellation at any stage releases pages, prefix pins, and parked
transfer bytes without leaking.  Fault injection hooks exercise the
failover paths in core.fault.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence as Seq, Tuple

import numpy as np

from ..core.fault import HeartbeatMonitor
from ..core.kv_transfer import TransferManager, kv_bytes, pipelined_finish
from ..core.scheduler import DisaggDispatcher, FCFSQueue, least_loaded
from ..core.workload import Request
from .api import (FINISH_FAILED, GREEDY, BackendBase, RequestState,
                  RequestStatus, ServedResult, sequence_tokens)
from .engine import Engine, KVBlob, Sequence, release_blob

__all__ = ["ServingCluster", "DisaggCluster", "ColocatedCluster",
           "ServedResult"]


def _page_bytes(cfg, page_size: int, dtype_bytes: int = 2) -> Optional[int]:
    per_tok = cfg.kv_bytes_per_token(dtype_bytes)
    return per_tok * page_size if per_tok else None


def _slice_blob(blob, skip_tokens: int):
    """Drop the first `skip_tokens` positions from a migration blob — the
    decode side already holds that prefix, so only the suffix ships."""
    cache, n_tok = blob
    if not skip_tokens:
        return blob
    sliced = {k: ({"k": v["k"][:, :, skip_tokens:],
                   "v": v["v"][:, :, skip_tokens:]}
                  if k.startswith("seg") else v)
              for k, v in cache.items()}
    return sliced, n_tok


class _LiveBackend(BackendBase):
    """Sequence construction shared with pre-unification code paths."""

    def _init_live(self, cfg, seed: int, tracker=None, tracer=None,
                   metrics=None):
        self.cfg = cfg
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        self._init_backend(tracker=tracker, tracer=tracer, metrics=metrics)

    def _reset_loop(self):
        """Fresh event loop, virtual clocks, and token rng (the legacy
        `run` contract: every replay of the same trace restarts at t=0
        and derives identical token streams)."""
        self._rng = np.random.default_rng(self.seed)
        self._init_backend(tracker=self.tracker,
                           tracer=self.tracer or None, metrics=self.metrics)
        self._reset_clocks()

    def _reset_clocks(self):
        raise NotImplementedError

    def _make_sequence(self, state: RequestState) -> Sequence:
        r, sp = state.request, state.sampling
        seq = Sequence(r.rid, sequence_tokens(self.cfg, r, self._rng),
                       sp.out_len(r.out_len),
                       sampling=None if sp == GREEDY else sp)
        state.seq = seq
        return seq


def _prefill_tok(s: Sequence) -> int:
    # queue load = tokens still to prefill (partial prompts re-queue
    # with their remaining suffix only)
    return max(len(s.tokens) - s.prefilled, 0)


def _mixed_tok(s: Sequence) -> int:
    return len(s.tokens)


class _LiveInstance:
    """Per-instance runtime state; `role` decides which containers are
    live. The engine and the birth `label` survive role flips (tracer
    lanes stay stable); the role-local `iid` is reassigned per flip (it
    keys transfer links and fresh metric rows, mirroring the simulator's
    twin-object iids)."""

    def __init__(self, gid: int, role: str, iid: int, engine: Engine,
                 label: str):
        self.gid = gid
        self.role = role
        self.iid = iid
        self.engine = engine
        self.label = label
        self.draining = False
        self.target: Optional[str] = None
        self.failed = False
        self.free_at = 0.0                  # virtual busy-until clock
        # prefill-role
        self.queue: FCFSQueue = FCFSQueue(token_of=_prefill_tok)
        # decode-role
        self.active: List[Sequence] = []
        # (state, skip_tokens, pinned_pages) awaiting decode admission
        self.pending: List[Tuple[RequestState, int, List[int]]] = []
        # (state, skip, pinned, reserved_pages): streamed chunked prefills
        # whose residency is granted, waiting for the final chunk to land
        self.granted: List[Tuple[RequestState, int, List[int], int]] = []
        # mixed-role
        self.waiting: FCFSQueue = FCFSQueue(token_of=_mixed_tok)
        # chunked-prefill absorption (decode-role intra-instance
        # aggregation): whole prompts spilled here under prefill bursts
        self.absorb: FCFSQueue = FCFSQueue(token_of=_prefill_tok)
        self.absorbing: set = set()         # rids mid-absorption

    @property
    def load(self) -> int:
        if self.role == "mixed":
            return len(self.waiting) + len(self.active)
        n = len(self.active) + len(self.pending) + len(self.granted)
        if self.absorb.items or self.absorbing:
            n += len(self.absorbing | {s.rid for s in self.absorb.items})
        return n

    def clear(self):
        self.free_at = 0.0
        self.active = []
        self.pending = []
        self.granted = []
        self.queue = FCFSQueue(token_of=_prefill_tok)
        self.waiting = FCFSQueue(token_of=_mixed_tok)
        self.absorb = FCFSQueue(token_of=_prefill_tok)
        self.absorbing = set()


class ServingCluster(_LiveBackend):
    """N role-carrying live engines behind one virtual-clock event loop
    (see the module docstring for semantics)."""

    def __init__(self, cfg, params, roles: Seq[str], *,
                 max_batch: int = 8, max_len: int = 256,
                 transfer_bandwidth: float = 50e9, lm_tokens: int = 256,
                 max_prefill_tokens: int = 512,
                 attn_blocks=(64, 64), page_size: int = 16,
                 decode_num_pages: Optional[int] = None,
                 num_pages: Optional[int] = None,
                 paged: Optional[bool] = None,
                 prefix_cache: bool = False,
                 prefill_num_pages: Optional[int] = None,
                 fused_prefix: Optional[bool] = None,
                 chunk_tokens=None,
                 absorb_tokens: Optional[int] = None,
                 seed: int = 0, tracker=None, tracer=None,
                 charge=None, metrics=None):
        self._init_live(cfg, seed, tracker=tracker, tracer=tracer,
                        metrics=metrics)
        # optional deterministic charge model: replace measured kernel
        # times with `core.latency_model.EngineCharge` analytic times, so
        # the live event timeline (and trace) is float-identical to the
        # simulator's on the same request trace
        self.charge = charge
        if (prefix_cache or chunk_tokens) and prefill_num_pages is None:
            # a prefill engine's default pool (one resident sequence) has
            # no room to retain prefixes or to hold several chunked
            # prompts' reserved residencies; keep a few sequences' worth
            prefill_num_pages = 8 * -(-max_len // page_size) + 1
        self.prefix_cache = prefix_cache
        base = dict(max_len=max_len, attn_blocks=attn_blocks, paged=paged,
                    page_size=page_size)
        # engines are shaped by their *birth* role (legacy-identical
        # configs for the shims); a flipped instance keeps its engine, so
        # dynamic deployments should size pools for both roles
        self._engine_kw = {
            "prefill": dict(base, max_batch=1, num_pages=prefill_num_pages,
                            prefix_cache=prefix_cache,
                            fused_prefix=fused_prefix),
            "decode": dict(base, max_batch=max_batch,
                           num_pages=decode_num_pages,
                           prefix_cache=prefix_cache),
            "mixed": dict(base, max_batch=max_batch, num_pages=num_pages),
        }
        self._params = params
        self.inst: List[_LiveInstance] = []
        self._iid_next = {"prefill": 0, "decode": 0, "mixed": 0}
        self.monitor = HeartbeatMonitor(timeout=1e9)
        for role in roles:
            self.inst.append(self._make_instance(role))
        # chunked prefill needs the paged runtime (in-place page writes);
        # chunk_tokens="auto" sizes the chunk from the latency model (the
        # live cluster reaches the model through its EngineCharge)
        if chunk_tokens == "auto":
            if charge is None:
                raise ValueError("chunk_tokens='auto' needs a "
                                 "charge=EngineCharge(lm, par) model")
            chunk_tokens = charge.lm.auto_chunk_tokens(
                charge.par, page_tokens=page_size)
        p0 = next((x for x in self.inst if x.role == "prefill"), None)
        self.chunk_tokens = (chunk_tokens if chunk_tokens and p0 is not None
                             and p0.engine.paged else None)
        # absorption: spill whole prompts to decode/mixed instances when
        # every routable prefill queue is deeper than absorb_tokens
        self.absorb_tokens = absorb_tokens
        self._absorb_chunk = self.chunk_tokens or chunk_tokens
        if absorb_tokens is not None and not self._absorb_chunk \
                and charge is not None:
            self._absorb_chunk = charge.lm.auto_chunk_tokens(
                charge.par, page_tokens=page_size)
        self.dispatcher = DisaggDispatcher()
        self.tx = TransferManager(transfer_bandwidth,
                                  page_bytes=_page_bytes(cfg, page_size),
                                  n_layers=cfg.num_layers)
        self.lm_tokens = lm_tokens
        self.max_prefill_tokens = max_prefill_tokens
        self.failed_prefill: set = set()
        self.failed_decode: set = set()
        # rid -> (decode_inst, src_inst, skip): streamed-migration route
        # chosen at first-chunk completion
        self._stream: Dict[int, Tuple[_LiveInstance, _LiveInstance,
                                      int]] = {}
        self._backlog: List[RequestState] = []  # arrivals held mid-re-role
        self._role_events: List[Tuple[float, str, str]] = []
        self.absorbed = 0
        self.busy_absorb = 0.0
        if self.tracer.enabled:
            self.tx.tracer = self.tracer
            self.dispatcher.tracer = self.tracer
        if metrics is not None:
            metrics.register(self._collect_metrics)

    # -- instance construction / role views ------------------------------
    def _make_instance(self, role: str) -> _LiveInstance:
        if role not in self._engine_kw:
            raise ValueError(f"unknown role {role!r}")
        iid = self._iid_next[role]
        self._iid_next[role] += 1
        label = f"engine{iid}" if role == "mixed" else f"{role}{iid}"
        engine = Engine(self.cfg, self._params, **self._engine_kw[role])
        x = _LiveInstance(len(self.inst), role, iid, engine, label)
        self.monitor.register(label)
        return x

    def _role(self, role: str) -> List[_LiveInstance]:
        return [x for x in self.inst if x.role == role]

    @property
    def roles(self) -> List[str]:
        return [x.role for x in self.inst]

    # engine-list views (legacy attribute compatibility)
    @property
    def prefill(self) -> List[Engine]:
        return [x.engine for x in self._role("prefill")]

    @property
    def decode(self) -> List[Engine]:
        return [x.engine for x in self._role("decode")]

    @property
    def engines(self) -> List[Engine]:
        return [x.engine for x in self._role("mixed")]

    @property
    def queues(self) -> List[FCFSQueue]:
        return [x.queue for x in self._role("prefill")]

    def _collect_metrics(self) -> Dict[str, float]:
        """Pull-collector for a `MetricsRegistry`: per-engine dispatch and
        page-pool stats, queue depths, transfer-manager totals. Key names
        stay byte-identical to the legacy per-class collectors for static
        role vectors."""
        out: Dict[str, float] = {}
        P, D, E = (self._role("prefill"), self._role("decode"),
                   self._role("mixed"))
        for side, lst in (("prefill", P), ("decode", D)):
            for i, x in enumerate(lst):
                for k, v in x.engine.stats().items():
                    out[f"{side}{i}.{k}"] = v
        for i, x in enumerate(P):
            out[f"queue{i}.depth"] = len(x.queue)
            out[f"queue{i}.tokens"] = x.queue.queued_tokens
        if P or D:
            for k, v in self.tx.stats().items():
                out[f"tx.{k}"] = v
            out["decode_pending"] = sum(len(x.pending) for x in D)
            out["decode_granted"] = sum(len(x.granted) for x in D)
            out["decode_active"] = sum(len(x.active) for x in D)
        for i, x in enumerate(E):
            for k, v in x.engine.stats().items():
                out[f"engine{i}.{k}"] = v
            # pure-colocated fleets keep the legacy queue{i} keys; mixed
            # fleets with a prefill tier would collide, so nest them
            qk = f"engine{i}.queue" if (P or D) else f"queue{i}"
            out[f"{qk}.depth"] = len(x.waiting)
            out[f"{qk}.tokens"] = x.waiting.queued_tokens
            out[f"engine{i}.active"] = len(x.active)
        if self._role_events:        # dynamic fleets: expose role ids
            ids = {"prefill": 0.0, "decode": 1.0, "mixed": 2.0}
            for x in self.inst:
                out[f"{x.label}.role_id"] = ids[x.role]
                out[f"{x.label}.draining"] = float(x.draining)
            out["role_changes"] = float(len(self._role_events))
            out["absorbed"] = float(self.absorbed)
        return out

    # -- fault injection ------------------------------------------------
    def fail_decode(self, idx: int) -> List[int]:
        """Kill a decode instance; returns rids needing re-prefill."""
        d = self._role("decode")[idx]
        self.monitor.mark_failed(d.label)
        self.failed_decode.add(idx)
        d.failed = True
        # `_active` may predate the latest iteration's completion filter —
        # sequences that already finished are not lost
        lost = [s.rid for s in getattr(d.engine, "_active", [])
                if not s.done]
        return lost

    def fail_prefill(self, idx: int) -> List[int]:
        p = self._role("prefill")[idx]
        self.monitor.mark_failed(p.label)
        self.failed_prefill.add(idx)
        p.failed = True
        return [s.rid for s in p.queue.items]

    def _reset_clocks(self):
        for x in self.inst:
            x.clear()
        self._stream = {}
        self._backlog = []

    def _prefill_hits(self, tokens):
        if not self.prefix_cache:
            return None
        return [x.engine.prefix_peek(tokens) for x in self._role("prefill")]

    # -- ServingBackend hooks -------------------------------------------
    def _do_submit(self, state: RequestState, t: float):
        self._make_sequence(state)
        self._ev.push(t, "arrive", state)

    def _handle(self, t: float, kind: str, payload: Any):
        if kind == "arrive":
            self._on_arrive(payload, t)
        elif kind == "poke_prefill":
            self._poke_prefill(payload, t)
        elif kind == "dispatch_decode":
            self._on_dispatch_decode(payload, t)
        elif kind == "predispatch_decode":
            self._on_predispatch(payload, t)
        elif kind == "finalize_stream":
            self._on_finalize_stream(payload, t)
        elif kind == "poke_decode":
            self._poke_decode(payload, t)
        elif kind == "poke":
            self._step_engine(payload, t)
        elif kind == "fail_decode":
            self._on_fail_decode(payload, t)

    # -- arrival routing -------------------------------------------------
    def _on_arrive(self, state: RequestState, t: float):
        if state.done:                      # cancelled before arrival
            return
        seq = state.seq
        P = self._role("prefill")
        alive = [j for j, x in enumerate(P)
                 if not x.failed and not x.draining]
        if not alive:
            # no routable prefill tier: colocated (all-mixed) deployment,
            # or a transient all-decode fleet -> absorb everywhere
            E = [x for x in self._role("mixed") if not x.draining]
            D_abs = [x for x in self._absorb_targets()
                     if x.role == "decode"]
            if E and not (self.absorb_tokens is not None and D_abs):
                self._mixed_arrive(state, t)
            elif not self._route_absorb(state, t):
                if any(x.target is not None for x in self.inst):
                    # mid-re-role transient: every sink is draining. Hold
                    # the arrival; `_complete_flip` re-dispatches it.
                    self._backlog.append(state)
                    state.where = ("backlog", None)
                    if self.tracer.enabled:
                        self.tracer.phase(state.rid, "queued", t, "backlog")
                    return
                raise RuntimeError(
                    "no routable prefill/mixed instance and absorption "
                    "is unavailable")
            return
        if (self.absorb_tokens is not None
                and min(P[j].queue.queued_tokens for j in alive)
                > self.absorb_tokens
                and self._route_absorb(state, t)):
            return
        qi = self.dispatcher.pick_prefill(state.rid, [x.queue for x in P],
                                          alive,
                                          hits=self._prefill_hits(seq.tokens),
                                          now=t)
        p = P[qi]
        p.queue.push(seq)
        state.where = ("prefill", p)
        if self.tracer.enabled:
            self.tracer.phase(state.rid, "queued", t, p.label)
        self._ev.push(t, "poke_prefill", p)

    def _absorb_targets(self) -> List[_LiveInstance]:
        """Instances that can take a whole prompt when the prefill tier is
        saturated: paged decode instances with chunk machinery, mixed
        engines."""
        out: List[_LiveInstance] = []
        for x in self.inst:
            if x.draining or x.failed:
                continue
            if x.role == "decode" and self._absorb_chunk \
                    and x.engine.paged:
                out.append(x)
            elif x.role == "mixed":
                out.append(x)
        return out

    def _route_absorb(self, state: RequestState, t: float) -> bool:
        targets = self._absorb_targets()
        if not targets:
            return False
        seq = state.seq
        loads = [float(x.load) for x in targets]
        ai = self.dispatcher.pick_absorb(state.rid, loads, now=t)
        x = targets[ai]
        self.absorbed += 1
        if x.role == "mixed":
            x.waiting.push(seq)
            state.where = ("engine", x)
            if self.tracer.enabled:
                self.tracer.phase(state.rid, "queued", t, x.label)
            self._ev.push(t, "poke", x)
        else:
            x.absorb.push(seq)
            state.where = ("absorb", x)
            if self.tracer.enabled:
                self.tracer.phase(state.rid, "queued", t, x.label)
            self._ev.push(t, "poke_decode", x)
        return True

    def _mixed_arrive(self, state: RequestState, t: float):
        E = [x for x in self._role("mixed") if not x.draining]
        e = E[least_loaded([x.load for x in E])]
        e.waiting.push(state.seq)
        state.where = ("engine", e)
        if self.tracer.enabled:
            self.tracer.phase(state.rid, "queued", t, e.label)
        self._ev.push(t, "poke", e)

    # -- prefill role -----------------------------------------------------
    def _poke_prefill(self, p: _LiveInstance, now: float):
        if p.role != "prefill" or p.failed:
            return
        if not p.queue.items:
            self._check_flip(p, now)
            return
        if p.free_at > now:                 # busy: come back when free
            self._ev.push(p.free_at, "poke_prefill", p)
            return
        if self.chunk_tokens:
            self._prefill_chunk_step(p, now)
            return
        batch = p.queue.form_batch(self.lm_tokens, max_batch=1)
        for seq in batch:
            state = self._states[seq.rid]
            state.to_status(RequestStatus.PREFILLING)
            req = state.request
            first, blob, dt = p.engine.prefill_request(seq)
            if self.charge is not None:
                dt = self.charge.prefill([len(seq.tokens) - seq.prefix_hit])
            if self.tracer.enabled:
                self.tracer.phase(seq.rid, "prefilling", now, p.label)
                self.tracer.complete(
                    "compute", "prefill_batch", now, now + dt,
                    p.label, rid=seq.rid,
                    tokens=len(seq.tokens) - seq.prefix_hit,
                    hit=seq.prefix_hit)
            seq.append_token(first)
            req.first_token = now + dt
            self._emit_token(state, first, now + dt)
            if seq.done:
                release_blob(blob)      # nothing will migrate: drop pins
                self._finish_state(state, now + dt)
            else:
                # decode target (and hence shipped bytes) is chosen at
                # dispatch time, where the decode-side prefix is known
                self._ev.push(now + dt, "dispatch_decode", (state, blob, p))
            p.free_at = now + dt
            self._ev.push(now + dt, "poke_prefill", p)

    def _prefill_chunk_step(self, p: _LiveInstance, now: float):
        """One chunk of the head-of-queue prompt. Unfinished prompts
        re-queue at the tail (chunk-granular round-robin), each finished
        chunk's KV is parked as a shippable segment, and the decode
        target is chosen at *first*-chunk completion so the wire can
        overlap the remaining chunks' compute."""
        e = p.engine
        # a page-blocked *new* head must not strand the resumable partials
        # queued behind it: their reservations free only by finishing, so
        # form_batch may drain them past the head (retry for the head
        # arrives via the poke each pull/finish schedules)
        batch = p.queue.form_batch(
            self.lm_tokens, max_batch=1, can_take=e.can_start_chunked,
            chunk_tokens=self.chunk_tokens, resumable=e.has_partial)
        if not batch:
            return
        seq = batch[0]
        state = self._states[seq.rid]
        req = state.request
        state.to_status(RequestStatus.PREFILLING)
        prev = seq.prefilled
        done, first, blob, dt, _c = e.prefill_chunk(seq, self.chunk_tokens)
        if self.charge is not None:
            dt = self.charge.chunk(_c, prev)
        t_end = now + dt
        if self.tracer.enabled:
            self.tracer.phase(seq.rid, "prefilling", now, p.label)
            self.tracer.complete("compute", "chunk", now, t_end,
                                 p.label, rid=seq.rid,
                                 tokens=_c, ctx=prev)
        state.progress = seq.prefilled
        seg_bytes = kv_bytes(self.cfg, seq.prefilled) - \
            (kv_bytes(self.cfg, prev) if prev else 0)
        self.tx.park_partial(seq.rid, max(seg_bytes, 0), t_end)
        if not done:
            p.queue.push(seq)
            state.where = ("prefill", p)
            if seq.rid not in self._stream:
                self._ev.push(t_end, "predispatch_decode", (state, p))
        else:
            seq.append_token(first)
            req.first_token = t_end
            self._emit_token(state, first, t_end)
            if seq.done:                    # out_len == 1 / instant stop
                release_blob(blob)
                self._drop_stream(state, t_end)
                self.tx.drop_partial(seq.rid)
                self._finish_state(state, t_end)
            elif seq.rid in self._stream:
                self._ev.push(t_end, "finalize_stream", (state, blob))
            else:                           # single-chunk prompt
                self._ev.push(t_end, "dispatch_decode", (state, blob, p))
        p.free_at = t_end
        self._ev.push(t_end, "poke_prefill", p)

    # -- prefill -> decode handoff ----------------------------------------
    def _decode_cands(self, D: List[_LiveInstance]) -> List[int]:
        """Routable decode indices. Draining instances still accept work
        finished on a prefill instance when nothing else can (their flip
        waits for load to reach zero)."""
        cand = [j for j, x in enumerate(D)
                if not x.failed and not x.draining]
        return cand or [j for j, x in enumerate(D) if not x.failed]

    def _on_predispatch(self, payload, t: float):
        """First chunk landed: pick the decode target now so segments can
        be granted pages and start crossing the wire while later chunks
        are still computing."""
        state, src = payload
        if state.done or state.rid in self._stream:
            return
        seq, req = state.seq, state.request
        n_tok = len(seq.tokens)
        D = self._role("decode")
        cand = self._decode_cands(D)
        if not cand:        # aggregation drain: adopt at the final chunk
            return
        loads = [x.load for x in D]
        d_hits = None
        if self.prefix_cache:
            d_hits = [x.engine.prefix_peek(seq.tokens[:n_tok]) for x in D]
        di = self.dispatcher.pick_decode(req.rid, loads, cand, hits=d_hits,
                                         now=t)
        d = D[di]
        skip, pinned = d.engine.pin_prefix(seq.tokens[:n_tok])
        self._stream[state.rid] = (d, src, skip)
        d.pending.append((state, skip, pinned))
        self._ev.push(t, "poke_decode", d)

    def _on_finalize_stream(self, payload, t: float):
        """Final chunk landed: close the stream — park the page-backed
        blob with the decode-side ship size; admission (or the earlier
        grant) pulls the per-segment schedule."""
        state, blob = payload
        if state.done:                      # cancelled mid-final-chunk
            release_blob(blob)
            self.tx.drop_partial(state.rid)
            return
        if state.rid not in self._stream:
            # a decode-failure re-route (_on_fail_decode) reclaimed the
            # stream at this same timestamp and queued a fresh
            # predispatch behind this event; defer until that lands and
            # re-establishes the route
            self._ev.push(t, "finalize_stream", (state, blob))
            return
        d, src, skip = self._stream.pop(state.rid)
        seq = state.seq
        ship = blob.n_tok - skip
        nbytes = kv_bytes(self.cfg, ship) if ship else 0
        self.tx.park(seq.rid, blob, nbytes, t, src=src.iid)
        state.where = ("decode", d)
        state.to_status(RequestStatus.MIGRATING)
        if self.tracer.enabled:
            self.tracer.phase(seq.rid, "migrating", t, d.label)
        self._ev.push(t, "poke_decode", d)

    def _drop_stream(self, state: RequestState, t: float):
        """Remove every trace of a streamed chunked migration: the chosen
        route, the pending/granted decode-side entry (pins + page
        reservation), and the parked chunk segments."""
        rid = state.rid
        self.tx.drop_partial(rid)
        info = self._stream.pop(rid, None)
        if info is None:
            return
        d, _src, _skip = info
        for j, entry in enumerate(d.pending):
            if entry[0] is state:
                del d.pending[j]
                d.engine.unpin(entry[2])
                break
        for j, entry in enumerate(d.granted):
            if entry[0] is state:
                del d.granted[j]
                d.engine.unpin(entry[2])
                if not d.failed:
                    d.engine.unreserve(entry[3])
                break
        self._ev.push(t, "poke_decode", d)

    def _poke_src(self, src_iid: int, now: float):
        """The pull released prefill-side pages: a stalled chunked
        prefill may be able to start its next prompt now. Transfer links
        key on role-local iids; a source that has since flipped away
        needs no poke."""
        pk = next((x for x in self._role("prefill") if x.iid == src_iid),
                  None)
        if pk is not None:
            self._ev.push(now, "poke_prefill", pk)

    def _engine_adopt(self, state: RequestState, blob, now: float):
        """No decode-role instance remains (an aggregation re-role
        overlapped in-flight prefill work): hand the finished prefill
        straight to a mixed engine's batch. The KV is spliced locally;
        wire time is charged as zero — this only occurs in the drain
        transient."""
        E = [x for x in self._role("mixed") if not x.draining] \
            or self._role("mixed")
        seq, req = state.seq, state.request
        e = E[least_loaded([x.load for x in E])]
        wire = blob.owner.materialize_wire(blob, 0) \
            if isinstance(blob, KVBlob) else blob
        e.engine.insert_kv(seq, wire)
        self.tx.drop_partial(seq.rid)
        req.decode_admit = now
        req.transfer_done = now
        state.where = ("engine", e)
        state.to_status(RequestStatus.DECODING)
        if self.tracer.enabled:
            self.tracer.phase(seq.rid, "decoding", now, e.label)
        e.active.append(seq)
        self._ev.push(now, "poke", e)

    def _on_dispatch_decode(self, payload, t: float):
        state, blob, src = payload
        if state.done:                      # cancelled mid-prefill: the
            release_blob(blob)              # blob is dropped (fused blobs
            return                          # release their prefix pins)
        seq, req = state.seq, state.request
        D = self._role("decode")
        if not D:                           # aggregation drain transient
            self._engine_adopt(state, blob, t)
            return
        cand = self._decode_cands(D)
        loads = [x.load for x in D]
        n_tok = blob[1]
        d_hits = None
        if self.prefix_cache:
            d_hits = [x.engine.prefix_peek(seq.tokens[:n_tok]) for x in D]
        di = self.dispatcher.pick_decode(req.rid, loads, cand, hits=d_hits,
                                         now=t)
        d = D[di]
        # pin the decode-resident prefix and ship only the rest
        skip, pinned = d.engine.pin_prefix(seq.tokens[:n_tok])
        ship = n_tok - skip
        nbytes = kv_bytes(self.cfg, ship) if ship else 0
        src_iid = src.iid if isinstance(src, _LiveInstance) else src
        self.tx.park(seq.rid, blob, nbytes, t, src=src_iid)
        d.pending.append((state, skip, pinned))
        state.where = ("decode", d)
        state.to_status(RequestStatus.MIGRATING)
        if self.tracer.enabled:
            self.tracer.phase(seq.rid, "migrating", t, d.label)
        self._ev.push(t, "poke_decode", d)

    # -- decode role ------------------------------------------------------
    def _admit_one(self, d: _LiveInstance, state: RequestState, skip: int,
                   pinned: List[int], now: float):
        """Pull one parked request's KV over the wire and splice it in.
        `pull_streamed` charges the per-segment schedule for chunked
        streams and degenerates to the per-layer schedule for whole-blob
        parks."""
        seq, req = state.seq, state.request
        src = self.tx.parked[seq.rid].src
        blob, t_first, t_full = self.tx.pull_streamed(seq.rid, now,
                                                      dst=d.iid)
        if isinstance(blob, KVBlob):
            # page-backed blob: the prefill engine stitches the wire
            # payload from its page pool (and drops its pins)
            wire = blob.owner.materialize_wire(blob, skip)
        else:
            wire = _slice_blob(blob, skip)
        d.engine.insert_kv(seq, wire, shared=pinned, skip_tokens=skip)
        d.engine.unpin(pinned)
        # per-layer streaming: decode starts attending once the first
        # layer of the last chunk lands, not at blob-complete; a granted
        # stream's wire may have finished during prefill (t_full < now),
        # so both marks clamp forward to keep the timeline monotone
        seq.kv_first = max(now, t_first)
        seq.kv_full = max(t_full, seq.kv_first)
        req.decode_admit = seq.kv_first
        req.transfer_done = seq.kv_full
        state.to_status(RequestStatus.DECODING)
        if self.tracer.enabled:
            # decode starts attending at first-layer-landed, the same
            # instant the simulator stamps `decode_admit`
            self.tracer.phase(seq.rid, "decoding", seq.kv_first, d.label)
        d.active.append(seq)
        self._poke_src(src, now)

    def _poke_decode(self, d: _LiveInstance, now: float):
        if d.role != "decode" or d.failed:
            return
        if d.free_at > now:
            self._ev.push(d.free_at, "poke_decode", d)
            return
        e = d.engine
        pending = d.pending
        granted = d.granted

        # pull-based admission against free KV pages (paper §4.3);
        # shared prefix pages are already resident, so only the
        # suffix needs fresh pages
        def admit_ready():
            # granted streams whose final chunk has landed insert first
            # (their pages are already held; the wire has been moving
            # since the grant)
            progress = True
            while progress:
                progress = False
                for j, (state, skip, pinned, n_res) in enumerate(granted):
                    if self.tx.has_parked(state.rid):
                        del granted[j]
                        e.unreserve(n_res)
                        self._admit_one(d, state, skip, pinned, now)
                        progress = True
                        break
            while pending:
                state, skip, pinned = pending[0]
                if d.absorbing and len(d.active) + len(d.absorbing) \
                        >= e.max_batch:
                    break       # absorbed residents hold future slots
                if not e.can_admit(state.seq, len(pinned)):
                    break
                pending.pop(0)
                if not self.tx.has_parked(state.rid):
                    # streamed chunked prefill still computing: grant its
                    # residency so parked segments start crossing now
                    n_res = e.reserve_for(state.seq, len(pinned))
                    self.tx.grant(state.rid, now)
                    granted.append((state, skip, pinned, n_res))
                    continue
                self._admit_one(d, state, skip, pinned, now)

        admit_ready()
        if pending and not d.active and not granted:
            # liveness fallback: nothing is running (so no future poke
            # will fire) and the head still can't admit — its eviction
            # is blocked by pages pinned for *later* pending requests.
            # Drop every pin (those requests fall back to a full-blob
            # transfer); with no pins and nothing running, the head's
            # residency always fits after LRU eviction.
            for j, (state, _skip, pinned) in enumerate(pending):
                e.unpin(pinned)
                pending[j] = (state, 0, [])
            admit_ready()
        # amortized marking: entries append at the tail, marked ones
        # accumulate at the front (see the simulator twin); streamed
        # entries stay PREFILLING-with-progress until their final chunk
        for state, _skip, _pinned in reversed(pending):
            if state.status is RequestStatus.PENDING_ADMIT:
                break
            if state.status is RequestStatus.MIGRATING:
                state.to_status(RequestStatus.PENDING_ADMIT)
                if self.tracer.enabled:
                    self.tracer.phase(state.rid, "pending_admit", now,
                                      d.label)
        # absorbed prompts chunk-prefill between decode iterations
        # (prefill-priority, like a mixed engine; the chunk size bounds
        # the decode stall)
        if d.absorb.items and self._absorb_chunk:
            if self._absorb_step(d, now):
                return
        e._active = d.active
        if not d.active:
            self._check_flip(d, now)
            return
        # Under a virtual clock, streamed migrants join the batch only
        # once their first layer has landed (the simulator admits at
        # `transfer_first`); until then they hold pages but must not
        # stall batchmates. Without a charge the engine's KV is
        # physically resident the moment `_admit_one` spliced it, so
        # membership stays immediate (anything else would change batch
        # groupings and thus the token stream) and the modeled landing
        # time is charged through `pipelined_finish` below instead.
        batch = d.active
        landing: List = []
        if self.charge is not None:
            batch = [s for s in d.active if s.kv_first <= now]
            if not batch:
                self._ev.push(min(s.kv_first for s in d.active),
                              "poke_decode", d)
                return
            landing = [s for s in d.active if s.kv_first > now]
        ctx_tokens = sum(len(s.tokens) - 1 for s in batch)
        dt = e.decode_step(batch)
        if self.charge is not None:
            dt = self.charge.decode(len(batch), ctx_tokens)
        done_t = now + dt
        for seq in batch:
            if seq.kv_full > now:
                # a member's later layers are still crossing the wire:
                # layer l's attention runs only after layer l lands, so
                # the iteration drains at the pipelined finish time
                done_t = max(done_t, pipelined_finish(
                    now, dt, seq.kv_full, self.tx.n_layers))
            seq.kv_first = seq.kv_full = 0.0
        d.free_at = done_t
        if self.tracer.enabled:
            self.tracer.complete("step", "decode_step", now, done_t,
                                 d.label, batch=len(batch), compute=dt)
        still = []
        for seq in batch:
            state = self._states[seq.rid]
            self._emit_token(state, seq.tokens[-1], done_t)
            if seq.done:
                self._finish_state(state, done_t)
                e.release(seq)
            else:
                still.append(seq)
        # late joiners append at the tail, as the simulator's `arrived`
        # entries extend `running`
        d.active = still + landing
        self._ev.push(done_t, "poke_decode", d)

    # -- chunked-prefill absorption (intra-instance aggregation) ---------
    def _absorb_step(self, d: _LiveInstance, now: float) -> bool:
        """One bounded prefill chunk on a decode instance, between its
        decode iterations (prefill-priority, like a mixed engine). The
        chunk's fresh KV is written in place into the decode engine's own
        page pool — nothing ever crosses the wire; the final chunk's
        page-backed blob is spliced locally."""
        e = d.engine

        def can_take(seq):
            if e.has_partial(seq):
                return True
            return (len(d.active) + len(d.absorbing) < e.max_batch
                    and e.can_admit(seq) and e.can_start_chunked(seq))

        batch = d.absorb.form_batch(
            self.lm_tokens, max_batch=1, can_take=can_take,
            chunk_tokens=self._absorb_chunk, resumable=e.has_partial)
        if not batch:
            return False
        seq = batch[0]
        state = self._states[seq.rid]
        req = state.request
        state.to_status(RequestStatus.PREFILLING)
        state.where = ("absorb", d)
        d.absorbing.add(seq.rid)
        prev = seq.prefilled
        done, first, blob, dt, _c = e.prefill_chunk(seq, self._absorb_chunk)
        if self.charge is not None:
            dt = self.charge.chunk(_c, prev)
        t_end = now + dt
        self.busy_absorb += dt
        if self.tracer.enabled:
            self.tracer.phase(seq.rid, "prefilling", now, d.label)
            self.tracer.complete("compute", "absorb_chunk", now, t_end,
                                 d.label, rid=seq.rid, tokens=_c, ctx=prev)
        if not done:
            d.absorb.push(seq)
        else:
            d.absorbing.discard(seq.rid)
            seq.append_token(first)
            req.first_token = t_end
            self._emit_token(state, first, t_end)
            if seq.done:                    # out_len == 1 / instant stop
                release_blob(blob)
                self._finish_state(state, t_end)
            else:
                # KV is already local: splice the page-backed blob into
                # this engine's own tables (no wire, no migration states)
                wire = e.materialize_wire(blob, 0) \
                    if isinstance(blob, KVBlob) else blob
                e.insert_kv(seq, wire)
                req.decode_admit = t_end
                req.transfer_done = t_end
                state.to_status(RequestStatus.DECODING)
                if self.tracer.enabled:
                    self.tracer.phase(seq.rid, "decoding", t_end, d.label)
                d.active.append(seq)
        d.free_at = t_end
        self._ev.push(t_end, "poke_decode", d)
        return True

    # -- mixed role (colocated semantics) ---------------------------------
    def _step_engine(self, x: _LiveInstance, now: float):
        if x.role != "mixed":
            return
        if x.free_at > now:
            self._ev.push(x.free_at, "poke", x)
            return
        e = x.engine
        # prefill priority; page-aware admission via the shared core
        batch = x.waiting.form_batch(self.max_prefill_tokens,
                                     max_batch=1, can_take=e.can_admit)
        if batch:
            seq = batch[0]
            state = self._states[seq.rid]
            state.to_status(RequestStatus.PREFILLING)
            req = state.request
            first, blob, dt = e.prefill_request(seq)
            if self.charge is not None:
                dt = self.charge.prefill([len(seq.tokens) - seq.prefix_hit])
            if self.tracer.enabled:
                self.tracer.phase(seq.rid, "prefilling", now, x.label)
                self.tracer.complete(
                    "compute", "prefill_batch", now, now + dt,
                    x.label, rid=seq.rid,
                    tokens=len(seq.tokens) - seq.prefix_hit,
                    hit=seq.prefix_hit)
            seq.append_token(first)
            req.first_token = now + dt
            self._emit_token(state, first, now + dt)
            e.insert_kv(seq, blob)
            if seq.done:
                e.release(seq)
                self._finish_state(state, now + dt)
            else:
                state.to_status(RequestStatus.DECODING)
                if self.tracer.enabled:
                    self.tracer.phase(seq.rid, "decoding", now + dt,
                                      x.label)
                x.active.append(seq)
            x.free_at = now + dt
            self._ev.push(now + dt, "poke", x)
            return
        if x.active:
            batch2 = x.active
            ctx_tokens = sum(len(s.tokens) - 1 for s in batch2)
            dt = e.decode_step(batch2)
            if self.charge is not None:
                dt = self.charge.decode(len(batch2), ctx_tokens)
            done_t = now + dt
            if self.tracer.enabled:
                self.tracer.complete("step", "decode_step", now, done_t,
                                     x.label, batch=len(batch2),
                                     compute=dt)
            still = []
            for seq in batch2:
                state = self._states[seq.rid]
                self._emit_token(state, seq.tokens[-1], done_t)
                if seq.done:
                    e.release(seq)
                    self._finish_state(state, done_t)
                else:
                    still.append(seq)
            x.active = still
            x.free_at = done_t
            self._ev.push(done_t, "poke", x)
            return
        self._check_flip(x, now)

    # -- failover ---------------------------------------------------------
    def _on_fail_decode(self, idx, t: float):
        D = self._role("decode")
        # idx: role-local index from the fail_decode event, or the
        # instance record itself (tests inject failures by record)
        d = idx if isinstance(idx, _LiveInstance) else D[idx]
        lost = self.fail_decode(D.index(d))
        P = self._role("prefill")
        alive_p = [j for j, x in enumerate(P) if not x.failed]
        # failover: re-prefill lost requests (keep generated tokens)
        for rid in lost:
            state = self._states[rid]
            if state.done:
                continue
            seq = state.seq
            d.engine.release(seq)
            seq.done = False
            if not alive_p:                 # nowhere to recover to
                self._finish_state(state, t, FINISH_FAILED)
                continue
            qi = self.dispatcher.pick_prefill(
                rid, [x.queue for x in P], alive_p,
                hits=self._prefill_hits(seq.tokens), now=t)
            p = P[qi]
            p.queue.push(seq)
            state.where = ("prefill", p)
            state.to_status(RequestStatus.QUEUED)
            if self.tracer.enabled:
                self.tracer.phase(rid, "queued", t, p.label)
            self._ev.push(t, "poke_prefill", p)
        d.active = []
        # also re-route ready-but-unpulled requests (drop the dead
        # instance's prefix pin; the new target re-pins its own)
        moved = [(st, pinned) for st, _skip, pinned in d.pending]
        moved += [(st, pinned) for st, _skip, pinned, _n in d.granted]
        d.pending = []
        d.granted = []
        for state, pinned in moved:
            d.engine.unpin(pinned)
            if self.tx.has_parked(state.rid):
                parked = self.tx.parked.pop(state.rid)
                self.tx._granted.pop(state.rid, None)
                self._ev.push(t, "dispatch_decode",
                              (state, parked.blob, parked.src))
            else:
                # streamed chunked prefill mid-flight: re-route the stream
                _d, src, _skip = self._stream.pop(state.rid)
                self.tx._granted.pop(state.rid, None)
                self._ev.push(t, "predispatch_decode", (state, src))

    # -- runtime re-roling ------------------------------------------------
    def set_role(self, g: int, role: str, now: Optional[float] = None):
        """Flip instance ``g`` to ``role`` ("prefill"/"decode"/"mixed").

        The instance leaves the routing views immediately. Queued-but-
        unstarted work is re-routed through the shared dispatcher;
        resident work — running decodes, granted/streaming KV, partial
        chunks — drains in place, and the flip completes when the
        instance is idle. The engine (and its page pool) survives the
        flip; a decode→prefill flip completes only once no sequence
        tables or reservations remain, so it never strands or leaks KV."""
        assert role in ("prefill", "decode", "mixed"), role
        now = self._ev.now if now is None else now
        inst = self.inst[g]
        if inst.role == role:
            inst.target = None          # flip-back cancels a pending drain
            inst.draining = False
            return
        if inst.target == role:
            return
        # validate the fleet *after* every pending drain completes:
        # somebody must accept arrivals, and prefill output needs a
        # decode target (draining instances count as their target role)
        after = [x.target or x.role for x in self.inst if x is not inst] \
            + [role]
        if not any(r2 in ("prefill", "mixed")
                   or (r2 == "decode" and self._absorb_chunk)
                   for r2 in after):
            raise ValueError("re-roling would leave no instance able to "
                             "accept arrivals")
        if "prefill" in after and "decode" not in after:
            raise ValueError("re-roling would leave prefill instances "
                             "with no decode target")
        inst.draining = True
        inst.target = role
        if self.tracer.enabled:
            self.tracer.event("role_drain", now, lane=inst.label, role=role)
        self._reroute_unstarted(inst, now)
        self._check_flip(inst, now)

    def apply_roles(self, roles: Seq[str], now: Optional[float] = None):
        """Reconcile the fleet's per-instance roles with a plan vector
        (`FleetRouter.elastic_callback` / placement `mode_search`).
        Decode-creating flips run first so a later prefill-creating flip
        never transits through a prefill-without-decode-target fleet."""
        order = {"decode": 0, "mixed": 1, "prefill": 2}
        for g in sorted(range(min(len(roles), len(self.inst))),
                        key=lambda g: order.get(roles[g], 3)):
            self.set_role(g, roles[g], now=now)

    def pressure(self) -> Dict[str, float]:
        """Load signals for role controllers and routers: prefill queue
        depth and decode KV-page occupancy (the memory-bound overload
        signal queue depth misses). Same keys as the simulator twin."""
        P = [x for x in self._role("prefill")
             if not x.draining and not x.failed]
        D = [x for x in self._role("decode")
             if not x.draining and not x.failed]
        E = [x for x in self._role("mixed") if not x.draining]
        now = self._ev.now
        util = 0.0
        for d in D:
            s = d.engine.stats()
            if s.get("kv.num_pages"):
                util = max(util, s["kv.used_pages"] / s["kv.num_pages"])
        return {
            "prefill_queued_tokens": float(sum(x.queue.queued_tokens
                                               for x in P)),
            "prefill_inflight": float(sum(1 for x in P
                                          if x.free_at > now)),
            "decode_kv_util": float(util),
            "decode_load": float(sum(x.load for x in D)),
            "mixed_load": float(sum(x.load for x in E)),
            "n_prefill": float(len(P)), "n_decode": float(len(D)),
            "n_mixed": float(len(E)),
        }

    def kv_utilization(self) -> float:
        """Decode page-pool occupancy in [0, 1] (router-side KV-pressure
        overload signal)."""
        return self.pressure()["decode_kv_util"]

    def _reroute_unstarted(self, x: _LiveInstance, now: float):
        if x.role == "prefill":
            for seq in list(x.queue.items):
                if x.engine.has_partial(seq) or seq.rid in self._stream:
                    continue        # mid-chunk: finish here
                x.queue.remove(seq)
                st = self._states[seq.rid]
                st.where = None
                self._ev.push(now, "arrive", st)
            self._ev.push(now, "poke_prefill", x)
        elif x.role == "decode":
            others = [d for d in self._role("decode")
                      if d is not x and not d.draining and not d.failed]
            if others:
                for entry in list(x.pending):
                    state, _skip, pinned = entry
                    x.pending.remove(entry)
                    x.engine.unpin(pinned)
                    # the parked wire bytes were fixed at park time, so
                    # the re-pick skips prefix hits and pins (full blob)
                    di = self.dispatcher.pick_decode(
                        state.rid, [d.load for d in others], now=now)
                    nd = others[di]
                    if state.rid in self._stream:
                        _d, src, _s = self._stream[state.rid]
                        self._stream[state.rid] = (nd, src, 0)
                    nd.pending.append((state, 0, []))
                    state.where = ("decode", nd)
                    self._ev.push(now, "poke_decode", nd)
            for seq in list(x.absorb.items):
                if seq.rid in x.absorbing:
                    continue        # partial chunks: finish here
                x.absorb.remove(seq)
                st = self._states[seq.rid]
                st.where = None
                self._ev.push(now, "arrive", st)
            self._ev.push(now, "poke_decode", x)
        else:
            for seq in list(x.waiting.items):
                x.waiting.remove(seq)
                st = self._states[seq.rid]
                st.where = None
                self._ev.push(now, "arrive", st)
            self._ev.push(now, "poke", x)

    def _check_flip(self, x: _LiveInstance, now: float):
        if x.target is None:
            return
        if x.role == "prefill":
            if x.queue.items or x.engine._partial:
                return
        elif x.role == "decode":
            if (x.active or x.pending or x.granted or x.absorb.items
                    or x.absorbing):
                return
            s = x.engine.stats()
            assert not s.get("kv.tables", 0) \
                and not s.get("kv.reserved_pages", 0), \
                "role flip with resident sequences or reservations"
        else:
            if x.waiting.items or x.active:
                return
        if x.free_at > now:
            kind = {"prefill": "poke_prefill", "decode": "poke_decode",
                    "mixed": "poke"}[x.role]
            self._ev.push(x.free_at, kind, x)
            return
        self._complete_flip(x, now)

    def _complete_flip(self, x: _LiveInstance, now: float):
        role = x.target
        x.target = None
        x.draining = False
        x.role = role
        x.iid = self._iid_next[role]
        self._iid_next[role] += 1
        self._role_events.append((now, x.label, role))
        if self.tracer.enabled:
            self.tracer.event("role_change", now, lane=x.label, role=role)
        # fresh capacity: poke so blocked global work can move
        kind = {"prefill": "poke_prefill", "decode": "poke_decode",
                "mixed": "poke"}[role]
        self._ev.push(now, kind, x)
        if self._backlog:
            held, self._backlog = self._backlog, []
            for st in held:
                st.where = None
                self._ev.push(now, "arrive", st)

    # -- cancellation ----------------------------------------------------
    def _do_cancel(self, state: RequestState, t: float):
        """Release whatever this request holds at its current stage:
        QUEUED -> leave the FCFS/absorb/waiting queue; PREFILLING -> the
        in-flight dispatch event drops the blob (chunked: abort the
        partial + reclaim the stream); MIGRATING / PENDING_ADMIT ->
        unpark the transfer + drop the decode-side prefix pins;
        DECODING -> free the batch slot and every KV page."""
        seq = state.seq
        if state.where is None:
            return
        stage, loc = state.where
        if stage == "backlog":              # held during a re-role drain
            self._backlog = [st for st in self._backlog
                             if st.rid != state.rid]
            return
        if state.status is RequestStatus.QUEUED:
            if stage == "prefill":
                loc.queue.remove(seq)
            elif stage == "engine":
                loc.waiting.remove(seq)
            elif stage == "absorb":
                loc.absorb.remove(seq)
        elif state.status is RequestStatus.PREFILLING:
            if stage == "prefill":
                # chunked prefill: the request may sit re-queued between
                # chunks with a reserved residency and a predispatched
                # stream
                loc.queue.remove(seq)
                loc.engine.abort_partial(seq)
                self._drop_stream(state, t)
                self._ev.push(t, "poke_prefill", loc)
            elif stage == "absorb":
                loc.absorb.remove(seq)
                if seq.rid in loc.absorbing:
                    loc.absorbing.discard(seq.rid)
                    loc.engine.abort_partial(seq)
                self._ev.push(t, "poke_decode", loc)
        elif state.status in (RequestStatus.MIGRATING,
                              RequestStatus.PENDING_ADMIT):
            d = loc
            for j, (st, _skip, pinned) in enumerate(d.pending):
                if st is state:
                    del d.pending[j]
                    d.engine.cancel(seq, pinned)
                    break
            for j, (st, _skip, pinned, n_res) in enumerate(d.granted):
                if st is state:
                    del d.granted[j]
                    d.engine.unreserve(n_res)
                    d.engine.cancel(seq, pinned)
                    break
            self._stream.pop(state.rid, None)
            p = self.tx.cancel(state.rid)   # drops chunk segments too
            if p is not None:
                release_blob(p.blob)        # drop prefill-side prefix pins
                self._poke_src(p.src, t)
            self._ev.push(t, "poke_decode", d)  # head may admit now
        elif state.status is RequestStatus.DECODING:
            x = loc
            for j, s in enumerate(x.active):
                if s is seq:
                    del x.active[j]
                    break
            x.engine.cancel(seq)
            kind = "poke" if stage == "engine" else "poke_decode"
            self._ev.push(t, kind, x)       # freed pages may admit

    # -- legacy closed-world shim ----------------------------------------
    def run(self, requests: List[Request],
            fail_decode_at: Optional[Tuple[float, int]] = None
            ) -> Dict[int, ServedResult]:
        """Submit-all-then-drain compatibility shim: drive a whole trace
        to completion on the virtual clock (pre-lifecycle behavior,
        byte-identical results on no-cancel traces)."""
        self._reset_loop()
        for r in requests:
            self.submit(r)
        if fail_decode_at is not None:
            self._ev.push(fail_decode_at[0], "fail_decode",
                          fail_decode_at[1])
        return self.drain()

    # -- prefix-cache stats ----------------------------------------------
    def prefix_stats(self) -> Dict[str, Any]:
        """Aggregate radix-tree stats across the fleet (per-side)."""
        def agg(engines):
            out: Dict[str, float] = {}
            for e in engines:
                if not e.prefix_caching:
                    continue
                for k, v in e.prefix_cache.stats.as_dict().items():
                    out[k] = out.get(k, 0) + v
            return out
        out = {"prefill": agg(self.prefill), "decode": agg(self.decode)}
        if self.engines:
            out["mixed"] = agg(self.engines)
        return out

    def extras(self) -> Dict[str, Any]:
        """Dynamic-deployment counters (role flips, absorption)."""
        out: Dict[str, Any] = {"decisions": self.dispatcher.decisions,
                               "states": dict(self._states)}
        if self.busy_absorb or self.absorbed:
            out["absorb_busy_s"] = self.busy_absorb
            out["absorbed"] = self.absorbed
        if self._role_events:
            out["role_events"] = list(self._role_events)
        return out


class DisaggCluster(ServingCluster):
    """Legacy disaggregated entrypoint: ``n_prefill + n_decode`` live
    engines, translated to a prefill+decode role vector over the
    role-unified `ServingCluster`. Schedules, token streams, dispatch
    decisions and metric keys are byte-identical to the pre-unification
    class."""

    def __init__(self, cfg, params, *, n_prefill: int = 1, n_decode: int = 1,
                 max_batch: int = 8, max_len: int = 256,
                 transfer_bandwidth: float = 50e9, lm_tokens: int = 256,
                 attn_blocks=(64, 64), page_size: int = 16,
                 decode_num_pages: Optional[int] = None,
                 paged: Optional[bool] = None,
                 prefix_cache: bool = False,
                 prefill_num_pages: Optional[int] = None,
                 fused_prefix: Optional[bool] = None,
                 chunk_tokens: Optional[int] = None,
                 seed: int = 0, tracker=None, tracer=None,
                 charge=None, metrics=None):
        super().__init__(
            cfg, params,
            ["prefill"] * n_prefill + ["decode"] * n_decode,
            max_batch=max_batch, max_len=max_len,
            transfer_bandwidth=transfer_bandwidth, lm_tokens=lm_tokens,
            attn_blocks=attn_blocks, page_size=page_size,
            decode_num_pages=decode_num_pages, paged=paged,
            prefix_cache=prefix_cache,
            prefill_num_pages=prefill_num_pages,
            fused_prefix=fused_prefix, chunk_tokens=chunk_tokens,
            seed=seed, tracker=tracker, tracer=tracer,
            charge=charge, metrics=metrics)


class ColocatedCluster(ServingCluster):
    """vLLM-like baseline: each engine runs prefill + decode interleaved
    with prefill priority (iteration-level batching) — the degenerate
    "all instances mixed" case of the role-unified `ServingCluster`.
    Statuses skip MIGRATING / PENDING_ADMIT (nothing migrates)."""

    def __init__(self, cfg, params, *, n_engines: int = 1, max_batch: int = 8,
                 max_len: int = 256, max_prefill_tokens: int = 512,
                 attn_blocks=(64, 64), page_size: int = 16,
                 num_pages: Optional[int] = None,
                 paged: Optional[bool] = None,
                 seed: int = 0, tracker=None, tracer=None,
                 charge=None, metrics=None):
        super().__init__(
            cfg, params, ["mixed"] * n_engines,
            max_batch=max_batch, max_len=max_len,
            max_prefill_tokens=max_prefill_tokens,
            attn_blocks=attn_blocks, page_size=page_size,
            num_pages=num_pages, paged=paged,
            seed=seed, tracker=tracker, tracer=tracer,
            charge=charge, metrics=metrics)

    def run(self, requests: List[Request]) -> Dict[int, ServedResult]:
        return super().run(requests)
