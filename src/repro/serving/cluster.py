"""Live disaggregated cluster (DistServe runtime, Fig. 6) and the colocated
baseline, on real JAX engines with virtual-clock concurrency emulation.

Controller: FCFS arrival queue -> shortest-queue prefill dispatch ->
pull-based, page-granular KV migration -> least-loaded decode dispatch.
All dispatch decisions and batch formation go through the shared scheduler
core in `core.scheduler` (the same code the discrete-event simulator
runs), and decode admission is gated on free KV *pages*, not whole slots.
Fault injection hooks exercise the failover paths in core.fault.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.fault import HeartbeatMonitor, plan_failover
from ..core.kv_transfer import TransferManager, kv_bytes
from ..core.scheduler import (DisaggDispatcher, EventLoop, FCFSQueue,
                              least_loaded)
from ..core.workload import Request
from .engine import Engine, Sequence


@dataclasses.dataclass
class ServedResult:
    rid: int
    tokens: List[int]
    ttft: float
    tpot: float
    finish: float


def _page_bytes(cfg, page_size: int, dtype_bytes: int = 2) -> Optional[int]:
    per_tok = cfg.kv_bytes_per_token(dtype_bytes)
    return per_tok * page_size if per_tok else None


class DisaggCluster:
    """n_prefill + n_decode live engines; virtual-clock event loop."""

    def __init__(self, cfg, params, *, n_prefill: int = 1, n_decode: int = 1,
                 max_batch: int = 8, max_len: int = 256,
                 transfer_bandwidth: float = 50e9, lm_tokens: int = 256,
                 attn_blocks=(64, 64), page_size: int = 16,
                 decode_num_pages: Optional[int] = None,
                 paged: Optional[bool] = None):
        self.cfg = cfg
        self.prefill = [Engine(cfg, params, max_batch=1, max_len=max_len,
                               attn_blocks=attn_blocks, paged=paged,
                               page_size=page_size)
                        for _ in range(n_prefill)]
        self.decode = [Engine(cfg, params, max_batch=max_batch,
                              max_len=max_len, attn_blocks=attn_blocks,
                              paged=paged, page_size=page_size,
                              num_pages=decode_num_pages)
                       for _ in range(n_decode)]
        self.queues = [FCFSQueue(token_of=lambda s: len(s.tokens))
                       for _ in range(n_prefill)]
        self.dispatcher = DisaggDispatcher()
        self.tx = TransferManager(transfer_bandwidth,
                                  page_bytes=_page_bytes(cfg, page_size),
                                  n_layers=cfg.num_layers)
        self.lm_tokens = lm_tokens
        self.monitor = HeartbeatMonitor(timeout=1e9)
        for i in range(n_prefill):
            self.monitor.register(f"prefill{i}")
        for i in range(n_decode):
            self.monitor.register(f"decode{i}")
        self.failed_prefill: set = set()
        self.failed_decode: set = set()

    # -- fault injection ------------------------------------------------
    def fail_decode(self, idx: int) -> List[int]:
        """Kill a decode instance; returns rids needing re-prefill."""
        self.monitor.mark_failed(f"decode{idx}")
        self.failed_decode.add(idx)
        # `_active` may predate the latest iteration's completion filter —
        # sequences that already finished are not lost
        lost = [s.rid for s in getattr(self.decode[idx], "_active", [])
                if not s.done]
        return lost

    def fail_prefill(self, idx: int) -> List[int]:
        self.monitor.mark_failed(f"prefill{idx}")
        self.failed_prefill.add(idx)
        return [s.rid for s in self.queues[idx].items]

    # -- main loop --------------------------------------------------------
    def run(self, requests: List[Request],
            fail_decode_at: Optional[Tuple[float, int]] = None
            ) -> Dict[int, ServedResult]:
        """Drive all requests to completion on the virtual clock."""
        rng = np.random.default_rng(0)
        seqs: Dict[int, Sequence] = {}
        for r in requests:
            toks = rng.integers(1, self.cfg.vocab_size,
                                size=r.in_len).tolist()
            seqs[r.rid] = Sequence(r.rid, toks, r.out_len)

        ev = EventLoop()
        for r in requests:
            ev.push(r.arrive, "arrive", r)
        if fail_decode_at is not None:
            ev.push(fail_decode_at[0], "fail_decode", fail_decode_at[1])

        # per-engine virtual clocks
        p_free = [0.0] * len(self.prefill)
        d_free = [0.0] * len(self.decode)
        d_active: List[List[Sequence]] = [[] for _ in self.decode]
        d_pending: List[List[Tuple[Request, Sequence]]] = [[] for _ in self.decode]
        results: Dict[int, ServedResult] = {}

        def alive_p():
            return [i for i in range(len(self.prefill))
                    if i not in self.failed_prefill]

        def alive_d():
            return [i for i in range(len(self.decode))
                    if i not in self.failed_decode]

        def _finish(req, seq, t):
            ttft = req.first_token - req.arrive
            tpot = ((req.finish - req.first_token) / max(seq.out_len - 1, 1))
            results[req.rid] = ServedResult(req.rid, seq.tokens, ttft, tpot,
                                            req.finish)

        def poke_prefill(i, now):
            if i in self.failed_prefill or not self.queues[i].items:
                return
            if p_free[i] > now:                  # busy: come back when free
                ev.push(p_free[i], "poke_prefill", i)
                return
            batch = self.queues[i].form_batch(self.lm_tokens, max_batch=1)
            for seq in batch:
                req = seq._req
                first, blob, dt = self.prefill[i].prefill_request(seq)
                seq.tokens.append(first)
                seq.produced += 1
                req.first_token = now + dt
                if seq.produced >= seq.out_len:
                    seq.done = True
                    req.finish = now + dt
                    _finish(req, seq, now + dt)
                else:
                    nbytes = kv_bytes(self.cfg, len(seq.tokens) - 1)
                    self.tx.park(seq.rid, blob, nbytes, now + dt, src=i)
                    ev.push(now + dt, "dispatch_decode", (req, seq))
                p_free[i] = now + dt
                ev.push(now + dt, "poke_prefill", i)

        def poke_decode(i, now):
            if i in self.failed_decode:
                return
            if d_free[i] > now:
                ev.push(d_free[i], "poke_decode", i)
                return
            d = self.decode[i]
            # pull-based admission against free KV pages (paper §4.3)
            while d_pending[i] and d.can_admit(d_pending[i][0][1]):
                req, seq = d_pending[i].pop(0)
                blob, t_done = self.tx.pull(seq.rid, now, dst=i)
                d.insert_kv(seq, blob)
                req.decode_admit = max(now, t_done)
                d_active[i].append(seq)
            d._active = d_active[i]
            if not d_active[i]:
                return
            dt = d.decode_step(d_active[i])
            done_t = now + dt
            d_free[i] = done_t
            still = []
            for seq in d_active[i]:
                if seq.done:
                    seq._req.finish = done_t
                    _finish(seq._req, seq, done_t)
                    d.release(seq)
                else:
                    still.append(seq)
            d_active[i] = still
            ev.push(done_t, "poke_decode", i)

        while ev:
            t, kind, payload = ev.pop()
            if kind == "arrive":
                r = payload
                seq = seqs[r.rid]
                seq._req = r
                qi = self.dispatcher.pick_prefill(r.rid, self.queues,
                                                  alive_p())
                self.queues[qi].push(seq)
                ev.push(t, "poke_prefill", qi)
            elif kind == "poke_prefill":
                poke_prefill(payload, t)
            elif kind == "dispatch_decode":
                req, seq = payload
                alive = alive_d()
                loads = [len(d_active[i]) + len(d_pending[i])
                         for i in range(len(self.decode))]
                di = self.dispatcher.pick_decode(req.rid, loads, alive)
                d_pending[di].append((req, seq))
                ev.push(t, "poke_decode", di)
            elif kind == "poke_decode":
                poke_decode(payload, t)
            elif kind == "fail_decode":
                idx = payload
                lost = self.fail_decode(idx)
                # failover: re-prefill lost requests (keep generated tokens)
                for rid in lost:
                    seq = seqs[rid]
                    self.decode[idx].release(seq)
                    seq.done = False
                    qi = self.dispatcher.pick_prefill(rid, self.queues,
                                                      alive_p())
                    self.queues[qi].push(seq)
                    ev.push(t, "poke_prefill", qi)
                d_active[idx] = []
                # also re-route ready-but-unpulled requests
                moved = d_pending[idx]
                d_pending[idx] = []
                for req, seq in moved:
                    ev.push(t, "dispatch_decode", (req, seq))
        return results


class ColocatedCluster:
    """vLLM-like baseline: each engine runs prefill + decode interleaved
    with prefill priority (iteration-level batching)."""

    def __init__(self, cfg, params, *, n_engines: int = 1, max_batch: int = 8,
                 max_len: int = 256, max_prefill_tokens: int = 512,
                 attn_blocks=(64, 64), page_size: int = 16,
                 num_pages: Optional[int] = None,
                 paged: Optional[bool] = None):
        self.cfg = cfg
        self.engines = [Engine(cfg, params, max_batch=max_batch,
                               max_len=max_len, attn_blocks=attn_blocks,
                               paged=paged, page_size=page_size,
                               num_pages=num_pages)
                        for _ in range(n_engines)]
        self.max_prefill_tokens = max_prefill_tokens

    def run(self, requests: List[Request]) -> Dict[int, ServedResult]:
        rng = np.random.default_rng(0)
        results: Dict[int, ServedResult] = {}
        ev = EventLoop()

        waiting = [FCFSQueue(token_of=lambda s: len(s.tokens))
                   for _ in self.engines]
        active: List[List[Sequence]] = [[] for _ in self.engines]
        free_at = [0.0] * len(self.engines)

        for r in requests:
            toks = rng.integers(1, self.cfg.vocab_size, size=r.in_len).tolist()
            s = Sequence(r.rid, toks, r.out_len)
            s._req = r
            ev.push(r.arrive, "arrive", (r, s))

        def _finish(req, seq, t):
            req.finish = t
            ttft = req.first_token - req.arrive
            tpot = (req.finish - req.first_token) / max(seq.out_len - 1, 1)
            results[req.rid] = ServedResult(req.rid, seq.tokens, ttft, tpot, t)

        def step(i, now):
            if free_at[i] > now:
                ev.push(free_at[i], "poke", i)
                return
            e = self.engines[i]
            # prefill priority; page-aware admission via the shared core
            batch = waiting[i].form_batch(self.max_prefill_tokens,
                                          max_batch=1, can_take=e.can_admit)
            if batch:
                seq = batch[0]
                req = seq._req
                first, blob, dt = e.prefill_request(seq)
                seq.tokens.append(first)
                seq.produced += 1
                req.first_token = now + dt
                e.insert_kv(seq, blob)
                if seq.produced >= seq.out_len:
                    seq.done = True
                    e.release(seq)
                    _finish(req, seq, now + dt)
                else:
                    active[i].append(seq)
                free_at[i] = now + dt
                ev.push(now + dt, "poke", i)
                return
            if active[i]:
                dt = e.decode_step(active[i])
                done_t = now + dt
                still = []
                for seq in active[i]:
                    if seq.done:
                        e.release(seq)
                        _finish(seq._req, seq, done_t)
                    else:
                        still.append(seq)
                active[i] = still
                free_at[i] = done_t
                ev.push(done_t, "poke", i)

        while ev:
            t, kind, payload = ev.pop()
            if kind == "arrive":
                r, s = payload
                i = least_loaded([len(waiting[j]) + len(active[j])
                                  for j in range(len(self.engines))])
                waiting[i].push(s)
                ev.push(t, "poke", i)
            elif kind == "poke":
                step(payload, t)
        return results
