"""Live disaggregated cluster (DistServe runtime, Fig. 6) and the colocated
baseline, on real JAX engines with virtual-clock concurrency emulation.

Controller: FCFS arrival queue -> shortest-queue prefill dispatch ->
pull-based, page-granular KV migration -> least-loaded decode dispatch.
All dispatch decisions and batch formation go through the shared scheduler
core in `core.scheduler` (the same code the discrete-event simulator
runs), and decode admission is gated on free KV *pages*, not whole slots.
Fault injection hooks exercise the failover paths in core.fault.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.fault import HeartbeatMonitor, plan_failover
from ..core.kv_transfer import TransferManager, kv_bytes
from ..core.scheduler import (DisaggDispatcher, EventLoop, FCFSQueue,
                              least_loaded)
from ..core.workload import Request
from .engine import Engine, Sequence


@dataclasses.dataclass
class ServedResult:
    rid: int
    tokens: List[int]
    ttft: float
    tpot: float
    finish: float
    prefix_hit: int = 0        # prompt tokens served from the prefill-side
                               # radix tree (prefill compute skipped)
    decode_hit: int = 0        # prompt tokens already resident on the
                               # decode side (transfer bytes skipped)


def _page_bytes(cfg, page_size: int, dtype_bytes: int = 2) -> Optional[int]:
    per_tok = cfg.kv_bytes_per_token(dtype_bytes)
    return per_tok * page_size if per_tok else None


def _slice_blob(blob, skip_tokens: int):
    """Drop the first `skip_tokens` positions from a migration blob — the
    decode side already holds that prefix, so only the suffix ships."""
    cache, n_tok = blob
    if not skip_tokens:
        return blob
    sliced = {k: ({"k": v["k"][:, :, skip_tokens:],
                   "v": v["v"][:, :, skip_tokens:]}
                  if k.startswith("seg") else v)
              for k, v in cache.items()}
    return sliced, n_tok


class DisaggCluster:
    """n_prefill + n_decode live engines; virtual-clock event loop."""

    def __init__(self, cfg, params, *, n_prefill: int = 1, n_decode: int = 1,
                 max_batch: int = 8, max_len: int = 256,
                 transfer_bandwidth: float = 50e9, lm_tokens: int = 256,
                 attn_blocks=(64, 64), page_size: int = 16,
                 decode_num_pages: Optional[int] = None,
                 paged: Optional[bool] = None,
                 prefix_cache: bool = False,
                 prefill_num_pages: Optional[int] = None):
        self.cfg = cfg
        if prefix_cache and prefill_num_pages is None:
            # a prefill engine's default pool (one resident sequence) has
            # no room to retain prefixes; keep a few sequences' worth
            prefill_num_pages = 8 * -(-max_len // page_size) + 1
        self.prefix_cache = prefix_cache
        self.prefill = [Engine(cfg, params, max_batch=1, max_len=max_len,
                               attn_blocks=attn_blocks, paged=paged,
                               page_size=page_size,
                               num_pages=prefill_num_pages,
                               prefix_cache=prefix_cache)
                        for _ in range(n_prefill)]
        self.decode = [Engine(cfg, params, max_batch=max_batch,
                              max_len=max_len, attn_blocks=attn_blocks,
                              paged=paged, page_size=page_size,
                              num_pages=decode_num_pages,
                              prefix_cache=prefix_cache)
                       for _ in range(n_decode)]
        self.queues = [FCFSQueue(token_of=lambda s: len(s.tokens))
                       for _ in range(n_prefill)]
        self.dispatcher = DisaggDispatcher()
        self.tx = TransferManager(transfer_bandwidth,
                                  page_bytes=_page_bytes(cfg, page_size),
                                  n_layers=cfg.num_layers)
        self.lm_tokens = lm_tokens
        self.monitor = HeartbeatMonitor(timeout=1e9)
        for i in range(n_prefill):
            self.monitor.register(f"prefill{i}")
        for i in range(n_decode):
            self.monitor.register(f"decode{i}")
        self.failed_prefill: set = set()
        self.failed_decode: set = set()

    # -- fault injection ------------------------------------------------
    def fail_decode(self, idx: int) -> List[int]:
        """Kill a decode instance; returns rids needing re-prefill."""
        self.monitor.mark_failed(f"decode{idx}")
        self.failed_decode.add(idx)
        # `_active` may predate the latest iteration's completion filter —
        # sequences that already finished are not lost
        lost = [s.rid for s in getattr(self.decode[idx], "_active", [])
                if not s.done]
        return lost

    def fail_prefill(self, idx: int) -> List[int]:
        self.monitor.mark_failed(f"prefill{idx}")
        self.failed_prefill.add(idx)
        return [s.rid for s in self.queues[idx].items]

    # -- main loop --------------------------------------------------------
    def run(self, requests: List[Request],
            fail_decode_at: Optional[Tuple[float, int]] = None
            ) -> Dict[int, ServedResult]:
        """Drive all requests to completion on the virtual clock."""
        rng = np.random.default_rng(0)
        seqs: Dict[int, Sequence] = {}
        for r in requests:
            if r.tokens is not None:    # shared-prefix traces carry ids
                toks = [int(t) % self.cfg.vocab_size for t in r.tokens]
            else:
                toks = rng.integers(1, self.cfg.vocab_size,
                                    size=r.in_len).tolist()
            seqs[r.rid] = Sequence(r.rid, toks, r.out_len)

        ev = EventLoop()
        for r in requests:
            ev.push(r.arrive, "arrive", r)
        if fail_decode_at is not None:
            ev.push(fail_decode_at[0], "fail_decode", fail_decode_at[1])

        # per-engine virtual clocks
        p_free = [0.0] * len(self.prefill)
        d_free = [0.0] * len(self.decode)
        d_active: List[List[Sequence]] = [[] for _ in self.decode]
        d_pending: List[List[Tuple[Request, Sequence]]] = [[] for _ in self.decode]
        results: Dict[int, ServedResult] = {}

        def alive_p():
            return [i for i in range(len(self.prefill))
                    if i not in self.failed_prefill]

        def alive_d():
            return [i for i in range(len(self.decode))
                    if i not in self.failed_decode]

        def _finish(req, seq, t):
            ttft = req.first_token - req.arrive
            tpot = ((req.finish - req.first_token) / max(seq.out_len - 1, 1))
            req.prefix_hit = seq.prefix_hit
            req.decode_hit = seq.decode_hit
            results[req.rid] = ServedResult(req.rid, seq.tokens, ttft, tpot,
                                            req.finish, seq.prefix_hit,
                                            seq.decode_hit)

        def poke_prefill(i, now):
            if i in self.failed_prefill or not self.queues[i].items:
                return
            if p_free[i] > now:                  # busy: come back when free
                ev.push(p_free[i], "poke_prefill", i)
                return
            batch = self.queues[i].form_batch(self.lm_tokens, max_batch=1)
            for seq in batch:
                req = seq._req
                first, blob, dt = self.prefill[i].prefill_request(seq)
                seq.tokens.append(first)
                seq.produced += 1
                req.first_token = now + dt
                if seq.produced >= seq.out_len:
                    seq.done = True
                    req.finish = now + dt
                    _finish(req, seq, now + dt)
                else:
                    # decode target (and hence shipped bytes) is chosen at
                    # dispatch time, where the decode-side prefix is known
                    ev.push(now + dt, "dispatch_decode", (req, seq, blob, i))
                p_free[i] = now + dt
                ev.push(now + dt, "poke_prefill", i)

        def poke_decode(i, now):
            if i in self.failed_decode:
                return
            if d_free[i] > now:
                ev.push(d_free[i], "poke_decode", i)
                return
            d = self.decode[i]

            # pull-based admission against free KV pages (paper §4.3);
            # shared prefix pages are already resident, so only the
            # suffix needs fresh pages
            def admit_ready():
                while d_pending[i] and d.can_admit(d_pending[i][0][1],
                                                   len(d_pending[i][0][3])):
                    req, seq, skip, pinned = d_pending[i].pop(0)
                    (blob, _, _), t_done = self.tx.pull(seq.rid, now, dst=i)
                    d.insert_kv(seq, _slice_blob(blob, skip), shared=pinned,
                                skip_tokens=skip)
                    d.unpin(pinned)
                    req.decode_admit = max(now, t_done)
                    d_active[i].append(seq)

            admit_ready()
            if d_pending[i] and not d_active[i]:
                # liveness fallback: nothing is running (so no future poke
                # will fire) and the head still can't admit — its eviction
                # is blocked by pages pinned for *later* pending requests.
                # Drop every pin (those requests fall back to a full-blob
                # transfer); with no pins and nothing running, the head's
                # residency always fits after LRU eviction.
                for j, (rq, sq, _skip, pinned) in enumerate(d_pending[i]):
                    d.unpin(pinned)
                    d_pending[i][j] = (rq, sq, 0, [])
                admit_ready()
            d._active = d_active[i]
            if not d_active[i]:
                return
            dt = d.decode_step(d_active[i])
            done_t = now + dt
            d_free[i] = done_t
            still = []
            for seq in d_active[i]:
                if seq.done:
                    seq._req.finish = done_t
                    _finish(seq._req, seq, done_t)
                    d.release(seq)
                else:
                    still.append(seq)
            d_active[i] = still
            ev.push(done_t, "poke_decode", i)

        def prefill_hits(tokens):
            if not self.prefix_cache:
                return None
            return [self.prefill[i].prefix_peek(tokens)
                    for i in range(len(self.prefill))]

        while ev:
            t, kind, payload = ev.pop()
            if kind == "arrive":
                r = payload
                seq = seqs[r.rid]
                seq._req = r
                qi = self.dispatcher.pick_prefill(r.rid, self.queues,
                                                  alive_p(),
                                                  hits=prefill_hits(seq.tokens))
                self.queues[qi].push(seq)
                ev.push(t, "poke_prefill", qi)
            elif kind == "poke_prefill":
                poke_prefill(payload, t)
            elif kind == "dispatch_decode":
                req, seq, blob, src = payload
                alive = alive_d()
                loads = [len(d_active[i]) + len(d_pending[i])
                         for i in range(len(self.decode))]
                n_tok = blob[1]
                d_hits = None
                if self.prefix_cache:
                    d_hits = [self.decode[i].prefix_peek(seq.tokens[:n_tok])
                              for i in range(len(self.decode))]
                di = self.dispatcher.pick_decode(req.rid, loads, alive,
                                                 hits=d_hits)
                # pin the decode-resident prefix and ship only the rest
                skip, pinned = self.decode[di].pin_prefix(seq.tokens[:n_tok])
                ship = n_tok - skip
                nbytes = kv_bytes(self.cfg, ship) if ship else 0
                self.tx.park(seq.rid, (blob, skip, pinned), nbytes, t,
                             src=src)
                d_pending[di].append((req, seq, skip, pinned))
                ev.push(t, "poke_decode", di)
            elif kind == "poke_decode":
                poke_decode(payload, t)
            elif kind == "fail_decode":
                idx = payload
                lost = self.fail_decode(idx)
                # failover: re-prefill lost requests (keep generated tokens)
                for rid in lost:
                    seq = seqs[rid]
                    self.decode[idx].release(seq)
                    seq.done = False
                    qi = self.dispatcher.pick_prefill(
                        rid, self.queues, alive_p(),
                        hits=prefill_hits(seq.tokens))
                    self.queues[qi].push(seq)
                    ev.push(t, "poke_prefill", qi)
                d_active[idx] = []
                # also re-route ready-but-unpulled requests (drop the dead
                # instance's prefix pin; the new target re-pins its own)
                moved = d_pending[idx]
                d_pending[idx] = []
                for req, seq, _skip, pinned in moved:
                    self.decode[idx].unpin(pinned)
                    parked = self.tx.parked.pop(req.rid)
                    blob = parked.blob[0]
                    ev.push(t, "dispatch_decode",
                            (req, seq, blob, parked.src))
        return results

    # -- prefix-cache stats ----------------------------------------------
    def prefix_stats(self) -> Dict[str, Any]:
        """Aggregate radix-tree stats across the fleet (per-side)."""
        def agg(engines):
            out: Dict[str, float] = {}
            for e in engines:
                if not e.prefix_caching:
                    continue
                for k, v in e.prefix_cache.stats.as_dict().items():
                    out[k] = out.get(k, 0) + v
            return out
        return {"prefill": agg(self.prefill), "decode": agg(self.decode)}


class ColocatedCluster:
    """vLLM-like baseline: each engine runs prefill + decode interleaved
    with prefill priority (iteration-level batching)."""

    def __init__(self, cfg, params, *, n_engines: int = 1, max_batch: int = 8,
                 max_len: int = 256, max_prefill_tokens: int = 512,
                 attn_blocks=(64, 64), page_size: int = 16,
                 num_pages: Optional[int] = None,
                 paged: Optional[bool] = None):
        self.cfg = cfg
        self.engines = [Engine(cfg, params, max_batch=max_batch,
                               max_len=max_len, attn_blocks=attn_blocks,
                               paged=paged, page_size=page_size,
                               num_pages=num_pages)
                        for _ in range(n_engines)]
        self.max_prefill_tokens = max_prefill_tokens

    def run(self, requests: List[Request]) -> Dict[int, ServedResult]:
        rng = np.random.default_rng(0)
        results: Dict[int, ServedResult] = {}
        ev = EventLoop()

        waiting = [FCFSQueue(token_of=lambda s: len(s.tokens))
                   for _ in self.engines]
        active: List[List[Sequence]] = [[] for _ in self.engines]
        free_at = [0.0] * len(self.engines)

        for r in requests:
            if r.tokens is not None:
                toks = [int(t) % self.cfg.vocab_size for t in r.tokens]
            else:
                toks = rng.integers(1, self.cfg.vocab_size,
                                    size=r.in_len).tolist()
            s = Sequence(r.rid, toks, r.out_len)
            s._req = r
            ev.push(r.arrive, "arrive", (r, s))

        def _finish(req, seq, t):
            req.finish = t
            ttft = req.first_token - req.arrive
            tpot = (req.finish - req.first_token) / max(seq.out_len - 1, 1)
            results[req.rid] = ServedResult(req.rid, seq.tokens, ttft, tpot, t)

        def step(i, now):
            if free_at[i] > now:
                ev.push(free_at[i], "poke", i)
                return
            e = self.engines[i]
            # prefill priority; page-aware admission via the shared core
            batch = waiting[i].form_batch(self.max_prefill_tokens,
                                          max_batch=1, can_take=e.can_admit)
            if batch:
                seq = batch[0]
                req = seq._req
                first, blob, dt = e.prefill_request(seq)
                seq.tokens.append(first)
                seq.produced += 1
                req.first_token = now + dt
                e.insert_kv(seq, blob)
                if seq.produced >= seq.out_len:
                    seq.done = True
                    e.release(seq)
                    _finish(req, seq, now + dt)
                else:
                    active[i].append(seq)
                free_at[i] = now + dt
                ev.push(now + dt, "poke", i)
                return
            if active[i]:
                dt = e.decode_step(active[i])
                done_t = now + dt
                still = []
                for seq in active[i]:
                    if seq.done:
                        e.release(seq)
                        _finish(seq._req, seq, done_t)
                    else:
                        still.append(seq)
                active[i] = still
                free_at[i] = done_t
                ev.push(done_t, "poke", i)

        while ev:
            t, kind, payload = ev.pop()
            if kind == "arrive":
                r, s = payload
                i = least_loaded([len(waiting[j]) + len(active[j])
                                  for j in range(len(self.engines))])
                waiting[i].push(s)
                ev.push(t, "poke", i)
            elif kind == "poke":
                step(payload, t)
        return results
