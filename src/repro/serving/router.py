"""Fleet-scale router: N `ServingBackend` replicas behind one backend.

The paper's goodput story ends at one disaggregated cluster; a
millions-of-users deployment is many replicas behind a router that must
preserve the per-phase SLO guarantees each cluster buys. `FleetRouter`
fronts N `ServingBackend` instances — live `DisaggCluster`s or
`SimDisaggBackend`s, freely mixed — and itself implements the
`ServingBackend` protocol, so a fleet composes anywhere a single backend
does (benchmarks, goodput search, `ServeHandle` streaming).

Routing is pluggable (`RoutingPolicy`):

  prefix_affinity  router-side token-hash trie (`TokenHashTrie`):
                   page-granular like `RadixPrefixCache`, but allocator-
                   less — nodes hold page *hashes* and the set of replicas
                   believed to hold that prefix, never pages. Longest
                   match wins unless that replica's outstanding-token load
                   exceeds the least-loaded replica's by more than
                   `affinity_slack` (the same locality-vs-queueing
                   tradeoff `DisaggDispatcher` applies inside a cluster).
  session          sticky map keyed on the prompt head (first page of
                   token ids — consecutive turns of one conversation share
                   it), falling back to least-loaded on first sight.
  shortest_queue   fewest outstanding prompt tokens.
  least_loaded     fewest outstanding requests.

Load signals are router-side bookkeeping (requests routed minus requests
finished, per replica), not replica introspection: the router's view
changes only at its own dispatch and harvest times, which makes routing
decisions reproducible — a sim fleet and a live fleet replay the same
trace into the identical `decisions` list (the discipline
`DisaggDispatcher` pins for intra-cluster dispatch). The same counts are
what `_collect_metrics` exports to a `MetricsRegistry`.

`OverloadDetector` drives router-side queuing and shedding: a replica
past `max_inflight` outstanding requests (or, optionally, past
`max_replica_queue` requests sitting QUEUED inside it — the queue-depth
signal the replica's own metrics collector exports) stops receiving
work; when every routable replica is overloaded, arrivals wait in the
router's FCFS queue (traced as a ``router_queued`` phase, so TTFT
attribution shows router wait as its own term). A request that would
wait past `shed_after_s` (TTFT headroom) — or that arrives with the
router queue at `max_queue` — is *shed*: a leak-free cancel with
``finish_reason="shed"``, counted separately by `SLOTracker` so admitted
-request attainment can be compared against a no-shed baseline.

Elastic replanning closes the loop: attach a `core.replan.Replanner`
(its `WorkloadProfiler` watches the arrival stream through the router)
and an `on_replan` callback — `elastic_callback` resizes the fleet to
the plan's replica count via `add_replica` / `drain_replica` (draining
replicas finish their in-flight work, take nothing new, and go dead at
zero inflight). `fleet_search` is a ready-made `Replanner` search:
per-replica goodput from the simulator at the refitted spec, fleet size
= ceil(rate / replica goodput).

Clocks: each replica owns its event loop; the router interleaves them by
`next_time()` (earliest event wins, router events first on ties, then
replica index), so one global virtual clock emerges and `run_until` /
`drain` / `ServeHandle` semantics are exactly those of a single backend.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..core.scheduler import FCFSQueue
from .api import (FINISH_CANCELLED, FINISH_SHED, BackendBase, RequestState,
                  RequestStatus, ServedResult)

__all__ = [
    "TokenHashTrie", "RoutingPolicy", "PrefixAffinityPolicy",
    "SessionAffinityPolicy", "ShortestQueuePolicy", "LeastLoadedPolicy",
    "make_policy", "POLICIES", "OverloadDetector", "ReplicaHandle",
    "FleetRouter", "aggregate_snapshots", "elastic_callback", "fleet_search",
    "FleetPlan", "replica_kv_utilization",
]


# ---------------------------------------------------------------------------
# router-side prefix index
# ---------------------------------------------------------------------------

class _TrieNode:
    __slots__ = ("children", "replicas")

    def __init__(self):
        self.children: Dict[int, "_TrieNode"] = {}
        self.replicas: Dict[int, int] = {}      # replica idx -> last touch


class TokenHashTrie:
    """Page-granular prefix index over page *hashes*, mirroring
    `RadixPrefixCache.match` semantics without owning pages: `match`
    reports, per replica, the deepest prefix the router has previously
    routed there; `insert` records a routing decision. Entries are hints
    (replicas evict their real trees independently), so hash collisions
    and staleness cost only a suboptimal route, never correctness."""

    def __init__(self, page_tokens: int = 16, max_nodes: int = 1 << 16):
        assert page_tokens > 0 and max_nodes > 0
        self.page_tokens = int(page_tokens)
        self.max_nodes = int(max_nodes)
        self.root = _TrieNode()
        self.nodes = 0
        self.tick = 0

    def _pages(self, tokens: Sequence[int]) -> List[int]:
        pt = self.page_tokens
        return [hash(tuple(tokens[i * pt:(i + 1) * pt]))
                for i in range(len(tokens) // pt)]

    def match(self, tokens: Sequence[int]) -> Dict[int, int]:
        """{replica: deepest known prefix in tokens} (page-granular)."""
        hits: Dict[int, int] = {}
        node, depth = self.root, 0
        for h in self._pages(tokens):
            node = node.children.get(h)
            if node is None:
                break
            depth += self.page_tokens
            for rep in node.replicas:
                hits[rep] = depth
        return hits

    def insert(self, tokens: Sequence[int], replica: int):
        self.tick += 1
        node = self.root
        for h in self._pages(tokens):
            nxt = node.children.get(h)
            if nxt is None:
                nxt = node.children[h] = _TrieNode()
                self.nodes += 1
            node = nxt
            node.replicas[replica] = self.tick
        if self.nodes > self.max_nodes:
            self._evict()

    def drop_replica(self, replica: int):
        """Forget a removed replica (replan shrink)."""
        stack = [self.root]
        while stack:
            node = stack.pop()
            node.replicas.pop(replica, None)
            stack.extend(node.children.values())

    def _evict(self):
        """LRU-ish: prune the least-recently-touched leaves until the
        node count is back under 3/4 of the cap."""
        target = self.max_nodes * 3 // 4
        while self.nodes > target:
            leaves: List[Tuple[int, _TrieNode, int]] = []
            stack = [self.root]
            while stack:
                node = stack.pop()
                for k, ch in node.children.items():
                    if ch.children:
                        stack.append(ch)
                    else:
                        leaves.append(
                            (max(ch.replicas.values(), default=0), node, k))
            if not leaves:
                return
            leaves.sort(key=lambda x: x[0])
            for _, parent, k in leaves[:max(len(leaves) // 4, 1)]:
                ch = parent.children.get(k)
                if ch is not None and not ch.children:
                    del parent.children[k]
                    self.nodes -= 1
                if self.nodes <= target:
                    break


# ---------------------------------------------------------------------------
# routing policies
# ---------------------------------------------------------------------------

class RoutingPolicy:
    """Picks a replica for each request. `choose` sees only routable,
    non-overloaded candidates (never empty) and returns
    ``(replica_idx, hit_tokens)`` — the hit length recorded in the
    decision tuple, mirroring `DisaggDispatcher`. `on_route` runs after
    the dispatch commits (trie inserts, sticky-map updates)."""
    name = "policy"

    def choose(self, router: "FleetRouter", req,
               cand: List[int]) -> Tuple[int, int]:
        raise NotImplementedError

    def on_route(self, router: "FleetRouter", req, idx: int):
        pass

    def on_replica_removed(self, router: "FleetRouter", idx: int):
        pass


class ShortestQueuePolicy(RoutingPolicy):
    name = "shortest_queue"

    def choose(self, router, req, cand):
        idx = min(cand, key=lambda i: (router.replicas[i].inflight_tokens, i))
        return idx, 0


class LeastLoadedPolicy(RoutingPolicy):
    name = "least_loaded"

    def choose(self, router, req, cand):
        idx = min(cand, key=lambda i: (router.replicas[i].inflight, i))
        return idx, 0


class PrefixAffinityPolicy(RoutingPolicy):
    """Longest trie match unless the matched replica's outstanding-token
    load is more than `affinity_slack` tokens past the lightest candidate
    (beyond that gap locality stops paying for queueing delay); falls
    back to shortest-queue. Ties: longer hit, lighter load, lower index."""
    name = "prefix_affinity"

    def __init__(self, page_tokens: int = 16, affinity_slack: int = 1024,
                 max_nodes: int = 1 << 16):
        self.trie = TokenHashTrie(page_tokens, max_nodes)
        self.affinity_slack = affinity_slack

    def choose(self, router, req, cand):
        toks = req.tokens
        hits = self.trie.match(toks) if toks else {}
        load = lambda i: router.replicas[i].inflight_tokens  # noqa: E731
        hcand = [i for i in cand if hits.get(i, 0) > 0]
        if hcand:
            best = min(hcand, key=lambda i: (-hits[i], load(i), i))
            if load(best) - min(load(i) for i in cand) <= self.affinity_slack:
                return best, hits[best]
        idx = min(cand, key=lambda i: (load(i), i))
        return idx, hits.get(idx, 0)

    def on_route(self, router, req, idx):
        if req.tokens:
            self.trie.insert(req.tokens, idx)

    def on_replica_removed(self, router, idx):
        self.trie.drop_replica(idx)


class SessionAffinityPolicy(RoutingPolicy):
    """Sticky per-session routing. The session key defaults to the first
    page of prompt token ids — consecutive turns of one conversation
    share their head — with least-loaded assignment on first sight. A
    sticky replica that is dead/draining/overloaded gets re-picked (and
    the stickiness moves with it)."""
    name = "session"

    def __init__(self, key: Optional[Callable[[Any], Any]] = None,
                 page_tokens: int = 16):
        self._key = key
        self.page_tokens = page_tokens
        self.sticky: Dict[Any, int] = {}

    def session_key(self, req):
        if self._key is not None:
            return self._key(req)
        if req.tokens:
            return tuple(req.tokens[:self.page_tokens])
        return req.rid

    def choose(self, router, req, cand):
        idx = self.sticky.get(self.session_key(req))
        if idx is not None and idx in cand:
            return idx, 1
        idx = min(cand, key=lambda i: (router.replicas[i].inflight, i))
        return idx, 0

    def on_route(self, router, req, idx):
        self.sticky[self.session_key(req)] = idx

    def on_replica_removed(self, router, idx):
        self.sticky = {k: v for k, v in self.sticky.items() if v != idx}


POLICIES = {p.name: p for p in (PrefixAffinityPolicy, SessionAffinityPolicy,
                                ShortestQueuePolicy, LeastLoadedPolicy)}


def make_policy(name: str, **kwargs) -> RoutingPolicy:
    return POLICIES[name](**kwargs)


# ---------------------------------------------------------------------------
# overload detection + replicas
# ---------------------------------------------------------------------------

def replica_kv_utilization(backend) -> float:
    """Decode KV page-pool occupancy of a replica, in [0, 1].

    Prefers the replica's `MetricsRegistry`: every paged engine/pool
    collector exports ``<instance>.kv.used_pages`` / ``.kv.num_pages``
    pairs, and the replica's occupancy is the max over its instances —
    the same signal an external autoscaler would scrape. Falls back to
    the backend's own `kv_utilization()` when no registry is attached
    (the common in-process case), 0.0 when neither exists."""
    reg = getattr(backend, "metrics", None)
    if reg is not None:
        snap = reg.snapshot()
        best, found = 0.0, False
        for k, v in snap.items():
            if k.endswith(".kv.num_pages") and v > 0:
                used = snap.get(k[:-len("num_pages")] + "used_pages")
                if used is not None:
                    found = True
                    best = max(best, used / v)
        if found:
            return best
    fn = getattr(backend, "kv_utilization", None)
    return float(fn()) if fn is not None else 0.0


@dataclasses.dataclass
class OverloadDetector:
    """Per-replica admission gate + router-queue shedding policy.

    A replica is overloaded at `max_inflight` outstanding requests
    (router-side count, deterministic in both worlds), or — when
    `max_replica_queue` is set — when that many of its requests still sit
    QUEUED inside it (the queue-depth signal its metrics collector
    exports; re-evaluated at arrival/dispatch boundaries), or — when
    `max_kv_util` is set — when its decode KV page-pool occupancy
    (`replica_kv_utilization`) reaches that fraction: queue depth misses
    memory-bound overload, where a few long-context requests fill the
    page pool while the queues look empty. The router queue sheds
    arrivals past `max_queue` outright, and sheds a queued request once
    it has waited `shed_after_s` (`from_slo` derives that deadline as a
    fraction of the TTFT SLO: past it the request could not meet its SLO
    even with an instant prefill, so shedding it protects the admitted
    requests' attainment instead of cascading the overload).
    """
    max_inflight: int = 64
    max_queue: int = 4096
    shed_after_s: Optional[float] = None
    max_replica_queue: Optional[int] = None
    max_kv_util: Optional[float] = None

    @classmethod
    def from_slo(cls, slo_ttft: float, *, headroom: float = 0.5,
                 max_inflight: int = 64, max_queue: int = 4096,
                 max_kv_util: Optional[float] = None
                 ) -> "OverloadDetector":
        return cls(max_inflight=max_inflight, max_queue=max_queue,
                   shed_after_s=slo_ttft * headroom,
                   max_kv_util=max_kv_util)

    def overloaded(self, rep: "ReplicaHandle") -> bool:
        if rep.inflight >= self.max_inflight:
            return True
        if self.max_replica_queue is not None:
            queued = sum(1 for rid in rep.rids
                         if rep.backend.states[rid].status
                         is RequestStatus.QUEUED)
            if queued >= self.max_replica_queue:
                return True
        if self.max_kv_util is not None and \
                replica_kv_utilization(rep.backend) >= self.max_kv_util:
            return True
        return False


@dataclasses.dataclass
class ReplicaHandle:
    """Router-side view of one replica: the backend plus the outstanding
    work the router has routed there and not yet harvested back."""
    backend: Any
    name: str
    alive: bool = True              # routable and steppable
    draining: bool = False          # finish in-flight, accept nothing new
    inflight: int = 0
    inflight_tokens: int = 0        # prompt tokens outstanding
    routed: int = 0
    finished: int = 0
    rids: set = dataclasses.field(default_factory=set)

    @property
    def routable(self) -> bool:
        return self.alive and not self.draining


# ---------------------------------------------------------------------------
# the router
# ---------------------------------------------------------------------------

class FleetRouter(BackendBase):
    """`ServingBackend` over N child backends (see module docstring).

    Requests submitted to the router arrive in its own event loop, get
    routed (or router-queued, or shed) by the policy + detector, and are
    mirrored back as they stream: the child backend's per-token callback
    feeds the router's `RequestState`, tracker, and tracer, and the
    terminal result is harvested into `router.results` at the replica's
    finish time. Decision tuples land in `router.decisions` as
    ``("route", rid, replica, hit)`` / ``("shed", rid, -1, 0)``.
    """

    def __init__(self, backends: Sequence[Any], *,
                 policy: Any = "prefix_affinity",
                 detector: Optional[OverloadDetector] = None,
                 tracker=None, tracer=None, metrics=None,
                 replanner=None, on_replan: Optional[Callable] = None,
                 record_events: bool = True,
                 names: Optional[Sequence[str]] = None):
        self._init_backend(tracker=tracker, tracer=tracer, metrics=metrics)
        self._record_tokens = record_events
        self.replicas: List[ReplicaHandle] = []
        for i, be in enumerate(backends):
            self.add_replica(be, name=names[i] if names else None)
        self.policy: RoutingPolicy = (make_policy(policy)
                                      if isinstance(policy, str) else policy)
        self.detector = detector or OverloadDetector()
        self.decisions: List[Tuple[str, int, int, int]] = []
        self._rqueue: FCFSQueue = FCFSQueue(token_of=lambda r: r.in_len)
        self._routed: Dict[int, int] = {}       # rid -> replica idx
        self.shed_count = 0
        self.replanner = replanner
        self.on_replan = on_replan
        if metrics is not None:
            metrics.register(self._collect_metrics)

    # -- fleet membership ----------------------------------------------
    def add_replica(self, backend, name: Optional[str] = None) -> int:
        idx = len(self.replicas)
        self.replicas.append(ReplicaHandle(backend, name or f"replica{idx}"))
        return idx

    def drain_replica(self, idx: int):
        """Stop routing to a replica; it finishes its in-flight requests
        and goes dead at zero inflight (replan shrink path)."""
        rep = self.replicas[idx]
        rep.draining = True
        self.policy.on_replica_removed(self, idx)
        if rep.inflight == 0:
            rep.alive = False

    @property
    def fleet_size(self) -> int:
        return sum(1 for r in self.replicas if r.routable)

    # -- clock: interleave replicas by next event time ------------------
    def next_time(self) -> Optional[float]:
        best = self._ev.peek_time()
        for rep in self.replicas:
            if not rep.alive:
                continue
            nt = rep.backend.next_time()
            if nt is not None and (best is None or nt < best):
                best = nt
        return best

    def step(self) -> bool:
        src, best = -1, self._ev.peek_time()
        for i, rep in enumerate(self.replicas):
            if not rep.alive:
                continue
            nt = rep.backend.next_time()
            if nt is not None and (best is None or nt < best):
                src, best = i, nt
        if best is None:
            return False
        if src < 0:
            return super().step()           # router's own event is earliest
        if not self.replicas[src].backend.step():
            return False                    # defensive: replica refused
        self._ev.now = max(self._ev.now, best)
        self._harvest(src, best)
        return True

    def run_until(self, t: float) -> None:
        while True:
            nxt = self.next_time()
            if nxt is None or nxt > t:
                return
            if not self.step():
                return

    # -- router events --------------------------------------------------
    def _do_submit(self, state: RequestState, t: float):
        self._ev.push(t, "arrive", state)

    def _handle(self, t: float, kind: str, payload: Any):
        if kind == "arrive":
            self._on_arrive(payload, t)
        elif kind == "shed_check":
            if not payload.done and payload.rid not in self._routed:
                self._shed(payload, t)
        else:                               # pragma: no cover
            raise AssertionError(f"unknown router event {kind}")

    def _on_arrive(self, state: RequestState, t: float):
        req = state.request
        if self.replanner is not None:
            before = self.replanner.replans
            self.replanner.observe(req)     # profiler + drift-gated search
            if self.replanner.replans != before and self.on_replan is not None:
                self.on_replan(self, self.replanner.current_placement)
        if self.tracer.enabled:
            self.tracer.phase(state.rid, "router_queued", t, "router")
        if len(self._rqueue) >= self.detector.max_queue:
            self._rqueue.push(req)          # _shed pops it back out
            self._shed(state, t)
            return
        self._rqueue.push(req)
        self._dispatch_queued(t)
        if (not state.done and state.rid not in self._routed
                and self.detector.shed_after_s is not None):
            self._ev.push(t + self.detector.shed_after_s, "shed_check", state)

    # -- dispatch -------------------------------------------------------
    def _dispatch_queued(self, t: float) -> int:
        """Drain the router queue head-first while some routable replica
        is under its overload gates. Returns dispatches made."""
        n = 0
        while self._rqueue.items:
            cand = [i for i, rep in enumerate(self.replicas)
                    if rep.routable and not self.detector.overloaded(rep)]
            if not cand:
                break
            req = self._rqueue.items[0]
            state = self._states[req.rid]
            idx, hit = self.policy.choose(self, req, cand)
            self._rqueue.remove(req)
            self._dispatch(state, idx, hit, t)
            n += 1
        return n

    def _dispatch(self, state: RequestState, idx: int, hit: int, t: float):
        rep, req = self.replicas[idx], state.request
        self.decisions.append(("route", req.rid, idx, hit))
        if self.tracer.enabled:
            self.tracer.event("route_replica", t, rid=req.rid,
                              replica=idx, hit=hit)
        shared = getattr(rep.backend, "tracer", None) is self.tracer
        if self.tracer.enabled and not shared:
            # replica traces elsewhere (or not at all): close the router
            # phase here so router_queued stays an honest wait measure
            self.tracer.phase(req.rid, "dispatched", t, rep.name)
        mirror = None
        if (self._record_tokens or self.tracker is not None
                or state.on_token is not None or self.tracer.enabled):
            mirror = (lambda _rs, ev, s=state, sh=shared:
                      self._mirror_token(s, ev, sh))
        # the child re-stamps arrive/cancel_at on submit; arrive must stay
        # the user-facing arrival (TTFT spans router wait) and cancellation
        # is driven from the router loop only, so stash and restore both
        orig_arrive, orig_cancel = req.arrive, req.cancel_at
        req.cancel_at = None
        rep.backend.submit(req, t, sampling=state.sampling, on_token=mirror)
        req.arrive, req.cancel_at = orig_arrive, orig_cancel
        self._routed[req.rid] = idx
        rep.rids.add(req.rid)
        rep.inflight += 1
        rep.inflight_tokens += req.in_len
        rep.routed += 1
        self.policy.on_route(self, req, idx)

    def _mirror_token(self, state: RequestState, ev, shared: bool):
        if state.done:
            return
        state.record_token(ev.token, ev.t)
        if self.tracer.enabled and not shared:
            self.tracer.event("token", ev.t, rid=state.rid,
                              i=len(state.events) - 1)
        if self.tracker is not None:
            self.tracker.observe_event(state, state.events[-1])

    # -- harvest: replica terminals mirror onto router states -----------
    def _harvest(self, src: int, t: float):
        rep = self.replicas[src]
        done = sorted(rid for rid in rep.rids if rid in rep.backend.results)
        for rid in done:
            self._finish_routed(rid, src)
        if done:
            if rep.draining and rep.inflight == 0:
                rep.alive = False
            self._dispatch_queued(t)

    def _finish_routed(self, rid: int, src: int):
        rep = self.replicas[src]
        state = self._states[rid]
        res: ServedResult = rep.backend.results[rid]
        rep.rids.discard(rid)
        rep.inflight -= 1
        rep.inflight_tokens -= state.request.in_len
        rep.finished += 1
        self._routed.pop(rid, None)
        if state.done:
            return
        if res.finish_reason == FINISH_CANCELLED:
            # the replica trimmed pre-stamped future tokens; mirror that
            state.events = [e for e in state.events if e.t <= res.finish]
        state.finish(res.finish, res.finish_reason)
        self.results[rid] = res             # replica result: real tokens
        self._forget(rid)
        if self.tracer.enabled and \
                getattr(rep.backend, "tracer", None) is not self.tracer:
            self.tracer.finish_phase(rid, res.finish, state.status.name)
        if self.metrics is not None:
            self._observe_metrics(state)
        if self.tracker is not None:
            self.tracker.observe_finish(state)

    # -- cancellation / shedding ----------------------------------------
    def _apply_cancel(self, state: RequestState, t: float):
        if state.done:
            return
        src = self._routed.get(state.rid)
        if src is not None:
            # delegate: the replica releases everything it holds at t and
            # the terminal mirrors back through _harvest
            self.replicas[src].backend.cancel(state.rid, t)
            return
        self._rqueue.remove(state.request)  # held nothing but a queue slot
        state.events = [e for e in state.events if e.t <= t]
        state.finish(t, FINISH_CANCELLED)
        self._store_result(state)

    def _do_cancel(self, state: RequestState, t: float):
        raise AssertionError("unreachable: router overrides _apply_cancel")

    def _shed(self, state: RequestState, t: float):
        self.decisions.append(("shed", state.rid, -1, 0))
        self.shed_count += 1
        if self.tracer.enabled:
            self.tracer.event("shed", t, rid=state.rid)
        self._rqueue.remove(state.request)
        self._finish_state(state, t, FINISH_SHED)

    # -- metrics ---------------------------------------------------------
    def _collect_metrics(self) -> Dict[str, float]:
        out = {"router.queue_depth": float(len(self._rqueue)),
               "router.queue_tokens": float(self._rqueue.queued_tokens),
               "router.shed_total": float(self.shed_count),
               "router.replicas_alive": float(
                   sum(r.alive for r in self.replicas)),
               "router.replicas_routable": float(self.fleet_size)}
        for rep in self.replicas:
            pre = f"router.{rep.name}"
            out[f"{pre}.inflight"] = float(rep.inflight)
            out[f"{pre}.inflight_tokens"] = float(rep.inflight_tokens)
            out[f"{pre}.routed"] = float(rep.routed)
            out[f"{pre}.finished"] = float(rep.finished)
        return out


# ---------------------------------------------------------------------------
# fleet metrics aggregation + elastic replanning glue
# ---------------------------------------------------------------------------

def aggregate_snapshots(named: Dict[str, Dict[str, float]]
                        ) -> Dict[str, float]:
    """Fold per-replica metric snapshots into one namespace: every metric
    appears replica-prefixed (``replica0.queue0.depth``) and summed under
    ``fleet.`` — the multi-replica form `launch.diagnose --serve-metrics`
    prints."""
    out: Dict[str, float] = {}
    sums: Dict[str, float] = {}
    for rname, snap in named.items():
        for k, v in snap.items():
            out[f"{rname}.{k}"] = float(v)
            sums[k] = sums.get(k, 0.0) + float(v)
    for k, v in sums.items():
        out[f"fleet.{k}"] = v
    return out


@dataclasses.dataclass
class FleetPlan:
    """What `fleet_search` hands back to the `Replanner`: how many
    replicas the refitted workload needs at the observed rate, plus —
    when the search also ran the mode axis — the per-instance role
    vector each replica should reconcile to (`apply_roles`)."""
    replicas: int
    rate: float
    per_replica: float          # one replica's goodput (req/s)
    roles: Optional[List[str]] = None


def elastic_callback(make_backend: Callable[[int], Any],
                     size_of: Optional[Callable[[Any], int]] = None,
                     max_replicas: int = 64) -> Callable:
    """Build a `FleetRouter(on_replan=...)` callback that resizes the
    fleet to the plan's replica count: grows with `make_backend(idx)`,
    shrinks by draining the newest routable replicas first. A plan that
    carries a role vector (`FleetPlan.roles`) additionally *re-roles*
    every routable role-unified replica in place via `apply_roles` —
    capacity moves between prefill and decode without tearing a replica
    down (new replicas from `make_backend` are expected to be born with
    the planned roles)."""
    def cb(router: FleetRouter, plan):
        want = size_of(plan) if size_of is not None else (
            plan.replicas if isinstance(plan, FleetPlan) else int(plan))
        want = max(1, min(int(want), max_replicas))
        routable = [i for i, r in enumerate(router.replicas) if r.routable]
        if want > len(routable):
            for _ in range(want - len(routable)):
                router.add_replica(make_backend(len(router.replicas)))
        elif want < len(routable):
            for i in reversed(routable[want:]):
                router.drain_replica(i)
        roles = getattr(plan, "roles", None)
        if roles:
            for rep in router.replicas:
                apply = getattr(rep.backend, "apply_roles", None)
                if rep.routable and apply is not None:
                    apply(roles)
    return cb


def fleet_search(lm, prefill, decode, *, target: float = 0.9,
                 n_requests: int = 200, slo_scale: float = 1.0,
                 max_replicas: int = 64, search_modes: bool = False,
                 **sim_kwargs) -> Callable:
    """`Replanner` search callback for a fleet of identical replicas:
    per-replica goodput via the simulator (`max_goodput`, the paper's
    placement-search primitive) at the refitted spec, fleet size =
    ceil(observed rate / per-replica goodput).

    With ``search_modes=True`` the per-replica deployment *mode* becomes
    a search axis too (`core.placement.mode_search`): the replica's
    instances keep their count and parallelism but the prefill/decode/
    mixed role vector is re-chosen for the refitted workload, and the
    winning vector rides on the plan — `elastic_callback` then re-roles
    the existing replicas in place instead of rebuilding them."""
    from ..core.goodput import max_goodput
    from ..core.simulator import simulate_disaggregated, simulate_roles

    def search(spec, rate: float) -> FleetPlan:
        roles = None
        if search_modes:
            from ..core.placement import mode_search
            mp = mode_search(
                lm, spec, rate=rate, par=prefill.par,
                n_instances=prefill.count + decode.count,
                transfer_bw=sim_kwargs.get("transfer_bw", 50e9),
                chunk_tokens=sim_kwargs.get("chunk_tokens"),
                absorb_tokens=sim_kwargs.get("absorb_tokens"),
                n_requests=n_requests, seed=0)
            roles = mp.roles

            def run(reqs):
                return simulate_roles(reqs, lm, prefill.par, roles,
                                      **sim_kwargs)
        else:
            def run(reqs):
                return simulate_disaggregated(reqs, lm, prefill, decode,
                                              **sim_kwargs)
        chips = (prefill.count * prefill.par.num_chips
                 + decode.count * decode.par.num_chips)
        gp = max_goodput(run, spec, chips, target=target,
                         n_requests=n_requests, slo_scale=slo_scale)
        per = max(gp.rate, 1e-9)
        return FleetPlan(min(max(math.ceil(rate / per), 1), max_replicas),
                         rate, per, roles=roles)
    return search
