"""Shared-prefix KV reuse: a radix tree over token ids at page granularity.

Why a radix tree (sglang's RadixAttention, production-stack's prefix-aware
router): DistServe's goodput is bounded by prefill compute and by
prefill->decode transfer bytes, and both shrink in proportion to the
longest cached prefix when requests share prompt prefixes (system prompts,
multi-turn chat, few-shot templates).

Structure
---------
Each edge holds a run of tokens whose length is a whole number of pages
(``page_size`` tokens per page) plus the physical page ids backing that
run, so a node's path from the root spells out a page-aligned token prefix
and the pages that hold its KV. Children are keyed by their edge's first
*page* (a tuple of ``page_size`` tokens): matching and insertion compare
page-sized chunks, and edges split only at page boundaries. Only *full*
pages ever enter the tree — a partially filled tail page stays private to
its sequence (no reader may share a page whose later slots are still being
written; see `KVCacheManager.cow` for the copy-on-write escape hatch).

Ownership
---------
The tree owns one reference on every page it adopts (via the
``allocator`` — `serving.kv_cache.KVCacheManager` in the live engine).
Sequences using a matched prefix hold their own references through their
block tables, so a page's refcount is ``1 (tree) + #sequences``. Eviction
walks leaves in LRU order and drops only subtrees whose pages have no
references beyond the tree's own (refcount-0 from the outside), returning
the pages to the free list.

With ``allocator=None`` the tree manufactures synthetic page ids and skips
refcounting — this is the mode the discrete-event simulator runs in, so
the simulator and the live cluster share one matching/insertion
implementation and therefore report identical prefix-hit lengths and
routing decisions on the same trace.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class PrefixCacheStats:
    lookups: int = 0            # match() calls (routing peeks not counted)
    hits: int = 0               # match() calls with hit_tokens > 0
    lookup_tokens: int = 0      # tokens presented to match()
    hit_tokens: int = 0         # tokens served from the tree
    matched_pages: int = 0      # pages returned by match() (shared reuse)
    inserted_pages: int = 0     # pages adopted by the tree
    evicted_pages: int = 0      # pages released back by eviction

    @property
    def hit_rate(self) -> float:
        """Token-weighted hit rate over all lookups."""
        return self.hit_tokens / self.lookup_tokens if self.lookup_tokens else 0.0

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["hit_rate"] = round(self.hit_rate, 4)
        return d


class _Node:
    __slots__ = ("key", "pages", "children", "parent", "last_access")

    def __init__(self, key: Tuple[int, ...], pages: List[int],
                 parent: Optional["_Node"]):
        self.key = key          # tokens along the incoming edge (page multiple)
        self.pages = pages      # physical pages backing `key`
        self.children: Dict[Tuple[int, ...], _Node] = {}  # first page -> node
        self.parent = parent
        self.last_access = 0


class RadixPrefixCache:
    """Radix tree of page-aligned token prefixes over refcounted pages."""

    def __init__(self, page_size: int, allocator=None):
        assert page_size >= 1
        self.page_size = page_size
        self.allocator = allocator        # needs acquire/release/ref
        self.root = _Node((), [], None)
        self.stats = PrefixCacheStats()
        self._tick = itertools.count(1)
        self._synthetic = itertools.count(1)   # page ids when allocator=None

    # ---- lookup -------------------------------------------------------
    def _walk(self, tokens) -> Tuple[int, List[int], "_Node", int]:
        """Longest page-aligned match.

        Returns (hit_tokens, pages, node, within): `node` is the deepest
        node touched and `within` the number of tokens matched inside its
        edge (== len(node.key) when the whole edge matched)."""
        ps = self.page_size
        node = self.root
        pages: List[int] = []
        pos = 0
        while True:
            head = tuple(tokens[pos: pos + ps])
            nxt = node.children.get(head) if len(head) == ps else None
            if nxt is None:
                return pos, pages, node, len(node.key)
            k = 1   # `head` matched page 0 of the edge by construction
            while (k < len(nxt.pages)
                   and tuple(tokens[pos + k * ps: pos + (k + 1) * ps])
                   == nxt.key[k * ps: (k + 1) * ps]):
                k += 1
            pages.extend(nxt.pages[:k])
            pos += k * ps
            if k < len(nxt.pages):      # diverged mid-edge
                return pos, pages, nxt, k * ps
            node = nxt

    def peek(self, tokens) -> int:
        """Hit length for routing probes: no LRU bump, no stats."""
        hit, _, _, _ = self._walk(tokens)
        return hit

    def _bump(self, node: "_Node"):
        t = next(self._tick)
        while node is not None:
            node.last_access = t
            node = node.parent

    def match(self, tokens) -> Tuple[int, List[int]]:
        """Longest cached page-aligned prefix of `tokens` -> (hit, pages).

        Bumps LRU recency along the matched path and records stats. The
        caller must acquire references on the returned pages before using
        them — they are only guaranteed alive until the next eviction."""
        hit, pages, node, _ = self._walk(tokens)
        self._bump(node)
        self.stats.lookups += 1
        self.stats.lookup_tokens += len(tokens)
        self.stats.hit_tokens += hit
        self.stats.matched_pages += len(pages)
        if hit:
            self.stats.hits += 1
        return hit, pages

    # ---- insertion ----------------------------------------------------
    def insert(self, tokens, pages: Optional[List[int]] = None) -> int:
        """Adopt the full-page prefix of `tokens` backed by `pages`.

        `tokens` is truncated to whole pages; `pages` must cover them
        (page ids from the sequence's block table, in order). Regions
        already in the tree keep the tree's existing pages — a duplicate
        physical page stays private to the inserting sequence and dies
        with it. Newly adopted pages get one tree reference via
        ``allocator.acquire``. Returns the number of pages adopted."""
        ps = self.page_size
        n_full = len(tokens) // ps
        tokens = tuple(tokens[: n_full * ps])
        if pages is None:
            assert self.allocator is None, "live tree needs real page ids"
            pages = [next(self._synthetic) for _ in range(n_full)]
        assert len(pages) >= n_full, (len(pages), n_full)
        hit, _, node, within = self._walk(tokens)
        self._bump(node)
        if hit == len(tokens):
            return 0
        if within < len(node.key):      # stopped mid-edge: split at boundary
            node = self._split(node, within)
        new_toks = tokens[hit:]
        new_pages = list(pages[hit // ps: n_full])
        child = _Node(new_toks, new_pages, node)
        child.last_access = node.last_access
        node.children[new_toks[:ps]] = child
        if self.allocator is not None:
            self.allocator.acquire(new_pages)
        self.stats.inserted_pages += len(new_pages)
        return len(new_pages)

    def _split(self, node: _Node, keep_tokens: int) -> _Node:
        """Split `node`'s edge after `keep_tokens` (a page multiple);
        returns the new upper node."""
        ps = self.page_size
        kp = keep_tokens // ps
        assert 0 < kp < len(node.pages)
        upper = _Node(node.key[:keep_tokens], node.pages[:kp], node.parent)
        upper.last_access = node.last_access
        node.parent.children[upper.key[:ps]] = upper
        node.key = node.key[keep_tokens:]
        node.pages = node.pages[kp:]
        node.parent = upper
        upper.children[node.key[:ps]] = node
        return upper

    # ---- eviction -----------------------------------------------------
    def _evictable_leaves(self) -> List[_Node]:
        out = []

        def rec(n):
            for c in n.children.values():
                rec(c)
            if n is not self.root and not n.children:
                if self.allocator is None or all(
                        self.allocator.ref(p) <= 1 for p in n.pages):
                    out.append(n)
        rec(self.root)
        return sorted(out, key=lambda n: n.last_access)

    def evict(self, n_pages: int) -> List[int]:
        """Drop LRU leaf subtrees with no outside references until at
        least `n_pages` pages are released (or nothing evictable remains).
        Evicting a leaf can expose its parent; the loop re-collects until
        the target is met. Returns the released page ids."""
        freed: List[int] = []
        while len(freed) < n_pages:
            leaves = self._evictable_leaves()
            if not leaves:
                break
            for leaf in leaves:
                leaf.parent.children.pop(leaf.key[: self.page_size])
                if self.allocator is not None:
                    self.allocator.release(leaf.pages)
                freed.extend(leaf.pages)
                self.stats.evicted_pages += len(leaf.pages)
                if len(freed) >= n_pages:
                    break
        return freed

    # ---- introspection ------------------------------------------------
    def pages_in_tree(self) -> List[int]:
        out: List[int] = []

        def rec(n):
            out.extend(n.pages)
            for c in n.children.values():
                rec(c)
        rec(self.root)
        return out

    def num_pages(self) -> int:
        return len(self.pages_in_tree())

    def metrics(self) -> Dict[str, float]:
        """Pull-collector snapshot for a `MetricsRegistry`: cumulative
        hit/insert/evict counters plus the live tree footprint."""
        out = {k: float(v) for k, v in self.stats.as_dict().items()}
        out["pages_in_tree"] = float(self.num_pages())
        return out
