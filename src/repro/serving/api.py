"""First-class request lifecycle for online serving.

DistServe's headline metric is per-request SLO attainment — TTFT and TPOT
measured per token, online — so the serving surface is built around a
request lifecycle instead of closed-world trace replay:

  * `SamplingParams` — generation controls (max_tokens, stop token ids,
    greedy/temperature sampling).
  * `RequestStatus` — the state machine every request walks:
    QUEUED -> PREFILLING -> MIGRATING -> PENDING_ADMIT -> DECODING ->
    FINISHED | CANCELLED | FAILED.  (Backends may skip MIGRATING /
    PENDING_ADMIT when a hop is instantaneous — e.g. the colocated
    engines never migrate.)
  * `TokenEvent` — one generated token with its virtual-clock timestamp;
    the events list is the ground truth TTFT / inter-token-latency
    distribution (max/p99, not just the mean).
  * `RequestState` — the shared lifecycle record both the live clusters
    and the discrete-event simulator maintain; `ServedResult` is built
    from it.
  * `ServeHandle` — what `submit` returns: `.cancel()`, `.result()`, and
    a token iterator that drives the backend's virtual clock just far
    enough to yield the next token.
  * `ServingBackend` — the protocol all four drivers implement
    (`DisaggCluster`, `ColocatedCluster`, `SimDisaggBackend`,
    `SimColocatedBackend`): `submit(request, t)` / `step()` /
    `run_until(t)` / `drain()` / `cancel(rid)`, plus `on_token`
    callbacks, so live and simulated serving are driven through one API.

The legacy closed-world entrypoints (`DisaggCluster.run(requests)`,
`simulate_disaggregated(reqs, ...)`) remain as thin
submit-all-then-drain shims over this API.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import (Any, Callable, Dict, Iterator, List, Optional, Protocol,
                    Tuple, runtime_checkable)


class RequestStatus(enum.Enum):
    QUEUED = "queued"                # waiting in a prefill FCFS queue
    PREFILLING = "prefilling"       # prompt running through a prefill engine
    MIGRATING = "migrating"         # KV parked / on the wire to decode
    PENDING_ADMIT = "pending_admit"  # waiting for free decode KV pages
    DECODING = "decoding"           # in a decode instance's running batch
    FINISHED = "finished"
    CANCELLED = "cancelled"
    FAILED = "failed"

    @property
    def terminal(self) -> bool:
        return self in _TERMINAL


_TERMINAL = (RequestStatus.FINISHED, RequestStatus.CANCELLED,
             RequestStatus.FAILED)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Generation controls carried by a request.

    max_tokens caps the request's out_len (None -> use the request's);
    stop token ids end generation early with finish_reason "stop";
    temperature 0.0 is greedy argmax (the default, and the only mode the
    token-equality tests pin), > 0 samples the softmax with a rng seeded
    per request from `seed`.
    """
    max_tokens: Optional[int] = None
    stop: Tuple[int, ...] = ()
    temperature: float = 0.0
    seed: int = 0

    def out_len(self, requested: int) -> int:
        if self.max_tokens is None:
            return requested
        return max(min(requested, self.max_tokens), 1)


GREEDY = SamplingParams()

# finish reasons surfaced in ServedResult
FINISH_LENGTH = "length"        # produced out_len tokens
FINISH_STOP = "stop"            # hit a SamplingParams.stop token id
FINISH_CANCELLED = "cancelled"  # cancel() mid-flight
FINISH_FAILED = "failed"        # instance failure with no recovery
FINISH_SHED = "shed"            # load-shed by a router before any work ran


def percentile(xs, q: float) -> float:
    """Linear-interpolated percentile (numpy's default 'linear' method) —
    the one implementation every latency distribution in the repo uses
    (`simulator.summarize`, `ServedResult.tpot_p99`, benchmarks)."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    if len(xs) == 1:
        return xs[0]
    pos = q * (len(xs) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    index: int                  # 0-based position in the generated stream
    token: int                  # token id (simulated backends emit -1)
    t: float                    # virtual-clock emission time


@dataclasses.dataclass
class RequestState:
    """Shared per-request lifecycle record (live cluster and simulator)."""
    request: Any                            # core.workload.Request
    sampling: SamplingParams = GREEDY
    status: RequestStatus = RequestStatus.QUEUED
    events: List[TokenEvent] = dataclasses.field(default_factory=list)
    finish_reason: Optional[str] = None
    seq: Any = None                         # live backends: engine.Sequence
    on_token: Optional[Callable[["RequestState", TokenEvent], None]] = None
    # backend-private routing bookkeeping (which queue/instance holds it)
    where: Any = None
    # PREFILLING-with-progress: prompt tokens whose KV is already resident
    # (chunked prefill updates this after every chunk)
    progress: int = 0

    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def done(self) -> bool:
        return self.status.terminal

    def record_token(self, token: int, t: float):
        ev = TokenEvent(len(self.events), int(token), t)
        self.events.append(ev)
        if self.on_token is not None:
            self.on_token(self, ev)

    @property
    def token_times(self) -> Tuple[float, ...]:
        return tuple(e.t for e in self.events)

    def itl(self) -> List[float]:
        """Inter-token latencies (the real TPOT distribution)."""
        ts = self.token_times
        return [b - a for a, b in zip(ts, ts[1:])]

    @property
    def ttft(self) -> float:
        return self.request.first_token - self.request.arrive

    @property
    def tpot(self) -> float:
        """Mean inter-token latency over the tokens actually produced
        (equals the legacy (finish-first)/(out_len-1) on full runs)."""
        n = len(self.events)
        if n <= 1:
            return 0.0
        return (self.request.finish - self.request.first_token) / (n - 1)

    def to_status(self, status: RequestStatus):
        if not self.status.terminal:        # terminal states are sticky
            self.status = status

    def finish(self, t: float, reason: str = FINISH_LENGTH):
        if self.status.terminal:
            return
        self.request.finish = t
        self.request.finish_reason = reason
        self.finish_reason = reason
        self.status = (RequestStatus.CANCELLED
                       if reason in (FINISH_CANCELLED, FINISH_SHED)
                       else RequestStatus.FAILED if reason == FINISH_FAILED
                       else RequestStatus.FINISHED)


@dataclasses.dataclass
class ServedResult:
    """Per-request serving outcome, built from the RequestState.

    The first seven fields match the pre-lifecycle ServedResult exactly
    (the legacy `run(requests)` shims reproduce them byte-for-byte on
    no-cancel traces); the lifecycle redesign adds the finish reason and
    the full per-token timestamp vector, so TPOT is a distribution
    (`itl()`, `tpot_max`, `tpot_p99`), not just a mean.
    """
    rid: int
    tokens: List[int]
    ttft: float
    tpot: float
    finish: float
    prefix_hit: int = 0        # prompt tokens served from the prefill-side
                               # radix tree (prefill compute skipped)
    decode_hit: int = 0        # prompt tokens already resident on the
                               # decode side (transfer bytes skipped)
    finish_reason: str = FINISH_LENGTH
    token_times: Tuple[float, ...] = ()

    def itl(self) -> List[float]:
        return [b - a for a, b in zip(self.token_times, self.token_times[1:])]

    @property
    def n_generated(self) -> int:
        return len(self.token_times)

    @property
    def tpot_max(self) -> float:
        itl = self.itl()
        return max(itl) if itl else 0.0

    @property
    def tpot_p99(self) -> float:
        return percentile(self.itl(), 0.99)

    @classmethod
    def from_state(cls, state: RequestState) -> "ServedResult":
        req, seq = state.request, state.seq
        n = len(state.events)
        ttft = req.first_token - req.arrive
        tpot = ((req.finish - req.first_token) / max(n - 1, 1)
                if n else 0.0)
        return cls(req.rid, list(seq.tokens) if seq is not None else [],
                   ttft, tpot, req.finish,
                   getattr(seq, "prefix_hit", req.prefix_hit),
                   getattr(seq, "decode_hit", req.decode_hit),
                   state.finish_reason or FINISH_LENGTH,
                   state.token_times)


class ServeHandle:
    """Live view of one submitted request.

    Iterating yields `TokenEvent`s, driving the backend's virtual clock
    just far enough to produce each next token; `result()` drives it to
    this request's completion; `cancel()` frees everything it holds
    (pages, pins, parked transfer bytes) at the backend's current time.
    """

    def __init__(self, backend: "ServingBackend", state: RequestState):
        self._backend = backend
        self.state = state

    @property
    def rid(self) -> int:
        return self.state.rid

    @property
    def status(self) -> RequestStatus:
        return self.state.status

    @property
    def done(self) -> bool:
        return self.state.done

    def cancel(self, t: Optional[float] = None):
        self._backend.cancel(self.state.rid, t)

    def tokens(self) -> Iterator[TokenEvent]:
        i = 0
        while True:
            while i < len(self.state.events):
                yield self.state.events[i]
                i += 1
            if self.state.done or not self._backend.step():
                while i < len(self.state.events):   # events from last step
                    yield self.state.events[i]
                    i += 1
                return

    __iter__ = tokens

    def result(self) -> ServedResult:
        while not self.state.done and self._backend.step():
            pass
        stored = self._backend.results.get(self.state.rid)
        if stored is not None:
            return stored
        # backend went idle (horizon hit, failed instance, ...) with the
        # request unfinished: surface a snapshot instead of crashing
        return ServedResult.from_state(self.state)


@runtime_checkable
class ServingBackend(Protocol):
    """One protocol for live clusters and discrete-event simulators.

    `submit` enqueues a request at virtual time `t` (default: the
    request's `arrive`) and returns a `ServeHandle`; `step` processes one
    event (False when idle); `run_until(t)` processes events up to and
    including time `t`; `drain()` runs to quiescence and returns the
    accumulated `{rid: ServedResult}`; `cancel(rid, t)` aborts a request
    at any lifecycle stage, releasing pages/pins/parked bytes.
    """
    results: Dict[int, ServedResult]

    def submit(self, request: Any, t: Optional[float] = None, *,
               sampling: SamplingParams = GREEDY,
               on_token: Optional[Callable] = None) -> ServeHandle: ...
    def step(self) -> bool: ...
    def run_until(self, t: float) -> None: ...
    def drain(self) -> Dict[int, ServedResult]: ...
    def cancel(self, rid: int, t: Optional[float] = None) -> bool: ...


class BackendBase:
    """Event-loop plumbing shared by every `ServingBackend`: lifecycle
    records, submit/cancel event scheduling, step/run_until/drain, token
    emission (on_token callbacks + the online `SLOTracker`), and the
    leak-free cancellation frame.

    Subclasses implement `_do_submit(state, t)` (build backend-side state
    and push the arrive event), `_handle(t, kind, payload)` (the event
    handlers), and `_do_cancel(state, t)` (release whatever the request
    holds at its current lifecycle stage).
    """

    def _init_backend(self, tracker=None, tracer=None, metrics=None):
        from ..core.scheduler import EventLoop
        from ..core.telemetry import NULL_TRACER
        self._ev = EventLoop()
        self._states: Dict[int, RequestState] = {}
        self.results: Dict[int, ServedResult] = {}
        self.tracker = tracker
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics
        # per-token TokenEvent recording; simulator shims turn this off
        # for bulk goodput sweeps (millions of simulated tokens) — a
        # tracker or a per-request on_token callback still records
        self._record_tokens = True
        self._ontoken_rids: set = set()

    @property
    def now(self) -> float:
        return self._ev.now

    def next_time(self) -> Optional[float]:
        """Timestamp of the next event this backend would process, or None
        when idle (simulator backends clamp this to their horizon). A
        fleet router interleaves replicas by this clock."""
        return self._ev.peek_time()

    @property
    def states(self) -> Dict[int, RequestState]:
        return self._states

    # -- submission ----------------------------------------------------
    def submit(self, request: Any, t: Optional[float] = None, *,
               sampling: SamplingParams = GREEDY,
               on_token: Optional[Callable] = None) -> ServeHandle:
        """Enqueue one request at virtual time `t` (default: its own
        `arrive`; passing `t` re-stamps the arrival, so open-loop callers
        can submit "now" while the loop is running)."""
        if t is None:
            t = request.arrive
        else:
            request.arrive = t
        assert request.rid not in self._states, request.rid
        state = RequestState(request, sampling or GREEDY, on_token=on_token)
        self._states[request.rid] = state
        if on_token is not None:
            self._ontoken_rids.add(request.rid)
        self._do_submit(state, t)
        cancel_at = getattr(request, "cancel_at", None)
        if cancel_at is not None:       # trace-driven cancellation
            self._ev.push(max(cancel_at, t), "cancel", state)
        return ServeHandle(self, state)

    # -- clock ---------------------------------------------------------
    def step(self) -> bool:
        """Process one event; False when the loop is idle."""
        if not self._ev:
            return False
        t, kind, payload = self._ev.pop()
        if kind == "cancel":
            self._apply_cancel(payload, t)
        else:
            self._handle(t, kind, payload)
        return True

    def run_until(self, t: float) -> None:
        while True:
            nxt = self._ev.peek_time()
            if nxt is None or nxt > t:
                return
            if not self.step():     # backend refused (e.g. sim horizon)
                return

    def drain(self) -> Dict[int, ServedResult]:
        while self.step():
            pass
        return self.results

    # -- cancellation --------------------------------------------------
    def cancel(self, rid: int, t: Optional[float] = None) -> bool:
        """Abort a request at any lifecycle stage. `t=None` applies at
        the loop's current time; otherwise a cancel event is scheduled.
        Returns False if the request is unknown or already terminal."""
        state = self._states.get(rid)
        if state is None or state.done:
            return False
        if t is None:
            self._apply_cancel(state, self._ev.now)
        else:
            self._ev.push(t, "cancel", state)
        return True

    def _apply_cancel(self, state: RequestState, t: float):
        if state.done:
            return
        self._do_cancel(state, t)
        # tokens stamped beyond the cancel point never happened
        state.events = [e for e in state.events if e.t <= t]
        seq = state.seq
        if seq is not None:
            drop = seq.produced - len(state.events)
            if drop > 0:
                del seq.tokens[-drop:]
                seq.produced = len(state.events)
            seq.done = True
        state.finish(t, FINISH_CANCELLED)
        self._store_result(state)

    # -- lifecycle plumbing for subclasses -----------------------------
    @property
    def _recording(self) -> bool:
        return (self._record_tokens or self.tracker is not None
                or self.tracer.enabled)

    def _emit_token(self, state: RequestState, token: int, t: float):
        if not self._record_tokens and self.tracker is None \
                and state.on_token is None and not self.tracer.enabled:
            return
        state.record_token(token, t)
        if self.tracer.enabled:
            self.tracer.event("token", t, rid=state.rid,
                              i=len(state.events) - 1)
        if self.tracker is not None:
            self.tracker.observe_event(state, state.events[-1])

    def _finish_state(self, state: RequestState, t: float,
                      reason: Optional[str] = None):
        if state.done:
            return
        if reason is None:
            reason = (state.seq.finish_reason if state.seq is not None
                      else FINISH_LENGTH)
        state.finish(t, reason)
        self._store_result(state)

    def _store_result(self, state: RequestState):
        seq = state.seq
        if seq is not None:     # sync cache hits back onto the Request
            state.request.prefix_hit = seq.prefix_hit
            state.request.decode_hit = seq.decode_hit
            # decode iterations that ran (sim backends maintain this
            # themselves); keeps Request.tpot meaningful on early stops
            state.request.tokens_done = max(len(state.events) - 1, 0)
        self.results[state.rid] = ServedResult.from_state(state)
        self._forget(state.rid)
        if self.tracer.enabled:
            self.tracer.finish_phase(state.rid, state.request.finish,
                                     state.status.name)
        if self.metrics is not None:
            self._observe_metrics(state)
        if self.tracker is not None:
            self.tracker.observe_finish(state)

    def _observe_metrics(self, state: RequestState):
        m, req, n = self.metrics, state.request, len(state.events)
        if state.status is RequestStatus.CANCELLED:
            m.counter("requests_shed" if state.finish_reason == FINISH_SHED
                      else "requests_cancelled")
        elif state.status is RequestStatus.FAILED:
            m.counter("requests_failed")
        else:
            m.counter("requests_finished")
            if n:
                m.observe("ttft_s", req.first_token - req.arrive)
                m.observe("e2e_s", req.finish - req.arrive)
            if n > 1:
                m.observe("tpot_s", (req.finish - req.first_token) / (n - 1))
        m.counter("tokens_emitted", n)

    def _forget(self, rid: int):
        """Drop per-request hot-loop bookkeeping once a request goes
        terminal (keeps fast paths enabled and containers bounded in
        long-running open-loop use)."""
        self._ontoken_rids.discard(rid)

    # subclass responsibilities
    def _do_submit(self, state: RequestState, t: float):
        raise NotImplementedError

    def _handle(self, t: float, kind: str, payload: Any):
        raise NotImplementedError

    def _do_cancel(self, state: RequestState, t: float):
        raise NotImplementedError


def sequence_tokens(cfg, request, rng) -> List[int]:
    """One place that turns a workload Request into engine token ids.

    Shared-prefix traces carry explicit ids (`request.tokens`); plain
    length-only requests draw them from `rng` — previously copied (with a
    hardcoded default_rng(0)) between `DisaggCluster.run` and
    `ColocatedCluster.run`.  The rng is owned by the backend and seeded
    by its explicit `seed` parameter; draws happen in submission order,
    so the legacy submit-all shims reproduce the historical streams.
    """
    if request.tokens is not None:
        return [int(t) % cfg.vocab_size for t in request.tokens]
    return rng.integers(1, cfg.vocab_size, size=request.in_len).tolist()
