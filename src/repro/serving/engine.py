"""Live single-instance inference engine (CPU-runnable, TPU-shaped).

KV storage is *paged* for plain-attention archs (dense/GQA/MoE/VLM without
sliding windows): a pool of fixed-size pages plus per-sequence block
tables, managed by `KVCacheManager`. Prefill caches are spliced in at page
granularity (a block-table update + O(pages) scatter, never a full-cache
rewrite) and decode dispatches through the `kernels/paged_decode` op.
State-carrying archs (SSM, hybrid, encdec, sliding-window ring caches)
fall back to the dense `max_batch x max_len` slot slab.

Prefill runs per-request, right-padded to length buckets (bounded
recompiles) — padding sits *after* the causal horizon and beyond `pos`, so
it is never attended. Archs whose prefill carries running state through
the sequence use exact lengths instead.

Step times are measured and accumulated on a virtual clock so a 1-CPU host
can emulate N concurrent instances honestly (used by the Table-2
simulator-accuracy experiment).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.api import build_model, supports_paged
from .api import FINISH_LENGTH, FINISH_STOP, SamplingParams
from .kv_cache import KVCacheManager, TRASH_PAGE
from .prefix_cache import RadixPrefixCache

_BUCKETS = (16, 32, 64, 128, 256, 512, 1024, 2048, 4096)


@dataclasses.dataclass
class KVBlob:
    """Migration payload handed to the transfer layer.

    On the fused prefix path `cache` carries only the *suffix* KV: the
    prefix tokens stay in the owning prefill engine's page pool, pinned
    via `prefix_pages` until `Engine.materialize_wire` stitches the wire
    payload (gathering only the pages the decode side actually needs) or
    `release_blob` drops the claim. Unpacks like the legacy
    `(cache, n_tok)` tuple for non-prefix consumers."""
    cache: Any
    n_tok: int
    prefix_tokens: int = 0
    prefix_pages: List[int] = dataclasses.field(default_factory=list)
    owner: Optional["Engine"] = None

    def __iter__(self):
        return iter((self.cache, self.n_tok))

    def __getitem__(self, i):
        return (self.cache, self.n_tok)[i]


def release_blob(blob):
    """Drop a blob's claim on its owner's prefix pages (no-op for legacy
    tuple blobs and for blobs already materialized)."""
    if isinstance(blob, KVBlob) and blob.prefix_pages:
        blob.owner.unpin(blob.prefix_pages)
        blob.prefix_pages = []


@dataclasses.dataclass
class PartialPrefill:
    """Resumable chunked-prefill state: the prompt's full block table is
    reserved at chunk 0; `done` tracks how many prompt tokens have KV
    resident in pool pages (cached prefix included)."""
    table: List[int]
    hit: int                    # cached-prefix tokens (page-aligned)
    done: int                   # resident prompt tokens (>= hit)
    chunks: int = 0             # chunks computed so far


@dataclasses.dataclass
class Sequence:
    rid: int
    tokens: List[int]
    out_len: int
    slot: int = -1
    produced: int = 0
    done: bool = False
    prefilled: int = 0          # resident prompt tokens (chunked prefill)
    prefix_hit: int = 0         # prefill-side cached-prefix tokens
    decode_hit: int = 0         # decode-side shared-prefix tokens
    kv_first: float = 0.0       # when the first layer's KV landed (stream)
    kv_full: float = 0.0        # when the last layer's KV lands (stream)
    sampling: Optional[SamplingParams] = None
    finish_reason: str = FINISH_LENGTH
    _rng: Any = None            # lazy, only for temperature > 0

    def append_token(self, tok: int):
        """Append one generated token and apply the stop conditions:
        a SamplingParams.stop id ends generation early (finish_reason
        "stop"); otherwise the out_len budget ends it ("length")."""
        self.tokens.append(tok)
        self.produced += 1
        sp = self.sampling
        if sp is not None and sp.stop and tok in sp.stop:
            self.done = True
            self.finish_reason = FINISH_STOP
        elif self.produced >= self.out_len:
            self.done = True

    def rng(self):
        if self._rng is None:
            sp = self.sampling or SamplingParams()
            self._rng = np.random.default_rng((sp.seed, self.rid))
        return self._rng


class Engine:
    def __init__(self, cfg, params=None, *, max_batch: int = 8,
                 max_len: int = 512, seed: int = 0, attn_blocks=(128, 128),
                 dtype=jnp.float32, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 paged: Optional[bool] = None,
                 prefix_cache: bool = False,
                 fused_prefix: Optional[bool] = None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.dtype = dtype
        if params is None:
            params = self.model.init(jax.random.PRNGKey(seed))
        self.params = self.model.cast(params, dtype)
        self.max_batch = max_batch
        self.max_len = max_len
        self.attn_blocks = attn_blocks
        # exact-length prefill for state-carrying families
        self.exact_len = (cfg.family in ("ssm", "hybrid", "encdec")
                          or cfg.sliding_window > 0)
        self.paged = supports_paged(cfg) if paged is None \
            else (paged and supports_paged(cfg))
        self.clock = 0.0                      # virtual seconds
        self.steps = 0
        self.prefill_tokens = 0               # tokens actually computed
        self.prefix_hit_tokens = 0            # tokens served from the tree
        self.decode_tokens = 0
        self.fused_dispatches = 0             # prefix_prefill kernel calls
        self.chunk_dispatches = 0             # chunked-prefill kernel calls
        if self.paged:
            pps = -(-max_len // page_size)
            # default pool: dense-slab-equivalent capacity + trash page 0
            num_pages = num_pages or (max_batch * pps + 1)
            assert num_pages >= pps + 1, \
                "page pool must fit at least one max_len sequence"
            self._kv = KVCacheManager(num_pages, page_size, max_len)
        else:
            self._kv = None
        self.prefix_caching = bool(prefix_cache and self.paged)
        self.prefix_cache = (RadixPrefixCache(page_size, allocator=self._kv)
                             if self.prefix_caching else None)
        # fused paged-prefix prefill (prefix_prefill kernel) is the default
        # on paged archs; the dense-gather fallback stays behind the flag
        # for non-paged archs and for A/B token-equality tests
        self.fused_prefix = (self.prefix_caching if fused_prefix is None
                             else bool(fused_prefix and self.prefix_caching))
        self._cache = self._empty_cache()
        self._partial: Dict[int, PartialPrefill] = {}
        self._slot_free = list(range(max_batch))
        self._prefill_fn: Dict[int, Any] = {}
        self._suffix_fn: Dict[Tuple[int, int], Any] = {}
        self._fused_fn: Dict[Tuple[int, int], Any] = {}
        self._insert_fn: Dict[Tuple[int, int], Any] = {}
        self._gather_fn: Dict[int, Any] = {}
        self._write_fn: Dict[Tuple[int, int], Any] = {}

        if self.paged:
            def _decode(params, cache, tokens):
                return self.model.decode_step_paged(params, cache, tokens)
        else:
            def _decode(params, cache, tokens):
                return self.model.decode_step(params, cache, tokens)
        self._decode_fn = jax.jit(_decode, donate_argnums=(1,))

    def stats(self) -> Dict[str, float]:
        """Pull-collector snapshot for a `MetricsRegistry`: cumulative
        dispatch counters plus page-pool occupancy and prefix-tree state
        when paged/prefix-caching."""
        out: Dict[str, float] = {
            "clock_s": self.clock, "steps": self.steps,
            "prefill_tokens": self.prefill_tokens,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "decode_tokens": self.decode_tokens,
            "fused_dispatches": self.fused_dispatches,
            "chunk_dispatches": self.chunk_dispatches,
            "slots_free": len(self._slot_free),
            "partial_prefills": len(self._partial),
        }
        if self._kv is not None:
            out.update(self._kv.stats())
        if self.prefix_caching:
            for k, v in self.prefix_cache.metrics().items():
                out[f"prefix.{k}"] = v
        return out

    # ---- cache plumbing ------------------------------------------------
    def _empty_cache(self):
        if self.paged:
            specs = self.model.paged_cache_specs(
                self.max_batch, self._kv.num_pages, self._kv.page_size,
                self.dtype, max_len=self.max_len)
        else:
            specs = self.model.cache_specs(self.max_batch, self.max_len,
                                           self.dtype)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)

    def _get_prefill_fn(self, bucket: int):
        if bucket not in self._prefill_fn:
            # paged engines emit a bucket-sized cache (the migration blob);
            # slab engines pad to max_len so the merge is a pure slot write
            target_len = None if self.paged else self.max_len
            # exact-length families take the final position's logits anyway
            # and their forward() signatures don't accept last_pos
            exact = self.exact_len

            def _pf(params, toks, last_pos):
                mod = self.model
                from ..models import api as _api
                m = _api._mod(mod.cfg)
                kw = {} if exact else {"last_pos": last_pos}
                logits, cache, _ = m.forward(
                    params, toks, mod.cfg, attn_blocks=self.attn_blocks,
                    return_cache=True, max_len=target_len, **kw)
                return logits, cache
            self._prefill_fn[bucket] = jax.jit(_pf)
        return self._prefill_fn[bucket]

    def _get_suffix_prefill_fn(self, bucket: int, n_prefix_pages: int):
        """Dense-gather fallback: prefill only the uncached suffix, with
        queries attending over the gathered prefix KV + themselves (exact
        attention, offset causal mask). `n_prefix_pages` is a power-of-two
        bucket — the gather is trash-padded to it and `plen` masks the
        padding — so the jit cache stays O(log pages), not O(pages)."""
        key = (bucket, n_prefix_pages)
        if key not in self._suffix_fn:
            def _sf(params, toks, prefix_kv, plen, offset, last_pos):
                mod = self.model
                from ..models import api as _api
                m = _api._mod(mod.cfg)
                logits, cache, _ = m.forward(
                    params, toks, mod.cfg, attn_blocks=self.attn_blocks,
                    return_cache=True, max_len=None, prefix_kv=prefix_kv,
                    prefix_len=plen, pos_offset=offset, last_pos=last_pos)
                return logits, cache
            self._suffix_fn[key] = jax.jit(_sf)
        return self._suffix_fn[key]

    def _get_fused_suffix_fn(self, bucket: int, n_prefix_pages: int):
        """Fused paged-prefix prefill: suffix queries attend over the
        prefix straight from the page pools through the `prefix_prefill`
        kernel — no dense prefix KV is ever materialized. `n_prefix_pages`
        is a power-of-two bucket; the block table is trash-padded to it
        and `plen` masks the padding."""
        key = (bucket, n_prefix_pages)
        if key not in self._fused_fn:
            seg_names = [k for k in self._cache if k.startswith("seg")]

            def _ff(params, toks, pools, table, plen, offset, last_pos):
                mod = self.model
                from ..models import api as _api
                m = _api._mod(mod.cfg)
                pages = {name: pools[name] for name in seg_names}
                logits, cache, _ = m.forward(
                    params, toks, mod.cfg, attn_blocks=self.attn_blocks,
                    return_cache=True, max_len=None, prefix_pages=pages,
                    prefix_table=table, prefix_len=plen,
                    pos_offset=offset, last_pos=last_pos)
                return logits, cache
            self._fused_fn[key] = jax.jit(_ff)
        return self._fused_fn[key]

    def _bucket_pages(self, n: int) -> int:
        """Power-of-two page-count bucket (capped at a full sequence) so
        long-running serving compiles O(log pages) suffix/gather variants
        instead of one per distinct prefix length."""
        pps = -(-self.max_len // self._kv.page_size)
        return min(1 << max(n - 1, 0).bit_length(), pps) if n else 0

    def _padded_page_ids(self, pages: List[int], n_bucket: int):
        return jnp.asarray(list(pages) + [TRASH_PAGE] * (n_bucket - len(pages)),
                           jnp.int32)

    def _get_gather_fn(self, n_pages: int):
        """Gather `n_pages` pool pages into a dense (layers, 1, n*ps, Hkv,
        hd) per-segment blob — used both as the suffix prefill's prefix KV
        and as the migration blob shipped to the decode side."""
        if n_pages not in self._gather_fn:
            ps = self._kv.page_size
            seg_names = [k for k in self._cache if k.startswith("seg")]

            def _g(cache, ids):
                out = {}
                for name in seg_names:
                    o = {}
                    for part in ("k", "v"):
                        pool = cache[name][part]   # (L, num_pages, ps, H, hd)
                        sel = pool[:, ids]
                        o[part] = sel.reshape(
                            pool.shape[0], n_pages * ps, *pool.shape[3:]
                        )[:, None]
                    out[name] = o
                return out
            self._gather_fn[n_pages] = jax.jit(_g)
        return self._gather_fn[n_pages]

    def _get_page_write_fn(self, n_splice: int, src_len: int):
        """Scatter a dense (layers, 1, src_len, Hkv, hd) blob into pool
        pages (the prefill-side twin of the insert splice — no block-table
        or pos rows, the prefill engine keeps those host-side)."""
        key = (n_splice, src_len)
        if key not in self._write_fn:
            ps = self._kv.page_size

            def _w(dst, src_segs, splice_ids):
                out = dict(dst)
                span = n_splice * ps
                for name, seg in src_segs.items():
                    k_src, v_src = seg["k"][:, 0], seg["v"][:, 0]
                    if src_len > span:
                        k_src, v_src = k_src[:, :span], v_src[:, :span]
                    elif src_len < span:
                        pad = [(0, 0), (0, span - src_len), (0, 0), (0, 0)]
                        k_src, v_src = jnp.pad(k_src, pad), jnp.pad(v_src, pad)
                    n = k_src.shape[0]
                    shp = (n, n_splice, ps) + k_src.shape[2:]
                    dk, dv = dst[name]["k"], dst[name]["v"]
                    out[name] = {
                        "k": dk.at[:, splice_ids].set(
                            k_src.reshape(shp).astype(dk.dtype)),
                        "v": dv.at[:, splice_ids].set(
                            v_src.reshape(shp).astype(dv.dtype)),
                    }
                return out
            self._write_fn[key] = jax.jit(_w, donate_argnums=(0,))
        return self._write_fn[key]

    # ---- public API -----------------------------------------------------
    def has_slot(self) -> bool:
        return bool(self._slot_free)

    @property
    def free_slots(self) -> int:
        return len(self._slot_free)

    @property
    def free_pages(self) -> int:
        return self._kv.free_pages if self.paged else self.free_slots

    @staticmethod
    def tokens_needed(seq: Sequence) -> int:
        """KV positions for the sequence's full residency: cached prompt +
        every remaining decode write. Invariant across prefill (prefill
        appends one token and bumps `produced` together)."""
        return len(seq.tokens) - 1 + seq.out_len - seq.produced

    def can_admit(self, seq: Sequence, n_shared_pages: int = 0) -> bool:
        """Pull-based admission signal: a free batch slot AND enough free
        KV pages for the whole residency (paper §4.3). Shared prefix pages
        don't need fresh pages, so admission gets easier with reuse. Under
        pressure, cached-but-unreferenced prefix subtrees are reclaimed
        (LRU) before rejecting — retained prefixes must never starve
        admission."""
        if not self._slot_free:
            return False
        if not self.paged:
            return True
        need = self._kv.pages_for(self.tokens_needed(seq)) - n_shared_pages
        if need > self._kv.free_pages and self.prefix_caching:
            self.prefix_cache.evict(need - self._kv.free_pages)
        return self._kv.can_admit(self.tokens_needed(seq), n_shared_pages)

    def reserve_for(self, seq: Sequence, n_shared: int = 0) -> int:
        """Hold the sequence's full residency ahead of its insert
        (streamed chunked admission: the grant lets the wire start while
        prefill is still computing). Returns the page count for
        `unreserve`; the later `insert_kv` allocates the same residency
        the reservation covered."""
        n = max(self._kv.pages_for(self.tokens_needed(seq)) - n_shared, 0)
        self._kv.reserve(n)
        return n

    def unreserve(self, n_pages: int):
        if n_pages:
            self._kv.unreserve(n_pages)

    # ---- prefix-cache surface ------------------------------------------
    def prefix_peek(self, tokens) -> int:
        """Routing probe: longest cached prefix (tokens), no LRU bump."""
        return self.prefix_cache.peek(tokens) if self.prefix_caching else 0

    def pin_prefix(self, tokens) -> Tuple[int, List[int]]:
        """Match + take a reference on the hit pages so they survive until
        `insert_kv` (eviction skips referenced pages). Returns
        (hit_tokens, page_ids); release with `unpin`."""
        if not self.prefix_caching:
            return 0, []
        hit, pages = self.prefix_cache.match(tokens)
        if pages:
            self._kv.acquire(pages)
        return hit, pages

    def unpin(self, pages: List[int]):
        if pages:
            self._kv.release(pages)

    def _bucket(self, n: int) -> int:
        b = next((b for b in _BUCKETS if n <= b), n)
        return min(max(b, n), self.max_len)

    def _forward_chunk(self, padded, ctx_pages: List[int], ctx_len: int,
                       last_pos: int, fused: bool):
        """One bounded prefill pass, shared by the whole-prompt prefix path
        and the chunked state machine: `padded` right-padded tokens attend
        over `ctx_len` tokens resident in `ctx_pages` (empty -> plain
        prefill) plus themselves under the offset causal mask. `last_pos`
        is the last *real* (unpadded) query position — `logits[0, 0]` is
        that row, the one first-token sampling must read. Returns
        (logits, cache, prefix_kv); `prefix_kv` is the dense gather, only
        on the non-fused fallback (callers stitch blobs from it)."""
        bucket = padded.shape[1]
        if not ctx_len:
            fn = self._get_prefill_fn(bucket)
            logits, cache = fn(self.params, jnp.asarray(padded),
                               jnp.asarray(last_pos, jnp.int32))
            return logits, cache, None
        npb = self._bucket_pages(len(ctx_pages))
        if fused:
            # fused hot path: queries attend over the context pages in
            # place (prefix_prefill kernel) — no dense gather at all
            self.fused_dispatches += 1
            table = self._padded_page_ids(ctx_pages, npb)[None]
            pools = {k: v for k, v in self._cache.items()
                     if k.startswith("seg")}
            fn = self._get_fused_suffix_fn(bucket, npb)
            logits, cache = fn(self.params, jnp.asarray(padded), pools,
                               table, jnp.asarray(ctx_len, jnp.int32),
                               jnp.asarray(ctx_len, jnp.int32),
                               jnp.asarray(last_pos, jnp.int32))
            return logits, cache, None
        # flagged fallback: dense gather padded to the page bucket, with
        # the padding masked out by plen
        prefix_kv = self._get_gather_fn(npb)(
            self._cache, self._padded_page_ids(ctx_pages, npb))
        fn = self._get_suffix_prefill_fn(bucket, npb)
        logits, cache = fn(self.params, jnp.asarray(padded), prefix_kv,
                           jnp.asarray(ctx_len, jnp.int32),
                           jnp.asarray(ctx_len, jnp.int32),
                           jnp.asarray(last_pos, jnp.int32))
        return logits, cache, prefix_kv

    def prefill_request(self, seq: Sequence) -> Tuple[int, Any, float]:
        """Run prefill; returns (first_token, kv_blob, step_time).

        With the prefix cache on, only the uncached suffix runs through
        the prefill kernel: the longest page-aligned cached prefix (capped
        so at least one suffix token remains to produce the first output
        logits) is gathered from the page pools and attended as context.
        The new full prompt pages are inserted into the radix tree for
        later requests, and the blob handed to the transfer layer is
        stitched from shared + fresh pages."""
        toks = np.asarray(seq.tokens, np.int32)
        S = len(toks)
        assert S < self.max_len, (S, self.max_len)
        if self.prefix_caching:
            return self._prefill_with_prefix(seq, toks)
        if self.exact_len:
            bucket = S
        else:
            bucket = self._bucket(S)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :S] = toks                                  # right-pad
        fn = self._get_prefill_fn(bucket)
        t0 = time.perf_counter()
        logits, cache = fn(self.params, jnp.asarray(padded),
                           jnp.asarray(S - 1, jnp.int32))
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        self.clock += dt
        self.steps += 1
        self.prefill_tokens += S
        first = self._sample_token(seq, logits[0, 0])
        return first, (cache, S), dt

    def _prefill_with_prefix(self, seq: Sequence, toks) -> Tuple[int, Any, float]:
        ps = self._kv.page_size
        S = len(toks)
        token_list = [int(t) for t in toks]
        hit, hit_pages = self.prefix_cache.match(token_list)
        # keep >= 1 suffix token: the first output comes from its logits
        hit = min(hit, ((S - 1) // ps) * ps)
        hit_pages = hit_pages[:hit // ps]
        if hit_pages:
            self._kv.acquire(hit_pages)     # pin across compute + eviction
        suffix = toks[hit:]
        Ssuf = len(suffix)
        bucket = self._bucket(Ssuf)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :Ssuf] = suffix
        t0 = time.perf_counter()
        fused = bool(hit and self.fused_prefix)
        logits, cache, prefix_kv = self._forward_chunk(
            padded, hit_pages, hit, Ssuf - 1, fused)
        first = self._sample_token(seq, logits[0, 0])

        # the migration blob: on the fused path it carries only the suffix
        # KV (the prefix stays pinned in the page pool until the transfer
        # layer materializes the wire payload); on the fallback path it is
        # stitched from the already-gathered prefix KV + fresh suffix
        # (never a second gather of the hit pages)
        blob_cache = {}
        for name, seg in cache.items():
            if not name.startswith("seg"):
                continue
            if prefix_kv is not None:
                pk = prefix_kv[name]
                blob_cache[name] = {
                    p: jnp.concatenate([pk[p][:, :, :hit], seg[p]], axis=2)
                    for p in ("k", "v")}
            else:
                blob_cache[name] = {p: seg[p] for p in ("k", "v")}

        # write the fresh suffix pages back into the pools and publish the
        # new full prompt pages in the radix tree for later requests (on
        # pool exhaustion the request simply isn't retained — eviction
        # already ran — and the blob above is still complete)
        total_pages = -(-S // ps)
        fresh_needed = total_pages - len(hit_pages)
        if fresh_needed > self._kv.free_pages:
            self.prefix_cache.evict(fresh_needed - self._kv.free_pages)
        if fresh_needed <= self._kv.free_pages:
            table = self._kv.alloc(seq.rid, S, shared=hit_pages)
            src_len = next(iter(
                c for k, c in cache.items() if k.startswith("seg")
            ))["k"].shape[2]
            self._cache = self._get_page_write_fn(fresh_needed, src_len)(
                self._cache, {k: v for k, v in cache.items()
                              if k.startswith("seg")},
                jnp.asarray(table[len(hit_pages):], jnp.int32))
            self.prefix_cache.insert(token_list[:(S // ps) * ps],
                                     table[:S // ps])
            self._kv.free(seq.rid)          # tree refs keep shared pages
        jax.block_until_ready(blob_cache)
        dt = time.perf_counter() - t0
        self.clock += dt
        self.steps += 1
        self.prefill_tokens += Ssuf
        self.prefix_hit_tokens += hit
        seq.prefix_hit = hit
        if fused:
            # the blob keeps the pin: pages must survive tree eviction
            # until materialize_wire/release_blob
            return first, KVBlob(blob_cache, S, prefix_tokens=hit,
                                 prefix_pages=hit_pages, owner=self), dt
        if hit_pages:
            self._kv.release(hit_pages)     # unpin
        return first, (blob_cache, S), dt

    # ---- chunked prefill (incremental state machine) --------------------
    def has_partial(self, seq: Sequence) -> bool:
        """True for a sequence mid-chunked-prefill on this engine: its
        whole residency is already reserved, so it can always resume (the
        scheduler may drain it past a page-blocked queue head)."""
        return seq.rid in self._partial

    def can_start_chunked(self, seq: Sequence) -> bool:
        """Admission gate for starting a chunked prefill: the *whole*
        prompt's pages are reserved at chunk 0 (minus the cached prefix),
        so later chunks never deadlock on pool space. Already-started
        sequences always resume."""
        if seq.rid in self._partial:
            return True
        S = len(seq.tokens)
        ps = self._kv.page_size
        hit = min(self.prefix_peek(seq.tokens), ((S - 1) // ps) * ps)
        need = -(-S // ps) - hit // ps
        if need > self._kv.free_pages and self.prefix_caching:
            self.prefix_cache.evict(need - self._kv.free_pages)
        return need <= self._kv.free_pages

    def prefill_chunk(self, seq: Sequence,
                      chunk_tokens: int) -> Tuple[bool, Optional[int],
                                                  Any, float, int]:
        """Run (at most) one more chunk of the sequence's prefill.

        Chunk k's queries attend over chunks 0..k-1's KV resident in pool
        pages through the fused `prefix_prefill` kernel (same offset
        causal mask as the prefix-cache path), and the chunk's fresh KV is
        written *directly into pool pages* — no dense per-request blob is
        ever materialized on this path. Non-final chunks are rounded down
        to whole pages (>= 1 page) so the next chunk's page writes never
        clobber a partially-filled page; the final chunk takes the ragged
        tail. Returns ``(done, first_token, blob, dt, new_tokens)`` —
        `first_token`/`blob` are None until the final chunk, where the
        blob is fully page-backed (`prefix_tokens == n_tok`, pages pinned
        until `materialize_wire`/`release_blob`)."""
        assert self.paged, "chunked prefill needs the paged runtime"
        toks = np.asarray(seq.tokens, np.int32)
        S = len(toks)
        assert S < self.max_len, (S, self.max_len)
        ps = self._kv.page_size
        t0 = time.perf_counter()
        st = self._partial.get(seq.rid)
        if st is None:
            token_list = [int(t) for t in toks]
            hit, hit_pages = (self.prefix_cache.match(token_list)
                              if self.prefix_caching else (0, []))
            # keep >= 1 suffix token: the first output needs its logits
            hit = min(hit, ((S - 1) // ps) * ps)
            hit_pages = hit_pages[:hit // ps]
            if hit_pages:
                self._kv.acquire(hit_pages)  # pin across eviction
            need = -(-S // ps) - len(hit_pages)
            if need > self._kv.free_pages and self.prefix_caching:
                self.prefix_cache.evict(need - self._kv.free_pages)
            table = self._kv.alloc(seq.rid, S, shared=hit_pages)
            if hit_pages:
                self._kv.release(hit_pages)  # table refs hold them now
            st = PartialPrefill(list(table), hit, hit)
            self._partial[seq.rid] = st
        ctx = st.done
        c = min(chunk_tokens, S - ctx)
        if ctx + c < S:
            # non-final chunks end on a page boundary
            c = min(max((c // ps) * ps, ps), S - ctx)
        final = ctx + c == S
        padded = np.zeros((1, self._bucket(c)), np.int32)
        padded[0, :c] = toks[ctx:ctx + c]
        fused = self.fused_prefix if self.prefix_caching else True
        logits, cache, _ = self._forward_chunk(
            padded, st.table[:ctx // ps], ctx, c - 1, fused)
        first = self._sample_token(seq, logits[0, 0]) if final else None
        # in-place paged write of the chunk's fresh KV
        first_page = ctx // ps
        n_chunk_pages = -(-(ctx + c) // ps) - first_page
        segs = {k: v for k, v in cache.items() if k.startswith("seg")}
        src_len = next(iter(segs.values()))["k"].shape[2]
        self._cache = self._get_page_write_fn(n_chunk_pages, src_len)(
            self._cache, segs,
            jnp.asarray(st.table[first_page:first_page + n_chunk_pages],
                        jnp.int32))
        jax.block_until_ready(self._cache)
        dt = time.perf_counter() - t0
        self.clock += dt
        self.steps += 1
        self.prefill_tokens += c
        self.chunk_dispatches += 1
        st.done = ctx + c
        st.chunks += 1
        seq.prefilled = st.done
        if not final:
            return False, None, None, dt, c
        # close out: the blob is the page set itself — pin every page,
        # publish the full-page prefix in the radix tree, drop the table
        self._kv.acquire(st.table)
        if self.prefix_caching:
            self.prefix_cache.insert([int(t) for t in toks[:(S // ps) * ps]],
                                     st.table[:S // ps])
        self._kv.free(seq.rid)              # blob pins + tree refs remain
        blob = KVBlob({}, S, prefix_tokens=S,
                      prefix_pages=list(st.table), owner=self)
        del self._partial[seq.rid]
        self.prefix_hit_tokens += st.hit
        seq.prefix_hit = st.hit
        return True, first, blob, dt, c

    def abort_partial(self, seq: Sequence):
        """Cancel a mid-chunk prefill without leaking: drop the resumable
        state and release the whole reserved residency (shared head pages
        survive through their tree references)."""
        st = self._partial.pop(seq.rid, None)
        if st is not None:
            self._kv.free(seq.rid)
            seq.prefilled = 0

    def materialize_wire(self, blob, skip_tokens: int = 0):
        """Stitch the wire payload actually shipped to the decode side.

        For a fused-path `KVBlob`, gathers only the prefix pages beyond
        `skip_tokens` (positions the decode side already holds) and
        concatenates the suffix KV — the decode-side cached prefix is
        never gathered or shipped. Drops the blob's page pins. For legacy
        tuple blobs, slices the dense cache at `skip_tokens`. Returns the
        (cache, n_tok) tuple `insert_kv` consumes, whose seg token axis
        starts at position `skip_tokens`."""
        if not isinstance(blob, KVBlob):
            cache, n_tok = blob
            if skip_tokens:
                cache = {k: ({p: v[p][:, :, skip_tokens:] for p in ("k", "v")}
                             if k.startswith("seg") else v)
                         for k, v in cache.items()}
            return cache, n_tok
        ps = self._kv.page_size
        hit = blob.prefix_tokens
        Ssuf = blob.n_tok - hit
        out = {}
        if skip_tokens < hit:
            assert skip_tokens % ps == 0
            ship_pages = blob.prefix_pages[skip_tokens // ps:]
            npb = self._bucket_pages(len(ship_pages))
            pk = self._get_gather_fn(npb)(
                self._cache, self._padded_page_ids(ship_pages, npb))
            # the paged span may end ragged (chunked blobs carry the whole
            # prompt in pages, incl. an un-page-aligned tail)
            span = hit - skip_tokens
            for name in pk:
                pieces = {p: [pk[name][p][:, :, :span]] for p in ("k", "v")}
                if name in blob.cache:      # fused path: fresh suffix KV
                    for p in ("k", "v"):
                        pieces[p].append(blob.cache[name][p][:, :, :Ssuf])
                out[name] = {p: (pieces[p][0] if len(pieces[p]) == 1 else
                                 jnp.concatenate(pieces[p], axis=2))
                             for p in ("k", "v")}
        else:
            cut = skip_tokens - hit
            for name, seg in blob.cache.items():
                out[name] = {p: seg[p][:, :, cut:Ssuf] for p in ("k", "v")}
        release_blob(blob)
        return out, blob.n_tok

    def kv_blob_bytes(self, kv_blob) -> int:
        cache, _ = kv_blob
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))

    # ---- paged insert (block-table splice) ------------------------------
    def _get_insert_fn(self, n_splice: int, src_len: int):
        key = (n_splice, src_len)
        if key not in self._insert_fn:
            ps = self._kv.page_size

            def _ins(dst, src_segs, splice_ids, row, slot, n_tok):
                out = dict(dst)
                span = n_splice * ps
                for name, seg in src_segs.items():
                    k_src, v_src = seg["k"][:, 0], seg["v"][:, 0]
                    if src_len > span:
                        k_src, v_src = k_src[:, :span], v_src[:, :span]
                    elif src_len < span:
                        pad = [(0, 0), (0, span - src_len), (0, 0), (0, 0)]
                        k_src, v_src = jnp.pad(k_src, pad), jnp.pad(v_src, pad)
                    n = k_src.shape[0]
                    shp = (n, n_splice, ps) + k_src.shape[2:]
                    dk, dv = dst[name]["k"], dst[name]["v"]
                    out[name] = {
                        "k": dk.at[:, splice_ids].set(
                            k_src.reshape(shp).astype(dk.dtype)),
                        "v": dv.at[:, splice_ids].set(
                            v_src.reshape(shp).astype(dv.dtype)),
                    }
                out["block_tables"] = dst["block_tables"].at[slot].set(row)
                out["pos"] = dst["pos"].at[slot].set(n_tok)
                return out

            self._insert_fn[key] = jax.jit(_ins, donate_argnums=(0,))
        return self._insert_fn[key]

    def insert_kv(self, seq: Sequence, kv_blob, shared: List[int] = (),
                  skip_tokens: int = 0) -> int:
        """Install a transferred prefill cache.

        Paged: allocate the block table for the sequence's residency —
        `shared` pages (pinned via `pin_prefix`) head the table, covering
        the first `skip_tokens` positions, and the blob (which carries only
        the suffix KV beyond `skip_tokens`) is spliced into the fresh
        pages — touches O(suffix pages) of device memory, not the whole
        cache. Dense fallback: slot write into the slab."""
        cache, n_tok = kv_blob
        if self.paged:
            return self._insert_kv_paged(seq, cache, n_tok, shared,
                                         skip_tokens)
        assert not shared and not skip_tokens
        slot = self._slot_free.pop(0)
        seq.slot = slot

        def merge(dst, src):
            if dst.ndim == src.ndim:
                for ax in range(dst.ndim):
                    if (dst.shape[ax] == self.max_batch
                            and src.shape[ax] == 1
                            and dst.shape[:ax] == src.shape[:ax]):
                        idx = [slice(None)] * dst.ndim
                        idx[ax] = slot
                        # sequence axes may be shorter in src (bucket < max)
                        sl = tuple(slice(0, s) for s in src.shape)
                        src_sq = jnp.squeeze(src[sl], axis=ax)
                        full_idx = list(idx)
                        j = 0
                        for i2 in range(dst.ndim):
                            if i2 == ax:
                                continue
                            full_idx[i2] = slice(0, src_sq.shape[j])
                            j += 1
                        return dst.at[tuple(full_idx)].set(src_sq.astype(dst.dtype))
            return dst
        self._cache = jax.tree.map(merge, self._cache, cache)
        self._cache["pos"] = self._cache["pos"].at[slot].set(
            jnp.asarray(n_tok, jnp.int32))
        return slot

    def _insert_kv_paged(self, seq: Sequence, cache, n_tok: int,
                         shared: List[int] = (), skip_tokens: int = 0) -> int:
        ps = self._kv.page_size
        assert skip_tokens % ps == 0 and skip_tokens // ps == len(shared)
        need = self._kv.pages_for(max(self.tokens_needed(seq), n_tok))
        if need - len(shared) > self._kv.free_pages and self.prefix_caching:
            self.prefix_cache.evict(need - len(shared) - self._kv.free_pages)
        slot = self._slot_free.pop(0)
        seq.slot = slot
        # same residency formula the admission check approved
        page_ids = self._kv.alloc(seq.rid, max(self.tokens_needed(seq), n_tok),
                                  shared=shared)
        n_prompt = min(-(-n_tok // ps), len(page_ids))
        n_splice = n_prompt - len(shared)
        splice_ids = page_ids[len(shared):n_prompt]
        row = jnp.asarray(self._kv.padded_table(seq.rid), jnp.int32)
        if n_splice > 0:
            src_segs = {k: v for k, v in cache.items() if k.startswith("seg")}
            src_len = next(iter(src_segs.values()))["k"].shape[2]
            fn = self._get_insert_fn(n_splice, src_len)
            self._cache = fn(
                self._cache, src_segs,
                jnp.asarray(splice_ids, jnp.int32),
                row, jnp.asarray(slot, jnp.int32),
                jnp.asarray(n_tok, jnp.int32))
        else:   # fully shared prompt: just point the slot at the table
            self._cache["block_tables"] = \
                self._cache["block_tables"].at[slot].set(row)
            self._cache["pos"] = self._cache["pos"].at[slot].set(
                jnp.asarray(n_tok, jnp.int32))
        if self.prefix_caching:
            # publish the full prompt pages for future shared-prefix hits
            n_full = n_tok // ps
            self.prefix_cache.insert(seq.tokens[:n_full * ps],
                                     page_ids[:n_full])
            seq.decode_hit = skip_tokens
        return slot

    def release(self, seq: Sequence):
        if seq.slot >= 0:
            if self.paged:
                self._kv.free(seq.rid)
                # repoint the slot at the trash page; later writes are inert
                self._cache["block_tables"] = (
                    self._cache["block_tables"].at[seq.slot].set(TRASH_PAGE))
                self._cache["pos"] = self._cache["pos"].at[seq.slot].set(0)
            self._slot_free.append(seq.slot)
            seq.slot = -1

    def cancel(self, seq: Sequence, pinned: List[int] = ()):
        """Abort a sequence at any lifecycle stage without leaking: drop
        any prefix pins taken on its behalf (`pin_prefix` references held
        while it was parked in transfer) and free its pages/slot if it was
        resident. Safe to call for sequences that never reached this
        engine (both paths no-op on nothing-held)."""
        if pinned:
            self.unpin(list(pinned))
        if self.paged:
            self.abort_partial(seq)
        self.release(seq)

    def _sample_token(self, seq: Sequence, logits_row) -> int:
        """Greedy argmax (default) or temperature softmax sampling with
        the sequence's per-request rng."""
        sp = seq.sampling
        if sp is None or sp.temperature <= 0.0:
            return int(jnp.argmax(logits_row))
        x = np.asarray(logits_row, np.float64) / sp.temperature
        x -= x.max()
        p = np.exp(x)
        p /= p.sum()
        return int(seq.rng().choice(p.shape[0], p=p))

    def decode_step(self, seqs: List[Sequence]) -> float:
        """One decode iteration for all active sequences."""
        if not seqs:
            return 0.0
        tokens = np.zeros((self.max_batch,), np.int32)
        for s in seqs:
            tokens[s.slot] = s.tokens[-1]
        t0 = time.perf_counter()
        logits, self._cache = self._decode_fn(self.params, self._cache,
                                              jnp.asarray(tokens))
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        self.clock += dt
        self.steps += 1
        self.decode_tokens += len(seqs)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        sampled = any(s.sampling is not None and s.sampling.temperature > 0
                      for s in seqs)
        rows = np.asarray(logits) if sampled else None
        for s in seqs:
            if s.sampling is not None and s.sampling.temperature > 0:
                tok = self._sample_token(s, rows[s.slot])
            else:
                tok = int(nxt[s.slot])
            s.append_token(tok)
        return dt
