"""Live single-instance inference engine (CPU-runnable, TPU-shaped).

KV storage is *paged* for plain-attention archs (dense/GQA/MoE/VLM without
sliding windows): a pool of fixed-size pages plus per-sequence block
tables, managed by `KVCacheManager`. Prefill caches are spliced in at page
granularity (a block-table update + O(pages) scatter, never a full-cache
rewrite) and decode dispatches through the `kernels/paged_decode` op.
State-carrying archs (SSM, hybrid, encdec, sliding-window ring caches)
fall back to the dense `max_batch x max_len` slot slab.

Prefill runs per-request, right-padded to length buckets (bounded
recompiles) — padding sits *after* the causal horizon and beyond `pos`, so
it is never attended. Archs whose prefill carries running state through
the sequence use exact lengths instead.

Step times are measured and accumulated on a virtual clock so a 1-CPU host
can emulate N concurrent instances honestly (used by the Table-2
simulator-accuracy experiment).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.api import build_model, supports_paged
from .kv_cache import KVCacheManager, TRASH_PAGE

_BUCKETS = (16, 32, 64, 128, 256, 512, 1024, 2048, 4096)


@dataclasses.dataclass
class Sequence:
    rid: int
    tokens: List[int]
    out_len: int
    slot: int = -1
    produced: int = 0
    done: bool = False


class Engine:
    def __init__(self, cfg, params=None, *, max_batch: int = 8,
                 max_len: int = 512, seed: int = 0, attn_blocks=(128, 128),
                 dtype=jnp.float32, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 paged: Optional[bool] = None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.dtype = dtype
        if params is None:
            params = self.model.init(jax.random.PRNGKey(seed))
        self.params = self.model.cast(params, dtype)
        self.max_batch = max_batch
        self.max_len = max_len
        self.attn_blocks = attn_blocks
        # exact-length prefill for state-carrying families
        self.exact_len = (cfg.family in ("ssm", "hybrid", "encdec")
                          or cfg.sliding_window > 0)
        self.paged = supports_paged(cfg) if paged is None \
            else (paged and supports_paged(cfg))
        self.clock = 0.0                      # virtual seconds
        self.steps = 0
        self.prefill_tokens = 0
        self.decode_tokens = 0
        if self.paged:
            pps = -(-max_len // page_size)
            # default pool: dense-slab-equivalent capacity + trash page 0
            num_pages = num_pages or (max_batch * pps + 1)
            assert num_pages >= pps + 1, \
                "page pool must fit at least one max_len sequence"
            self._kv = KVCacheManager(num_pages, page_size, max_len)
        else:
            self._kv = None
        self._cache = self._empty_cache()
        self._slot_free = list(range(max_batch))
        self._prefill_fn: Dict[int, Any] = {}
        self._insert_fn: Dict[Tuple[int, int], Any] = {}

        if self.paged:
            def _decode(params, cache, tokens):
                return self.model.decode_step_paged(params, cache, tokens)
        else:
            def _decode(params, cache, tokens):
                return self.model.decode_step(params, cache, tokens)
        self._decode_fn = jax.jit(_decode, donate_argnums=(1,))

    # ---- cache plumbing ------------------------------------------------
    def _empty_cache(self):
        if self.paged:
            specs = self.model.paged_cache_specs(
                self.max_batch, self._kv.num_pages, self._kv.page_size,
                self.dtype, max_len=self.max_len)
        else:
            specs = self.model.cache_specs(self.max_batch, self.max_len,
                                           self.dtype)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)

    def _get_prefill_fn(self, bucket: int):
        if bucket not in self._prefill_fn:
            # paged engines emit a bucket-sized cache (the migration blob);
            # slab engines pad to max_len so the merge is a pure slot write
            target_len = None if self.paged else self.max_len
            def _pf(params, toks):
                mod = self.model
                from ..models import api as _api
                m = _api._mod(mod.cfg)
                logits, cache, _ = m.forward(
                    params, toks, mod.cfg, attn_blocks=self.attn_blocks,
                    return_cache=True, max_len=target_len)
                return logits, cache
            self._prefill_fn[bucket] = jax.jit(_pf)
        return self._prefill_fn[bucket]

    # ---- public API -----------------------------------------------------
    def has_slot(self) -> bool:
        return bool(self._slot_free)

    @property
    def free_slots(self) -> int:
        return len(self._slot_free)

    @property
    def free_pages(self) -> int:
        return self._kv.free_pages if self.paged else self.free_slots

    @staticmethod
    def tokens_needed(seq: Sequence) -> int:
        """KV positions for the sequence's full residency: cached prompt +
        every remaining decode write. Invariant across prefill (prefill
        appends one token and bumps `produced` together)."""
        return len(seq.tokens) - 1 + seq.out_len - seq.produced

    def can_admit(self, seq: Sequence) -> bool:
        """Pull-based admission signal: a free batch slot AND enough free
        KV pages for the whole residency (paper §4.3)."""
        if not self._slot_free:
            return False
        if not self.paged:
            return True
        return self._kv.can_admit(self.tokens_needed(seq))

    def prefill_request(self, seq: Sequence) -> Tuple[int, Any, float]:
        """Run prefill; returns (first_token, kv_blob, step_time)."""
        toks = np.asarray(seq.tokens, np.int32)
        S = len(toks)
        assert S < self.max_len, (S, self.max_len)
        if self.exact_len:
            bucket = S
        else:
            bucket = next((b for b in _BUCKETS if S <= b), S)
            bucket = min(max(bucket, S), self.max_len)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :S] = toks                                  # right-pad
        fn = self._get_prefill_fn(bucket)
        t0 = time.perf_counter()
        logits, cache = fn(self.params, jnp.asarray(padded))
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        self.clock += dt
        self.steps += 1
        self.prefill_tokens += S
        first = int(jnp.argmax(logits[0, S - 1]))
        return first, (cache, S), dt

    def kv_blob_bytes(self, kv_blob) -> int:
        cache, _ = kv_blob
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))

    # ---- paged insert (block-table splice) ------------------------------
    def _get_insert_fn(self, n_splice: int, src_len: int):
        key = (n_splice, src_len)
        if key not in self._insert_fn:
            ps = self._kv.page_size

            def _ins(dst, src_segs, splice_ids, row, slot, n_tok):
                out = dict(dst)
                span = n_splice * ps
                for name, seg in src_segs.items():
                    k_src, v_src = seg["k"][:, 0], seg["v"][:, 0]
                    if src_len > span:
                        k_src, v_src = k_src[:, :span], v_src[:, :span]
                    elif src_len < span:
                        pad = [(0, 0), (0, span - src_len), (0, 0), (0, 0)]
                        k_src, v_src = jnp.pad(k_src, pad), jnp.pad(v_src, pad)
                    n = k_src.shape[0]
                    shp = (n, n_splice, ps) + k_src.shape[2:]
                    dk, dv = dst[name]["k"], dst[name]["v"]
                    out[name] = {
                        "k": dk.at[:, splice_ids].set(
                            k_src.reshape(shp).astype(dk.dtype)),
                        "v": dv.at[:, splice_ids].set(
                            v_src.reshape(shp).astype(dv.dtype)),
                    }
                out["block_tables"] = dst["block_tables"].at[slot].set(row)
                out["pos"] = dst["pos"].at[slot].set(n_tok)
                return out

            self._insert_fn[key] = jax.jit(_ins, donate_argnums=(0,))
        return self._insert_fn[key]

    def insert_kv(self, seq: Sequence, kv_blob) -> int:
        """Install a transferred prefill cache.

        Paged: allocate the block table for the sequence's residency, then
        splice the blob's pages into the pools — touches O(prompt pages) of
        device memory, not the whole cache. Dense fallback: slot write into
        the slab."""
        cache, n_tok = kv_blob
        if self.paged:
            return self._insert_kv_paged(seq, cache, n_tok)
        slot = self._slot_free.pop(0)
        seq.slot = slot

        def merge(dst, src):
            if dst.ndim == src.ndim:
                for ax in range(dst.ndim):
                    if (dst.shape[ax] == self.max_batch
                            and src.shape[ax] == 1
                            and dst.shape[:ax] == src.shape[:ax]):
                        idx = [slice(None)] * dst.ndim
                        idx[ax] = slot
                        # sequence axes may be shorter in src (bucket < max)
                        sl = tuple(slice(0, s) for s in src.shape)
                        src_sq = jnp.squeeze(src[sl], axis=ax)
                        full_idx = list(idx)
                        j = 0
                        for i2 in range(dst.ndim):
                            if i2 == ax:
                                continue
                            full_idx[i2] = slice(0, src_sq.shape[j])
                            j += 1
                        return dst.at[tuple(full_idx)].set(src_sq.astype(dst.dtype))
            return dst
        self._cache = jax.tree.map(merge, self._cache, cache)
        self._cache["pos"] = self._cache["pos"].at[slot].set(
            jnp.asarray(n_tok, jnp.int32))
        return slot

    def _insert_kv_paged(self, seq: Sequence, cache, n_tok: int) -> int:
        slot = self._slot_free.pop(0)
        seq.slot = slot
        # same residency formula the admission check approved
        page_ids = self._kv.alloc(seq.rid, max(self.tokens_needed(seq), n_tok))
        ps = self._kv.page_size
        n_splice = min(-(-n_tok // ps), len(page_ids))
        src_segs = {k: v for k, v in cache.items() if k.startswith("seg")}
        src_len = next(iter(src_segs.values()))["k"].shape[2]
        fn = self._get_insert_fn(n_splice, src_len)
        self._cache = fn(
            self._cache, src_segs,
            jnp.asarray(page_ids[:n_splice], jnp.int32),
            jnp.asarray(self._kv.padded_table(seq.rid), jnp.int32),
            jnp.asarray(slot, jnp.int32), jnp.asarray(n_tok, jnp.int32))
        return slot

    def release(self, seq: Sequence):
        if seq.slot >= 0:
            if self.paged:
                self._kv.free(seq.rid)
                # repoint the slot at the trash page; later writes are inert
                self._cache["block_tables"] = (
                    self._cache["block_tables"].at[seq.slot].set(TRASH_PAGE))
                self._cache["pos"] = self._cache["pos"].at[seq.slot].set(0)
            self._slot_free.append(seq.slot)
            seq.slot = -1

    def decode_step(self, seqs: List[Sequence]) -> float:
        """One decode iteration for all active sequences."""
        if not seqs:
            return 0.0
        tokens = np.zeros((self.max_batch,), np.int32)
        for s in seqs:
            tokens[s.slot] = s.tokens[-1]
        t0 = time.perf_counter()
        logits, self._cache = self._decode_fn(self.params, self._cache,
                                              jnp.asarray(tokens))
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        self.clock += dt
        self.steps += 1
        self.decode_tokens += len(seqs)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s in seqs:
            tok = int(nxt[s.slot])
            s.tokens.append(tok)
            s.produced += 1
            if s.produced >= s.out_len:
                s.done = True
        return dt
