"""Live single-instance inference engine (CPU-runnable, TPU-shaped).

Slot-based KV cache: `max_batch` slots x `max_len` tokens. Prefill runs
per-request, right-padded to length buckets (bounded recompiles) — padding
sits *after* the causal horizon and beyond `pos`, so it is never attended.
Archs whose prefill carries running state through the sequence (SSM,
hybrid, sliding-window ring packing) use exact lengths instead.

Step times are measured and accumulated on a virtual clock so a 1-CPU host
can emulate N concurrent instances honestly (used by the Table-2
simulator-accuracy experiment).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.api import build_model

_BUCKETS = (16, 32, 64, 128, 256, 512, 1024, 2048, 4096)


@dataclasses.dataclass
class Sequence:
    rid: int
    tokens: List[int]
    out_len: int
    slot: int = -1
    produced: int = 0
    done: bool = False


class Engine:
    def __init__(self, cfg, params=None, *, max_batch: int = 8,
                 max_len: int = 512, seed: int = 0, attn_blocks=(128, 128),
                 dtype=jnp.float32):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.dtype = dtype
        if params is None:
            params = self.model.init(jax.random.PRNGKey(seed))
        self.params = self.model.cast(params, dtype)
        self.max_batch = max_batch
        self.max_len = max_len
        self.attn_blocks = attn_blocks
        # exact-length prefill for state-carrying families
        self.exact_len = (cfg.family in ("ssm", "hybrid", "encdec")
                          or cfg.sliding_window > 0)
        self.clock = 0.0                      # virtual seconds
        self.steps = 0
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self._cache = self._empty_cache()
        self._slot_free = list(range(max_batch))
        self._prefill_fn: Dict[int, Any] = {}

        def _decode(params, cache, tokens):
            return self.model.decode_step(params, cache, tokens)
        self._decode_fn = jax.jit(_decode, donate_argnums=(1,))

    # ---- cache plumbing ------------------------------------------------
    def _empty_cache(self):
        specs = self.model.cache_specs(self.max_batch, self.max_len,
                                       self.dtype)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)

    def _get_prefill_fn(self, bucket: int):
        if bucket not in self._prefill_fn:
            def _pf(params, toks):
                mod = self.model
                from ..models import api as _api
                m = _api._mod(mod.cfg)
                logits, cache, _ = m.forward(
                    params, toks, mod.cfg, attn_blocks=self.attn_blocks,
                    return_cache=True, max_len=self.max_len)
                return logits, cache
            self._prefill_fn[bucket] = jax.jit(_pf)
        return self._prefill_fn[bucket]

    # ---- public API -----------------------------------------------------
    def has_slot(self) -> bool:
        return bool(self._slot_free)

    @property
    def free_slots(self) -> int:
        return len(self._slot_free)

    def prefill_request(self, seq: Sequence) -> Tuple[int, Any, float]:
        """Run prefill; returns (first_token, kv_blob, step_time)."""
        toks = np.asarray(seq.tokens, np.int32)
        S = len(toks)
        assert S < self.max_len, (S, self.max_len)
        if self.exact_len:
            bucket = S
        else:
            bucket = next((b for b in _BUCKETS if S <= b), S)
            bucket = min(max(bucket, S), self.max_len)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :S] = toks                                  # right-pad
        fn = self._get_prefill_fn(bucket)
        t0 = time.perf_counter()
        logits, cache = fn(self.params, jnp.asarray(padded))
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        self.clock += dt
        self.steps += 1
        self.prefill_tokens += S
        first = int(jnp.argmax(logits[0, S - 1]))
        return first, (cache, S), dt

    def kv_blob_bytes(self, kv_blob) -> int:
        cache, _ = kv_blob
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))

    def insert_kv(self, seq: Sequence, kv_blob) -> int:
        """Install a transferred prefill cache into a free slot."""
        cache, n_tok = kv_blob
        slot = self._slot_free.pop(0)
        seq.slot = slot

        def merge(dst, src):
            if dst.ndim == src.ndim:
                for ax in range(dst.ndim):
                    if (dst.shape[ax] == self.max_batch
                            and src.shape[ax] == 1
                            and dst.shape[:ax] == src.shape[:ax]):
                        idx = [slice(None)] * dst.ndim
                        idx[ax] = slot
                        # sequence axes may be shorter in src (bucket < max)
                        sl = tuple(slice(0, s) for s in src.shape)
                        src_sq = jnp.squeeze(src[sl], axis=ax)
                        grow = [slice(0, n) for n in src_sq.shape]
                        full_idx = list(idx)
                        j = 0
                        for i2 in range(dst.ndim):
                            if i2 == ax:
                                continue
                            full_idx[i2] = slice(0, src_sq.shape[j])
                            j += 1
                        return dst.at[tuple(full_idx)].set(src_sq.astype(dst.dtype))
            return dst
        self._cache = jax.tree.map(merge, self._cache, cache)
        self._cache["pos"] = self._cache["pos"].at[slot].set(
            jnp.asarray(n_tok, jnp.int32))
        return slot

    def release(self, seq: Sequence):
        if seq.slot >= 0:
            self._slot_free.append(seq.slot)
            seq.slot = -1

    def decode_step(self, seqs: List[Sequence]) -> float:
        """One decode iteration for all active sequences."""
        if not seqs:
            return 0.0
        tokens = np.zeros((self.max_batch,), np.int32)
        for s in seqs:
            tokens[s.slot] = s.tokens[-1]
        t0 = time.perf_counter()
        logits, self._cache = self._decode_fn(self.params, self._cache,
                                              jnp.asarray(tokens))
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        self.clock += dt
        self.steps += 1
        self.decode_tokens += len(seqs)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s in seqs:
            tok = int(nxt[s.slot])
            s.tokens.append(tok)
            s.produced += 1
            if s.produced >= s.out_len:
                s.done = True
        return dt
