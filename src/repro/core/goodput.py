"""Goodput = max request rate served within SLOs at the attainment target,
per chip provisioned (the paper's objective)."""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

from .simulator import SimResult, summarize
from .workload import WorkloadSpec, sample_requests


@dataclasses.dataclass
class GoodputResult:
    rate: float                 # max sustainable total rate (req/s)
    per_chip: float             # rate / chips
    attain_at_rate: float
    chips: int


def attainment_at_rate(run_sim: Callable, spec: WorkloadSpec, rate: float,
                       n_requests: int = 400, seed: int = 0,
                       slo_scale: float = 1.0, min_duration_s: float = 45.0,
                       max_requests: int = 4000) -> SimResult:
    """Sample enough traffic to reach steady state at this rate: at least
    `min_duration_s` of arrivals (capped), measured past a warmup window."""
    n = int(min(max(n_requests, rate * min_duration_s), max_requests))
    reqs = sample_requests(spec, rate, n, seed=seed)
    reqs, extras = run_sim(reqs)
    return summarize(reqs, spec, slo_scale=slo_scale, extra=extras)


def max_goodput(run_sim: Callable, spec: WorkloadSpec, chips: int, *,
                target: float = 0.9, n_requests: int = 400, seed: int = 0,
                slo_scale: float = 1.0, lo: float = 0.05, hi: float = 512.0,
                iters: int = 12) -> GoodputResult:
    """Binary search the max rate with attainment >= target (paper §4.1)."""
    def attain(rate: float) -> float:
        return attainment_at_rate(run_sim, spec, rate, n_requests, seed,
                                  slo_scale).attain

    if attain(lo) < target:
        return GoodputResult(0.0, 0.0, attain(lo), chips)
    if attain(hi) >= target:   # saturates the search cap
        return GoodputResult(hi, hi / chips, target, chips)
    best = lo
    for _ in range(iters):
        mid = (lo + hi) / 2
        if attain(mid) >= target:
            best, lo = mid, mid
        else:
            hi = mid
        if hi - lo < 0.02 * max(lo, 0.1):
            break
    return GoodputResult(best, best / chips, attain(best), chips)


def min_slo_scale(run_sim: Callable, spec: WorkloadSpec, rate: float, *,
                  target: float = 0.9, n_requests: int = 400, seed: int = 0,
                  lo: float = 0.05, hi: float = 8.0, iters: int = 12) -> float:
    """Most stringent SLO scale sustainable at a fixed rate (Fig. 8 row 2)."""
    def ok(scale: float) -> bool:
        return attainment_at_rate(run_sim, spec, rate, n_requests, seed,
                                  scale).attain >= target

    if not ok(hi):
        return float("inf")
    best = hi
    for _ in range(iters):
        mid = (lo + hi) / 2
        if ok(mid):
            best, hi = mid, mid
        else:
            lo = mid
    return best
