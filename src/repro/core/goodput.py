"""Goodput = max request rate served within SLOs at the attainment target,
per chip provisioned (the paper's objective) — plus the online
`SLOTracker` every `ServingBackend` (live cluster or simulator) can feed
token events into, so attainment is one metrics object whether it comes
from a goodput binary search or a live streaming run."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from .simulator import SimResult, summarize
from .workload import WorkloadSpec, sample_requests


@dataclasses.dataclass
class GoodputResult:
    rate: float                 # max sustainable total rate (req/s)
    per_chip: float             # rate / chips
    attain_at_rate: float
    chips: int


@dataclasses.dataclass
class SLOViolation:
    """One request that missed its TTFT and/or TPOT SLO, with the latency
    attribution (when the backend carried a `Tracer`) naming the dominant
    cause of the miss."""
    rid: int
    ttft: float
    tpot: float
    ttft_over: float            # ttft / slo_ttft (1.0 = exactly at SLO)
    tpot_over: float
    attribution: Optional[object] = None    # telemetry.Attribution

    @property
    def severity(self) -> float:
        return max(self.ttft_over, self.tpot_over)

    def format(self) -> str:
        head = (f"rid={self.rid} ttft={self.ttft:.4f}s "
                f"({self.ttft_over:.2f}x slo) tpot={self.tpot:.4f}s "
                f"({self.tpot_over:.2f}x slo)")
        if self.attribution is None:
            return head
        return head + "\n    " + self.attribution.format()


@dataclasses.dataclass
class SLOReport:
    """Attainment snapshot (the unified metrics object: `summarize` embeds
    it in `SimResult.slo`; live benchmarks print it from the tracker)."""
    total: int = 0              # requests in the denominator
    finished: int = 0
    cancelled: int = 0
    failed: int = 0
    shed: int = 0               # router load-shed (finish_reason "shed")
    ttft_ok: int = 0
    tpot_ok: int = 0
    both_ok: int = 0
    worst_itl: float = 0.0      # max inter-token latency seen anywhere

    @property
    def ttft_attain(self) -> float:
        return self.ttft_ok / max(self.total, 1)

    @property
    def tpot_attain(self) -> float:
        return self.tpot_ok / max(self.total, 1)

    @property
    def attain(self) -> float:
        return self.both_ok / max(self.total, 1)


class SLOTracker:
    """Online per-token SLO attainment (paper §2: TTFT + TPOT per request).

    Backends feed it as tokens stream (`observe_event` on every
    `TokenEvent`, `observe_finish` when a request goes terminal) — pass
    one as `tracker=` to any `ServingBackend` — or in bulk from recorded
    latencies (`observe_result`, the path `simulator.summarize` uses).
    Cancelled/failed requests are counted but never enter the attainment
    numerator or denominator (an abandoned request has no SLO to meet).
    """

    def __init__(self, spec: WorkloadSpec, slo_scale: float = 1.0,
                 tracer=None):
        self.spec = spec
        self.slo_ttft = spec.slo_ttft * slo_scale
        self.slo_tpot = spec.slo_tpot * slo_scale
        self.tracer = tracer        # optional telemetry.Tracer: violations
                                    # get a per-request latency attribution
        self.violations: List[SLOViolation] = []
        self._ttft: Dict[int, float] = {}       # in-flight: rid -> ttft
        self._last_t: Dict[int, float] = {}
        self._itl_sum: Dict[int, float] = {}
        self._itl_n: Dict[int, int] = {}
        self._report = SLOReport()

    # -- streaming path (live backends and simulators) -------------------
    def observe_event(self, state, ev):
        rid = state.rid
        if ev.index == 0:
            self._ttft[rid] = ev.t - state.request.arrive
        else:
            itl = ev.t - self._last_t[rid]
            self._itl_sum[rid] = self._itl_sum.get(rid, 0.0) + itl
            self._itl_n[rid] = self._itl_n.get(rid, 0) + 1
            self._report.worst_itl = max(self._report.worst_itl, itl)
        self._last_t[rid] = ev.t

    def observe_finish(self, state):
        rid = state.rid
        ttft = self._ttft.pop(rid, None)
        n = self._itl_n.pop(rid, 0)
        tpot = self._itl_sum.pop(rid, 0.0) / n if n else 0.0
        self._last_t.pop(rid, None)
        from ..serving.api import FINISH_SHED, RequestStatus
        if state.status is RequestStatus.CANCELLED:
            # shed requests never entered service; count them apart from
            # user cancels so a router sweep can report shed rate directly
            if state.finish_reason == FINISH_SHED:
                self._report.shed += 1
            else:
                self._report.cancelled += 1
            return
        if state.status is RequestStatus.FAILED:
            self._report.failed += 1
            return
        ttft = ttft if ttft is not None else float("inf")
        self.observe_result(ttft, tpot)
        if ttft > self.slo_ttft or tpot > self.slo_tpot:
            att = None
            if self.tracer is not None and getattr(self.tracer, "enabled",
                                                   False):
                from .telemetry import attribute_request
                att = attribute_request(self.tracer, rid)
            self.violations.append(SLOViolation(
                rid, ttft, tpot, ttft / self.slo_ttft,
                tpot / self.slo_tpot, att))

    def top_violations(self, n: int = 3) -> List[SLOViolation]:
        """Worst SLO misses by severity (max of the TTFT/TPOT overrun)."""
        return sorted(self.violations, key=lambda v: -v.severity)[:n]

    # -- bulk path (summarize over recorded traces) ----------------------
    def observe_result(self, ttft: float, tpot: float):
        self._report.total += 1
        self._report.finished += 1
        ttft_ok = ttft <= self.slo_ttft
        tpot_ok = tpot <= self.slo_tpot
        self._report.ttft_ok += ttft_ok
        self._report.tpot_ok += tpot_ok
        self._report.both_ok += ttft_ok and tpot_ok

    # -- reporting -------------------------------------------------------
    def report(self, total: Optional[int] = None) -> SLOReport:
        """Snapshot; `total` overrides the denominator (e.g. to count
        still-unfinished requests against attainment, as `summarize`
        does for its steady-state window)."""
        rep = dataclasses.replace(self._report)
        if total is not None:
            rep.total = total
        return rep

    def summary(self) -> Dict[str, float]:
        rep = self.report()
        return {"finished": rep.finished, "cancelled": rep.cancelled,
                "failed": rep.failed, "shed": rep.shed,
                "ttft_attain": round(rep.ttft_attain, 4),
                "tpot_attain": round(rep.tpot_attain, 4),
                "attain": round(rep.attain, 4),
                "worst_itl": rep.worst_itl,
                "slo_ttft": self.slo_ttft, "slo_tpot": self.slo_tpot}


def attainment_at_rate(run_sim: Callable, spec: WorkloadSpec, rate: float,
                       n_requests: int = 400, seed: int = 0,
                       slo_scale: float = 1.0, min_duration_s: float = 45.0,
                       max_requests: int = 4000) -> SimResult:
    """Sample enough traffic to reach steady state at this rate: at least
    `min_duration_s` of arrivals (capped), measured past a warmup window."""
    n = int(min(max(n_requests, rate * min_duration_s), max_requests))
    reqs = sample_requests(spec, rate, n, seed=seed)
    reqs, extras = run_sim(reqs)
    return summarize(reqs, spec, slo_scale=slo_scale, extra=extras)


def max_goodput(run_sim: Callable, spec: WorkloadSpec, chips: int, *,
                target: float = 0.9, n_requests: int = 400, seed: int = 0,
                slo_scale: float = 1.0, lo: float = 0.05, hi: float = 512.0,
                iters: int = 12) -> GoodputResult:
    """Binary search the max rate with attainment >= target (paper §4.1)."""
    def attain(rate: float) -> float:
        return attainment_at_rate(run_sim, spec, rate, n_requests, seed,
                                  slo_scale).attain

    if attain(lo) < target:
        return GoodputResult(0.0, 0.0, attain(lo), chips)
    if attain(hi) >= target:   # saturates the search cap
        return GoodputResult(hi, hi / chips, target, chips)
    best = lo
    for _ in range(iters):
        mid = (lo + hi) / 2
        if attain(mid) >= target:
            best, lo = mid, mid
        else:
            hi = mid
        if hi - lo < 0.02 * max(lo, 0.1):
            break
    return GoodputResult(best, best / chips, attain(best), chips)


def min_slo_scale(run_sim: Callable, spec: WorkloadSpec, rate: float, *,
                  target: float = 0.9, n_requests: int = 400, seed: int = 0,
                  lo: float = 0.05, hi: float = 8.0, iters: int = 12) -> float:
    """Most stringent SLO scale sustainable at a fixed rate (Fig. 8 row 2)."""
    def ok(scale: float) -> bool:
        return attainment_at_rate(run_sim, spec, rate, n_requests, seed,
                                  scale).attain >= target

    if not ok(hi):
        return float("inf")
    best = hi
    for _ in range(iters):
        mid = (lo + hi) / 2
        if ok(mid):
            best, hi = mid, mid
        else:
            lo = mid
    return best
