"""Synthetic workloads emulating the paper's three applications (Fig. 7).

The datasets themselves aren't shipped offline; we fit the same shapes the
paper reports: ShareGPT (chat, medium in/out), HumanEval (short in, short
out), LongBench (very long in, short out) — lognormal lengths + Poisson
arrivals, seeded.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    arrive: float
    in_len: int
    out_len: int
    # prompt token ids; None -> lengths-only request (no prefix matching).
    # When set, len(tokens) == in_len and the live cluster feeds these ids
    # to the engines, so simulator and cluster see the same prefixes.
    tokens: Optional[Tuple[int, ...]] = None
    # trace-driven cancellation: a backend submitting this request also
    # schedules a cancel event at this virtual time (clamped to >= arrive)
    cancel_at: Optional[float] = None
    # filled by the simulator / engine
    prefill_start: float = -1.0
    first_token: float = -1.0      # TTFT reference point
    transfer_done: float = -1.0
    decode_admit: float = -1.0
    finish: float = -1.0
    finish_reason: Optional[str] = None   # length | stop | cancelled | failed
    tokens_done: int = 0
    prefix_hit: int = 0            # prefill-side cached-prefix tokens
    decode_hit: int = 0            # decode-side shared-prefix tokens

    @property
    def ttft(self) -> float:
        return self.first_token - self.arrive

    @property
    def tpot(self) -> float:
        if self.out_len <= 1:
            return 0.0
        if 0 < self.tokens_done < self.out_len - 1:
            # early termination (stop token / cancellation): average over
            # the decode iterations that actually ran
            return (self.finish - self.first_token) / self.tokens_done
        return (self.finish - self.first_token) / (self.out_len - 1)


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    in_mu: float
    in_sigma: float
    in_clip: Tuple[int, int]
    out_mu: float
    out_sigma: float
    out_clip: Tuple[int, int]
    slo_ttft: float     # seconds (paper Table 1 scale)
    slo_tpot: float
    # shared-prefix / multi-turn shape (0/1/0.0 -> plain independent
    # single-turn requests, the paper's original workloads). When any is
    # set, `sample_requests` emits explicit token ids so the prefix cache
    # (engine + simulator) can match them.
    sys_len: int = 0            # system-prompt tokens heading every prompt
    turns: int = 1              # requests per chat session (history grows)
    share: float = 0.0          # fraction of sessions on the shared prompt


SHAREGPT = WorkloadSpec("sharegpt", 5.0, 1.2, (4, 2048), 5.0, 1.0, (4, 2048),
                        slo_ttft=0.4, slo_tpot=0.1)
HUMANEVAL = WorkloadSpec("humaneval", 4.8, 0.6, (32, 1024), 4.2, 0.8, (16, 512),
                         slo_ttft=0.125, slo_tpot=0.2)
LONGBENCH = WorkloadSpec("longbench", 8.6, 0.8, (512, 32768), 4.6, 0.7, (16, 512),
                         slo_ttft=15.0, slo_tpot=0.15)

WORKLOADS = {w.name: w for w in (SHAREGPT, HUMANEVAL, LONGBENCH)}

# SLO stringency multipliers relative to the deployment's own latencies
# (the paper sets SLOs "empirically based on service target" against A100
# execution times — we keep the same *stringency ratios* but anchor them to
# the target chip + model, so the experiments remain meaningful across
# hardware). (ttft_mult x median prefill, tpot_mult x decode floor).
SLO_MULTS = {
    "sharegpt": (1.6, 2.0),
    "humaneval": (1.25, 3.0),
    "longbench": (6.0, 1.6),
}


def reference_tp(latency_model, hbm_frac: float = 0.5, max_tp: int = 16) -> int:
    """Smallest TP whose per-chip weight footprint is <= hbm_frac of HBM —
    matches the paper's memory regime (OPT-13B fp16 = 32% of an A100-80G)."""
    tp = 1
    while (latency_model.param_bytes() / tp
           > latency_model.chip.hbm_bytes * hbm_frac) and tp < max_tp:
        tp *= 2
    return tp


def derive_slos(spec: WorkloadSpec, latency_model,
                tp: Optional[int] = None) -> WorkloadSpec:
    """Anchor SLOs to the model x chip (paper Table 1 analogue).

    TTFT anchored on the p90 prompt's unloaded prefill time at the reference
    parallelism (a tail prompt must be feasible); TPOT on a *loaded*
    reference decode iteration (B=64)."""
    import numpy as np
    from .latency_model import Parallelism
    ttft_m, tpot_m = SLO_MULTS.get(spec.name, (1.6, 2.0))
    # anchor at most at node width (tp=8): bigger models get relaxed SLOs,
    # exactly as the paper relaxes OPT-175B's TTFT 20x vs OPT-13B
    tp = tp or min(reference_tp(latency_model), 8)
    p50_in = int(np.exp(spec.in_mu))
    p50_out = int(np.exp(spec.out_mu))
    p90_in = int(min(np.exp(spec.in_mu + 1.2816 * spec.in_sigma),
                     spec.in_clip[1]))
    par = Parallelism(tp, 1)
    ttft = ttft_m * latency_model.prefill_time([max(p90_in, 16)], par)
    ref_b = 64
    tpot = tpot_m * latency_model.decode_time(
        ref_b, ref_b * (p50_in + p50_out / 2), par)
    return dataclasses.replace(spec, slo_ttft=float(ttft), slo_tpot=float(tpot))


def sample_requests(spec: WorkloadSpec, rate: float, n: int,
                    seed: int = 0) -> List[Request]:
    if spec.turns > 1 or spec.sys_len > 0:
        return sample_multi_turn(spec, rate, n, seed=seed)
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    arrive = np.cumsum(gaps)
    in_lens = np.clip(rng.lognormal(spec.in_mu, spec.in_sigma, n).astype(int),
                      *spec.in_clip)
    out_lens = np.clip(rng.lognormal(spec.out_mu, spec.out_sigma, n).astype(int),
                       *spec.out_clip)
    return [Request(i, float(arrive[i]), int(in_lens[i]), int(out_lens[i]))
            for i in range(n)]


def sample_multi_turn(spec: WorkloadSpec, rate: float, n: int, *,
                      seed: int = 0, vocab: int = 32000,
                      think_s: Optional[float] = None) -> List[Request]:
    """Shared-prefix / multi-turn trace with explicit token ids (Nexus /
    "Inference without Interference": workload-aware disaggregation).

    Sessions arrive Poisson at ``rate / turns`` (total request rate stays
    ~``rate``). A fraction ``share`` of sessions opens with one global
    system prompt (``sys_len`` tokens — the cross-session shared prefix);
    the rest get private system prompts of the same length. Within a
    session, turn k's prompt is turn k-1's prompt + a stand-in assistant
    reply + fresh user tokens, so consecutive turns share a growing prefix
    (the multi-turn reuse the radix tree monetizes). Turn k+1 arrives a
    think-time gap after turn k. Prompts are trimmed to ``in_clip[1]``;
    a session whose history hits the cap restarts its context.

    The stand-in reply tokens are *not* the model's actual outputs — the
    trace is open-loop — but prefix matching only needs the bytes to be
    identical across requests, which they are.
    """
    assert spec.sys_len >= 0 and spec.turns >= 1
    rng = np.random.default_rng(seed)
    think = think_s if think_s is not None else max(2.0 / max(rate, 1e-9), 0.5)
    shared_sys = rng.integers(1, vocab, size=spec.sys_len).tolist()
    n_sessions = max(-(-n // spec.turns), 1)
    sess_rate = rate / spec.turns
    starts = np.cumsum(rng.exponential(1.0 / sess_rate, size=n_sessions))
    cap = spec.in_clip[1]
    reqs: List[Request] = []
    for s in range(n_sessions):
        if spec.sys_len and rng.random() < spec.share:
            history = list(shared_sys)
        else:
            history = rng.integers(1, vocab, size=spec.sys_len).tolist()
        t = float(starts[s])
        for _ in range(spec.turns):
            u = int(np.clip(rng.lognormal(spec.in_mu, spec.in_sigma),
                            *spec.in_clip))
            out = int(np.clip(rng.lognormal(spec.out_mu, spec.out_sigma),
                              *spec.out_clip))
            if len(history) + u > cap:          # context-cap reset
                history = history[:spec.sys_len]
            u = min(u, max(cap - len(history), 1))
            prompt = history + rng.integers(1, vocab, size=u).tolist()
            reqs.append(Request(0, t, len(prompt), out,
                                tokens=tuple(prompt)))
            # stand-in assistant reply extends the next turn's prefix
            history = prompt + rng.integers(1, vocab, size=out).tolist()
            t += float(rng.exponential(think)) + 1e-3
    reqs.sort(key=lambda r: r.arrive)
    reqs = reqs[:n] if n else reqs
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


def with_cancellations(reqs: List[Request], frac: float, *,
                       seed: int = 0,
                       mean_wait_s: float = 1.0) -> List[Request]:
    """Stamp `cancel_at` times onto a fraction of a trace (user abandons:
    close the tab, hit stop).  The cancel fires an exponential wait after
    arrival, so cancellations land at every lifecycle stage — queued,
    mid-prefill, parked in transfer, mid-decode.  Mutates and returns
    `reqs` (the same list shape every sampler produces)."""
    rng = np.random.default_rng(seed)
    for r in reqs:
        if rng.random() < frac:
            r.cancel_at = r.arrive + float(rng.exponential(mean_wait_s))
    return reqs


def fit_spec(reqs: List[Request], name: str = "fitted",
             slo_ttft: float = 0.4, slo_tpot: float = 0.1) -> WorkloadSpec:
    """Refit a lognormal spec from observed traffic (used by replanning)."""
    ins = np.array([max(r.in_len, 1) for r in reqs], float)
    outs = np.array([max(r.out_len, 1) for r in reqs], float)
    return WorkloadSpec(
        name,
        float(np.mean(np.log(ins))), float(np.std(np.log(ins)) + 1e-6),
        (int(ins.min()), int(ins.max())),
        float(np.mean(np.log(outs))), float(np.std(np.log(outs)) + 1e-6),
        (int(outs.min()), int(outs.max())),
        slo_ttft, slo_tpot)
