"""Placement search — Algorithm 1 (high node-affinity) and Algorithm 2 (low
node-affinity), plus the vLLM++ ablation (best colocated parallelism).

TPU adaptation: the paper's "node" (NVLink island, M GPUs) maps to an ICI
slice of M chips; "cross-node" bandwidth maps to DCN. Alg. 2's constraint —
prefill/decode instance segments of the same pipeline stage colocated on one
node so KV flows over the fast fabric — becomes "same ICI slice".
"""
from __future__ import annotations

import dataclasses
import math
import time
from functools import partial
from typing import Dict, List, Optional, Tuple

from . import hw
from .goodput import GoodputResult, SLOReport, attainment_at_rate, max_goodput
from .latency_model import LatencyModel, Parallelism
from .simulator import (InstanceConfig, simulate_colocated,
                        simulate_disaggregated, simulate_roles)
from .workload import WorkloadSpec


@dataclasses.dataclass
class PhasePlan:
    par: Parallelism
    goodput_per_chip: float     # req/s per chip at the attainment target


@dataclasses.dataclass
class Placement:
    prefill: PhasePlan
    decode: PhasePlan
    n_prefill: int
    n_decode: int
    kv_bandwidth: float
    algo: str
    search_s: float = 0.0
    # unified metrics snapshot of the chosen fleet at the target rate
    # (same SLOReport object live benchmarks produce from SLOTracker,
    # scored from per-token timestamps by the same summarize path)
    slo: Optional[SLOReport] = None

    @property
    def chips(self) -> int:
        return (self.n_prefill * self.prefill.par.num_chips
                + self.n_decode * self.decode.par.num_chips)

    def summary(self) -> Dict:
        out = {
            "algo": self.algo,
            "prefill": {"tp": self.prefill.par.tp, "pp": self.prefill.par.pp,
                        "count": self.n_prefill,
                        "goodput_per_chip": round(self.prefill.goodput_per_chip, 4)},
            "decode": {"tp": self.decode.par.tp, "pp": self.decode.par.pp,
                       "count": self.n_decode,
                       "goodput_per_chip": round(self.decode.goodput_per_chip, 4)},
            "chips": self.chips,
            "search_s": round(self.search_s, 2),
        }
        if self.slo is not None:
            out["attain_at_rate"] = round(self.slo.attain, 4)
        return out


def _fleet_slo(lm: LatencyModel, spec: WorkloadSpec, pre: PhasePlan,
               dec: PhasePlan, n: int, m: int, rate: float,
               transfer_bw: float, n_requests: int, seed: int) -> SLOReport:
    """One closing simulation of the *whole* chosen fleet at the target
    rate; the report (fed by per-token timestamps through SLOTracker) is
    attached to the Placement so operators see projected attainment, not
    just the per-phase goodputs the search optimized."""
    def run(reqs):
        return simulate_disaggregated(
            reqs, lm, InstanceConfig(pre.par, n), InstanceConfig(dec.par, m),
            transfer_bw=transfer_bw)
    res = attainment_at_rate(run, spec, rate, n_requests=n_requests,
                             seed=seed)
    return res.slo


def _fits(lm: LatencyModel, par: Parallelism, chip: hw.Chip,
          headroom: float = 0.8) -> bool:
    return lm.param_bytes() / par.num_chips <= chip.hbm_bytes * headroom


def _phase_goodput(lm: LatencyModel, par: Parallelism, spec: WorkloadSpec,
                   phase: str, *, target: float, n_requests: int,
                   transfer_bw: float, seed: int = 0) -> float:
    """Per-chip goodput of a single phase instance (simu_prefill/simu_decode)."""
    if phase == "prefill":
        def run(reqs):
            return simulate_disaggregated(
                reqs, lm, InstanceConfig(par, 1),
                InstanceConfig(par, 1),
                transfer_bw=1e15, phase="prefill")
    else:
        def run(reqs):
            return simulate_disaggregated(
                reqs, lm, InstanceConfig(par, 1),
                InstanceConfig(par, 1),
                transfer_bw=1e15, phase="decode")
    g = max_goodput(run, spec, par.num_chips, target=target,
                    n_requests=n_requests, seed=seed)
    return g.per_chip


def algo1_high_affinity(lm: LatencyModel, spec: WorkloadSpec, *,
                        rate: float,
                        n_node: int = 4, m_per_node: int = 8,
                        chip: hw.Chip = hw.DEFAULT,
                        target: float = 0.9, n_requests: int = 300,
                        seed: int = 0, final_slo: bool = True) -> Placement:
    """Paper Alg. 1: independent per-phase config search + replication.
    High cross-node bandwidth -> KV transfer over the full fabric.
    final_slo=False skips the closing fleet-level attainment sim (callers
    that only need the config, e.g. search-time benchmarks)."""
    t0 = time.time()
    transfer_bw = chip.ici_bw  # high-affinity: fast fabric everywhere
    best: Dict[str, Optional[PhasePlan]] = {"prefill": None, "decode": None}
    for intra in [2 ** i for i in range(int(math.log2(m_per_node)) + 1)]:
        max_pp = max(n_node * m_per_node // intra, 1)
        for inter in range(1, max_pp + 1):
            par = Parallelism(tp=intra, pp=inter)
            if not _fits(lm, par, chip):
                continue
            for phase in ("prefill", "decode"):
                g = _phase_goodput(lm, par, spec, phase, target=target,
                                   n_requests=n_requests,
                                   transfer_bw=transfer_bw, seed=seed)
                cur = best[phase]
                if cur is None or g > cur.goodput_per_chip:
                    best[phase] = PhasePlan(par, g)
    pre, dec = best["prefill"], best["decode"]
    assert pre is not None and dec is not None, "no feasible config"

    def _count(plan):
        g = plan.goodput_per_chip * plan.par.num_chips
        if g <= 1e-9:
            return 1          # infeasible at this SLO; report 1x honestly
        return max(math.ceil(rate / g), 1)
    n, m = _count(pre), _count(dec)
    search_s = time.time() - t0     # search work only: the closing SLO
                                    # sim below is validation, not search
    slo = _fleet_slo(lm, spec, pre, dec, n, m, rate, transfer_bw,
                     n_requests, seed) if final_slo else None
    return Placement(pre, dec, n, m, transfer_bw, "high-affinity",
                     search_s, slo=slo)


def algo2_low_affinity(lm: LatencyModel, spec: WorkloadSpec, *,
                       rate: float,
                       n_node: int = 4, m_per_node: int = 8,
                       chip: hw.Chip = hw.DEFAULT,
                       target: float = 0.9, n_requests: int = 300,
                       seed: int = 0, final_slo: bool = True) -> Placement:
    """Paper Alg. 2: prefill+decode segments of the same stage share a node;
    KV flows over intra-node fabric only. Searches (inter_op, intra-node
    split) jointly. final_slo as in algo1_high_affinity."""
    t0 = time.time()
    transfer_bw = chip.ici_bw * chip.ici_links  # intra-slice fabric
    best: Optional[Tuple[float, PhasePlan, PhasePlan]] = None
    for inter in range(1, n_node + 1):
        # per-node split: prefill_tp + decode_tp <= m_per_node (any ints,
        # the paper's OPT-175B placement uses tp=3)
        opts = list(range(1, m_per_node + 1))
        for ptp in opts:
            for dtp in opts:
                if ptp + dtp > m_per_node:
                    continue
                p_par = Parallelism(tp=ptp, pp=inter)
                d_par = Parallelism(tp=dtp, pp=inter)
                if not (_fits(lm, p_par, chip) and _fits(lm, d_par, chip)):
                    continue

                def run(reqs, p_par=p_par, d_par=d_par):
                    return simulate_disaggregated(
                        reqs, lm, InstanceConfig(p_par, 1),
                        InstanceConfig(d_par, 1),
                        transfer_bw=transfer_bw)
                chips = p_par.num_chips + d_par.num_chips
                g = max_goodput(run, spec, chips, target=target,
                                n_requests=n_requests, seed=seed)
                if best is None or g.per_chip > best[0]:
                    best = (g.per_chip,
                            PhasePlan(p_par, g.per_chip),
                            PhasePlan(d_par, g.per_chip))
    assert best is not None, "no feasible config"
    per_chip, pre, dec = best
    pair_chips = pre.par.num_chips + dec.par.num_chips
    if per_chip * pair_chips <= 1e-9:
        n = 1                 # infeasible at this SLO; report 1x honestly
    else:
        n = max(math.ceil(rate / (per_chip * pair_chips)), 1)
    search_s = time.time() - t0
    slo = _fleet_slo(lm, spec, pre, dec, n, n, rate, transfer_bw,
                     n_requests, seed) if final_slo else None
    return Placement(pre, dec, n, n, transfer_bw, "low-affinity",
                     search_s, slo=slo)


@dataclasses.dataclass
class ModePlacement:
    """Result of `mode_search`: the per-instance role vector for a fixed
    fleet of `len(roles)` identical instances, plus the attainment the
    closing simulation measured for it at the target rate."""
    roles: List[str]
    par: Parallelism
    mode: str                   # "disagg" | "colocated" | "mixed-k"
    attain: float
    slo: Optional[SLOReport] = None
    search_s: float = 0.0

    @property
    def chips(self) -> int:
        return len(self.roles) * self.par.num_chips

    def summary(self) -> Dict:
        return {"mode": self.mode, "roles": list(self.roles),
                "tp": self.par.tp, "pp": self.par.pp,
                "chips": self.chips, "attain": round(self.attain, 4),
                "search_s": round(self.search_s, 2)}


def mode_candidates(n_instances: int) -> List[Tuple[str, List[str]]]:
    """Candidate ``(mode, roles)`` vectors for a fleet of N identical
    instances: every pure disaggregated split, every mixed-k hybrid
    (k colocated instances riding with a disaggregated remainder), and
    fully colocated. Disaggregated splits come first so attainment ties
    resolve toward the paper's baseline architecture."""
    assert n_instances >= 1
    out: List[Tuple[str, List[str]]] = []
    for n_p in range(1, n_instances):
        out.append(("disagg", ["prefill"] * n_p
                    + ["decode"] * (n_instances - n_p)))
    for k in range(1, n_instances - 1):
        for n_p in range(1, n_instances - k):
            n_d = n_instances - k - n_p
            out.append((f"mixed-{k}", ["prefill"] * n_p
                        + ["decode"] * n_d + ["mixed"] * k))
    out.append(("colocated", ["mixed"] * n_instances))
    return out


def mode_search(lm: LatencyModel, spec: WorkloadSpec, *, rate: float,
                par: Parallelism, n_instances: int,
                chip: hw.Chip = hw.DEFAULT,
                transfer_bw: Optional[float] = None,
                chunk_tokens=None, absorb_tokens: Optional[int] = None,
                n_requests: int = 200, seed: int = 0) -> ModePlacement:
    """Mode-per-instance placement search: with roles as runtime state,
    the deployment mode itself becomes a placement axis. For a fixed
    fleet of `n_instances` identical instances, simulate every candidate
    role vector (`mode_candidates`) at the target rate and keep the one
    with the highest SLO attainment. The winning vector feeds
    `apply_roles` on a live fleet — re-roling existing replicas instead
    of rebuilding them (`serving.router.fleet_search`)."""
    t0 = time.time()
    bw = chip.ici_bw if transfer_bw is None else transfer_bw
    best: Optional[ModePlacement] = None
    for mode, roles in mode_candidates(n_instances):
        def run(reqs, roles=roles):
            return simulate_roles(reqs, lm, par, roles, transfer_bw=bw,
                                  chunk_tokens=chunk_tokens,
                                  absorb_tokens=absorb_tokens)
        res = attainment_at_rate(run, spec, rate, n_requests=n_requests,
                                 seed=seed)
        if best is None or res.slo.attain > best.attain:
            best = ModePlacement(list(roles), par, mode, res.slo.attain,
                                 slo=res.slo)
    assert best is not None
    best.search_s = time.time() - t0
    return best


def ratio_counts(prefill_gp: float, decode_gp: float,
                 p_chips: int, d_chips: int, max_total: int = 8):
    """Smallest (n_prefill, n_decode) replication matching per-phase
    instance goodputs (Alg. 1's n/m, normalized for simulation)."""
    gp = max(prefill_gp * p_chips, 1e-9)   # per prefill instance
    gd = max(decode_gp * d_chips, 1e-9)
    best = (1, 1, 1e18)
    for n in range(1, max_total):
        for m in range(1, max_total):
            if n + m > max_total:
                continue
            waste = abs(n * gp - m * gd) / max(n * gp, m * gd)
            if waste < best[2]:
                best = (n, m, waste)
    return best[0], best[1]


def vllm_pp_search(lm: LatencyModel, spec: WorkloadSpec, *,
                   rate: float, n_node: int = 4, m_per_node: int = 8,
                   chip: hw.Chip = hw.DEFAULT, target: float = 0.9,
                   n_requests: int = 300, seed: int = 0,
                   fixed: Optional[Parallelism] = None
                   ) -> Tuple[Parallelism, float]:
    """vLLM++ ablation: best colocated parallelism by the same simulator."""
    best: Optional[Tuple[float, Parallelism]] = None
    cands = ([fixed] if fixed else
             [Parallelism(tp, pp)
              for tp in [2 ** i for i in range(int(math.log2(m_per_node)) + 1)]
              for pp in range(1, n_node + 1)])
    for par in cands:
        if not _fits(lm, par, chip):
            continue

        def run(reqs, par=par):
            return simulate_colocated(reqs, lm, InstanceConfig(par, 1))
        g = max_goodput(run, spec, par.num_chips, target=target,
                        n_requests=n_requests, seed=seed)
        if best is None or g.per_chip > best[0]:
            best = (g.per_chip, par)
    assert best is not None
    return best[1], best[0]
