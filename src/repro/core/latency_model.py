"""Analytical latency model — Appendix A of the paper, adapted to TPU v5e.

The paper fits constants C1..C5 by profiling A100 kernels. We derive them
from first principles on the target chip (MXU peak x efficiency, HBM
bandwidth), keep the same structural form, and expose a `calibrate()` hook
that refits the efficiency knobs against measured engine step times (used
for the Table-2 simulator-accuracy experiment on CPU).

Forms (per instance, with tensor parallelism tp and pipeline pp):
  prefill:  T = GEMM_flops/(tp*peak*mm_eff) + attn_flops/(tp*peak*attn_eff)
              + comm(t) + C3
  decode:   T = (param_bytes/tp + kv_bytes)/HBM + comm_latency + C3'
SSM archs swap the per-token KV term for a constant state read.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from . import hw
from ..configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class Parallelism:
    tp: int = 1
    pp: int = 1

    @property
    def num_chips(self) -> int:
        return self.tp * self.pp


@dataclasses.dataclass
class LatencyModel:
    cfg: ModelConfig
    chip: hw.Chip = hw.DEFAULT
    dtype_bytes: int = 2
    # calibration multipliers (refit by calibrate())
    c_mm: float = 1.0
    c_attn: float = 1.0
    c_hbm: float = 1.0
    c_over: float = 1.0

    # ---- static model quantities ------------------------------------
    def param_bytes(self) -> float:
        return self.cfg.num_params() * self.dtype_bytes

    def active_param_bytes(self, batch: int = 1) -> float:
        """Bytes of weights actually read in a decode step (MoE-aware)."""
        c = self.cfg
        if c.family != "moe":
            return self.param_bytes()
        m = c.moe
        # activated experts: each token activates k of E; a batch of B
        # tokens touches ~E*(1-(1-k/E)^B) experts
        frac = 1.0 - (1.0 - m.num_experts_per_tok / m.num_experts) ** max(batch, 1)
        expert_p = (c.num_layers - m.first_k_dense) * m.num_experts * 3 * c.d_model * c.d_ff
        rest = c.num_params() - expert_p
        return (rest + expert_p * frac) * self.dtype_bytes

    def gemm_flops_per_token(self) -> float:
        c = self.cfg
        d = c.d_model
        attn_proj = 2 * d * (c.q_dim + 2 * c.kv_dim) + 2 * c.q_dim * d
        if c.family == "moe":
            m = c.moe
            ff = 6 * d * c.d_ff * (m.num_experts_per_tok + m.num_shared_experts)
            per_moe = attn_proj + ff
            per_dense = attn_proj + 6 * d * (m.dense_d_ff or c.d_ff)
            L_moe = c.num_layers - m.first_k_dense
            total = L_moe * per_moe + m.first_k_dense * per_dense
        elif c.family == "ssm":
            s = c.ssm
            d_in = s.expand * d
            gn = s.ngroups * s.state_dim
            nh = d_in // s.head_dim
            per = 2 * d * (2 * d_in + 2 * gn + nh) + 2 * d_in * d
            # ssd state flops ~ 6 * d_in * N per token
            per += 6 * d_in * s.state_dim
            total = c.num_layers * per
        elif c.family == "hybrid":
            s = c.ssm
            d_in = s.expand * d
            gn = s.ngroups * s.state_dim
            nh = d_in // s.head_dim
            per = 2 * d * (2 * d_in + 2 * gn + nh) + 2 * d_in * d + 6 * d_in * s.state_dim
            total = c.num_layers * per
            n_attn = c.num_layers // max(c.hybrid_attn_every, 1)
            total += n_attn * (attn_proj + 6 * d * c.d_ff)
        else:
            per = attn_proj + 6 * d * c.d_ff
            L = c.num_layers + c.encoder_layers
            total = L * per
            if c.is_encdec:
                total += c.num_layers * attn_proj  # cross-attention proj
        total += 2 * d * c.vocab_size  # lm head
        return float(total)

    def attn_flops(self, lens: Sequence[int]) -> float:
        """Score+PV flops for a prefill batch with given prompt lengths."""
        c = self.cfg
        if c.family == "ssm":
            return 0.0
        n_attn = c.num_layers + c.encoder_layers
        if c.family == "hybrid":
            n_attn = c.num_layers // max(c.hybrid_attn_every, 1)
        total = 0.0
        for l in lens:
            eff_l2 = l * min(l, c.sliding_window) if c.sliding_window else l * l
            # causal -> half the square
            total += 4 * c.q_dim * (eff_l2 / 2)
        return float(total) * n_attn

    def kv_read_bytes(self, ctx_tokens: float) -> float:
        """Decode-step KV bytes for `ctx_tokens` total cached tokens."""
        c = self.cfg
        if c.family == "ssm":
            s = c.ssm
            d_in = s.expand * c.d_model
            nh = d_in // s.head_dim
            state = nh * s.head_dim * s.state_dim * 4
            return c.num_layers * state  # per batch element, ctx-independent
        per_tok = c.kv_bytes_per_token(self.dtype_bytes)
        if c.sliding_window:
            # ring caches bound the window (approximation: all-local archs)
            pass
        return per_tok * ctx_tokens

    # ---- phase latencies --------------------------------------------
    def tp_comm_time(self, tokens: float, tp: int, layers: Optional[int] = None) -> float:
        """Per-layer activation all-reduces under TP (2 per layer)."""
        if tp <= 1:
            return 0.0
        c = self.cfg
        L = layers if layers is not None else (c.num_layers + c.encoder_layers)
        bytes_per = tokens * c.d_model * self.dtype_bytes
        wire = 2.0 * bytes_per * (tp - 1) / tp          # ring all-reduce
        bw = self.chip.ici_bw * min(self.chip.ici_links, 2)
        return L * (2 * (wire / bw + self.chip.coll_latency))

    def prefill_time(self, lens: Sequence[int], par: Parallelism) -> float:
        """One prefill batch (sum over pipeline stages = full latency)."""
        t = float(sum(lens))
        gemm = self.gemm_flops_per_token() * t
        attn = self.attn_flops(lens)
        chip = self.chip
        t_mm = self.c_mm * gemm / (par.tp * chip.peak_flops_bf16 * chip.mm_eff)
        t_at = self.c_attn * attn / (par.tp * chip.peak_flops_bf16 * chip.attn_eff)
        t_comm = self.tp_comm_time(t, par.tp)
        t_weights = self.param_bytes() / par.tp / (chip.hbm_bw * chip.hbm_eff)
        compute = max(t_mm + t_at + t_comm, t_weights)
        return compute + self.c_over * chip.step_overhead

    def prefill_stage_time(self, lens: Sequence[int], par: Parallelism) -> float:
        """Occupancy of one pipeline stage (admission interval under PP)."""
        return self.prefill_time(lens, par) / par.pp

    def attn_flops_chunked(self, pairs: Sequence[Sequence[int]]) -> float:
        """Score+PV flops for a chunked-prefill batch: each entry is
        ``(new_tokens, ctx_tokens)`` — the chunk's queries attend over the
        already-resident context plus themselves (causal within the
        chunk). ``ctx = 0`` reduces to `attn_flops([new])`."""
        c = self.cfg
        if c.family == "ssm":
            return 0.0
        n_attn = c.num_layers + c.encoder_layers
        if c.family == "hybrid":
            n_attn = c.num_layers // max(c.hybrid_attn_every, 1)
        total = 0.0
        for new, ctx in pairs:
            if c.sliding_window:
                w = c.sliding_window
                eff = new * min(ctx, w) + new * min(new, w) / 2
            else:
                eff = new * ctx + new * new / 2
            total += 4 * c.q_dim * eff
        return float(total) * n_attn

    def prefill_chunk_time(self, pairs: Sequence[Sequence[int]],
                           par: Parallelism) -> float:
        """One chunked-prefill batch: entries are ``(new, ctx)`` pairs.
        Linear (GEMM) work scales with the new tokens only; attention pays
        the new-tokens-vs-context cross term, so the sum over a prompt's
        chunks charges the same attention flops as one unchunked prefill
        plus one batch overhead per chunk."""
        t = float(sum(new for new, _ in pairs))
        gemm = self.gemm_flops_per_token() * t
        attn = self.attn_flops_chunked(pairs)
        chip = self.chip
        t_mm = self.c_mm * gemm / (par.tp * chip.peak_flops_bf16 * chip.mm_eff)
        t_at = self.c_attn * attn / (par.tp * chip.peak_flops_bf16 * chip.attn_eff)
        t_comm = self.tp_comm_time(t, par.tp)
        t_weights = self.param_bytes() / par.tp / (chip.hbm_bw * chip.hbm_eff)
        compute = max(t_mm + t_at + t_comm, t_weights)
        return compute + self.c_over * chip.step_overhead

    def decode_time(self, batch: int, ctx_tokens: float, par: Parallelism) -> float:
        """One decode iteration for `batch` sequences, total cached tokens."""
        chip = self.chip
        w_bytes = self.active_param_bytes(batch) / par.tp
        kv = self.kv_read_bytes(ctx_tokens) if self.cfg.family != "ssm" \
            else self.kv_read_bytes(0) * batch
        kv /= par.tp
        t_mem = self.c_hbm * (w_bytes + kv) / (chip.hbm_bw * chip.hbm_eff)
        gemm = self.gemm_flops_per_token() * batch
        t_mm = self.c_mm * gemm / (par.tp * chip.peak_flops_bf16 * chip.mm_eff)
        L = self.cfg.num_layers + self.cfg.encoder_layers
        t_comm = self.tp_comm_time(batch, par.tp) if par.tp > 1 else 0.0
        t = max(t_mem, t_mm) + t_comm + self.c_over * chip.step_overhead
        return t / 1.0

    def decode_stage_time(self, batch: int, ctx_tokens: float, par: Parallelism) -> float:
        return self.decode_time(batch, ctx_tokens, par) / par.pp

    # ---- derived knobs ------------------------------------------------
    def saturation_tokens(self, par: Parallelism) -> int:
        """L_m: prompt tokens at which prefill turns compute-bound — the
        paper's batch-formation threshold (§3.1 / §4.3)."""
        chip = self.chip
        per_tok_time = self.gemm_flops_per_token() / (
            par.tp * chip.peak_flops_bf16 * chip.mm_eff)
        weight_time = self.param_bytes() / par.tp / (chip.hbm_bw * chip.hbm_eff)
        lm = max(int(weight_time / per_tok_time), 1)
        return min(lm, 8192)

    def auto_chunk_tokens(self, par: Parallelism, *,
                          page_tokens: int = 16,
                          overhead_frac: float = 0.1,
                          ref_tokens: int = 2048) -> int:
        """Model-derived chunked-prefill chunk size: the smallest page
        multiple whose chunking cost on a `ref_tokens` prompt stays within
        ``overhead_frac`` of the unchunked prefill time.

        Chunking re-pays the per-batch overhead (`c_over *
        chip.step_overhead`) once per chunk and loses weight-read
        amortization on short chunks, so tiny chunks are expensive; huge
        chunks stall decode longer (the interference `prefill_chunk_time`
        charges when a chunk runs on a decode/mixed instance). This walks
        chunk sizes up one page at a time and returns the first that fits
        the overhead budget — callers keep `chunk_tokens=<int>` as a
        manual override.
        """
        page_tokens = max(int(page_tokens), 1)
        ref = max(int(ref_tokens), page_tokens)
        base = self.prefill_time([ref], par)
        budget = (1.0 + overhead_frac) * base
        c = page_tokens
        while c < ref:
            total, ctx = 0.0, 0
            while ctx < ref:
                new = min(c, ref - ctx)
                total += self.prefill_chunk_time([(new, ctx)], par)
                ctx += new
            if total <= budget:
                break
            c += page_tokens
        return min(c, ref)

    def kv_transfer_time(self, prompt_len: int, bandwidth: float) -> float:
        c = self.cfg
        if c.family == "ssm":
            return self.kv_read_bytes(0) / bandwidth
        eff_len = min(prompt_len, c.sliding_window) if c.sliding_window else prompt_len
        return c.kv_bytes_per_token(self.dtype_bytes) * eff_len / bandwidth

    def kv_transfer_first_layer_time(self, prompt_len: int,
                                     bandwidth: float) -> float:
        """Exposed transfer latency under per-layer streaming: layers ship
        back-to-back, decode starts attending when layer 1 lands, so only
        1/L of the wire time sits on the critical path before the first
        decode iteration (the rest overlaps per-layer compute)."""
        L = max(self.cfg.num_layers, 1)
        return self.kv_transfer_time(prompt_len, bandwidth) / L

    def max_decode_batch(self, avg_ctx: float, par: Parallelism,
                         reserve: float = 0.35) -> int:
        """KV-capacity bound on the decode batch (paper §3.2)."""
        c = self.cfg
        hbm = self.chip.hbm_bytes * par.num_chips
        free = hbm * (1 - reserve) - self.param_bytes()
        if free <= 0:
            return 0
        if c.family == "ssm":
            per_req = self.kv_read_bytes(0)
        else:
            eff = min(avg_ctx, c.sliding_window) if c.sliding_window else avg_ctx
            per_req = c.kv_bytes_per_token(self.dtype_bytes) * eff
        return max(int(free / max(per_req, 1.0)), 0)


@dataclasses.dataclass
class EngineCharge:
    """Deterministic virtual-clock charge model for live engines.

    A live `DisaggCluster` normally charges measured `perf_counter` kernel
    times to its event loop; with `charge=EngineCharge(lm, par)` it charges
    the analytic `LatencyModel` time for each dispatch instead, so a live
    run's event timeline — and therefore its trace spans — is
    float-identical to `SimDisaggBackend` on the same request trace.  The
    three hooks mirror exactly what the simulator charges:

      prefill  `lm.prefill_time(suffix_lens, par)` — lengths net of any
               prefix-cache hit, the same lens the sim batches.
      chunk    `lm.prefill_chunk_time([(new, ctx)], par)`.
      decode   `lm.decode_time(max(b/pp, 1), ctx/pp, Parallelism(tp, 1))` —
               the sim's per-stage effective-batch form.
    """
    lm: LatencyModel
    par: Parallelism = Parallelism()

    def prefill(self, suffix_lens: Sequence[int]) -> float:
        return self.lm.prefill_time(suffix_lens, self.par)

    def chunk(self, new: int, ctx: int) -> float:
        return self.lm.prefill_chunk_time([(new, ctx)], self.par)

    def decode(self, batch: int, ctx_tokens: float) -> float:
        eff_b = max(batch / self.par.pp, 1.0)
        return self.lm.decode_time(eff_b, ctx_tokens / self.par.pp,
                                   Parallelism(self.par.tp, 1))
