"""Periodic replanning (paper §4.3): a workload profiler watches arrival
rate and length distributions; on significant drift it re-runs the
placement algorithm on recent history. Weight reloads take minutes vs the
hourly timescale of drift, so replanning is treated as cheap.

`RoleController` is the fast inner loop the paper's replanner doesn't
have: on a role-unified backend (`SimServingBackend` /
`serving.cluster.ServingCluster`) an instance's prefill/decode/mixed role
is runtime state, so shifting capacity between phases needs no weight
reload at all — just a drain-and-flip. The controller watches the
backend's `pressure()` signal and flips one instance at a time with
hysteresis and a cooldown, seconds-scale rebalancing between the
minutes-scale replans."""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

from .workload import Request, WorkloadSpec, fit_spec


@dataclasses.dataclass
class WorkloadStats:
    rate: float
    mean_in: float
    mean_out: float
    n: int


class WorkloadProfiler:
    def __init__(self, window: int = 512):
        self.window: Deque[Request] = deque(maxlen=window)

    def observe(self, req: Request):
        self.window.append(req)

    def stats(self) -> Optional[WorkloadStats]:
        if len(self.window) < 16:
            return None
        rs = list(self.window)
        span = max(rs[-1].arrive - rs[0].arrive, 1e-6)
        return WorkloadStats(
            rate=(len(rs) - 1) / span,
            mean_in=sum(r.in_len for r in rs) / len(rs),
            mean_out=sum(r.out_len for r in rs) / len(rs),
            n=len(rs))


def drifted(old: WorkloadStats, new: WorkloadStats,
            rel_threshold: float = 0.3) -> bool:
    """Significant pattern shift -> trigger replan."""
    def rel(a, b):
        return abs(a - b) / max(abs(a), 1e-9)
    return (rel(old.rate, new.rate) > rel_threshold
            or rel(old.mean_in, new.mean_in) > rel_threshold
            or rel(old.mean_out, new.mean_out) > rel_threshold)


class RoleController:
    """Overload-driven runtime re-roling over a role-unified backend.

    The backend contract is the role-unified serving surface both worlds
    share: a ``roles`` property (per-instance role vector, birth order),
    ``pressure()`` (prefill queue depth / decode KV occupancy / loads) and
    ``set_role(g, role, now=...)``. Policy:

    * prefill backlog — queued prefill tokens per routable prefill
      instance above `prefill_high` while decode KV occupancy is below
      `kv_low` — flips one decode (or mixed) instance to prefill. The
      flip drains in place: the donor's resident KV finishes decoding
      where it sits, so no pages move.
    * KV pressure — decode page occupancy above `kv_high` while the
      prefill side is idle (queued tokens per instance under
      `prefill_low`) — flips one prefill (or mixed) instance to decode;
      prefill drains within a batch, there is no KV to move.

    One flip per `cooldown_s` (drains take time to pay off; flapping is
    worse than either static mode), floors on the surviving per-role
    counts, and the donor is always the highest-index instance of the
    donor role, so decisions are deterministic and replayable. Flips the
    backend rejects (they would strand arrivals or prefill output) are
    skipped. `flips` records ``(t, instance, role, reason)``.
    """

    def __init__(self, backend, *,
                 prefill_high: float = 2048.0,
                 prefill_low: float = 256.0,
                 kv_high: float = 0.85,
                 kv_low: float = 0.5,
                 cooldown_s: float = 1.0,
                 min_prefill: int = 1,
                 min_decode: int = 1):
        assert prefill_low <= prefill_high and kv_low <= kv_high
        self.backend = backend
        self.prefill_high = prefill_high
        self.prefill_low = prefill_low
        self.kv_high = kv_high
        self.kv_low = kv_low
        self.cooldown_s = cooldown_s
        self.min_prefill = min_prefill
        self.min_decode = min_decode
        self.flips: List[Tuple[float, int, str, str]] = []
        self._pending: Dict[int, str] = {}      # flips still draining
        self._last_flip = -math.inf

    def _roles(self) -> List[str]:
        """Effective per-instance roles: the backend's vector with
        still-draining flips applied (a draining instance already left
        the routing views; counting it as its old role would double-flip
        during long drains)."""
        roles = list(self.backend.roles)
        for g, r in list(self._pending.items()):
            if roles[g] == r:
                del self._pending[g]            # drain completed
            else:
                roles[g] = r
        return roles

    def _donor(self, roles: List[str], want: str) -> Optional[int]:
        for role in ("decode", "mixed") if want == "prefill" \
                else ("prefill", "mixed"):
            cand = [g for g, r in enumerate(roles)
                    if r == role and g not in self._pending]
            if cand:
                return cand[-1]
        return None

    def tick(self, now: float) -> Optional[Tuple[int, str]]:
        """Inspect pressure; start at most one role flip. Returns the
        ``(instance, new_role)`` it initiated, else None."""
        if now - self._last_flip < self.cooldown_s:
            return None
        p = self.backend.pressure()
        roles = self._roles()
        n_p = sum(r == "prefill" for r in roles)
        n_d = sum(r == "decode" for r in roles)
        queued = p["prefill_queued_tokens"] / max(n_p, 1)
        if (queued > self.prefill_high and p["decode_kv_util"] < self.kv_low
                and n_d > self.min_decode):
            g, role, reason = self._donor(roles, "prefill"), "prefill", \
                "prefill_backlog"
        elif (p["decode_kv_util"] > self.kv_high
                and queued < self.prefill_low and n_p > self.min_prefill):
            g, role, reason = self._donor(roles, "decode"), "decode", \
                "kv_pressure"
        else:
            return None
        if g is None or roles[g] == role:
            return None
        try:
            self.backend.set_role(g, role, now=now)
        except ValueError:
            return None                 # backend guard: flip would strand
        self._pending[g] = role
        self._last_flip = now
        self.flips.append((now, g, role, reason))
        return (g, role)


class Replanner:
    """Glue: profiler -> drift check -> placement search callback."""

    def __init__(self, search: Callable[[WorkloadSpec, float], object],
                 slo_ttft: float, slo_tpot: float,
                 check_every: int = 256):
        self.search = search
        self.profiler = WorkloadProfiler()
        self.baseline: Optional[WorkloadStats] = None
        self.slo = (slo_ttft, slo_tpot)
        self.check_every = check_every
        self._since_check = 0
        self.replans = 0
        self.current_placement = None

    def observe(self, req: Request):
        self.profiler.observe(req)
        self._since_check += 1
        if self._since_check >= self.check_every:
            self._since_check = 0
            self.maybe_replan()

    def maybe_replan(self) -> bool:
        stats = self.profiler.stats()
        if stats is None:
            return False
        if self.baseline is None:
            self.baseline = stats
            return False
        if not drifted(self.baseline, stats):
            return False
        spec = fit_spec(list(self.profiler.window), "drift",
                        self.slo[0], self.slo[1])
        self.current_placement = self.search(spec, stats.rate)
        self.baseline = stats
        self.replans += 1
        return True
