"""Periodic replanning (paper §4.3): a workload profiler watches arrival
rate and length distributions; on significant drift it re-runs the
placement algorithm on recent history. Weight reloads take minutes vs the
hourly timescale of drift, so replanning is treated as cheap."""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Callable, Deque, List, Optional

from .workload import Request, WorkloadSpec, fit_spec


@dataclasses.dataclass
class WorkloadStats:
    rate: float
    mean_in: float
    mean_out: float
    n: int


class WorkloadProfiler:
    def __init__(self, window: int = 512):
        self.window: Deque[Request] = deque(maxlen=window)

    def observe(self, req: Request):
        self.window.append(req)

    def stats(self) -> Optional[WorkloadStats]:
        if len(self.window) < 16:
            return None
        rs = list(self.window)
        span = max(rs[-1].arrive - rs[0].arrive, 1e-6)
        return WorkloadStats(
            rate=(len(rs) - 1) / span,
            mean_in=sum(r.in_len for r in rs) / len(rs),
            mean_out=sum(r.out_len for r in rs) / len(rs),
            n=len(rs))


def drifted(old: WorkloadStats, new: WorkloadStats,
            rel_threshold: float = 0.3) -> bool:
    """Significant pattern shift -> trigger replan."""
    def rel(a, b):
        return abs(a - b) / max(abs(a), 1e-9)
    return (rel(old.rate, new.rate) > rel_threshold
            or rel(old.mean_in, new.mean_in) > rel_threshold
            or rel(old.mean_out, new.mean_out) > rel_threshold)


class Replanner:
    """Glue: profiler -> drift check -> placement search callback."""

    def __init__(self, search: Callable[[WorkloadSpec, float], object],
                 slo_ttft: float, slo_tpot: float,
                 check_every: int = 256):
        self.search = search
        self.profiler = WorkloadProfiler()
        self.baseline: Optional[WorkloadStats] = None
        self.slo = (slo_ttft, slo_tpot)
        self.check_every = check_every
        self._since_check = 0
        self.replans = 0
        self.current_placement = None

    def observe(self, req: Request):
        self.profiler.observe(req)
        self._since_check += 1
        if self._since_check >= self.check_every:
            self._since_check = 0
            self.maybe_replan()

    def maybe_replan(self) -> bool:
        stats = self.profiler.stats()
        if stats is None:
            return False
        if self.baseline is None:
            self.baseline = stats
            return False
        if not drifted(self.baseline, stats):
            return False
        spec = fit_spec(list(self.profiler.window), "drift",
                        self.slo[0], self.slo[1])
        self.current_placement = self.search(spec, stats.rate)
        self.baseline = stats
        self.replans += 1
        return True
