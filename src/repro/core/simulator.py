"""Discrete-event simulator for disaggregated and colocated LLM serving.

Iteration-level fidelity, mirroring the runtime in repro/serving:
  * prefill instances: FCFS queues, batch formation up to the L_m token
    budget (paper §4.3), PP admission every T/pp with full-T latency
    (M/D/1-consistent), shortest-queue dispatch at arrival.
  * decode instances: continuous batching; per-iteration time from the
    analytical latency model; KV-capacity admission (pull-based transfer —
    requests stay buffered on the prefill side until the decode instance
    has room, paper §4.3 "combat burstiness").
  * colocated engine (vLLM-like baseline): prefill-priority iteration-level
    scheduling, decode stalls during prefill iterations (the interference
    the paper measures in Fig. 1/2).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Tuple

from .latency_model import LatencyModel, Parallelism
from .workload import Request, WorkloadSpec


@dataclasses.dataclass
class InstanceConfig:
    par: Parallelism
    count: int = 1


@dataclasses.dataclass
class SimResult:
    requests: List[Request]
    ttft_attain: float
    tpot_attain: float
    attain: float
    p50_ttft: float
    p90_ttft: float
    p50_tpot: float
    p90_tpot: float
    kv_transfer_total_s: float = 0.0
    kv_transfer_p95_s: float = 0.0
    breakdown: Optional[Dict[str, float]] = None


def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = min(int(q * len(xs)), len(xs) - 1)
    return xs[i]


def summarize(reqs: List[Request], spec: WorkloadSpec,
              slo_scale: float = 1.0,
              extra: Optional[Dict] = None,
              warmup_frac: float = 0.25) -> SimResult:
    """Attainment over the steady-state window (arrivals after warmup)."""
    if reqs:
        t_end = max(r.arrive for r in reqs)
        t_warm = t_end * warmup_frac
        reqs = [r for r in reqs if r.arrive >= t_warm] or reqs
    done = [r for r in reqs if r.finish >= 0]
    ttfts = [r.ttft for r in done]
    tpots = [r.tpot for r in done]
    ok_ttft = [r for r in done if r.ttft <= spec.slo_ttft * slo_scale]
    ok_tpot = [r for r in done if r.tpot <= spec.slo_tpot * slo_scale]
    ok = [r for r in done
          if r.ttft <= spec.slo_ttft * slo_scale
          and r.tpot <= spec.slo_tpot * slo_scale]
    n = max(len(reqs), 1)
    res = SimResult(
        requests=reqs,
        ttft_attain=len(ok_ttft) / n,
        tpot_attain=len(ok_tpot) / n,
        attain=len(ok) / n,
        p50_ttft=_percentile(ttfts, 0.5), p90_ttft=_percentile(ttfts, 0.9),
        p50_tpot=_percentile(tpots, 0.5), p90_tpot=_percentile(tpots, 0.9),
    )
    if extra:
        res.kv_transfer_total_s = extra.get("kv_total", 0.0)
        res.kv_transfer_p95_s = extra.get("kv_p95", 0.0)
        res.breakdown = extra.get("breakdown")
    return res


# ---------------------------------------------------------------------------
# Disaggregated simulation
# ---------------------------------------------------------------------------

class _PrefillInstance:
    def __init__(self, iid, lm: LatencyModel, par: Parallelism, lm_tokens: int):
        self.iid = iid
        self.lm = lm
        self.par = par
        self.budget = lm_tokens
        self.queue: List[Request] = []
        self.inflight = 0            # batches in the pipeline
        self.next_admit = 0.0
        self.queued_tokens = 0

    def can_admit(self, now: float) -> bool:
        return self.queue and self.inflight < self.par.pp

    def form_batch(self) -> List[Request]:
        batch = [self.queue.pop(0)]
        tok = batch[0].in_len
        while self.queue and tok + self.queue[0].in_len <= self.budget:
            r = self.queue.pop(0)
            tok += r.in_len
            batch.append(r)
        self.queued_tokens -= tok
        return batch


class _DecodeInstance:
    def __init__(self, iid, lm: LatencyModel, par: Parallelism,
                 kv_capacity: float, max_batch: int):
        self.iid = iid
        self.lm = lm
        self.par = par
        self.kv_capacity = kv_capacity   # bytes available for KV
        self.max_batch = max_batch
        self.kv_used = 0.0
        self.running: List[Request] = []
        self.ready: List[Request] = []    # transferred, awaiting admission
        self.busy = False

    @property
    def load(self) -> int:
        return len(self.running) + len(self.ready)

    def kv_bytes(self, r: Request) -> float:
        c = self.lm.cfg
        if c.family == "ssm":
            return self.lm.kv_read_bytes(0)
        n = r.in_len + r.out_len
        if c.sliding_window:
            n = min(n, c.sliding_window)
        return c.kv_bytes_per_token(self.lm.dtype_bytes) * n

    def can_admit(self, r: Request) -> bool:
        return (len(self.running) < self.max_batch
                and self.kv_used + self.kv_bytes(r) <= self.kv_capacity)

    def ctx_tokens(self) -> float:
        return float(sum(r.in_len + r.tokens_done for r in self.running))


def simulate_disaggregated(
        reqs: List[Request],
        lm: LatencyModel,
        prefill: InstanceConfig,
        decode: InstanceConfig,
        *,
        transfer_bw: float = 50e9,
        lm_tokens: Optional[int] = None,
        max_decode_batch: Optional[int] = None,
        kv_reserve: float = 0.1,
        phase: str = "both",
        horizon: float = 1e9) -> Tuple[List[Request], Dict]:
    """Returns (requests with timestamps, extras).

    phase="prefill": requests finish at first token (simu_prefill, Alg. 1);
    phase="decode": prefill is instantaneous (simu_decode, Alg. 1)."""
    lm_tok = lm_tokens or lm.saturation_tokens(prefill.par)
    cap = (lm.chip.hbm_bytes * decode.par.num_chips * (1 - kv_reserve)
           - lm.param_bytes())
    cap = max(cap, lm.chip.hbm_bytes * 0.05 * decode.par.num_chips)
    max_b = max_decode_batch or 4096

    P = [_PrefillInstance(i, lm, prefill.par, lm_tok)
         for i in range(prefill.count)]
    D = [_DecodeInstance(i, lm, decode.par, cap, max_b)
         for i in range(decode.count)]

    evq: List[Tuple[float, int, str, object]] = []
    ctr = itertools.count()
    push = lambda t, kind, payload: heapq.heappush(evq, (t, next(ctr), kind, payload))

    for r in reqs:
        push(r.arrive, "arrive", r)

    kv_times: List[float] = []
    busy_prefill = 0.0
    busy_decode = 0.0
    t_now = 0.0

    def try_start_prefill(p: _PrefillInstance, now: float):
        while p.can_admit(now):
            start = max(now, p.next_admit)
            if start > now:
                push(start, "prefill_poke", p)
                return
            batch = p.form_batch()
            T = lm.prefill_time([r.in_len for r in batch], p.par)
            p.next_admit = now + T / p.par.pp
            p.inflight += 1
            for r in batch:
                r.prefill_start = now
            push(now + T, "prefill_done", (p, batch, T))

    def try_start_decode(d: _DecodeInstance, now: float):
        nonlocal busy_decode
        if d.busy:
            return
        # pull-based admission: take from ready while KV capacity remains
        while d.ready and d.can_admit(d.ready[0]):
            r = d.ready.pop(0)
            r.decode_admit = now
            d.kv_used += d.kv_bytes(r)
            d.running.append(r)
        if not d.running:
            return
        d.busy = True
        eff_b = max(len(d.running) / d.par.pp, 1.0)
        tau = lm.decode_time(eff_b, d.ctx_tokens() / d.par.pp,
                             Parallelism(d.par.tp, 1))
        push(now + tau, "decode_iter", (d, tau))

    while evq:
        t_now, _, kind, payload = heapq.heappop(evq)
        if t_now > horizon:
            break
        if kind == "arrive":
            r = payload
            if phase == "decode":
                r.prefill_start = t_now
                r.first_token = t_now
                d = min(D, key=lambda x: x.load)
                push(t_now, "transfer_done", (d, r))
                continue
            p = min(P, key=lambda x: x.queued_tokens)
            p.queue.append(r)
            p.queued_tokens += r.in_len
            try_start_prefill(p, t_now)
        elif kind == "prefill_poke":
            try_start_prefill(payload, t_now)
        elif kind == "prefill_done":
            p, batch, T = payload
            p.inflight -= 1
            busy_prefill += T
            for r in batch:
                r.first_token = t_now
                if phase == "prefill":
                    r.finish = t_now
                    continue
                d = min(D, key=lambda x: x.load)
                tt = lm.kv_transfer_time(r.in_len, transfer_bw)
                kv_times.append(tt)
                push(t_now + tt, "transfer_done", (d, r))
            try_start_prefill(p, t_now)
        elif kind == "transfer_done":
            d, r = payload
            d.ready.append(r)
            try_start_decode(d, t_now)
        elif kind == "decode_iter":
            d, tau = payload
            busy_decode += tau
            d.busy = False
            still = []
            for r in d.running:
                r.tokens_done += 1
                if r.tokens_done >= r.out_len - 1 or r.out_len <= 1:
                    r.finish = t_now
                    d.kv_used -= d.kv_bytes(r)
                else:
                    still.append(r)
            d.running = still
            try_start_decode(d, t_now)

    extras = {
        "kv_total": sum(kv_times),
        "kv_p95": _percentile(kv_times, 0.95),
        "breakdown": {"prefill_busy_s": busy_prefill,
                      "decode_busy_s": busy_decode,
                      "lm_tokens": lm_tok, "max_decode_batch": max_b},
    }
    return reqs, extras


# ---------------------------------------------------------------------------
# Colocated (vLLM-like) simulation
# ---------------------------------------------------------------------------

def simulate_colocated(
        reqs: List[Request],
        lm: LatencyModel,
        inst: InstanceConfig,
        *,
        max_batch: Optional[int] = None,
        max_prefill_tokens: int = 2048,
        kv_reserve: float = 0.1,
        horizon: float = 1e9) -> Tuple[List[Request], Dict]:
    """Continuous batching with prefill-priority (vLLM v0 default)."""
    max_b = max_batch or 4096
    cap = (lm.chip.hbm_bytes * inst.par.num_chips * (1 - kv_reserve)
           - lm.param_bytes())
    cap = max(cap, lm.chip.hbm_bytes * 0.05 * inst.par.num_chips)

    def kv_bytes(r):
        c = lm.cfg
        if c.family == "ssm":
            return lm.kv_read_bytes(0)
        n = r.in_len + r.out_len
        if c.sliding_window:
            n = min(n, c.sliding_window)
        return c.kv_bytes_per_token(lm.dtype_bytes) * n

    class Engine:
        def __init__(self, iid):
            self.iid = iid
            self.waiting: List[Request] = []
            self.running: List[Request] = []
            self.kv_used = 0.0
            self.busy = False

        @property
        def load(self):
            return len(self.waiting) + len(self.running)

        def can_admit(self, r):
            return (len(self.running) < max_b
                    and self.kv_used + kv_bytes(r) <= cap)

    engines = [Engine(i) for i in range(inst.count)]
    evq: List[Tuple[float, int, str, object]] = []
    ctr = itertools.count()
    push = lambda t, kind, payload: heapq.heappush(evq, (t, next(ctr), kind, payload))
    for r in reqs:
        push(r.arrive, "arrive", r)

    def step(e: Engine, now: float):
        if e.busy:
            return
        # prefill first (vLLM prioritizes waiting prefills)
        if e.waiting and e.can_admit(e.waiting[0]):
            batch, tok = [], 0
            while (e.waiting and e.can_admit(e.waiting[0])
                   and (not batch or tok + e.waiting[0].in_len <= max_prefill_tokens)):
                r = e.waiting.pop(0)
                tok += r.in_len
                e.kv_used += kv_bytes(r)
                batch.append(r)
            if batch:
                e.busy = True
                T = lm.prefill_time([r.in_len for r in batch], inst.par)
                for r in batch:
                    r.prefill_start = now
                push(now + T, "prefill_done", (e, batch))
                return
        if e.running:
            e.busy = True
            eff_b = max(len(e.running) / inst.par.pp, 1.0)
            ctx = sum(r.in_len + r.tokens_done for r in e.running)
            tau = lm.decode_time(eff_b, ctx / inst.par.pp,
                                 Parallelism(inst.par.tp, 1))
            push(now + tau, "decode_iter", (e, tau))

    t_now = 0.0
    while evq:
        t_now, _, kind, payload = heapq.heappop(evq)
        if t_now > horizon:
            break
        if kind == "arrive":
            r = payload
            e = min(engines, key=lambda x: x.load)
            e.waiting.append(r)
            step(e, t_now)
        elif kind == "prefill_done":
            e, batch = payload
            e.busy = False
            for r in batch:
                r.first_token = t_now
                r.decode_admit = t_now
                e.running.append(r)
            step(e, t_now)
        elif kind == "decode_iter":
            e, tau = payload
            e.busy = False
            still = []
            for r in e.running:
                r.tokens_done += 1
                if r.tokens_done >= r.out_len - 1 or r.out_len <= 1:
                    r.finish = t_now
                    e.kv_used -= kv_bytes(r)
                else:
                    still.append(r)
            e.running = still
            step(e, t_now)

    return reqs, {"kv_total": 0.0, "kv_p95": 0.0, "breakdown": {}}
