"""Discrete-event simulator for disaggregated and colocated LLM serving.

Iteration-level fidelity, mirroring the runtime in repro/serving — batch
formation, dispatch, and pull-based admission all come from the shared
scheduler core in `core.scheduler` (the live cluster runs the same code):
  * prefill instances: FCFS queues (`FCFSQueue.form_batch` up to the L_m
    token budget, paper §4.3), PP admission every T/pp with full-T latency
    (M/D/1-consistent), shortest-queue dispatch at arrival.
  * decode instances: continuous batching; per-iteration time from the
    analytical latency model; *page-granular* KV admission via `PagePool` —
    finished prefills stay parked on the prefill side (`TransferManager`)
    until the decode instance has free pages, then transfer over the
    per-link wire (paper §4.3 "combat burstiness").
  * colocated engine (vLLM-like baseline): prefill-priority iteration-level
    scheduling, decode stalls during prefill iterations (the interference
    the paper measures in Fig. 1/2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .kv_transfer import TransferManager, kv_bytes
from .latency_model import LatencyModel, Parallelism
from .scheduler import (DisaggDispatcher, EventLoop, FCFSQueue, PagePool,
                        least_loaded)
from .workload import Request, WorkloadSpec
from ..serving.prefix_cache import RadixPrefixCache


@dataclasses.dataclass
class InstanceConfig:
    par: Parallelism
    count: int = 1


@dataclasses.dataclass
class SimResult:
    requests: List[Request]
    ttft_attain: float
    tpot_attain: float
    attain: float
    p50_ttft: float
    p90_ttft: float
    p50_tpot: float
    p90_tpot: float
    kv_transfer_total_s: float = 0.0
    kv_transfer_p95_s: float = 0.0
    breakdown: Optional[Dict[str, float]] = None


def _percentile(xs: List[float], q: float) -> float:
    """Linear-interpolated percentile (numpy's default 'linear' method)."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    if len(xs) == 1:
        return xs[0]
    pos = q * (len(xs) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def summarize(reqs: List[Request], spec: WorkloadSpec,
              slo_scale: float = 1.0,
              extra: Optional[Dict] = None,
              warmup_frac: float = 0.25) -> SimResult:
    """Attainment over the steady-state window (arrivals after warmup)."""
    if reqs:
        t_end = max(r.arrive for r in reqs)
        t_warm = t_end * warmup_frac
        reqs = [r for r in reqs if r.arrive >= t_warm] or reqs
    done = [r for r in reqs if r.finish >= 0]
    ttfts = [r.ttft for r in done]
    tpots = [r.tpot for r in done]
    ok_ttft = [r for r in done if r.ttft <= spec.slo_ttft * slo_scale]
    ok_tpot = [r for r in done if r.tpot <= spec.slo_tpot * slo_scale]
    ok = [r for r in done
          if r.ttft <= spec.slo_ttft * slo_scale
          and r.tpot <= spec.slo_tpot * slo_scale]
    n = max(len(reqs), 1)
    res = SimResult(
        requests=reqs,
        ttft_attain=len(ok_ttft) / n,
        tpot_attain=len(ok_tpot) / n,
        attain=len(ok) / n,
        p50_ttft=_percentile(ttfts, 0.5), p90_ttft=_percentile(ttfts, 0.9),
        p50_tpot=_percentile(tpots, 0.5), p90_tpot=_percentile(tpots, 0.9),
    )
    if extra:
        res.kv_transfer_total_s = extra.get("kv_total", 0.0)
        res.kv_transfer_p95_s = extra.get("kv_p95", 0.0)
        res.breakdown = extra.get("breakdown")
    return res


# ---------------------------------------------------------------------------
# Disaggregated simulation
# ---------------------------------------------------------------------------

class _PrefillInstance:
    def __init__(self, iid, lm: LatencyModel, par: Parallelism, lm_tokens: int,
                 tree: Optional[RadixPrefixCache] = None):
        self.iid = iid
        self.lm = lm
        self.par = par
        self.budget = lm_tokens
        self.queue: FCFSQueue = FCFSQueue(token_of=lambda r: r.in_len)
        self.inflight = 0            # batches in the pipeline
        self.next_admit = 0.0
        self.tree = tree             # prefix cache model (matches the live
                                     # engine's radix tree decisions)

    @property
    def queued_tokens(self) -> int:
        return self.queue.queued_tokens

    def can_admit(self) -> bool:
        return bool(self.queue.items) and self.inflight < self.par.pp

    def form_batch(self) -> List[Request]:
        return self.queue.form_batch(self.budget)


def _req_kv_bytes(lm: LatencyModel, r: Request) -> float:
    c = lm.cfg
    if c.family == "ssm":
        return lm.kv_read_bytes(0)
    n = r.in_len + r.out_len
    if c.sliding_window:
        n = min(n, c.sliding_window)
    return c.kv_bytes_per_token(lm.dtype_bytes) * n


class _DecodeInstance:
    def __init__(self, iid, lm: LatencyModel, par: Parallelism,
                 pool: PagePool, max_batch: int,
                 tree: Optional[RadixPrefixCache] = None):
        self.iid = iid
        self.lm = lm
        self.par = par
        self.pool = pool                 # page-granular KV admission
        self.max_batch = max_batch
        self.running: List[Request] = []
        self.pending: List[Request] = []  # parked on prefill side, assigned
        self.arrived: List[Request] = []  # transferred, joins at iter start
        self.in_transfer = 0
        self.busy = False
        self.tree = tree                 # decode-side shared-prefix model

    @property
    def load(self) -> int:
        return (len(self.running) + len(self.pending) + len(self.arrived)
                + self.in_transfer)

    def charge_pages(self, r: Request) -> int:
        """Fresh pages a request needs: full residency minus the pages its
        decode-side shared prefix already holds.

        Approximation: tree-*retained* pages (prefixes kept after their
        sequences finish) are not charged to the pool. The live engine
        does keep them resident, but reclaims them LRU on admission
        pressure (`Engine.can_admit`), so for admission purposes they
        behave as free; the residual error is the floor of pages actively
        shared by concurrent sequences (counted once live, zero here)."""
        full = self.pool.pages_for(_req_kv_bytes(self.lm, r))
        if self.tree is None or not r.decode_hit:
            return full
        page_tokens = self.tree.page_size
        return max(full - r.decode_hit // page_tokens, 0)

    def can_admit(self, r: Request) -> bool:
        resident = len(self.running) + len(self.arrived) + self.in_transfer
        return (resident < self.max_batch
                and self.pool.can_alloc(self.charge_pages(r)))

    def ctx_tokens(self) -> float:
        return float(sum(r.in_len + r.tokens_done for r in self.running))


def simulate_disaggregated(
        reqs: List[Request],
        lm: LatencyModel,
        prefill: InstanceConfig,
        decode: InstanceConfig,
        *,
        transfer_bw: float = 50e9,
        lm_tokens: Optional[int] = None,
        max_decode_batch: Optional[int] = None,
        kv_reserve: float = 0.1,
        page_tokens: int = 16,
        num_decode_pages: Optional[int] = None,
        dispatcher: Optional[DisaggDispatcher] = None,
        phase: str = "both",
        prefix_cache: Optional[bool] = None,
        horizon: float = 1e9) -> Tuple[List[Request], Dict]:
    """Returns (requests with timestamps, extras).

    phase="prefill": requests finish at first token (simu_prefill, Alg. 1);
    phase="decode": prefill is instantaneous (simu_decode, Alg. 1).

    prefix_cache: model per-instance radix-tree prefix caches — matched
    prefixes skip prefill compute (suffix-only prefill time) and
    prefill->decode transfer ships only the suffix the decode instance is
    missing. Default (None) auto-enables when the trace carries token ids
    (see `workload.sample_multi_turn`) and the model has per-token KV. The
    trees and routing policy are the exact classes the live cluster runs,
    so both report the same prefix-hit routing decisions on one trace."""
    lm_tok = lm_tokens or lm.saturation_tokens(prefill.par)
    cap = (lm.chip.hbm_bytes * decode.par.num_chips * (1 - kv_reserve)
           - lm.param_bytes())
    cap = max(cap, lm.chip.hbm_bytes * 0.05 * decode.par.num_chips)
    max_b = max_decode_batch or 4096
    # page-granular capacity: one page = page_tokens worth of KV bytes
    # (SSM archs: one page per constant-size state)
    per_tok = lm.cfg.kv_bytes_per_token(lm.dtype_bytes)
    page_bytes = per_tok * page_tokens if per_tok else lm.kv_read_bytes(0)
    page_bytes = max(page_bytes, 1.0)
    n_pages = num_decode_pages if num_decode_pages is not None \
        else max(int(cap // page_bytes), 1)

    if prefix_cache is None:
        prefix_cache = (per_tok > 0
                        and any(r.tokens is not None for r in reqs))
    prefix_on = bool(prefix_cache) and per_tok > 0

    P = [_PrefillInstance(i, lm, prefill.par, lm_tok,
                          RadixPrefixCache(page_tokens) if prefix_on else None)
         for i in range(prefill.count)]
    D = [_DecodeInstance(i, lm, decode.par, PagePool(n_pages, page_bytes),
                         max_b,
                         RadixPrefixCache(page_tokens) if prefix_on else None)
         for i in range(decode.count)]
    disp = dispatcher or DisaggDispatcher()
    tx = TransferManager(transfer_bw, page_bytes=int(page_bytes),
                         n_layers=lm.cfg.num_layers)

    ev = EventLoop()
    for r in reqs:
        ev.push(r.arrive, "arrive", r)

    busy_prefill = 0.0
    busy_decode = 0.0

    def try_start_prefill(p: _PrefillInstance, now: float):
        while p.can_admit():
            start = max(now, p.next_admit)
            if start > now:
                ev.push(start, "prefill_poke", p)
                return
            batch = p.form_batch()
            # prefix hits: only the uncached suffix runs through prefill
            # (match + insert at prefill start, mirroring the live engine,
            # which matches inside prefill_request and publishes the new
            # prompt pages before the next request runs)
            suffix = []
            for r in batch:
                if p.tree is not None and r.tokens is not None:
                    h, _ = p.tree.match(r.tokens)
                    # live engines keep >= 1 suffix token for the logits
                    h = min(h, ((r.in_len - 1) // page_tokens) * page_tokens)
                    r.prefix_hit = h
                    n_full = (r.in_len // page_tokens) * page_tokens
                    p.tree.insert(r.tokens[:n_full])
                suffix.append(r.in_len - r.prefix_hit)
            T = lm.prefill_time(suffix, p.par)
            p.next_admit = now + T / p.par.pp
            p.inflight += 1
            for r in batch:
                r.prefill_start = now
            ev.push(now + T, "prefill_done", (p, batch, T))

    def assign_decode(r: Request, now: float, src: int):
        """Least-loaded decode dispatch + park on the prefill side."""
        d_hits = None
        if prefix_on and r.tokens is not None and phase != "decode":
            d_hits = [d.tree.peek(r.tokens) for d in D]
        di = disp.pick_decode(r.rid, [d.load for d in D], hits=d_hits)
        # wire bytes = prompt KV the decode side is missing (decode
        # positions are produced there; a shared prefix already resides
        # there); page reservation below covers the full residency. wire
        # time comes from the latency model so calibrated overrides
        # (benchmarks/table2) take effect.
        if phase == "decode":
            nbytes, wire_s = 0.0, 0.0
        else:
            r.decode_hit = d_hits[di] if d_hits else 0
            ship = r.in_len - r.decode_hit
            nbytes = kv_bytes(lm.cfg, ship, lm.dtype_bytes) if ship else 0.0
            wire_s = lm.kv_transfer_time(ship, transfer_bw) if ship else 0.0
        tx.park(r.rid, r, nbytes, now, src=src, wire_s=wire_s)
        D[di].pending.append(r)
        ev.push(now, "decode_poke", D[di])

    def try_admit(d: _DecodeInstance, now: float):
        """Pull-based admission: reserve pages, then pull over the link."""
        while d.pending and d.can_admit(d.pending[0]):
            r = d.pending.pop(0)
            d.pool.alloc(r.rid, d.charge_pages(r))
            d.in_transfer += 1
            if d.tree is not None and r.tokens is not None:
                d.tree.match(r.tokens)      # LRU bump, mirrors insert_kv
                n_full = (r.in_len // page_tokens) * page_tokens
                d.tree.insert(r.tokens[:n_full])
            _, t_done = tx.pull(r.rid, now, dst=d.iid)
            ev.push(t_done, "transfer_done", (d, r))

    def try_start_decode(d: _DecodeInstance, now: float):
        try_admit(d, now)
        if d.busy:
            return
        # transferred requests join the batch at an iteration boundary only
        # (mirrors the live cluster, which admits between decode steps)
        d.running.extend(d.arrived)
        d.arrived.clear()
        if not d.running:
            return
        d.busy = True
        eff_b = max(len(d.running) / d.par.pp, 1.0)
        tau = lm.decode_time(eff_b, d.ctx_tokens() / d.par.pp,
                             Parallelism(d.par.tp, 1))
        ev.push(now + tau, "decode_iter", (d, tau))

    while ev:
        t_now, kind, payload = ev.pop()
        if t_now > horizon:
            break
        if kind == "arrive":
            r = payload
            if phase == "decode":
                r.prefill_start = t_now
                r.first_token = t_now
                assign_decode(r, t_now, src=0)
                continue
            hits = None
            if prefix_on and r.tokens is not None:
                hits = [p.tree.peek(r.tokens) for p in P]
            pi = disp.pick_prefill(r.rid, [p.queue for p in P], hits=hits)
            P[pi].queue.push(r)
            ev.push(t_now, "prefill_poke", P[pi])
        elif kind == "prefill_poke":
            try_start_prefill(payload, t_now)
        elif kind == "prefill_done":
            p, batch, T = payload
            p.inflight -= 1
            busy_prefill += T
            for r in batch:
                r.first_token = t_now
                if phase == "prefill":
                    r.finish = t_now
                    continue
                assign_decode(r, t_now, src=p.iid)
            try_start_prefill(p, t_now)
        elif kind == "decode_poke":
            try_start_decode(payload, t_now)
        elif kind == "transfer_done":
            d, r = payload
            r.transfer_done = t_now
            r.decode_admit = t_now
            d.in_transfer -= 1
            d.arrived.append(r)
            try_start_decode(d, t_now)
        elif kind == "decode_iter":
            d, tau = payload
            busy_decode += tau
            d.busy = False
            for r in d.running:
                r.tokens_done += 1
            still = []
            for r in d.running:
                if r.tokens_done >= r.out_len - 1 or r.out_len <= 1:
                    r.finish = t_now
                    d.pool.free(r.rid)
                else:
                    still.append(r)
            d.running = still
            try_start_decode(d, t_now)

    extras = {
        "kv_total": tx.total_time,
        "kv_p95": _percentile(tx.times, 0.95),
        "kv_chunks": tx.total_chunks,
        "kv_bytes": tx.total_bytes,
        "parked_bytes_peak": tx.peak_parked_bytes,
        "decisions": disp.decisions,
        "breakdown": {"prefill_busy_s": busy_prefill,
                      "decode_busy_s": busy_decode,
                      "lm_tokens": lm_tok, "max_decode_batch": max_b,
                      "decode_pages": n_pages},
    }
    if prefix_on:
        prompt_tokens = sum(r.in_len for r in reqs)
        extras["prefix"] = {
            "hit_tokens": sum(r.prefix_hit for r in reqs),
            "decode_hit_tokens": sum(r.decode_hit for r in reqs),
            "prompt_tokens": prompt_tokens,
            "prefill_trees": [p.tree.stats.as_dict() for p in P],
            "decode_trees": [d.tree.stats.as_dict() for d in D],
        }
    return reqs, extras


# ---------------------------------------------------------------------------
# Colocated (vLLM-like) simulation
# ---------------------------------------------------------------------------

def simulate_colocated(
        reqs: List[Request],
        lm: LatencyModel,
        inst: InstanceConfig,
        *,
        max_batch: Optional[int] = None,
        max_prefill_tokens: int = 2048,
        kv_reserve: float = 0.1,
        horizon: float = 1e9) -> Tuple[List[Request], Dict]:
    """Continuous batching with prefill-priority (vLLM v0 default)."""
    max_b = max_batch or 4096
    cap = (lm.chip.hbm_bytes * inst.par.num_chips * (1 - kv_reserve)
           - lm.param_bytes())
    cap = max(cap, lm.chip.hbm_bytes * 0.05 * inst.par.num_chips)

    class Engine:
        def __init__(self, iid):
            self.iid = iid
            self.waiting: FCFSQueue = FCFSQueue(token_of=lambda r: r.in_len)
            self.running: List[Request] = []
            self.kv_used = 0.0
            self.busy = False

        @property
        def load(self):
            return len(self.waiting) + len(self.running)

        def can_admit(self, r):
            return (len(self.running) < max_b
                    and self.kv_used + _req_kv_bytes(lm, r) <= cap)

    engines = [Engine(i) for i in range(inst.count)]
    ev = EventLoop()
    for r in reqs:
        ev.push(r.arrive, "arrive", r)

    def step(e: Engine, now: float):
        if e.busy:
            return
        # prefill first (vLLM prioritizes waiting prefills), batch formed
        # by the shared core; the stateful can_take reserves KV as it admits
        taken = [0, 0.0]

        def can_take(r):
            if (len(e.running) + taken[0] < max_b
                    and e.kv_used + taken[1] + _req_kv_bytes(lm, r) <= cap):
                taken[0] += 1
                taken[1] += _req_kv_bytes(lm, r)
                return True
            return False

        batch = e.waiting.form_batch(max_prefill_tokens, can_take=can_take)
        if batch:
            e.kv_used += taken[1]
            e.busy = True
            T = lm.prefill_time([r.in_len for r in batch], inst.par)
            for r in batch:
                r.prefill_start = now
            ev.push(now + T, "prefill_done", (e, batch))
            return
        if e.running:
            e.busy = True
            eff_b = max(len(e.running) / inst.par.pp, 1.0)
            ctx = sum(r.in_len + r.tokens_done for r in e.running)
            tau = lm.decode_time(eff_b, ctx / inst.par.pp,
                                 Parallelism(inst.par.tp, 1))
            ev.push(now + tau, "decode_iter", (e, tau))

    while ev:
        t_now, kind, payload = ev.pop()
        if t_now > horizon:
            break
        if kind == "arrive":
            r = payload
            e = engines[least_loaded([x.load for x in engines])]
            e.waiting.push(r)
            step(e, t_now)
        elif kind == "prefill_done":
            e, batch = payload
            e.busy = False
            for r in batch:
                r.first_token = t_now
                r.decode_admit = t_now
                e.running.append(r)
            step(e, t_now)
        elif kind == "decode_iter":
            e, tau = payload
            e.busy = False
            still = []
            for r in e.running:
                r.tokens_done += 1
                if r.tokens_done >= r.out_len - 1 or r.out_len <= 1:
                    r.finish = t_now
                    e.kv_used -= _req_kv_bytes(lm, r)
                else:
                    still.append(r)
            e.running = still
            step(e, t_now)

    return reqs, {"kv_total": 0.0, "kv_p95": 0.0, "breakdown": {}}
