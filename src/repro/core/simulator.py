"""Discrete-event simulator for disaggregated and colocated LLM serving.

Iteration-level fidelity, mirroring the runtime in repro/serving — batch
formation, dispatch, and pull-based admission all come from the shared
scheduler core in `core.scheduler` (the live cluster runs the same code),
and both simulators implement the same `serving.api.ServingBackend`
protocol as the live clusters (`SimDisaggBackend` / `SimColocatedBackend`:
`submit` / `step` / `run_until` / `drain` / `cancel`), so one driver can
swap live engines for the analytical latency model without changing the
serving code around it.  The classic `simulate_disaggregated` /
`simulate_colocated` functions remain as submit-all-then-drain shims.

  * prefill instances: FCFS queues (`FCFSQueue.form_batch` up to the L_m
    token budget, paper §4.3), PP admission every T/pp with full-T latency
    (M/D/1-consistent), shortest-queue dispatch at arrival.
  * decode instances: continuous batching; per-iteration time from the
    analytical latency model; *page-granular* KV admission via `PagePool` —
    finished prefills stay parked on the prefill side (`TransferManager`)
    until the decode instance has free pages, then transfer over the
    per-link wire (paper §4.3 "combat burstiness").
  * colocated engine (vLLM-like baseline): prefill-priority iteration-level
    scheduling, decode stalls during prefill iterations (the interference
    the paper measures in Fig. 1/2).

Token ids are not modeled (the latency model has no logits), so simulated
`TokenEvent`s carry token id -1 and `SamplingParams.stop` cannot trigger;
`max_tokens` and cancellation are honored exactly as in the live runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .kv_transfer import TransferManager, kv_bytes, pipelined_finish
from .latency_model import LatencyModel, Parallelism
from .scheduler import (DisaggDispatcher, FCFSQueue, PagePool,
                        least_loaded)
from .workload import Request, WorkloadSpec
from ..serving.api import (FINISH_CANCELLED, BackendBase, RequestState,
                           RequestStatus, percentile)
from ..serving.prefix_cache import RadixPrefixCache


@dataclasses.dataclass
class InstanceConfig:
    par: Parallelism
    count: int = 1


@dataclasses.dataclass
class SimResult:
    requests: List[Request]
    ttft_attain: float
    tpot_attain: float
    attain: float
    p50_ttft: float
    p90_ttft: float
    p50_tpot: float
    p90_tpot: float
    kv_transfer_total_s: float = 0.0
    kv_transfer_p95_s: float = 0.0
    breakdown: Optional[Dict[str, float]] = None
    # real inter-token-latency distribution (pooled over finished
    # requests' per-token timestamps), available when the backend kept
    # lifecycle states; 0.0 otherwise
    p99_itl: float = 0.0
    max_itl: float = 0.0
    n_cancelled: int = 0
    slo: Optional[Any] = None   # goodput.SLOReport — the unified metrics
                                # object live benchmarks also produce


# the repo-wide linear-interpolated percentile (kept under the historic
# name; tests pin its behavior through this import path)
_percentile = percentile


def summarize(reqs: List[Request], spec: WorkloadSpec,
              slo_scale: float = 1.0,
              extra: Optional[Dict] = None,
              warmup_frac: float = 0.25) -> SimResult:
    """Attainment over the steady-state window (arrivals after warmup).

    SLO scoring goes through `goodput.SLOTracker` — the same object the
    live backends feed online — so placement search and live benchmarks
    consume one metrics type.  Cancelled requests are excluded from the
    latency distributions and the attainment denominator.
    """
    from .goodput import SLOTracker      # deferred: goodput imports us
    if reqs:
        t_end = max(r.arrive for r in reqs)
        t_warm = t_end * warmup_frac
        reqs = [r for r in reqs if r.arrive >= t_warm] or reqs
    n_cancelled = sum(r.finish_reason == FINISH_CANCELLED for r in reqs)
    live = [r for r in reqs if r.finish_reason != FINISH_CANCELLED]
    done = [r for r in live if r.finish >= 0]
    tracker = SLOTracker(spec, slo_scale=slo_scale)
    for r in done:
        tracker.observe_result(r.ttft, r.tpot)
    n = max(len(live), 1)
    slo = tracker.report(total=n)
    ttfts = [r.ttft for r in done]
    tpots = [r.tpot for r in done]
    res = SimResult(
        requests=reqs,
        ttft_attain=slo.ttft_attain,
        tpot_attain=slo.tpot_attain,
        attain=slo.attain,
        p50_ttft=_percentile(ttfts, 0.5), p90_ttft=_percentile(ttfts, 0.9),
        p50_tpot=_percentile(tpots, 0.5), p90_tpot=_percentile(tpots, 0.9),
        n_cancelled=n_cancelled,
        slo=slo,
    )
    if extra:
        res.kv_transfer_total_s = extra.get("kv_total", 0.0)
        res.kv_transfer_p95_s = extra.get("kv_p95", 0.0)
        res.breakdown = extra.get("breakdown")
        states = extra.get("states")
        if states:
            keep = {r.rid for r in done}
            itl = [d for rid, st in states.items() if rid in keep
                   for d in st.itl()]
            res.p99_itl = _percentile(itl, 0.99)
            res.max_itl = max(itl) if itl else 0.0
    return res


# ---------------------------------------------------------------------------
# Disaggregated simulation
# ---------------------------------------------------------------------------

class _PrefillInstance:
    def __init__(self, iid, lm: LatencyModel, par: Parallelism, lm_tokens: int,
                 tree: Optional[RadixPrefixCache] = None):
        self.iid = iid
        self.lm = lm
        self.par = par
        self.budget = lm_tokens
        self.queue: FCFSQueue = FCFSQueue(token_of=lambda r: r.in_len)
        self.inflight = 0            # batches in the pipeline
        self.next_admit = 0.0
        self.tree = tree             # prefix cache model (matches the live
                                     # engine's radix tree decisions)

    @property
    def queued_tokens(self) -> int:
        return self.queue.queued_tokens

    def can_admit(self) -> bool:
        return bool(self.queue.items) and self.inflight < self.par.pp

    def form_batch(self) -> List[Request]:
        return self.queue.form_batch(self.budget)


def _req_kv_bytes(lm: LatencyModel, r: Request) -> float:
    c = lm.cfg
    if c.family == "ssm":
        return lm.kv_read_bytes(0)
    n = r.in_len + r.out_len
    if c.sliding_window:
        n = min(n, c.sliding_window)
    return c.kv_bytes_per_token(lm.dtype_bytes) * n


class _DecodeInstance:
    def __init__(self, iid, lm: LatencyModel, par: Parallelism,
                 pool: PagePool, max_batch: int,
                 tree: Optional[RadixPrefixCache] = None):
        self.iid = iid
        self.lm = lm
        self.par = par
        self.pool = pool                 # page-granular KV admission
        self.max_batch = max_batch
        self.running: List[Request] = []
        self.pending: List[Request] = []  # parked on prefill side, assigned
        self.arrived: List[Request] = []  # transferred, joins at iter start
        # rid -> request: chunked-prefill streams whose residency (pages)
        # is already allocated, waiting for the final chunk to land
        self.granted: Dict[int, Request] = {}
        self.in_transfer = 0
        # rid -> last-layer-landed time for requests admitted while their
        # KV is still streaming layer-by-layer (consumed by the first
        # iteration they join; see `pipelined_finish`)
        self.kv_full: Dict[int, float] = {}
        self.busy = False
        self.tree = tree                 # decode-side shared-prefix model
        # chunked-prefill absorption (role-unified backend): whole prompts
        # spilled here when the prefill tier saturates. None on legacy
        # static-disagg instances — absorb paths never run there.
        self.absorb: Optional[FCFSQueue] = None
        self.absorbing: set = set()      # rids mid-absorption (resident)

    @property
    def load(self) -> int:
        n = (len(self.running) + len(self.pending) + len(self.arrived)
             + len(self.granted) + self.in_transfer)
        if self.absorb is not None and (self.absorb.items or self.absorbing):
            n += len(self.absorbing | {r.rid for r in self.absorb.items})
        return n

    def charge_pages(self, r: Request) -> int:
        """Fresh pages a request needs: full residency minus the pages its
        decode-side shared prefix already holds.

        Approximation: tree-*retained* pages (prefixes kept after their
        sequences finish) are not charged to the pool. The live engine
        does keep them resident, but reclaims them LRU on admission
        pressure (`Engine.can_admit`), so for admission purposes they
        behave as free; the residual error is the floor of pages actively
        shared by concurrent sequences (counted once live, zero here)."""
        full = self.pool.pages_for(_req_kv_bytes(self.lm, r))
        if self.tree is None or not r.decode_hit:
            return full
        page_tokens = self.tree.page_size
        return max(full - r.decode_hit // page_tokens, 0)

    def can_admit(self, r: Request) -> bool:
        resident = (len(self.running) + len(self.arrived) + self.in_transfer
                    + len(self.absorbing))
        return (resident < self.max_batch
                and self.pool.can_alloc(self.charge_pages(r)))

    def ctx_tokens(self) -> float:
        return float(sum(r.in_len + r.tokens_done for r in self.running))


class _SimBackend(BackendBase):
    """Plumbing shared by both simulator backends: horizon-guarded
    stepping, `SamplingParams.max_tokens` caps, and per-request cleanup
    (token ids are not modeled, so stop tokens cannot trigger here)."""

    def _init_sim(self, horizon: float, record_events: bool, tracker,
                  tracer=None, metrics=None):
        self._init_backend(tracker=tracker, tracer=tracer, metrics=metrics)
        # bulk goodput sweeps simulate millions of tokens: the closed-world
        # shims disable per-token TokenEvent recording (a tracker or
        # on_token callback re-enables it per consumer)
        self._record_tokens = record_events
        self.horizon = horizon
        self._out_cap: Dict[int, int] = {}      # rid -> max_tokens cap

    def step(self) -> bool:
        nxt = self._ev.peek_time()
        if nxt is None or nxt > self.horizon:
            return False
        return super().step()

    def next_time(self):
        nxt = self._ev.peek_time()
        return None if nxt is None or nxt > self.horizon else nxt

    def _forget(self, rid: int):
        super()._forget(rid)
        self._out_cap.pop(rid, None)

    def _cap_out(self, state: RequestState):
        if state.sampling.max_tokens is not None:
            self._out_cap[state.rid] = \
                state.sampling.out_len(state.request.out_len)


class SimServingBackend(_SimBackend):
    """Role-unified discrete-event serving simulator (the twin of
    `serving.cluster.ServingCluster`).

    Every instance carries a *role* — ``"prefill"``, ``"decode"`` or
    ``"mixed"`` — instead of the role being baked into the class. A
    disaggregated deployment is a prefill+decode role vector; a colocated
    (vLLM-like) deployment is the degenerate "all instances mixed" case.
    `SimDisaggBackend` / `SimColocatedBackend` remain as thin shims that
    translate their legacy constructor signatures into role vectors and
    produce byte-identical schedules.

    On top of the static roles:

    * `set_role(g, role)` flips an instance at runtime. The instance
      leaves the routing views immediately; queued-but-unstarted work is
      re-routed through the shared dispatcher; resident work (running
      decodes, granted/streaming KV, partial chunks) drains in place, and
      the flip completes when the instance is idle — so a decode→prefill
      flip never strands or leaks KV pages (`PagePool.used == 0` is
      asserted at completion). A prefill→decode flip drains in one batch
      time; there is no KV to move.
    * chunked-prefill *absorption*: when every routable prefill queue is
      deeper than ``absorb_tokens``, new prompts spill to a decode/mixed
      instance which prefills them locally in bounded chunks
      (`prefill_chunk_time` per chunk, serialized with its decode
      iterations) — intra-instance aggregation under prefill bursts.

    phase="prefill": requests finish at first token (simu_prefill, Alg. 1);
    phase="decode": prefill is instantaneous (simu_decode, Alg. 1).

    prefix_cache: model per-instance radix-tree prefix caches — matched
    prefixes skip prefill compute (suffix-only prefill time) and
    prefill->decode transfer ships only the suffix the decode instance is
    missing. Default (None) auto-enables when submitted requests carry
    token ids (see `workload.sample_multi_turn`) and the model has
    per-token KV. The trees and routing policy are the exact classes the
    live cluster runs, so both report the same prefix-hit routing
    decisions on one trace.
    """

    def __init__(self, lm: LatencyModel,
                 instances: Sequence[Tuple[str, Parallelism]], *,
                 transfer_bw: float = 50e9,
                 lm_tokens: Optional[int] = None,
                 max_decode_batch: Optional[int] = None,
                 kv_reserve: float = 0.1,
                 page_tokens: int = 16,
                 num_decode_pages: Optional[int] = None,
                 dispatcher: Optional[DisaggDispatcher] = None,
                 phase: str = "both",
                 prefix_cache: Optional[bool] = None,
                 chunk_tokens=None,
                 max_prefill_tokens: int = 2048,
                 max_mixed_batch: Optional[int] = None,
                 absorb_tokens: Optional[int] = None,
                 horizon: float = 1e9,
                 tracker=None,
                 record_events: bool = True,
                 tracer=None, metrics=None):
        self._init_sim(horizon, record_events, tracker, tracer=tracer,
                       metrics=metrics)
        self.lm = lm
        self.phase = phase
        self.transfer_bw = transfer_bw
        self.page_tokens = page_tokens
        self.max_prefill_tokens = max_prefill_tokens
        roles = [r for r, _ in instances]
        self._pars = [par for _, par in instances]
        ref_par = next((par for r, par in instances if r == "prefill"),
                       self._pars[0] if self._pars else Parallelism())
        lm_tok = lm_tokens or lm.saturation_tokens(ref_par)
        self._lm_tok = lm_tok
        self._kv_reserve = kv_reserve
        max_b = max_decode_batch or 4096
        self._max_b = max_b
        self._max_mb = max_mixed_batch or 4096
        # page-granular capacity: one page = page_tokens worth of KV bytes
        # (SSM archs: one page per constant-size state)
        per_tok = lm.cfg.kv_bytes_per_token(lm.dtype_bytes)
        page_bytes = per_tok * page_tokens if per_tok else lm.kv_read_bytes(0)
        page_bytes = max(page_bytes, 1.0)
        self._page_bytes = page_bytes
        self._num_decode_pages = num_decode_pages
        self._per_tok = per_tok
        has_pd = any(r in ("prefill", "decode") for r in roles)
        self._auto_prefix = prefix_cache is None and has_pd
        self.prefix_on = bool(prefix_cache) and per_tok > 0
        # birth-order construction; role-local iids give the legacy
        # labels/metric keys ("prefill0", "decode1", "engine0", ...)
        self.inst: List[Any] = []
        self._iid_next = {"prefill": 0, "decode": 0, "mixed": 0}
        for role, par in instances:
            self.inst.append(self._make_instance(role, par))
        if self.prefix_on:
            self._grow_trees()
        self.disp = dispatcher or DisaggDispatcher()
        self.tx = TransferManager(transfer_bw, page_bytes=int(page_bytes),
                                  n_layers=lm.cfg.num_layers)
        # chunked prefill mirror: same chunk-splitting policy and charge
        # structure as the live cluster (per-chunk `prefill_chunk_time`,
        # per-chunk `park_partial`, streamed admission). Needs per-token
        # KV (SSM state is constant-size; nothing to chunk-ship).
        # chunk_tokens="auto" sizes the chunk from the latency model: the
        # smallest page-multiple whose chunking overhead stays under the
        # model's budget fraction (the knob remains as an override).
        if chunk_tokens == "auto":
            chunk_tokens = lm.auto_chunk_tokens(ref_par,
                                                page_tokens=page_tokens)
        self.chunk_tokens = (chunk_tokens if chunk_tokens and per_tok > 0
                             and phase != "decode" else None)
        self._chunk_ctx: Dict[int, int] = {}    # rid -> tokens prefilled
        self._sim_stream: Dict[int, Any] = {}   # rid -> decode instance
        if self.chunk_tokens:
            for p in self.inst:
                if isinstance(p, _PrefillInstance):
                    # queue load = tokens still to prefill (matches the
                    # live re-queue-with-remaining-suffix accounting)
                    p.queue.token_of = self._remaining_tokens
        # absorption: spill whole prompts to decode/mixed instances when
        # every routable prefill queue is deeper than absorb_tokens
        self.absorb_tokens = absorb_tokens
        self._absorb_chunk = self.chunk_tokens
        if absorb_tokens is not None and not self._absorb_chunk \
                and per_tok > 0 and phase != "decode":
            self._absorb_chunk = lm.auto_chunk_tokens(
                ref_par, page_tokens=page_tokens)
        self.busy_prefill = 0.0
        self.busy_decode = 0.0
        self.busy_absorb = 0.0
        self.absorbed = 0
        self._role_events: List[Tuple[float, str, str]] = []
        self._twins: Dict[Tuple[int, str], Any] = {}
        self._backlog: List[RequestState] = []  # arrivals held mid-re-role
        d0 = next((x for x in self.inst
                   if isinstance(x, _DecodeInstance)), None)
        self._breakdown = {"lm_tokens": lm_tok, "max_decode_batch": max_b,
                           "decode_pages": d0.pool.num_pages if d0 else 0}
        if self.tracer.enabled:
            self.tx.tracer = self.tracer
            self.disp.tracer = self.tracer
        if metrics is not None:
            metrics.register(self._collect_metrics)

    # -- instance construction / role views ------------------------------
    def _remaining_tokens(self, r: Request) -> int:
        return max(r.in_len - self._chunk_ctx.get(r.rid, 0), 0)

    def _decode_cap(self, par: Parallelism) -> float:
        lm = self.lm
        cap = (lm.chip.hbm_bytes * par.num_chips * (1 - self._kv_reserve)
               - lm.param_bytes())
        return max(cap, lm.chip.hbm_bytes * 0.05 * par.num_chips)

    def _make_instance(self, role: str, par: Parallelism,
                       label: Optional[str] = None):
        iid = self._iid_next[role]
        self._iid_next[role] += 1
        if role == "prefill":
            x = _PrefillInstance(iid, self.lm, par, self._lm_tok)
            x.label = label or f"prefill{iid}"
            if getattr(self, "chunk_tokens", None):
                x.queue.token_of = self._remaining_tokens
        elif role == "decode":
            cap = self._decode_cap(par)
            n_pages = self._num_decode_pages \
                if self._num_decode_pages is not None \
                else max(int(cap // self._page_bytes), 1)
            x = _DecodeInstance(iid, self.lm, par,
                                PagePool(n_pages, self._page_bytes),
                                self._max_b)
            x.label = label or f"decode{iid}"
            x.absorb = FCFSQueue(token_of=self._remaining_tokens)
            x.absorbing = set()
        elif role == "mixed":
            x = _ColoEngine(iid, self._max_mb, self._decode_cap(par), par)
            x.label = label or f"engine{iid}"
        else:
            raise ValueError(f"unknown role {role!r}")
        x.par = par
        x.draining = False
        x.target = None
        if self.prefix_on and not isinstance(x, _ColoEngine):
            x.tree = RadixPrefixCache(self.page_tokens)
        return x

    @staticmethod
    def _role_of(inst) -> str:
        if isinstance(inst, _PrefillInstance):
            return "prefill"
        if isinstance(inst, _DecodeInstance):
            return "decode"
        return "mixed"

    @property
    def P(self) -> List["_PrefillInstance"]:
        return [x for x in self.inst if isinstance(x, _PrefillInstance)]

    @property
    def D(self) -> List["_DecodeInstance"]:
        return [x for x in self.inst if isinstance(x, _DecodeInstance)]

    @property
    def engines(self) -> List["_ColoEngine"]:
        return [x for x in self.inst if isinstance(x, _ColoEngine)]

    @property
    def roles(self) -> List[str]:
        return [self._role_of(x) for x in self.inst]

    def _p_route(self) -> List["_PrefillInstance"]:
        return [x for x in self.P if not x.draining]

    def _d_route(self) -> List["_DecodeInstance"]:
        return [x for x in self.D if not x.draining]

    def _e_route(self) -> List["_ColoEngine"]:
        return [x for x in self.engines if not x.draining]

    def _collect_metrics(self) -> Dict[str, float]:
        """Pull-collector for a `MetricsRegistry` (the simulator twin of
        `ServingCluster._collect_metrics`). Key names stay byte-identical
        to the legacy per-class collectors for static role vectors."""
        out: Dict[str, float] = {}
        P, D, E = self.P, self.D, self.engines
        if P or D:
            out["busy_prefill_s"] = self.busy_prefill
            out["busy_decode_s"] = self.busy_decode
        for p in P:
            out[f"queue{p.iid}.depth"] = len(p.queue)
            out[f"queue{p.iid}.tokens"] = p.queued_tokens
            out[f"prefill{p.iid}.inflight"] = p.inflight
        for d in D:
            pre = d.label
            out[f"{pre}.kv.num_pages"] = d.pool.num_pages
            out[f"{pre}.kv.used_pages"] = d.pool.used
            out[f"{pre}.kv.free_pages"] = d.pool.free_pages
            out[f"{pre}.kv.peak_used_pages"] = d.pool.peak_used
            out[f"{pre}.running"] = len(d.running)
            out[f"{pre}.pending"] = len(d.pending)
            out[f"{pre}.arrived"] = len(d.arrived)
            out[f"{pre}.granted"] = len(d.granted)
            out[f"{pre}.in_transfer"] = d.in_transfer
        for e in E:
            out[f"{e.label}.queue.depth"] = float(len(e.waiting))
            out[f"{e.label}.running"] = float(len(e.running))
            out[f"{e.label}.kv_used_bytes"] = float(e.kv_used)
        if P or D:
            for k, v in self.tx.stats().items():
                out[f"tx.{k}"] = v
        if self.prefix_on:
            for inst in (*P, *D):
                if inst.tree is None:
                    continue
                side = "prefill" if isinstance(inst, _PrefillInstance) \
                    else "decode"
                for k, v in inst.tree.metrics().items():
                    out[f"{side}{inst.iid}.prefix.{k}"] = v
        if self._role_events:        # dynamic fleets: expose role ids
            ids = {"prefill": 0.0, "decode": 1.0, "mixed": 2.0}
            for x in self.inst:
                out[f"{x.label}.role_id"] = ids[self._role_of(x)]
                out[f"{x.label}.draining"] = float(x.draining)
            out["role_changes"] = float(len(self._role_events))
            out["absorbed"] = float(self.absorbed)
        return out

    def _grow_trees(self):
        for inst in (*self.P, *self.D):
            if inst.tree is None:
                inst.tree = RadixPrefixCache(self.page_tokens)

    # -- ServingBackend hooks -------------------------------------------
    def _do_submit(self, state: RequestState, t: float):
        r = state.request
        self._cap_out(state)
        if (self._auto_prefix and not self.prefix_on
                and r.tokens is not None and self._per_tok > 0):
            self.prefix_on = True
            self._grow_trees()
        self._ev.push(t, "arrive", state)

    def _handle(self, t: float, kind: str, payload: Any):
        if kind == "arrive":
            self._on_arrive(payload, t)
        elif kind == "prefill_poke":
            self._try_start_prefill(payload, t)
        elif kind == "prefill_done":
            self._on_prefill_done(payload, t)
        elif kind == "chunk_done":
            self._on_chunk_done(payload, t)
        elif kind == "decode_poke":
            self._try_start_decode(payload, t)
        elif kind == "transfer_first":
            self._on_transfer_first(payload, t)
        elif kind == "decode_iter":
            self._on_decode_iter(payload, t)
        elif kind == "absorb_done":
            self._on_absorb_done(payload, t)
        elif kind == "poke":
            self._step_engine(payload, t)
        elif kind == "m_prefill_done":
            self._on_mixed_prefill_done(payload, t)
        elif kind == "m_decode_iter":
            self._on_mixed_decode_iter(payload, t)

    # -- event handlers --------------------------------------------------
    def _on_arrive(self, state: RequestState, t: float):
        if state.done:
            return
        r = state.request
        if self.phase == "decode":
            r.prefill_start = t
            r.first_token = t
            self._emit_token(state, -1, t)
            self._assign_decode(state, t, src=0)
            return
        P = self._p_route()
        if not P:
            # no routable prefill tier: colocated (all-mixed) deployment,
            # or a transient all-decode fleet -> absorb everywhere
            if self._e_route() and not (self.absorb_tokens is not None
                                        and self._d_route()):
                self._mixed_arrive(state, t)
            elif not self._route_absorb(state, t):
                if any(x.target is not None for x in self.inst):
                    # mid-re-role transient: every sink is draining. Hold
                    # the arrival; `_complete_flip` re-dispatches it.
                    self._backlog.append(state)
                    state.where = ("backlog", None)
                    if self.tracer.enabled:
                        self.tracer.phase(r.rid, "queued", t, "backlog")
                    return
                raise RuntimeError(
                    "no routable prefill/mixed instance and absorption "
                    "is unavailable")
            return
        if (self.absorb_tokens is not None
                and min(p.queued_tokens for p in P) > self.absorb_tokens
                and self._route_absorb(state, t)):
            return
        hits = None
        if self.prefix_on and r.tokens is not None:
            hits = [p.tree.peek(r.tokens) for p in P]
        pi = self.disp.pick_prefill(r.rid, [p.queue for p in P],
                                    hits=hits, now=t)
        p = P[pi]
        p.queue.push(r)
        state.where = ("prefill", p)
        if self.tracer.enabled:
            self.tracer.phase(r.rid, "queued", t, p.label)
        self._ev.push(t, "prefill_poke", p)

    def _absorb_targets(self) -> List[Any]:
        """Instances that can take a whole prompt when the prefill tier is
        saturated: decode instances with chunk machinery, mixed engines."""
        out: List[Any] = []
        for x in self.inst:
            if x.draining:
                continue
            if isinstance(x, _DecodeInstance) and self._absorb_chunk:
                out.append(x)
            elif isinstance(x, _ColoEngine):
                out.append(x)
        return out

    def _route_absorb(self, state: RequestState, t: float) -> bool:
        targets = self._absorb_targets()
        if not targets:
            return False
        r = state.request
        loads = [float(x.load) for x in targets]
        ai = self.disp.pick_absorb(r.rid, loads, now=t)
        x = targets[ai]
        self.absorbed += 1
        if isinstance(x, _ColoEngine):
            x.waiting.push(r)
            state.where = ("queued", x)
            if self.tracer.enabled:
                self.tracer.phase(r.rid, "queued", t, x.label)
            self._step_engine(x, t)
        else:
            x.absorb.push(r)
            state.where = ("absorb", x)
            if self.tracer.enabled:
                self.tracer.phase(r.rid, "queued", t, x.label)
            self._ev.push(t, "decode_poke", x)
        return True

    def _mixed_arrive(self, state: RequestState, t: float):
        E = self._e_route()
        e = E[least_loaded([x.load for x in E])]
        e.waiting.push(state.request)
        state.where = ("queued", e)
        if self.tracer.enabled:
            self.tracer.phase(state.rid, "queued", t, e.label)
        self._step_engine(e, t)

    def _try_start_prefill(self, p: _PrefillInstance, now: float):
        if self.chunk_tokens:
            self._chunk_step(p, now)
            self._check_flip(p, now)
            return
        while p.can_admit():
            start = max(now, p.next_admit)
            if start > now:
                self._ev.push(start, "prefill_poke", p)
                return
            batch = p.form_batch()
            # prefix hits: only the uncached suffix runs through prefill
            # (match + insert at prefill start, mirroring the live engine,
            # which matches inside prefill_request and publishes the new
            # prompt pages before the next request runs)
            suffix = []
            for r in batch:
                if p.tree is not None and r.tokens is not None:
                    h, _ = p.tree.match(r.tokens)
                    # live engines keep >= 1 suffix token for the logits
                    h = min(h, ((r.in_len - 1) // self.page_tokens)
                            * self.page_tokens)
                    r.prefix_hit = h
                    n_full = (r.in_len // self.page_tokens) * self.page_tokens
                    p.tree.insert(r.tokens[:n_full])
                suffix.append(r.in_len - r.prefix_hit)
            T = self.lm.prefill_time(suffix, p.par)
            p.next_admit = now + T / p.par.pp
            p.inflight += 1
            for r in batch:
                r.prefill_start = now
                st = self._states[r.rid]
                st.where = ("prefill_run", p)
                st.to_status(RequestStatus.PREFILLING)
                if self.tracer.enabled:
                    lane = p.label
                    self.tracer.phase(r.rid, "prefilling", now, lane)
                    self.tracer.complete(
                        "compute", "prefill_batch", now, now + T, lane,
                        rid=r.rid, tokens=r.in_len - r.prefix_hit,
                        hit=r.prefix_hit)
            self._ev.push(now + T, "prefill_done", (p, batch, T))
        self._check_flip(p, now)

    def _on_prefill_done(self, payload, t: float):
        p, batch, T = payload
        p.inflight -= 1
        self.busy_prefill += T
        for r in batch:
            state = self._states[r.rid]
            if state.done:              # cancelled mid-prefill
                continue
            r.first_token = t
            self._emit_token(state, -1, t)
            if self.phase == "prefill":
                self._finish_state(state, t)
                continue
            self._assign_decode(state, t, src=p.iid)
        self._try_start_prefill(p, t)

    # -- chunked prefill (simulator twin of `_prefill_chunk_step`) -------
    def _chunk_step(self, p: _PrefillInstance, now: float):
        """One chunk of the head-of-queue prompt; unfinished prompts
        re-queue at the tail. Chunk policy is byte-identical to the live
        engine: non-final chunks round down to whole pages (>= 1 page) so
        in-place page writes never straddle a partial page; the final
        chunk takes the ragged tail."""
        if p.inflight or not p.queue.items:
            return
        batch = p.queue.form_batch(p.budget, max_batch=1,
                                   chunk_tokens=self.chunk_tokens)
        if not batch:
            return
        r = batch[0]
        state = self._states[r.rid]
        state.to_status(RequestStatus.PREFILLING)
        state.where = ("prefill_run", p)
        ps = self.page_tokens
        S = r.in_len
        if r.rid not in self._chunk_ctx:        # first chunk: prefix match
            r.prefill_start = now
            if p.tree is not None and r.tokens is not None:
                h, _ = p.tree.match(r.tokens)
                h = min(h, ((S - 1) // ps) * ps)
                r.prefix_hit = h
                # publish happens at the FINAL chunk (_on_chunk_done),
                # matching the live engine's prefill_chunk: a prompt
                # cancelled mid-prefill never enters the tree
            self._chunk_ctx[r.rid] = r.prefix_hit
        ctx = self._chunk_ctx[r.rid]
        c = min(self.chunk_tokens, S - ctx)
        if ctx + c < S:
            c = min(max((c // ps) * ps, ps), S - ctx)
        T = self.lm.prefill_chunk_time([(c, ctx)], p.par)
        p.inflight += 1
        if self.tracer.enabled:
            lane = p.label
            self.tracer.phase(r.rid, "prefilling", now, lane)
            self.tracer.complete("compute", "chunk", now, now + T, lane,
                                 rid=r.rid, tokens=c, ctx=ctx)
        self._ev.push(now + T, "chunk_done", (p, r, T, ctx, c))

    def _on_chunk_done(self, payload, t: float):
        p, r, T, ctx, c = payload
        p.inflight -= 1
        self.busy_prefill += T
        state = self._states[r.rid]
        if state.done:                  # cancelled mid-chunk
            self._drop_sim_stream(r, t)
            self._chunk_ctx.pop(r.rid, None)
            self._try_start_prefill(p, t)
            return
        done_tok = ctx + c
        # park this chunk's KV as a shippable segment (same byte charge as
        # the live cluster: prefill-resident KV delta, incl. the prefix
        # hit — the decode-side skip is trimmed at pull time)
        prev = state.progress
        seg = kv_bytes(self.lm.cfg, done_tok, self.lm.dtype_bytes) - \
            (kv_bytes(self.lm.cfg, prev, self.lm.dtype_bytes) if prev else 0)
        self.tx.park_partial(r.rid, max(seg, 0), t)
        state.progress = done_tok
        self._chunk_ctx[r.rid] = done_tok
        if done_tok < r.in_len:
            p.queue.push(r)
            state.where = ("prefill", p)
            if r.rid not in self._sim_stream:
                # first chunk landed: pick the decode target now so the
                # wire can overlap the remaining chunks' compute
                self._predispatch_decode(state, t)
        else:
            if p.tree is not None and r.tokens is not None:
                # final chunk: publish the whole prompt into the prefix
                # tree, the same point the live engine inserts (never
                # earlier — concurrent arrivals must not hit a prompt
                # whose KV is still being computed)
                ps = self.page_tokens
                p.tree.insert(r.tokens[:(r.in_len // ps) * ps])
            r.first_token = t
            self._emit_token(state, -1, t)
            self._chunk_ctx.pop(r.rid, None)
            if self.phase == "prefill":
                self._drop_sim_stream(r, t)
                self._finish_state(state, t)
            elif r.rid in self._sim_stream:
                self._finalize_stream(state, t, src=p.iid)
            else:                       # single-chunk prompt
                self._assign_decode(state, t, src=p.iid)
        self._try_start_prefill(p, t)

    def _engine_adopt(self, state: RequestState, now: float):
        """No decode-role instance remains (an aggregation re-role overlapped
        in-flight prefill work): hand the finished prefill straight to a
        mixed engine's running batch. The KV moves with it; wire time is
        charged as zero — this only occurs in the drain transient."""
        E = self._e_route() or self.engines
        r = state.request
        e = E[least_loaded([x.load for x in E])]
        r.decode_admit = now
        r.transfer_done = now
        e.kv_used += _req_kv_bytes(self.lm, r)
        state.where = ("running", e)
        state.to_status(RequestStatus.DECODING)
        if self.tracer.enabled:
            self.tracer.phase(r.rid, "decoding", now, e.label)
        e.running.append(r)
        self._ev.push(now, "poke", e)

    def _predispatch_decode(self, state: RequestState, now: float):
        r = state.request
        D = self._d_route() or self.D
        if not D:       # aggregation drain: adopt at the final chunk
            return
        d_hits = None
        if self.prefix_on and r.tokens is not None:
            d_hits = [d.tree.peek(r.tokens) for d in D]
        di = self.disp.pick_decode(r.rid, [d.load for d in D],
                                   hits=d_hits, now=now)
        d = D[di]
        r.decode_hit = d_hits[di] if d_hits else 0
        self._sim_stream[r.rid] = d
        d.pending.append(r)
        self._ev.push(now, "decode_poke", d)

    def _finalize_stream(self, state: RequestState, now: float, src: int):
        """Final chunk landed: close the stream with the decode-side ship
        size; admission (or the earlier grant) pulls the per-segment
        schedule."""
        r = state.request
        d = self._sim_stream.pop(r.rid)
        ship = r.in_len - r.decode_hit
        nbytes = kv_bytes(self.lm.cfg, ship, self.lm.dtype_bytes) \
            if ship else 0.0
        self.tx.park(r.rid, r, nbytes, now, src=src)
        state.where = ("pending", d)
        state.to_status(RequestStatus.MIGRATING)
        if self.tracer.enabled:
            self.tracer.phase(r.rid, "migrating", now, d.label)
        self._ev.push(now, "decode_poke", d)

    def _drop_sim_stream(self, r: Request, t: float):
        """Remove every trace of a streamed chunked migration (cancel):
        parked segments, the route, and the granted pages."""
        self.tx.drop_partial(r.rid)
        d = self._sim_stream.pop(r.rid, None)
        if d is None:
            return
        if r in d.pending:
            d.pending.remove(r)
        if r.rid in d.granted:
            del d.granted[r.rid]
            d.pool.free(r.rid)
        self._ev.push(t, "decode_poke", d)

    def _assign_decode(self, state: RequestState, now: float, src: int):
        """Least-loaded decode dispatch + park on the prefill side.
        Draining decode instances still accept work finished on a prefill
        instance (their flip waits for load to reach zero); with no
        decode-typed instance left at all, a mixed engine adopts it."""
        r = state.request
        D = self._d_route() or self.D
        if not D:
            self._engine_adopt(state, now)
            return
        d_hits = None
        if self.prefix_on and r.tokens is not None and self.phase != "decode":
            d_hits = [d.tree.peek(r.tokens) for d in D]
        di = self.disp.pick_decode(r.rid, [d.load for d in D],
                                   hits=d_hits, now=now)
        d = D[di]
        # wire bytes = prompt KV the decode side is missing (decode
        # positions are produced there; a shared prefix already resides
        # there); page reservation below covers the full residency. wire
        # time comes from the latency model so calibrated overrides
        # (benchmarks/table2) take effect.
        if self.phase == "decode":
            nbytes, wire_s = 0.0, 0.0
        else:
            r.decode_hit = d_hits[di] if d_hits else 0
            ship = r.in_len - r.decode_hit
            nbytes = kv_bytes(self.lm.cfg, ship, self.lm.dtype_bytes) \
                if ship else 0.0
            wire_s = self.lm.kv_transfer_time(ship, self.transfer_bw) \
                if ship else 0.0
        self.tx.park(r.rid, r, nbytes, now, src=src, wire_s=wire_s)
        d.pending.append(r)
        state.where = ("pending", d)
        state.to_status(RequestStatus.MIGRATING)
        if self.tracer.enabled:
            self.tracer.phase(r.rid, "migrating", now, d.label)
        self._ev.push(now, "decode_poke", d)

    def _try_admit(self, d: _DecodeInstance, now: float):
        """Pull-based admission: reserve pages, then pull over the link."""
        if self.chunk_tokens:
            # granted streams whose final chunk has landed pull first
            # (their pages are already held; the wire has been moving
            # since the grant)
            progress = True
            while progress:
                progress = False
                for rid, r in list(d.granted.items()):
                    if self.tx.has_parked(rid):
                        del d.granted[rid]
                        self._start_pull(d, r, now)
                        progress = True
                        break
        while d.pending and d.can_admit(d.pending[0]):
            r = d.pending.pop(0)
            d.pool.alloc(r.rid, d.charge_pages(r))
            if self.chunk_tokens and not self.tx.has_parked(r.rid):
                # streamed chunked prefill still computing: grant its
                # residency so parked segments start crossing now
                self.tx.grant(r.rid, now)
                d.granted[r.rid] = r
                continue
            self._start_pull(d, r, now)
        # blocked entries: amortized O(1) marking — entries only append at
        # the tail, so once we hit an already-marked one the rest are too
        # (goodput sweeps run deliberately overloaded; an O(pending) pass
        # per decode event would go quadratic there); streamed entries
        # stay PREFILLING-with-progress until their final chunk
        for r in reversed(d.pending):
            st = self._states[r.rid]
            if st.status is RequestStatus.PENDING_ADMIT:
                break
            if st.status is RequestStatus.MIGRATING:
                st.to_status(RequestStatus.PENDING_ADMIT)
                if self.tracer.enabled:
                    self.tracer.phase(r.rid, "pending_admit", now,
                                      d.label)

    def _start_pull(self, d: _DecodeInstance, r: Request, now: float):
        """Start a request's wire transfer (pages already allocated)."""
        state = self._states[r.rid]
        d.in_transfer += 1
        if d.tree is not None and r.tokens is not None:
            d.tree.match(r.tokens)      # LRU bump, mirrors insert_kv
            n_full = (r.in_len // self.page_tokens) * self.page_tokens
            d.tree.insert(r.tokens[:n_full])
        if self.chunk_tokens:
            _, t_first, t_full = self.tx.pull_streamed(r.rid, now, dst=d.iid)
        else:
            _, t_first, t_full = self.tx.pull_layered(r.rid, now, dst=d.iid)
        state.where = ("transfer", d)
        # per-layer streaming: the request becomes joinable once the
        # first layer lands; the last layer's arrival only gates the
        # drain of the first iteration it joins (pipelined_finish); a
        # granted stream's wire may have finished during prefill, so the
        # joinable time never precedes the pull
        self._ev.push(max(t_first, now), "transfer_first", (d, r, t_full))

    def _on_transfer_first(self, payload, t: float):
        d, r, t_full = payload
        state = self._states[r.rid]
        if state.done:      # cancelled on the wire: pages already freed
            return
        # a granted stream's wire may have finished during prefill
        # (t_full < t): clamp forward so the recorded timeline stays
        # monotone (decode_admit <= transfer_done), as in the live twin
        r.transfer_done = max(t_full, t)
        r.decode_admit = t
        d.in_transfer -= 1
        if self.tracer.enabled:
            # decode starts attending once the first layer lands — the
            # same instant the live cluster stamps in `_admit_one`
            self.tracer.phase(r.rid, "decoding", t, d.label)
        d.arrived.append(r)
        d.kv_full[r.rid] = r.transfer_done
        state.where = ("arrived", d)
        self._try_start_decode(d, t)

    def _try_start_decode(self, d: _DecodeInstance, now: float):
        self._try_admit(d, now)
        if d.busy:
            return
        # absorbed prompts chunk-prefill between decode iterations
        # (prefill-priority, like a mixed engine; the chunk size bounds
        # the decode stall — the interference the chunk charge models)
        if d.absorb is not None and d.absorb.items and self._absorb_chunk \
                and self.phase == "both":
            if self._absorb_step(d, now):
                return
        # transferred requests join the batch at an iteration boundary only
        # (mirrors the live cluster, which admits between decode steps)
        for r in d.arrived:
            st = self._states[r.rid]
            st.where = ("running", d)
            st.to_status(RequestStatus.DECODING)
        d.running.extend(d.arrived)
        d.arrived.clear()
        if not d.running:
            self._check_flip(d, now)
            return
        d.busy = True
        eff_b = max(len(d.running) / d.par.pp, 1.0)
        tau = self.lm.decode_time(eff_b, d.ctx_tokens() / d.par.pp,
                                  Parallelism(d.par.tp, 1))
        end = now + tau
        if d.kv_full:
            for r in d.running:
                kf = d.kv_full.pop(r.rid, None)
                if kf is not None and kf > now:
                    # layer l's attention waits on layer l's pages — the
                    # same charge the live cluster applies
                    end = max(end, pipelined_finish(now, tau, kf,
                                                    self.tx.n_layers))
        if self.tracer.enabled:
            self.tracer.complete("step", "decode_step", now, end,
                                 d.label, batch=len(d.running),
                                 compute=tau)
        self._ev.push(end, "decode_iter", (d, tau))

    def _on_decode_iter(self, payload, t: float):
        d, tau = payload
        self.busy_decode += tau
        d.busy = False
        # hot loop (one pass per simulated decode iteration): when nothing
        # consumes token events and no max_tokens caps are set, skip every
        # per-request flag check and state lookup until finish time
        plain = (not self._recording and not self._ontoken_rids
                 and not self._out_cap)
        cap = self._out_cap
        still = []
        if plain:
            for r in d.running:
                r.tokens_done += 1
                if r.tokens_done >= r.out_len - 1 or r.out_len <= 1:
                    self._finish_state(self._states[r.rid], t)
                    d.pool.free(r.rid)
                else:
                    still.append(r)
        else:
            rec = self._recording
            ontoken = self._ontoken_rids
            for r in d.running:
                r.tokens_done += 1
                out_eff = cap[r.rid] if r.rid in cap else r.out_len
                if rec or r.rid in ontoken:
                    self._emit_token(self._states[r.rid], -1, t)
                if r.tokens_done >= out_eff - 1 or out_eff <= 1:
                    self._finish_state(self._states[r.rid], t)
                    d.pool.free(r.rid)
                else:
                    still.append(r)
        d.running = still
        self._try_start_decode(d, t)

    # -- chunked-prefill absorption (intra-instance aggregation) ---------
    def _absorb_step(self, d: _DecodeInstance, now: float) -> bool:
        """One bounded prefill chunk on a decode instance, between its
        decode iterations (prefill-priority, like a mixed engine). The
        chunk size caps the decode stall; the per-chunk charge is the
        same `prefill_chunk_time` the live engine is billed."""
        def can_take(r):
            if r.rid in d.absorbing:
                return True
            resident = (len(d.running) + len(d.arrived) + d.in_transfer
                        + len(d.absorbing))
            return (resident < d.max_batch
                    and d.pool.can_alloc(d.charge_pages(r)))

        batch = d.absorb.form_batch(
            self._lm_tok, max_batch=1, can_take=can_take,
            chunk_tokens=self._absorb_chunk,
            resumable=lambda r: r.rid in d.absorbing)
        if not batch:
            return False
        r = batch[0]
        state = self._states[r.rid]
        state.to_status(RequestStatus.PREFILLING)
        state.where = ("absorb_run", d)
        ps = self.page_tokens
        S = r.in_len
        if r.rid not in d.absorbing:    # first chunk: reserve residency
            d.absorbing.add(r.rid)
            d.pool.alloc(r.rid, d.charge_pages(r))
            r.prefill_start = now
            if d.tree is not None and r.tokens is not None:
                h, _ = d.tree.match(r.tokens)
                h = min(h, ((S - 1) // ps) * ps)
                r.prefix_hit = h
            self._chunk_ctx[r.rid] = r.prefix_hit
        ctx = self._chunk_ctx[r.rid]
        c = min(self._absorb_chunk, S - ctx)
        if ctx + c < S:
            c = min(max((c // ps) * ps, ps), S - ctx)
        T = self.lm.prefill_chunk_time([(c, ctx)], d.par)
        d.busy = True
        if self.tracer.enabled:
            self.tracer.phase(r.rid, "prefilling", now, d.label)
            self.tracer.complete("compute", "absorb_chunk", now, now + T,
                                 d.label, rid=r.rid, tokens=c, ctx=ctx)
        self._ev.push(now + T, "absorb_done", (d, r, T, ctx, c))
        return True

    def _on_absorb_done(self, payload, t: float):
        d, r, T, ctx, c = payload
        d.busy = False
        self.busy_absorb += T
        state = self._states[r.rid]
        if state.done:                  # cancelled mid-chunk
            if r.rid in d.absorbing:
                d.absorbing.discard(r.rid)
                d.pool.free(r.rid)
            self._chunk_ctx.pop(r.rid, None)
            self._try_start_decode(d, t)
            return
        done_tok = ctx + c
        self._chunk_ctx[r.rid] = done_tok
        if done_tok < r.in_len:
            d.absorb.push(r)
            state.where = ("absorb", d)
        else:
            if d.tree is not None and r.tokens is not None:
                d.tree.insert(r.tokens[:(r.in_len // self.page_tokens)
                                       * self.page_tokens])
            d.absorbing.discard(r.rid)
            self._chunk_ctx.pop(r.rid, None)
            r.first_token = t
            self._emit_token(state, -1, t)
            # KV is already local: no wire, joins at the next boundary
            r.decode_admit = t
            r.transfer_done = t
            d.arrived.append(r)
            state.where = ("arrived", d)
            if self.tracer.enabled:
                self.tracer.phase(r.rid, "decoding", t, d.label)
        self._try_start_decode(d, t)

    # -- mixed-role engine (colocated semantics) --------------------------
    def _step_engine(self, e: "_ColoEngine", now: float):
        if e.busy:
            return
        # prefill first (vLLM prioritizes waiting prefills), batch formed
        # by the shared core; the stateful can_take reserves KV as it admits
        taken = [0, 0.0]

        def can_take(r):
            if (len(e.running) + taken[0] < e.max_b
                    and e.kv_used + taken[1]
                    + _req_kv_bytes(self.lm, r) <= e.cap):
                taken[0] += 1
                taken[1] += _req_kv_bytes(self.lm, r)
                return True
            return False

        batch = e.waiting.form_batch(self.max_prefill_tokens,
                                     can_take=can_take)
        if batch:
            e.kv_used += taken[1]
            e.busy = True
            T = self.lm.prefill_time([r.in_len for r in batch], e.par)
            for r in batch:
                r.prefill_start = now
                st = self._states[r.rid]
                st.where = ("prefill_run", e)
                st.to_status(RequestStatus.PREFILLING)
                if self.tracer.enabled:
                    lane = e.label
                    self.tracer.phase(r.rid, "prefilling", now, lane)
                    self.tracer.complete(
                        "compute", "prefill_batch", now, now + T, lane,
                        rid=r.rid, tokens=r.in_len, hit=0)
            self._ev.push(now + T, "m_prefill_done", (e, batch))
            return
        if e.running:
            e.busy = True
            eff_b = max(len(e.running) / e.par.pp, 1.0)
            ctx = sum(r.in_len + r.tokens_done for r in e.running)
            tau = self.lm.decode_time(eff_b, ctx / e.par.pp,
                                      Parallelism(e.par.tp, 1))
            if self.tracer.enabled:
                self.tracer.complete("step", "decode_step", now, now + tau,
                                     e.label,
                                     batch=len(e.running), compute=tau)
            self._ev.push(now + tau, "m_decode_iter", (e, tau))
            return
        self._check_flip(e, now)

    def _on_mixed_prefill_done(self, payload, t: float):
        e, batch = payload
        e.busy = False
        for r in batch:
            state = self._states[r.rid]
            if state.done:              # cancelled mid-prefill
                e.kv_used -= _req_kv_bytes(self.lm, r)
                continue
            r.first_token = t
            r.decode_admit = t
            self._emit_token(state, -1, t)
            state.where = ("running", e)
            state.to_status(RequestStatus.DECODING)
            if self.tracer.enabled:
                self.tracer.phase(r.rid, "decoding", t, e.label)
            e.running.append(r)
        self._step_engine(e, t)

    def _on_mixed_decode_iter(self, payload, t: float):
        e, tau = payload
        e.busy = False
        rec = self._recording
        ontoken = self._ontoken_rids
        cap = self._out_cap
        still = []
        for r in e.running:
            r.tokens_done += 1
            out_eff = cap[r.rid] if r.rid in cap else r.out_len
            if rec or r.rid in ontoken:
                self._emit_token(self._states[r.rid], -1, t)
            if r.tokens_done >= out_eff - 1 or out_eff <= 1:
                self._finish_state(self._states[r.rid], t)
                e.kv_used -= _req_kv_bytes(self.lm, r)
            else:
                still.append(r)
        e.running = still
        self._step_engine(e, t)

    # -- runtime re-roling ------------------------------------------------
    def set_role(self, g: int, role: str, now: Optional[float] = None):
        """Flip instance ``g`` to ``role`` ("prefill"/"decode"/"mixed").

        The instance leaves the routing views immediately. Queued-but-
        unstarted work is re-routed through the shared dispatcher (so the
        decision log stays comparable across worlds); resident work —
        running decodes, granted/streaming KV, partial chunks — drains in
        place, and the swap to the new-role twin happens when the
        instance is idle. A decode→prefill flip therefore never moves or
        leaks pages (`pool.used == 0` is asserted at completion); a
        prefill→decode flip drains within one batch/chunk time."""
        assert role in ("prefill", "decode", "mixed"), role
        now = self._ev.now if now is None else now
        inst = self.inst[g]
        if self._role_of(inst) == role:
            inst.target = None          # flip-back cancels a pending drain
            inst.draining = False
            return
        if inst.target == role:
            return
        # validate the fleet *after* every pending drain completes:
        # somebody must accept arrivals, and prefill output needs a
        # decode target (draining instances count as their target role)
        after = [x.target or self._role_of(x)
                 for x in self.inst if x is not inst] + [role]
        if not any(r2 in ("prefill", "mixed")
                   or (r2 == "decode" and self._absorb_chunk)
                   for r2 in after):
            raise ValueError("re-roling would leave no instance able to "
                             "accept arrivals")
        if self.phase == "both" and "prefill" in after \
                and "decode" not in after:
            raise ValueError("re-roling would leave prefill instances "
                             "with no decode target")
        inst.draining = True
        inst.target = role
        if self.tracer.enabled:
            self.tracer.event("role_drain", now, lane=inst.label,
                              role=role)
        self._reroute_unstarted(inst, now)
        self._check_flip(inst, now)

    def apply_roles(self, roles: Sequence[str],
                    now: Optional[float] = None):
        """Reconcile the fleet's per-instance roles with a plan vector
        (`FleetRouter.elastic_callback` / placement `mode_search`).
        Decode-creating flips run first so a later prefill-creating flip
        never transits through a prefill-without-decode-target fleet."""
        order = {"decode": 0, "mixed": 1, "prefill": 2}
        for g in sorted(range(min(len(roles), len(self.inst))),
                        key=lambda g: order.get(roles[g], 3)):
            self.set_role(g, roles[g], now=now)

    def pressure(self) -> Dict[str, float]:
        """Load signals for role controllers and routers: prefill queue
        depth and decode KV-page occupancy (the memory-bound overload
        signal queue depth misses)."""
        P, D, E = self._p_route(), self._d_route(), self._e_route()
        util = max((d.pool.used / max(d.pool.num_pages, 1) for d in D),
                   default=0.0)
        return {
            "prefill_queued_tokens": float(sum(p.queued_tokens
                                               for p in P)),
            "prefill_inflight": float(sum(p.inflight for p in P)),
            "decode_kv_util": float(util),
            "decode_load": float(sum(d.load for d in D)),
            "mixed_load": float(sum(e.load for e in E)),
            "n_prefill": float(len(P)), "n_decode": float(len(D)),
            "n_mixed": float(len(E)),
        }

    def kv_utilization(self) -> float:
        """Peak decode page-pool occupancy in [0, 1] (router-side
        KV-pressure overload signal)."""
        return self.pressure()["decode_kv_util"]

    def _reroute_unstarted(self, inst, now: float):
        if isinstance(inst, _PrefillInstance):
            for r in list(inst.queue.items):
                if r.rid in self._chunk_ctx or r.rid in self._sim_stream:
                    continue        # mid-chunk: finish here
                inst.queue.remove(r)
                self._ev.push(now, "arrive", self._states[r.rid])
            self._ev.push(now, "prefill_poke", inst)
        elif isinstance(inst, _DecodeInstance):
            D = [d for d in self._d_route() if d is not inst]
            for r in list(inst.pending):
                if r.rid in inst.granted or not D:
                    continue        # pages/wire committed: drain here
                inst.pending.remove(r)
                # the parked wire bytes were fixed at park time, so the
                # re-pick skips prefix hits (hit=0 in the decision log)
                di = self.disp.pick_decode(r.rid, [d.load for d in D],
                                           now=now)
                nd = D[di]
                if r.rid in self._sim_stream:
                    self._sim_stream[r.rid] = nd
                nd.pending.append(r)
                self._states[r.rid].where = ("pending", nd)
                self._ev.push(now, "decode_poke", nd)
            if inst.absorb is not None:
                for r in list(inst.absorb.items):
                    if r.rid in inst.absorbing:
                        continue    # partial chunks: finish here
                    inst.absorb.remove(r)
                    self._ev.push(now, "arrive", self._states[r.rid])
            self._ev.push(now, "decode_poke", inst)
        else:
            for r in list(inst.waiting.items):
                inst.waiting.remove(r)
                self._ev.push(now, "arrive", self._states[r.rid])
            self._ev.push(now, "poke", inst)

    def _check_flip(self, inst, now: float):
        if inst.target is None:
            return
        if isinstance(inst, _PrefillInstance):
            if inst.queue.items or inst.inflight:
                return
        elif isinstance(inst, _DecodeInstance):
            if (inst.busy or inst.load or inst.absorb.items
                    or inst.absorbing):
                return
            assert inst.pool.used == 0, \
                f"role flip with {inst.pool.used} pages resident"
        else:
            if inst.busy or inst.waiting.items or inst.running:
                return
        self._complete_flip(inst, now)

    def _complete_flip(self, inst, now: float):
        g = self.inst.index(inst)
        role = inst.target
        inst.target = None
        inst.draining = False
        twin = self._twins.pop((g, role), None)
        if twin is None:
            twin = self._make_instance(role, self._pars[g],
                                       label=inst.label)
        twin.draining = False
        twin.target = None
        self._twins[(g, self._role_of(inst))] = inst
        self.inst[g] = twin
        self._role_events.append((now, inst.label, role))
        if self.tracer.enabled:
            self.tracer.event("role_change", now, lane=inst.label,
                              role=role)
        # fresh capacity: poke so blocked global work can move
        if isinstance(twin, _PrefillInstance):
            self._ev.push(now, "prefill_poke", twin)
        elif isinstance(twin, _DecodeInstance):
            self._ev.push(now, "decode_poke", twin)
        else:
            self._ev.push(now, "poke", twin)
        if self._backlog:
            held, self._backlog = self._backlog, []
            for st in held:
                st.where = None
                self._ev.push(now, "arrive", st)

    # -- cancellation ----------------------------------------------------
    def _do_cancel(self, state: RequestState, t: float):
        r = state.request
        if state.where is None:
            return
        stage, loc = state.where
        if stage == "prefill":              # queued (incl. between chunks)
            loc.queue.remove(r)
            if self.chunk_tokens:
                self._drop_sim_stream(r, t)
                self._chunk_ctx.pop(r.rid, None)
                self._ev.push(t, "prefill_poke", loc)
        elif stage == "prefill_run":        # in-flight prefill batch / chunk:
            pass                            # the done handler drops it
        elif stage == "backlog":            # held during a re-role drain
            self._backlog = [st for st in self._backlog
                             if st.rid != r.rid]
        elif stage == "queued":             # mixed-engine waiting queue
            loc.waiting.remove(r)
        elif stage == "absorb":             # absorb queue (incl. partials)
            loc.absorb.remove(r)
            if r.rid in loc.absorbing:
                loc.absorbing.discard(r.rid)
                loc.pool.free(r.rid)
            self._chunk_ctx.pop(r.rid, None)
            self._ev.push(t, "decode_poke", loc)
        elif stage == "absorb_run":         # mid-chunk: handler cleans up
            pass
        elif stage == "pending":            # parked, unassigned pages
            d = loc
            if r in d.pending:
                d.pending.remove(r)
            if r.rid in d.granted:          # finalized after a grant
                del d.granted[r.rid]
                d.pool.free(r.rid)
            self.tx.cancel(r.rid)           # drops chunk segments too
            self._ev.push(t, "decode_poke", d)  # head may admit now
        elif stage == "transfer":           # on the wire: pages reserved
            d = loc
            d.pool.free(r.rid)
            d.in_transfer -= 1
            self._ev.push(t, "decode_poke", d)
        elif stage == "arrived":
            d = loc
            if r in d.arrived:
                d.arrived.remove(r)
            d.kv_full.pop(r.rid, None)
            d.pool.free(r.rid)
            self._ev.push(t, "decode_poke", d)
        elif stage == "running":
            if isinstance(loc, _ColoEngine):
                if r in loc.running:
                    loc.running.remove(r)
                loc.kv_used -= _req_kv_bytes(self.lm, r)
                self._ev.push(t, "poke", loc)
            else:
                d = loc
                if r in d.running:
                    d.running.remove(r)
                d.kv_full.pop(r.rid, None)
                d.pool.free(r.rid)
                self._ev.push(t, "decode_poke", d)

    # -- metrics ---------------------------------------------------------
    def extras(self) -> Dict:
        reqs = [s.request for s in self._states.values()]
        extras = {
            "kv_total": self.tx.total_time,
            "kv_p95": _percentile(self.tx.times, 0.95),
            "kv_chunks": self.tx.total_chunks,
            "kv_bytes": self.tx.total_bytes,
            "parked_bytes_peak": self.tx.peak_parked_bytes,
            "kv_stream_saved_s": self.tx.stream_saved_s,
            "streamed_pulls": self.tx.streamed_pulls,
            "decisions": self.disp.decisions,
            "states": dict(self._states),
            "breakdown": {"prefill_busy_s": self.busy_prefill,
                          "decode_busy_s": self.busy_decode,
                          **self._breakdown},
        }
        if self.busy_absorb or self.absorbed:
            extras["breakdown"]["absorb_busy_s"] = self.busy_absorb
            extras["absorbed"] = self.absorbed
        if self._role_events:
            extras["role_events"] = list(self._role_events)
        if self.prefix_on:
            extras["prefix"] = {
                "hit_tokens": sum(r.prefix_hit for r in reqs),
                "decode_hit_tokens": sum(r.decode_hit for r in reqs),
                "prompt_tokens": sum(r.in_len for r in reqs),
                "prefill_trees": [p.tree.stats.as_dict() for p in self.P],
                "decode_trees": [d.tree.stats.as_dict() for d in self.D],
            }
        return extras


def simulate_disaggregated(
        reqs: List[Request],
        lm: LatencyModel,
        prefill: InstanceConfig,
        decode: InstanceConfig,
        **kwargs) -> Tuple[List[Request], Dict]:
    """Closed-world shim over `SimDisaggBackend`: submit-all-then-drain.
    Returns (requests with timestamps, extras) — see the backend class
    for the keyword knobs (transfer_bw, lm_tokens, phase, prefix_cache,
    num_decode_pages, dispatcher, horizon, tracker, ...).  Per-token
    event recording defaults OFF here (bulk sweeps); pass
    record_events=True (or a tracker) for ITL distributions."""
    kwargs.setdefault("record_events", False)
    backend = SimDisaggBackend(lm, prefill, decode, **kwargs)
    for r in reqs:
        backend.submit(r)
    backend.drain()
    return reqs, backend.extras()


# ---------------------------------------------------------------------------
# Mixed-role engine state + legacy shims
# ---------------------------------------------------------------------------

class _ColoEngine:
    """Continuous-batching engine state for a ``"mixed"``-role instance
    (vLLM-like prefill-priority; the degenerate colocated deployment is
    every instance carrying this role)."""

    def __init__(self, iid, max_b: float, cap: float,
                 par: Optional[Parallelism] = None):
        self.iid = iid
        self.max_b = max_b
        self.cap = cap
        self.par = par or Parallelism()
        self.waiting: FCFSQueue = FCFSQueue(token_of=lambda r: r.in_len)
        self.running: List[Request] = []
        self.kv_used = 0.0
        self.busy = False

    @property
    def load(self):
        return len(self.waiting) + len(self.running)


class SimDisaggBackend(SimServingBackend):
    """Legacy disaggregated entrypoint: ``(lm, prefill_cfg, decode_cfg)``
    translated to a prefill+decode role vector over the role-unified
    `SimServingBackend`. Schedules, token timestamps, dispatch decisions
    and metric keys are byte-identical to the pre-unification class."""

    def __init__(self, lm: LatencyModel, prefill: InstanceConfig,
                 decode: InstanceConfig, **kwargs):
        roles = ([("prefill", prefill.par)] * prefill.count
                 + [("decode", decode.par)] * decode.count)
        super().__init__(lm, roles, **kwargs)


class SimColocatedBackend(SimServingBackend):
    """Continuous batching with prefill-priority (vLLM v0 default),
    behind the ServingBackend protocol — the degenerate "all instances
    mixed" case of the role-unified `SimServingBackend`."""

    def __init__(self, lm: LatencyModel, inst: InstanceConfig, *,
                 max_batch: Optional[int] = None,
                 max_prefill_tokens: int = 2048,
                 kv_reserve: float = 0.1,
                 horizon: float = 1e9,
                 tracker=None,
                 record_events: bool = True,
                 tracer=None,
                 metrics=None):
        super().__init__(lm, [("mixed", inst.par)] * inst.count,
                         max_mixed_batch=max_batch,
                         max_prefill_tokens=max_prefill_tokens,
                         kv_reserve=kv_reserve,
                         prefix_cache=False,
                         horizon=horizon, tracker=tracker,
                         record_events=record_events,
                         tracer=tracer, metrics=metrics)
        self.par = inst.par

    def extras(self) -> Dict:
        return {"kv_total": 0.0, "kv_p95": 0.0, "breakdown": {},
                "states": dict(self._states)}


def simulate_colocated(
        reqs: List[Request],
        lm: LatencyModel,
        inst: InstanceConfig,
        **kwargs) -> Tuple[List[Request], Dict]:
    """Closed-world shim over `SimColocatedBackend` (see that class).
    Per-token event recording defaults OFF here, as in
    `simulate_disaggregated`."""
    kwargs.setdefault("record_events", False)
    backend = SimColocatedBackend(lm, inst, **kwargs)
    for r in reqs:
        backend.submit(r)
    backend.drain()
    return reqs, backend.extras()


def simulate_roles(
        reqs: List[Request],
        lm: LatencyModel,
        par: Parallelism,
        roles: Sequence[str],
        **kwargs) -> Tuple[List[Request], Dict]:
    """Closed-world shim over the role-unified backend for an arbitrary
    per-instance role vector (placement `mode_search` evaluates candidate
    vectors through this). Keyword knobs as in `SimServingBackend`."""
    kwargs.setdefault("record_events", False)
    backend = SimServingBackend(lm, [(r, par) for r in roles], **kwargs)
    for r in reqs:
        backend.submit(r)
    backend.drain()
    return reqs, backend.extras()
