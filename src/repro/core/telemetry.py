"""Unified request-lifecycle tracing + metrics for live and simulated serving.

Every `ServingBackend` (live cluster or discrete-event simulator) can carry a
`Tracer`: a virtual-clock span recorder with one lane per engine instance and
a per-request *phase* state machine mirroring `RequestStatus`.  Both worlds
emit the same span schema at the same lifecycle points, so a pinned trace
replayed on the simulator and on the live cluster (with an `EngineCharge`
virtual step-time model) produces span sequences a test can diff
timestamp-for-timestamp — the tracing twin of the dispatch-decision and
transfer-charge parity the repo already pins.

Span schema (categories):

  phase   one span per `RequestStatus` residence of a request: ``queued``,
          ``prefilling``, ``migrating``, ``pending_admit``, ``decoding``.
          The terminal transition appends a span event named ``FINISHED`` /
          ``CANCELLED`` / ``FAILED``.  Lane = the instance holding the
          request (``prefill0``, ``decode1``, ``engine0``).
  compute one span per prefill kernel dispatch: ``prefill_batch`` (whole
          prompt) or ``chunk`` (chunked prefill, args ``tokens``/``ctx``).
  step    one span per decode iteration on an instance lane (args
          ``batch``, ``compute`` = pure step seconds before any KV-stream
          pipelining stall).
  wire    one span per KV migration pull on a ``wire:src->dst`` lane
          (args ``bytes``; streamed pulls also carry ``t_first``).

Instant events: ``token`` (per emitted token, args ``i``), ``route_prefill``
/ ``route_decode`` (dispatcher decisions, args ``instance``/``hit``),
``park`` / ``park_chunk`` / ``grant`` (transfer-manager landings).

The disabled path is `NULL_TRACER` (the default everywhere): every method is
a no-op and backends keep their token-emission fast paths, so tracing off is
behavior-identical to not having this module at all.

`MetricsRegistry` is the counters/gauges/histograms side: push (`counter`,
`gauge`, `observe`) plus pull (`register` a collector callable sampled at
`snapshot()` time — page-pool occupancy, refcounts, queue depths cost
nothing until somebody asks).  `prometheus()` renders the text exposition
format; `to_chrome_trace` / `save_chrome_trace` render Perfetto-loadable
Chrome trace JSON, and `validate_chrome_trace` is the schema checker CI runs
against exported traces.

`attribute_request` decomposes one request's latency from its spans: TTFT
into queue + prefill-compute + prefill-stall (chunk round-robin waits), the
decode-startup path into migration + admission, and TPOT into batch-wait +
step-compute.  `goodput.SLOTracker` attaches this to SLO violations so a
miss comes annotated with its dominant cause.
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = [
    "Span", "SpanEvent", "Instant", "Tracer", "NullTracer", "NULL_TRACER",
    "MetricsRegistry", "Attribution", "attribute_request",
    "to_chrome_trace", "save_chrome_trace", "validate_chrome_trace",
]


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SpanEvent:
    """Typed event attached inside a span (e.g. the terminal status)."""
    name: str
    t: float
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Instant:
    """Global instant event (token emission, routing decision, ...).
    `wall_t` is the optional wall-clock stamp (None unless the tracer
    carries a `wall_clock` source)."""
    name: str
    t: float
    rid: Optional[int] = None
    lane: Optional[str] = None
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)
    wall_t: Optional[float] = None


@dataclasses.dataclass
class Span:
    cat: str
    name: str
    lane: str
    t0: float
    rid: Optional[int] = None
    t1: Optional[float] = None
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)
    events: List[SpanEvent] = dataclasses.field(default_factory=list)
    # wall-clock stamps (opt-in; virtual t0/t1 stay the span's identity)
    wall_t0: Optional[float] = None
    wall_t1: Optional[float] = None

    @property
    def open(self) -> bool:
        return self.t1 is None

    @property
    def dur(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0


class NullTracer:
    """Disabled tracer: every hook is a constant-time no-op.  Backends
    check only `enabled` on hot paths; everything else may call through
    unconditionally."""
    __slots__ = ()
    enabled = False

    def __bool__(self) -> bool:
        return False

    def begin(self, *a, **k):
        return None

    def end(self, *a, **k):
        return None

    def complete(self, *a, **k):
        return None

    def event(self, *a, **k):
        return None

    def phase(self, *a, **k):
        return None

    def finish_phase(self, *a, **k):
        return None


NULL_TRACER = NullTracer()


class Tracer:
    """Virtual-clock span recorder.

    `begin`/`end` manage explicit spans (every opened span must close
    exactly once — double closes and time-travel raise); `complete` records
    an already-finished span; `phase` drives the per-request phase state
    machine (ends the previous phase span at the transition time, opens the
    next; re-entering the same phase+lane is a no-op, which is what chunked
    prefill's re-queue does); `finish_phase` closes the open phase with a
    terminal `SpanEvent` (``FINISHED`` / ``CANCELLED`` / ``FAILED``).

    `sample_rate` < 1.0 turns on per-request trace sampling for fleet-scale
    runs: sampled requests keep every span, unsampled ones go instants-only
    (tokens, routing decisions, terminals still land; their per-request
    spans are created but never retained). The decision is a deterministic
    rid hash — no RNG — so the same request samples identically in the
    simulator and on the live cluster, and sampling can never perturb
    tokens, timings, or routing (it only filters what is *recorded*).
    Spans without a rid (decode step spans, batch-level compute) are
    instance-scoped, not request-scoped, and are always kept.

    `wall_clock` (opt-in, e.g. ``time.time``) adds wall-clock stamps
    alongside the virtual timestamps: spans gain `wall_t0`/`wall_t1`
    (sampled at `begin`/`end` call time), instants gain `wall_t`.
    Virtual time stays the identity — parity diffs and the phase state
    machine never look at wall stamps — but an exported trace carries
    both, so a live run can be lined up against real elapsed time (and
    a sim run against search wall-cost). Default None: no stamps, no
    per-event clock reads, byte-identical traces to before the knob.
    """
    enabled = True

    def __init__(self, sample_rate: float = 1.0, sample_seed: int = 0,
                 wall_clock: Optional[Callable[[], float]] = None):
        self.spans: List[Span] = []
        self.instants: List[Instant] = []
        self.terminals: Dict[int, Tuple[str, float]] = {}
        self._open_phase: Dict[int, Span] = {}
        self.sample_rate = float(sample_rate)
        self.sample_seed = int(sample_seed)
        self.wall_clock = wall_clock

    def sampled(self, rid: Optional[int]) -> bool:
        """Per-request keep-all decision (deterministic rid hash)."""
        if rid is None or self.sample_rate >= 1.0:
            return True
        if self.sample_rate <= 0.0:
            return False
        x = (rid * 0x9E3779B9 + self.sample_seed * 0x85EBCA6B) & 0xFFFFFFFF
        x ^= x >> 16
        x = (x * 0x45D9F3B) & 0xFFFFFFFF
        x ^= x >> 16
        return x / 2.0 ** 32 < self.sample_rate

    # -- explicit spans -------------------------------------------------
    def begin(self, cat: str, name: str, t: float, lane: str,
              rid: Optional[int] = None, **args) -> Span:
        sp = Span(cat, name, lane, t, rid=rid, args=args)
        if self.wall_clock is not None:
            sp.wall_t0 = float(self.wall_clock())
        if self.sampled(rid):
            self.spans.append(sp)
        return sp

    def end(self, span: Span, t: float, **args):
        if span.t1 is not None:
            raise ValueError(f"span closed twice: {span.cat}/{span.name} "
                             f"rid={span.rid}")
        if t < span.t0:
            raise ValueError(f"span ends before it starts: {span.name} "
                             f"{t} < {span.t0}")
        span.t1 = t
        if self.wall_clock is not None:
            span.wall_t1 = max(float(self.wall_clock()),
                               span.wall_t0 or -math.inf)
        if args:
            span.args.update(args)

    def complete(self, cat: str, name: str, t0: float, t1: float, lane: str,
                 rid: Optional[int] = None, **args) -> Span:
        sp = self.begin(cat, name, t0, lane, rid=rid, **args)
        self.end(sp, t1)
        return sp

    def event(self, name: str, t: float, rid: Optional[int] = None,
              lane: Optional[str] = None, **args):
        wall = (float(self.wall_clock())
                if self.wall_clock is not None else None)
        self.instants.append(Instant(name, t, rid=rid, lane=lane, args=args,
                                     wall_t=wall))

    # -- per-request phase state machine --------------------------------
    def phase(self, rid: int, name: str, t: float, lane: str, **args):
        if not self.sampled(rid):
            return
        cur = self._open_phase.get(rid)
        if cur is not None:
            if cur.name == name and cur.lane == lane:
                return                          # chunked re-entry: no-op
            self.end(cur, t)
        self._open_phase[rid] = self.begin("phase", name, t, lane,
                                           rid=rid, **args)

    def finish_phase(self, rid: int, t: float, terminal: str):
        self.terminals[rid] = (terminal, t)
        if not self.sampled(rid):
            return
        cur = self._open_phase.pop(rid, None)
        if cur is None:                         # e.g. cancel pre-arrival
            self.event(terminal, t, rid=rid)
            return
        cur.events.append(SpanEvent(terminal, t))
        self.end(cur, max(t, cur.t0))

    # -- queries --------------------------------------------------------
    def open_spans(self) -> List[Span]:
        return [s for s in self.spans if s.open]

    def for_rid(self, rid: int) -> List[Span]:
        return [s for s in self.spans if s.rid == rid]

    def tokens_for(self, rid: int) -> List[Instant]:
        return [i for i in self.instants
                if i.rid == rid and i.name == "token"]

    def lanes(self) -> List[str]:
        seen: Dict[str, None] = {}
        for s in self.spans:
            seen.setdefault(s.lane)
        for i in self.instants:
            if i.lane is not None:
                seen.setdefault(i.lane)
        return sorted(seen, key=_lane_sort_key)


def _lane_sort_key(lane: str) -> Tuple[int, str]:
    for rank, prefix in enumerate(("prefill", "engine", "decode", "wire")):
        if lane.startswith(prefix):
            return (rank, lane)
    return (9, lane)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

class MetricsRegistry:
    """Counters / gauges / histograms with a pull-collector side channel.

    Push: `counter(name, inc)`, `gauge(name, value)`, `observe(name, v)`
    (histogram sample).  Pull: `register(fn)` where `fn() -> {name: value}`
    is sampled at `snapshot()` time — components expose page occupancy,
    refcounts, and queue depths without any hot-path bookkeeping.
    """

    def __init__(self):
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, List[float]] = {}
        self._collectors: List[Callable[[], Dict[str, float]]] = []

    def counter(self, name: str, inc: float = 1.0):
        self._counters[name] = self._counters.get(name, 0.0) + inc

    def gauge(self, name: str, value: float):
        self._gauges[name] = float(value)

    def observe(self, name: str, value: float):
        self._hists.setdefault(name, []).append(float(value))

    def register(self, fn: Callable[[], Dict[str, float]]):
        self._collectors.append(fn)

    def snapshot(self) -> Dict[str, float]:
        from ..serving.api import percentile
        out: Dict[str, float] = dict(self._counters)
        out.update(self._gauges)
        for name, xs in self._hists.items():
            out[f"{name}_count"] = float(len(xs))
            out[f"{name}_sum"] = float(sum(xs))
            out[f"{name}_min"] = min(xs) if xs else 0.0
            out[f"{name}_max"] = max(xs) if xs else 0.0
            out[f"{name}_p50"] = percentile(xs, 0.5)
            out[f"{name}_p99"] = percentile(xs, 0.99)
        for fn in self._collectors:
            for k, v in fn().items():
                out[k] = float(v)
        return out

    def prometheus(self) -> str:
        """Prometheus text exposition format snapshot."""
        snap = self.snapshot()
        counters = set(self._counters)
        lines: List[str] = []
        for name in sorted(snap):
            metric = _prom_name(name)
            kind = "counter" if name in counters else "gauge"
            lines.append(f"# TYPE {metric} {kind}")
            lines.append(f"{metric} {snap[name]:.9g}")
        return "\n".join(lines) + "\n"


def _prom_name(name: str) -> str:
    n = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
    if not re.match(r"[a-zA-Z_:]", n):
        n = "_" + n
    return "repro_" + n


# ---------------------------------------------------------------------------
# latency attribution
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Attribution:
    """Where one request's latency went, decomposed from its spans.

    TTFT = router_queue + queue + prefill_compute + prefill_stall (chunk
    round-robin waits between this prompt's chunks); router_queue is the
    time a fleet router held the request before dispatching it to a
    replica (0 when no router is in the path).  Decode startup
    (first-token -> first decode iteration) = migrate + admit.  TPOT
    decomposes each inter-token gap into the emitting decode step's pure
    compute vs batch-wait (queueing behind other members' steps, KV-stream
    pipelining stalls, and — on colocated engines — prefill interference).
    """
    rid: int
    arrive: float
    ttft: float
    tpot: float
    n_tokens: int
    queue_s: float
    prefill_compute_s: float
    prefill_stall_s: float
    migrate_s: float
    admit_s: float
    decode_compute_s: float
    decode_wait_s: float
    terminal: str = "FINISHED"
    router_queue_s: float = 0.0

    def ttft_parts(self) -> Dict[str, float]:
        return {"router_queue": self.router_queue_s,
                "queue": self.queue_s,
                "prefill_compute": self.prefill_compute_s,
                "prefill_stall": self.prefill_stall_s}

    def tpot_parts(self) -> Dict[str, float]:
        return {"step_compute": self.decode_compute_s,
                "batch_wait": self.decode_wait_s}

    @property
    def dominant_ttft(self) -> str:
        parts = self.ttft_parts()
        return max(parts, key=lambda k: parts[k])

    @property
    def dominant_tpot(self) -> str:
        parts = self.tpot_parts()
        return max(parts, key=lambda k: parts[k])

    def format(self) -> str:
        return (f"rid={self.rid} ttft={self.ttft:.4f}s "
                f"(router={self.router_queue_s:.4f} "
                f"queue={self.queue_s:.4f} "
                f"prefill={self.prefill_compute_s:.4f} "
                f"stall={self.prefill_stall_s:.4f}) "
                f"startup(migrate={self.migrate_s:.4f} "
                f"admit={self.admit_s:.4f}) "
                f"tpot={self.tpot:.4f}s "
                f"(compute={self.decode_compute_s:.4f} "
                f"wait={self.decode_wait_s:.4f}) "
                f"dominant={self.dominant_ttft}/{self.dominant_tpot}")


def attribute_request(tracer: Tracer, rid: int) -> Optional[Attribution]:
    """Decompose one request's TTFT/TPOT from its recorded spans; None if
    the tracer never saw the request."""
    phases = [s for s in tracer.for_rid(rid) if s.cat == "phase"]
    if not phases:
        return None
    arrive = min(s.t0 for s in phases)
    tokens = tracer.tokens_for(rid)
    first_t = tokens[0].t if tokens else None
    last_t = tokens[-1].t if tokens else None

    def phase_dur(name: str) -> float:
        return sum(s.dur for s in phases if s.name == name and not s.open)

    router_queue_s = phase_dur("router_queued")
    queue_s = phase_dur("queued")
    prefill_s = phase_dur("prefilling")
    compute_s = sum(s.dur for s in tracer.for_rid(rid)
                    if s.cat == "compute" and not s.open)
    stall_s = max(prefill_s - compute_s, 0.0)
    migrate_s = phase_dur("migrating")
    admit_s = phase_dur("pending_admit")

    ttft = (first_t - arrive) if first_t is not None else 0.0
    n = len(tokens)
    tpot = (last_t - first_t) / (n - 1) if n > 1 else 0.0

    # per-gap wait/compute split against the decode lane's step spans
    decode_lanes = {s.lane for s in phases
                    if s.name in ("decoding", "prefilling")}
    steps: Dict[Tuple[str, float], Span] = {}
    for s in tracer.spans:
        if s.cat == "step" and s.lane in decode_lanes and not s.open:
            steps[(s.lane, s.t1)] = s
    compute = wait = 0.0
    for a, b in zip(tokens, tokens[1:]):
        gap = b.t - a.t
        sp = None
        for lane in decode_lanes:
            sp = steps.get((lane, b.t))
            if sp is not None:
                break
        if sp is None:
            compute += gap              # untracked step: assume compute
            continue
        c = min(float(sp.args.get("compute", sp.dur)), gap)
        compute += c
        wait += gap - c
    terminal, _ = tracer.terminals.get(rid, ("FINISHED", 0.0))
    return Attribution(rid, arrive, ttft, tpot, n, queue_s, compute_s,
                       stall_s, migrate_s, admit_s, compute, wait, terminal,
                       router_queue_s=router_queue_s)


# ---------------------------------------------------------------------------
# Chrome-trace / Perfetto export
# ---------------------------------------------------------------------------

_US = 1e6


def to_chrome_trace(tracer: Tracer,
                    metrics: Optional[MetricsRegistry] = None) -> Dict:
    """Render the tracer as Chrome-trace JSON (Perfetto-loadable).

    One process (pid) per lane, complete ("X") events for spans, instant
    ("i") events for tokens/decisions/landings, and flow arrows ("s"/"f")
    following each request across lanes (prefill -> decode migration).
    Events are globally sorted by timestamp; open spans (crashed runs)
    export with dur=0 and ``"open": true``.
    """
    lanes = tracer.lanes()
    pid_of = {lane: i + 1 for i, lane in enumerate(lanes)}

    # instants without a lane (tokens, routes) attach to the lane of the
    # request's phase span covering their timestamp
    by_rid: Dict[int, List[Span]] = {}
    for s in tracer.spans:
        if s.cat == "phase" and s.rid is not None:
            by_rid.setdefault(s.rid, []).append(s)
    for spans in by_rid.values():
        spans.sort(key=lambda s: s.t0)

    def lane_at(rid: Optional[int], t: float) -> Optional[str]:
        best = None
        for s in by_rid.get(rid, ()):
            if s.t0 <= t and (s.t1 is None or t <= s.t1):
                best = s.lane
            elif s.t0 > t:
                break
        return best

    meta: List[Dict] = []
    for lane in lanes:
        meta.append({"name": "process_name", "ph": "M", "pid": pid_of[lane],
                     "tid": 0, "args": {"name": lane}})
        meta.append({"name": "process_sort_index", "ph": "M",
                     "pid": pid_of[lane], "tid": 0,
                     "args": {"sort_index": pid_of[lane]}})

    events: List[Dict] = []
    for s in tracer.spans:
        args = {k: v for k, v in s.args.items()}
        if s.rid is not None:
            args["rid"] = s.rid
        if s.wall_t0 is not None:
            args["wall_t0"] = s.wall_t0
            if s.wall_t1 is not None:
                args["wall_t1"] = s.wall_t1
        ev = {"name": s.name, "cat": s.cat, "ph": "X", "ts": s.t0 * _US,
              "dur": (s.dur if not s.open else 0.0) * _US,
              "pid": pid_of[s.lane], "tid": 0, "args": args}
        if s.open:
            ev["args"]["open"] = True
        events.append(ev)
        for se in s.events:
            events.append({"name": se.name, "cat": s.cat, "ph": "i",
                           "s": "t", "ts": se.t * _US, "pid": pid_of[s.lane],
                           "tid": 0, "args": dict(se.args, rid=s.rid)})
    for i in tracer.instants:
        lane = i.lane or lane_at(i.rid, i.t)
        if lane is None:
            lane = lanes[0] if lanes else "global"
            if lane not in pid_of:
                pid_of[lane] = len(pid_of) + 1
                meta.append({"name": "process_name", "ph": "M",
                             "pid": pid_of[lane], "tid": 0,
                             "args": {"name": lane}})
        args = dict(i.args)
        if i.rid is not None:
            args["rid"] = i.rid
        if i.wall_t is not None:
            args["wall_t"] = i.wall_t
        events.append({"name": i.name, "cat": "instant", "ph": "i",
                       "s": "t", "ts": i.t * _US, "pid": pid_of[lane],
                       "tid": 0, "args": args})
    # flow arrows: a request hopping lanes between consecutive phase spans
    for rid, spans in by_rid.items():
        for a, b in zip(spans, spans[1:]):
            if a.lane == b.lane or a.t1 is None:
                continue
            events.append({"name": "request", "cat": "flow", "ph": "s",
                           "id": rid, "ts": a.t1 * _US, "pid": pid_of[a.lane],
                           "tid": 0, "args": {"rid": rid}})
            events.append({"name": "request", "cat": "flow", "ph": "f",
                           "bp": "e", "id": rid, "ts": b.t0 * _US,
                           "pid": pid_of[b.lane], "tid": 0,
                           "args": {"rid": rid}})
    events.sort(key=lambda e: e["ts"])
    out: Dict[str, Any] = {"traceEvents": meta + events,
                           "displayTimeUnit": "ms"}
    if metrics is not None:
        out["otherData"] = {"metrics": metrics.snapshot()}
    return out


def save_chrome_trace(path: str, tracer: Tracer,
                      metrics: Optional[MetricsRegistry] = None) -> Dict:
    doc = to_chrome_trace(tracer, metrics)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


_PHASES = set("XBEisfM")


def validate_chrome_trace(doc: Any) -> List[str]:
    """Schema checker for exported traces: well-formed events, globally
    monotone timestamps, matched begin/end, non-negative durations, and
    flow arrows whose finish has a matching start.  Returns a list of
    error strings (empty = valid)."""
    errors: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["not a Chrome-trace object (missing traceEvents)"]
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        return ["traceEvents is not a list"]
    last_ts = None
    stacks: Dict[Tuple[Any, Any], List[str]] = {}
    flow_started = set()
    for n, ev in enumerate(evs):
        where = f"event[{n}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PHASES:
            errors.append(f"{where}: bad ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            errors.append(f"{where}: missing name")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"{where}: missing/non-numeric ts")
            continue
        if ts < 0:
            errors.append(f"{where}: negative ts {ts}")
        if last_ts is not None and ts < last_ts:
            errors.append(f"{where}: non-monotone ts {ts} < {last_ts}")
        last_ts = ts
        key = (ev.get("pid"), ev.get("tid"))
        args = ev.get("args") or {}
        for wk in ("wall_t0", "wall_t1", "wall_t"):
            if wk in args and not isinstance(args[wk], (int, float)):
                errors.append(f"{where}: non-numeric {wk} {args[wk]!r}")
        if isinstance(args.get("wall_t0"), (int, float)) and \
                isinstance(args.get("wall_t1"), (int, float)) and \
                args["wall_t1"] < args["wall_t0"]:
            errors.append(f"{where}: wall_t1 {args['wall_t1']} < "
                          f"wall_t0 {args['wall_t0']}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: X event bad dur {dur!r}")
        elif ph == "B":
            stacks.setdefault(key, []).append(ev["name"])
        elif ph == "E":
            stack = stacks.get(key)
            if not stack:
                errors.append(f"{where}: E without matching B on {key}")
            else:
                stack.pop()
        elif ph == "s":
            flow_started.add((ev.get("id"), ev.get("name")))
        elif ph == "f":
            if (ev.get("id"), ev.get("name")) not in flow_started:
                errors.append(f"{where}: flow finish without start "
                              f"id={ev.get('id')!r}")
    for key, stack in stacks.items():
        if stack:
            errors.append(f"unclosed B events on {key}: {stack}")
    return errors


def _main(argv: List[str]) -> int:
    """CLI: ``python -m repro.core.telemetry trace.json [...]`` validates
    exported traces against the schema checker (CI uses this)."""
    if not argv:
        print("usage: python -m repro.core.telemetry TRACE.json [...]")
        return 2
    rc = 0
    for path in argv:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable: {e}")
            rc = 1
            continue
        errs = validate_chrome_trace(doc)
        n = len([e for e in doc.get("traceEvents", [])
                 if isinstance(e, dict)]) if isinstance(doc, dict) else 0
        if errs:
            print(f"{path}: INVALID ({len(errs)} errors, {n} events)")
            for e in errs[:20]:
                print(f"  {e}")
            rc = 1
        else:
            print(f"{path}: ok ({n} events)")
    return rc


if __name__ == "__main__":
    import sys
    sys.exit(_main(sys.argv[1:]))
