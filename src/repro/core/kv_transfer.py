"""Pull-based KV-cache migration (paper §4.3 "combat burstiness" + §3.3).

The prefill instance's HBM acts as the queuing buffer: finished prefills
park there; the decode instance *pulls* a request's KV only when it has a
free slot and capacity, so bursts never overload decode memory. Transfers
are layerwise and sized from the model config (GQA-aware; SSM archs move a
constant-size state instead of per-token KV).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple


def kv_bytes(cfg, prompt_len: int, dtype_bytes: int = 2) -> int:
    """Bytes migrated for one request (the paper's 1.13 GB/512-tok OPT-66B
    analogue, adjusted for GQA / SWA / SSM)."""
    if cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        nh = d_in // s.head_dim
        return cfg.num_layers * nh * s.head_dim * s.state_dim * 4
    eff = min(prompt_len, cfg.sliding_window) if cfg.sliding_window else prompt_len
    b = cfg.kv_bytes_per_token(dtype_bytes) * eff
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        nh = d_in // s.head_dim
        b += cfg.num_layers * nh * s.head_dim * s.state_dim * 4
    return b


@dataclasses.dataclass
class ParkedKV:
    rid: int
    blob: Any
    nbytes: int
    parked_at: float


class TransferManager:
    """Tracks parked KV on prefill side + models per-link wire time."""

    def __init__(self, bandwidth: float, track_wall: bool = False):
        self.bandwidth = bandwidth
        self.track_wall = track_wall
        self.parked: Dict[int, ParkedKV] = {}
        self.total_bytes = 0
        self.total_time = 0.0
        self.times: List[float] = []
        self._link_free_at = 0.0            # serialize per link

    def park(self, rid: int, blob: Any, nbytes: int, now: float):
        self.parked[rid] = ParkedKV(rid, blob, nbytes, now)

    def parked_bytes(self) -> int:
        return sum(p.nbytes for p in self.parked.values())

    def pull(self, rid: int, now: float) -> Tuple[Any, float]:
        """Decode side pulls; returns (blob, completion_time)."""
        p = self.parked.pop(rid)
        start = max(now, self._link_free_at)
        dt = p.nbytes / self.bandwidth
        self._link_free_at = start + dt
        self.total_bytes += p.nbytes
        self.total_time += dt
        self.times.append(dt)
        return p.blob, start + dt
