"""Pull-based, block-granular KV-cache migration (paper §4.3 "combat
burstiness" + §3.3).

The prefill instance's HBM acts as the queuing buffer: finished prefills
park there; the decode instance *pulls* a request's KV only when it has
free pages, so bursts never overload decode memory. Transfers move in
page-sized chunks over a dedicated link per prefill→decode pair (each pair
has its own `_link_free_at` serialization point; different pairs proceed in
parallel). Per-request wire time is accounted layer-wise: the last layer's
chunk completes at `start + nbytes/bw`, and the *exposed* latency before
decode can start attending is one layer's worth less when layer transfers
overlap the decode engine's per-layer compute (tracked in
`layer_overlap_s`). Sizes come from the model config (GQA-aware; SSM archs
move a constant-size state instead of per-token KV).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

from .telemetry import NULL_TRACER


def kv_bytes(cfg, prompt_len: int, dtype_bytes: int = 2) -> int:
    """Bytes migrated for one request (the paper's 1.13 GB/512-tok OPT-66B
    analogue, adjusted for GQA / SWA / SSM)."""
    if cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        nh = d_in // s.head_dim
        return cfg.num_layers * nh * s.head_dim * s.state_dim * 4
    eff = min(prompt_len, cfg.sliding_window) if cfg.sliding_window else prompt_len
    b = cfg.kv_bytes_per_token(dtype_bytes) * eff
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        nh = d_in // s.head_dim
        b += cfg.num_layers * nh * s.head_dim * s.state_dim * 4
    return b


def layered_times(start: float, wire_s: float,
                  n_layers: int) -> Tuple[float, float]:
    """Per-layer streaming schedule of one KV transfer: layers cross the
    wire back-to-back, so layer 1 lands at start + wire/L and the last at
    start + wire. Decode may start attending at first-layer-landed; only
    wire/L of the transfer is exposed when per-layer compute covers the
    rest."""
    L = max(n_layers, 1)
    return start + wire_s / L, start + wire_s


def pipelined_finish(iter_start: float, step_s: float, kv_full_at: float,
                     n_layers: int) -> float:
    """Finish time of a decode iteration whose member KV is still landing
    layer-by-layer: layer i's compute can only run after layer i's pages
    arrive, so the iteration drains at the later of plain compute and the
    last layer's arrival plus that layer's compute slice."""
    L = max(n_layers, 1)
    return max(iter_start + step_s, kv_full_at + step_s / L)


@dataclasses.dataclass
class ParkedKV:
    rid: int
    blob: Any
    nbytes: int
    parked_at: float
    src: int = 0                    # prefill instance holding the pages
    wire_s: Optional[float] = None  # override nbytes/bandwidth (e.g. an
                                    # empirically calibrated transfer time)


@dataclasses.dataclass
class KVSegment:
    """One chunk's worth of KV, ready to ship the moment its prefill chunk
    finished (chunked prefill parks per chunk, not per request)."""
    ready: float
    nbytes: int
    wire_s: Optional[float] = None  # override nbytes/bandwidth


class TransferManager:
    """Parked KV on the prefill side + per-link wire-time model.

    One serialization point per (src prefill, dst decode) link; transfers
    are chunked into `page_bytes` blocks and `n_layers` layer slices for
    accounting.
    """

    def __init__(self, bandwidth: float, *, page_bytes: Optional[int] = None,
                 n_layers: int = 1, track_wall: bool = False):
        self.bandwidth = bandwidth
        self.page_bytes = page_bytes
        self.n_layers = max(n_layers, 1)
        self.track_wall = track_wall
        self.parked: Dict[int, ParkedKV] = {}
        self.total_bytes = 0
        self.total_chunks = 0
        self.total_time = 0.0
        self.layer_overlap_s = 0.0      # wire time hidable under per-layer
                                        # decode compute (all but one layer)
        self.times: List[float] = []
        self.peak_parked_bytes = 0
        self.cancelled_bytes = 0        # parked bytes dropped by cancel()
        self.stream_saved_s = 0.0       # wire time hidden under later prefill
                                        # chunks (vs park-at-prefill-done)
        self.streamed_pulls = 0
        self.partial: Dict[int, List[KVSegment]] = {}
        self._granted: Dict[int, float] = {}
        self._link_free_at: Dict[Tuple[int, int], float] = {}
        self.tracer = NULL_TRACER       # backends swap in their Tracer

    def park(self, rid: int, blob: Any, nbytes: int, now: float, src: int = 0,
             wire_s: Optional[float] = None):
        self.parked[rid] = ParkedKV(rid, blob, nbytes, now, src, wire_s)
        self.peak_parked_bytes = max(self.peak_parked_bytes,
                                     self.parked_bytes())
        if self.tracer.enabled:
            self.tracer.event("park", now, rid=rid, bytes=int(nbytes),
                              src=src)

    def park_partial(self, rid: int, nbytes: int, now: float,
                     wire_s: Optional[float] = None):
        """Record one finished prefill chunk's KV as shippable from `now`.

        Chunked prefill calls this once per chunk; the final `park` (with
        the blob and the decode-side ship size) closes the stream and
        `pull_streamed` charges the per-segment wire schedule."""
        self.partial.setdefault(rid, []).append(
            KVSegment(now, int(nbytes), wire_s))
        self.peak_parked_bytes = max(self.peak_parked_bytes,
                                     self.parked_bytes())
        if self.tracer.enabled:
            self.tracer.event("park_chunk", now, rid=rid, bytes=int(nbytes))

    def grant(self, rid: int, now: float):
        """Decode side reserved pages for a still-prefilling request: the
        wire may start moving already-parked segments from `now` on, so the
        stream's start floor is the grant time, not the final-park time."""
        if rid not in self._granted and self.tracer.enabled:
            self.tracer.event("grant", now, rid=rid)
        self._granted.setdefault(rid, now)

    def has_parked(self, rid: int) -> bool:
        """True once the final `park` closed the request's stream."""
        return rid in self.parked

    def drop_partial(self, rid: int) -> int:
        """Forget a cancelled request's parked chunk segments (and any
        grant). Returns the number of bytes dropped."""
        segs = self.partial.pop(rid, None)
        self._granted.pop(rid, None)
        if not segs:
            return 0
        n = sum(s.nbytes for s in segs)
        self.cancelled_bytes += n
        return n

    def parked_bytes(self) -> int:
        return (sum(p.nbytes for p in self.parked.values())
                + sum(s.nbytes for segs in self.partial.values()
                      for s in segs))

    def stats(self) -> Dict[str, float]:
        """Pull-collector snapshot for a `MetricsRegistry`."""
        return {"parked_bytes": self.parked_bytes(),
                "parked_requests": len(self.parked),
                "partial_streams": len(self.partial),
                "peak_parked_bytes": self.peak_parked_bytes,
                "total_bytes": self.total_bytes,
                "total_chunks": self.total_chunks,
                "total_time_s": self.total_time,
                "layer_overlap_s": self.layer_overlap_s,
                "stream_saved_s": self.stream_saved_s,
                "streamed_pulls": self.streamed_pulls,
                "cancelled_bytes": self.cancelled_bytes}

    def cancel(self, rid: int) -> Optional[ParkedKV]:
        """Unpark a request whose transfer will never be pulled (request
        cancelled while MIGRATING / PENDING_ADMIT): the prefill-side HBM
        buffer is released, nothing crosses the wire. Returns the popped
        entry (truthy) so callers can release blob-held resources, or
        None if nothing was parked."""
        self.drop_partial(rid)
        p = self.parked.pop(rid, None)
        if p is None:
            return None
        self.cancelled_bytes += p.nbytes
        return p

    def chunks_for(self, nbytes: int) -> int:
        if nbytes <= 0:
            return 0
        if not self.page_bytes:
            return 1
        return math.ceil(nbytes / self.page_bytes)

    def pull(self, rid: int, now: float, dst: int = 0) -> Tuple[Any, float]:
        """Decode side pulls; returns (blob, completion_time). The wire is
        occupied per (src, dst) link; other links proceed in parallel."""
        blob, _, t_full = self.pull_layered(rid, now, dst)
        return blob, t_full

    def pull_layered(self, rid: int, now: float,
                     dst: int = 0) -> Tuple[Any, float, float]:
        """Pull with the per-layer streaming schedule exposed: returns
        (blob, first_layer_landed, last_layer_landed). Decode admission
        can start attending at the first time; the iteration that includes
        the request only drains past the second (see `pipelined_finish`).
        """
        p = self.parked.pop(rid)
        link = (p.src, dst)
        start = max(now, self._link_free_at.get(link, 0.0))
        dt = p.wire_s if p.wire_s is not None else p.nbytes / self.bandwidth
        self._link_free_at[link] = start + dt
        self.total_bytes += p.nbytes
        self.total_chunks += self.chunks_for(p.nbytes)
        self.total_time += dt
        self.layer_overlap_s += dt * (self.n_layers - 1) / self.n_layers
        self.times.append(dt)
        t_first, t_full = layered_times(start, dt, self.n_layers)
        if self.tracer.enabled:
            self.tracer.complete("wire", "kv_pull", start, t_full,
                                 f"wire:{p.src}->{dst}", rid=rid,
                                 bytes=int(p.nbytes), t_first=t_first)
        return p.blob, t_first, t_full

    def pull_streamed(self, rid: int, now: float,
                      dst: int = 0) -> Tuple[Any, float, float]:
        """Pull a request whose KV was parked chunk-by-chunk
        (`park_partial`) while later prefill chunks were still computing.

        Segments cross the (src, dst) link back-to-back in chunk order,
        each no earlier than its prefill chunk finished (`ready`) and no
        earlier than the decode side reserved pages (`grant`). The
        decode-side prefix hit is trimmed off the *front* of the stream
        (prefix pages ship first; the final `park`'s `nbytes` is the
        authoritative ship size). Returns (blob, t_first, t_full) where
        `t_first` is first-layer-of-last-chunk-landed — every earlier
        chunk has fully landed by then, so decode may start attending —
        and `t_full` is the last layer of the last chunk.

        With no parked segments this degenerates to `pull_layered`'s
        single-segment schedule."""
        p = self.parked.pop(rid)
        segs = self.partial.pop(rid, None)
        granted = self._granted.pop(rid, None)
        if not segs:
            segs = [KVSegment(p.parked_at, p.nbytes, p.wire_s)]
        # trim the decode-side hit off the front of the stream
        trim = max(sum(s.nbytes for s in segs) - p.nbytes, 0)
        keep: List[KVSegment] = []
        for s in segs:
            if trim >= s.nbytes:
                trim -= s.nbytes
                continue
            if trim > 0:
                frac = (s.nbytes - trim) / s.nbytes
                w = s.wire_s * frac if s.wire_s is not None else None
                keep.append(KVSegment(s.ready, s.nbytes - trim, w))
                trim = 0
            else:
                keep.append(s)
        link = (p.src, dst)
        floor = max(granted if granted is not None else now,
                    self._link_free_at.get(link, 0.0))
        if not keep:
            self._link_free_at[link] = floor
            self.times.append(0.0)
            if self.tracer.enabled:
                self.tracer.complete("wire", "kv_stream", floor, floor,
                                     f"wire:{p.src}->{dst}", rid=rid,
                                     bytes=0, segs=0)
            return p.blob, floor, floor
        t = floor
        t_start = max(floor, keep[0].ready)
        wire_total = 0.0
        w_last = 0.0
        for s in keep:
            w = s.wire_s if s.wire_s is not None else s.nbytes / self.bandwidth
            t = max(t, s.ready) + w
            wire_total += w
            w_last = w
        t_full = t
        t_first = t_full - w_last + w_last / self.n_layers
        self._link_free_at[link] = t_full
        nbytes = sum(s.nbytes for s in keep)
        self.total_bytes += nbytes
        self.total_chunks += sum(self.chunks_for(s.nbytes) for s in keep)
        self.total_time += wire_total
        self.layer_overlap_s += w_last * (self.n_layers - 1) / self.n_layers
        self.times.append(wire_total)
        # vs park-at-prefill-done (serial): start everything at the last
        # chunk's ready time
        last_ready = keep[-1].ready
        self.stream_saved_s += max(last_ready + wire_total - t_full, 0.0)
        self.streamed_pulls += 1
        if self.tracer.enabled:
            self.tracer.complete("wire", "kv_stream", t_start, t_full,
                                 f"wire:{p.src}->{dst}", rid=rid,
                                 bytes=int(nbytes), segs=len(keep),
                                 t_first=t_first)
        return p.blob, t_first, t_full
