"""Pull-based, block-granular KV-cache migration (paper §4.3 "combat
burstiness" + §3.3).

The prefill instance's HBM acts as the queuing buffer: finished prefills
park there; the decode instance *pulls* a request's KV only when it has
free pages, so bursts never overload decode memory. Transfers move in
page-sized chunks over a dedicated link per prefill→decode pair (each pair
has its own `_link_free_at` serialization point; different pairs proceed in
parallel). Per-request wire time is accounted layer-wise: the last layer's
chunk completes at `start + nbytes/bw`, and the *exposed* latency before
decode can start attending is one layer's worth less when layer transfers
overlap the decode engine's per-layer compute (tracked in
`layer_overlap_s`). Sizes come from the model config (GQA-aware; SSM archs
move a constant-size state instead of per-token KV).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple


def kv_bytes(cfg, prompt_len: int, dtype_bytes: int = 2) -> int:
    """Bytes migrated for one request (the paper's 1.13 GB/512-tok OPT-66B
    analogue, adjusted for GQA / SWA / SSM)."""
    if cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        nh = d_in // s.head_dim
        return cfg.num_layers * nh * s.head_dim * s.state_dim * 4
    eff = min(prompt_len, cfg.sliding_window) if cfg.sliding_window else prompt_len
    b = cfg.kv_bytes_per_token(dtype_bytes) * eff
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        nh = d_in // s.head_dim
        b += cfg.num_layers * nh * s.head_dim * s.state_dim * 4
    return b


def layered_times(start: float, wire_s: float,
                  n_layers: int) -> Tuple[float, float]:
    """Per-layer streaming schedule of one KV transfer: layers cross the
    wire back-to-back, so layer 1 lands at start + wire/L and the last at
    start + wire. Decode may start attending at first-layer-landed; only
    wire/L of the transfer is exposed when per-layer compute covers the
    rest."""
    L = max(n_layers, 1)
    return start + wire_s / L, start + wire_s


def pipelined_finish(iter_start: float, step_s: float, kv_full_at: float,
                     n_layers: int) -> float:
    """Finish time of a decode iteration whose member KV is still landing
    layer-by-layer: layer i's compute can only run after layer i's pages
    arrive, so the iteration drains at the later of plain compute and the
    last layer's arrival plus that layer's compute slice."""
    L = max(n_layers, 1)
    return max(iter_start + step_s, kv_full_at + step_s / L)


@dataclasses.dataclass
class ParkedKV:
    rid: int
    blob: Any
    nbytes: int
    parked_at: float
    src: int = 0                    # prefill instance holding the pages
    wire_s: Optional[float] = None  # override nbytes/bandwidth (e.g. an
                                    # empirically calibrated transfer time)


class TransferManager:
    """Parked KV on the prefill side + per-link wire-time model.

    One serialization point per (src prefill, dst decode) link; transfers
    are chunked into `page_bytes` blocks and `n_layers` layer slices for
    accounting.
    """

    def __init__(self, bandwidth: float, *, page_bytes: Optional[int] = None,
                 n_layers: int = 1, track_wall: bool = False):
        self.bandwidth = bandwidth
        self.page_bytes = page_bytes
        self.n_layers = max(n_layers, 1)
        self.track_wall = track_wall
        self.parked: Dict[int, ParkedKV] = {}
        self.total_bytes = 0
        self.total_chunks = 0
        self.total_time = 0.0
        self.layer_overlap_s = 0.0      # wire time hidable under per-layer
                                        # decode compute (all but one layer)
        self.times: List[float] = []
        self.peak_parked_bytes = 0
        self.cancelled_bytes = 0        # parked bytes dropped by cancel()
        self._link_free_at: Dict[Tuple[int, int], float] = {}

    def park(self, rid: int, blob: Any, nbytes: int, now: float, src: int = 0,
             wire_s: Optional[float] = None):
        self.parked[rid] = ParkedKV(rid, blob, nbytes, now, src, wire_s)
        self.peak_parked_bytes = max(self.peak_parked_bytes,
                                     self.parked_bytes())

    def parked_bytes(self) -> int:
        return sum(p.nbytes for p in self.parked.values())

    def cancel(self, rid: int) -> Optional[ParkedKV]:
        """Unpark a request whose transfer will never be pulled (request
        cancelled while MIGRATING / PENDING_ADMIT): the prefill-side HBM
        buffer is released, nothing crosses the wire. Returns the popped
        entry (truthy) so callers can release blob-held resources, or
        None if nothing was parked."""
        p = self.parked.pop(rid, None)
        if p is None:
            return None
        self.cancelled_bytes += p.nbytes
        return p

    def chunks_for(self, nbytes: int) -> int:
        if nbytes <= 0:
            return 0
        if not self.page_bytes:
            return 1
        return math.ceil(nbytes / self.page_bytes)

    def pull(self, rid: int, now: float, dst: int = 0) -> Tuple[Any, float]:
        """Decode side pulls; returns (blob, completion_time). The wire is
        occupied per (src, dst) link; other links proceed in parallel."""
        blob, _, t_full = self.pull_layered(rid, now, dst)
        return blob, t_full

    def pull_layered(self, rid: int, now: float,
                     dst: int = 0) -> Tuple[Any, float, float]:
        """Pull with the per-layer streaming schedule exposed: returns
        (blob, first_layer_landed, last_layer_landed). Decode admission
        can start attending at the first time; the iteration that includes
        the request only drains past the second (see `pipelined_finish`).
        """
        p = self.parked.pop(rid)
        link = (p.src, dst)
        start = max(now, self._link_free_at.get(link, 0.0))
        dt = p.wire_s if p.wire_s is not None else p.nbytes / self.bandwidth
        self._link_free_at[link] = start + dt
        self.total_bytes += p.nbytes
        self.total_chunks += self.chunks_for(p.nbytes)
        self.total_time += dt
        self.layer_overlap_s += dt * (self.n_layers - 1) / self.n_layers
        self.times.append(dt)
        t_first, t_full = layered_times(start, dt, self.n_layers)
        return p.blob, t_first, t_full
