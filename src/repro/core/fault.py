"""Fault tolerance — the paper explicitly leaves this to future work (§4.3);
we implement it, since disaggregation *introduces* the failure coupling the
paper warns about (one decode instance serves many prefill instances).

Mechanisms:
  - heartbeat tracking with a miss threshold -> instance marked dead;
  - prefill-instance failure: queued requests re-dispatched to healthy
    peers (idempotent — no generation state lost);
  - decode-instance failure: running requests lose their KV; they are
    re-queued for *re-prefill* with their already-generated tokens appended
    (exactly-once token delivery preserved by the controller's dedup);
  - parked-KV loss on prefill failure: requests whose KV was parked but not
    yet pulled are also re-prefilled;
  - scheduler-state checkpoint/restore for controller restarts.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Dict, List, Optional, Set


@dataclasses.dataclass
class InstanceHealth:
    iid: str
    last_beat: float
    alive: bool = True
    failures: int = 0


class HeartbeatMonitor:
    def __init__(self, timeout: float = 3.0, now: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.now = now
        self.instances: Dict[str, InstanceHealth] = {}

    def register(self, iid: str):
        self.instances[iid] = InstanceHealth(iid, self.now())

    def beat(self, iid: str):
        h = self.instances[iid]
        h.last_beat = self.now()
        if not h.alive:
            h.alive = True          # instance rejoined (elastic scale-up)

    def mark_failed(self, iid: str):
        h = self.instances[iid]
        h.alive = False
        h.failures += 1

    def sweep(self) -> List[str]:
        """Returns newly-dead instance ids."""
        dead = []
        t = self.now()
        for h in self.instances.values():
            if h.alive and t - h.last_beat > self.timeout:
                h.alive = False
                h.failures += 1
                dead.append(h.iid)
        return dead

    def alive_ids(self) -> Set[str]:
        return {h.iid for h in self.instances.values() if h.alive}


@dataclasses.dataclass
class FailoverPlan:
    reprefill: List[int]        # request ids needing prefill again
    redispatch: List[int]       # queued requests to move to healthy peers


def plan_failover(kind: str, queued: List[int], running: List[int],
                  parked: List[int]) -> FailoverPlan:
    """Policy table for an instance failure."""
    if kind == "prefill":
        # queued requests never started: move them; parked KV is lost.
        return FailoverPlan(reprefill=list(parked), redispatch=list(queued))
    # decode: running requests lost their KV mid-generation.
    return FailoverPlan(reprefill=list(running), redispatch=[])


class SchedulerCheckpoint:
    """Controller-state snapshot (request table + dispatch maps)."""

    @staticmethod
    def dump(state: Dict) -> bytes:
        return json.dumps(state, sort_keys=True).encode()

    @staticmethod
    def load(raw: bytes) -> Dict:
        return json.loads(raw.decode())
