"""Hardware constants for the roofline + analytical latency model.

TPU v5e is the deployment target (assignment constants). A100 numbers are
kept for sanity-checking the model against the paper's reported figures.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Chip:
    name: str
    peak_flops_bf16: float      # FLOP/s
    hbm_bw: float               # B/s
    hbm_bytes: float
    ici_bw: float               # B/s per link
    ici_links: int              # usable links per chip (2D torus: 4)
    dcn_bw: float               # B/s per chip, cross-pod
    # empirical efficiency knobs (profiled on comparable systems)
    mm_eff: float = 0.55        # large-GEMM MXU efficiency
    attn_eff: float = 0.35      # flash-attention MXU efficiency
    hbm_eff: float = 0.8
    coll_latency: float = 4e-6  # per-collective latency (s)
    step_overhead: float = 50e-6


V5E = Chip(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    hbm_bytes=16e9,
    ici_bw=50e9,
    ici_links=4,
    dcn_bw=6.25e9,   # ~50 Gbps/chip effective across pods
)

A100_80G = Chip(
    name="a100-80g",
    peak_flops_bf16=312e12,
    hbm_bw=2.0e12,
    hbm_bytes=80e9,
    ici_bw=300e9,    # NVLink effective per-GPU
    ici_links=2,
    dcn_bw=3.1e9,    # 25 Gbps testbed in the paper
)

DEFAULT = V5E

# mesh geometry for the dry-run roofline
CHIPS_PER_POD = 256
POD_MESH = (16, 16)
