"""Runtime scheduling policies (paper §4.3), shared by the simulator and
the live cluster runtime.

- FCFS central queue, dispatch to the prefill instance with the shortest
  queue (by queued tokens).
- Prefill batch formation up to the L_m saturation budget: batch short
  prompts together, schedule longer-than-L_m prompts alone (reduces
  pipeline bubbles from non-uniform lengths).
- Decode dispatch to the least-loaded decode instance.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Generic, List, Optional, Sequence, TypeVar

T = TypeVar("T")


@dataclasses.dataclass
class FCFSQueue(Generic[T]):
    token_of: Callable[[T], int]
    items: List[T] = dataclasses.field(default_factory=list)

    def push(self, item: T):
        self.items.append(item)

    @property
    def queued_tokens(self) -> int:
        return sum(self.token_of(x) for x in self.items)

    def __len__(self):
        return len(self.items)

    def form_batch(self, budget: int, max_batch: Optional[int] = None) -> List[T]:
        """Paper §4.3: total new tokens per batch ~ L_m; oversized prompts
        go alone; FCFS order preserved (no reordering — convoy effects are
        accepted, preemption is future work per the paper)."""
        if not self.items:
            return []
        batch = [self.items.pop(0)]
        tok = self.token_of(batch[0])
        while self.items and tok + self.token_of(self.items[0]) <= budget:
            if max_batch and len(batch) >= max_batch:
                break
            nxt = self.items.pop(0)
            tok += self.token_of(nxt)
            batch.append(nxt)
        return batch


def shortest_queue(queues: Sequence[FCFSQueue]) -> int:
    """Index of the prefill queue with the fewest queued tokens."""
    return min(range(len(queues)), key=lambda i: queues[i].queued_tokens)


def least_loaded(loads: Sequence[int]) -> int:
    return min(range(len(loads)), key=lambda i: loads[i])
