"""Runtime scheduling core (paper §4.3), shared by the discrete-event
simulator (`core.simulator`) and the live cluster runtime
(`serving.cluster`). One implementation of:

- FCFS central queue, dispatch to the prefill instance with the shortest
  queue (by queued tokens).
- Prefill batch formation up to the L_m saturation budget: batch short
  prompts together, schedule longer-than-L_m prompts alone (reduces
  pipeline bubbles from non-uniform lengths).
- Decode dispatch to the least-loaded decode instance.
- Pull-based admission against *page* availability (`PagePool`): finished
  prefills stay parked on the prefill side until the decode instance has
  free KV pages, so bursts never overload decode memory (§4.3 "combat
  burstiness").

`DisaggDispatcher` records every dispatch decision, so tests can assert
that the simulator and the live cluster make identical choices on the same
arrival trace. `EventLoop` is the shared heapq event queue both drivers
run on.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import (Any, Callable, Dict, Generic, List, Optional, Sequence,
                    Tuple, TypeVar)

T = TypeVar("T")


@dataclasses.dataclass
class FCFSQueue(Generic[T]):
    token_of: Callable[[T], int]
    items: List[T] = dataclasses.field(default_factory=list)
    _tokens: int = 0                    # incremental sum over items

    def push(self, item: T):
        self.items.append(item)
        self._tokens += self.token_of(item)

    @property
    def queued_tokens(self) -> int:
        return self._tokens

    def __len__(self):
        return len(self.items)

    def remove(self, item: T) -> bool:
        """Drop a queued item (request cancellation while QUEUED). Returns
        False when the item already left the queue (e.g. batched)."""
        for i, it in enumerate(self.items):
            if it is item:
                del self.items[i]
                self._tokens -= self.token_of(item)
                return True
        return False

    def form_batch(self, budget: int, max_batch: Optional[int] = None,
                   can_take: Optional[Callable[[T], bool]] = None,
                   chunk_tokens: Optional[int] = None,
                   resumable: Optional[Callable[[T], bool]] = None
                   ) -> List[T]:
        """Paper §4.3: total new tokens per batch ~ L_m; oversized prompts
        go alone; FCFS order preserved (no reordering — convoy effects are
        accepted, preemption is future work per the paper).

        `can_take` gates admission per item (e.g. KV-page availability);
        it is consulted exactly once per accepted item, in FCFS order, so
        stateful predicates that reserve capacity on True are safe.

        With `chunk_tokens`, an item charges the batch budget only
        ``min(token_of(item), chunk_tokens)`` — the caller runs at most one
        chunk per item and re-pushes unfinished items (with a smaller
        `token_of`), so a long prompt no longer monopolizes the batch.

        `resumable` marks items whose capacity is *already reserved*
        (chunked partial prefills re-queued between chunks). When the head
        of the queue fails `can_take`, the batch may start from the first
        resumable item behind it instead of returning empty: those items
        free their reservation only by finishing, so draining them past a
        blocked head is the difference between progress and deadlock. New
        (non-resumable) items are never taken out of FCFS order.
        """
        if not self.items:
            return []
        start = 0
        if can_take is not None and not can_take(self.items[0]):
            if resumable is None:
                return []
            start = next((j for j, it in enumerate(self.items)
                          if resumable(it)), -1)
            if start < 0:
                return []

        def charge(item: T) -> int:
            t = self.token_of(item)
            return min(t, chunk_tokens) if chunk_tokens else t

        batch = [self.items.pop(start)]
        tok = charge(batch[0])
        taken = self.token_of(batch[0])
        while self.items and tok + charge(self.items[0]) <= budget:
            if max_batch and len(batch) >= max_batch:
                break
            if can_take is not None and not can_take(self.items[0]):
                break
            nxt = self.items.pop(0)
            tok += charge(nxt)
            taken += self.token_of(nxt)
            batch.append(nxt)
        self._tokens -= taken
        return batch


def shortest_queue(queues: Sequence[FCFSQueue],
                   alive: Optional[Sequence[int]] = None) -> int:
    """Index of the prefill queue with the fewest queued tokens (ties break
    to the lowest index, deterministically)."""
    cand = range(len(queues)) if alive is None else alive
    return min(cand, key=lambda i: queues[i].queued_tokens)


def least_loaded(loads: Sequence[float],
                 alive: Optional[Sequence[int]] = None) -> int:
    cand = range(len(loads)) if alive is None else alive
    return min(cand, key=lambda i: loads[i])


@dataclasses.dataclass
class DisaggDispatcher:
    """Records the dispatch decisions of the shared policies.

    Both the simulator and the live cluster route arrivals and KV handoffs
    through one dispatcher, so a test can replay the same trace on both and
    diff `decisions` entry-by-entry. Decisions are
    ``(kind, rid, instance, prefix_hit_tokens)`` — the hit length the
    chosen instance's radix tree reported at decision time (0 when prefix
    caching is off).

    Prefix-affinity prefill routing: when any instance holds a cached
    prefix of the request, route to the longest match *unless* that
    instance's queue is more than `affinity_slack` tokens deeper than the
    least-loaded queue — beyond that load gap, locality stops paying for
    the queueing delay and the policy falls back to shortest-queue.
    """
    affinity_slack: int = 1024          # tokens of queue imbalance tolerated
    decisions: List[Tuple[str, int, int, int]] = dataclasses.field(
        default_factory=list)
    tracer: Any = None                  # backends swap in their Tracer

    def _record(self, kind: str, rid: int, idx: int, hit: int,
                now: Optional[float]):
        self.decisions.append((kind, rid, idx, hit))
        if self.tracer is not None and now is not None:
            self.tracer.event(f"route_{kind}", now, rid=rid,
                              instance=idx, hit=hit)

    def pick_prefill(self, rid: int, queues: Sequence[FCFSQueue],
                     alive: Optional[Sequence[int]] = None,
                     hits: Optional[Sequence[int]] = None,
                     now: Optional[float] = None) -> int:
        cand = list(range(len(queues)) if alive is None else alive)
        if hits is not None and max(hits[i] for i in cand) > 0:
            # longest match; ties -> shortest queue -> lowest index
            best = min(cand, key=lambda i: (-hits[i],
                                            queues[i].queued_tokens, i))
            qmin = min(queues[i].queued_tokens for i in cand)
            if queues[best].queued_tokens - qmin <= self.affinity_slack:
                self._record("prefill", rid, best, hits[best], now)
                return best
        idx = shortest_queue(queues, alive)
        self._record("prefill", rid, idx,
                     hits[idx] if hits is not None else 0, now)
        return idx

    def pick_decode(self, rid: int, loads: Sequence[float],
                    alive: Optional[Sequence[int]] = None,
                    hits: Optional[Sequence[int]] = None,
                    now: Optional[float] = None) -> int:
        idx = least_loaded(loads, alive)
        self._record("decode", rid, idx,
                     hits[idx] if hits is not None else 0, now)
        return idx

    def pick_absorb(self, rid: int, loads: Sequence[float],
                    alive: Optional[Sequence[int]] = None,
                    now: Optional[float] = None) -> int:
        """Prefill-saturation spill: route a *whole prompt* to a
        decode/mixed instance that will chunk-prefill it locally
        (intra-instance aggregation). Recorded apart from normal decode
        dispatch so parity tests and benchmarks can count absorbed work."""
        idx = least_loaded(loads, alive)
        self._record("absorb", rid, idx, 0, now)
        return idx

    def by_rid(self) -> Dict[int, Dict[str, int]]:
        out: Dict[int, Dict[str, int]] = {}
        for kind, rid, idx, _hit in self.decisions:
            out.setdefault(rid, {})[kind] = idx
        return out


class EventLoop:
    """Heapq event queue with a monotone tie-breaking counter (insertion
    order wins among same-time events — arrivals dispatch before pokes)."""

    def __init__(self):
        self._q: List[Tuple[float, int, str, Any]] = []
        self._ctr = itertools.count()
        self.now = 0.0

    def push(self, t: float, kind: str, payload: Any = None):
        heapq.heappush(self._q, (t, next(self._ctr), kind, payload))

    def pop(self) -> Tuple[float, str, Any]:
        t, _, kind, payload = heapq.heappop(self._q)
        self.now = t
        return t, kind, payload

    def peek_time(self) -> Optional[float]:
        """Timestamp of the next event, or None when the loop is idle
        (lets `run_until(t)` stop without consuming future events)."""
        return self._q[0][0] if self._q else None

    def __bool__(self) -> bool:
        return bool(self._q)

    def __len__(self) -> int:
        return len(self._q)


class PagePool:
    """Block-granular KV capacity accounting (the scheduler-side view of a
    paged KV cache: capacity is a page count, admission is page-granular).

    `unit` is the token (or byte) capacity of one page; `pages_for`
    converts a demand in those units to whole pages (ceil).
    """

    def __init__(self, num_pages: int, unit: float = 1.0):
        assert num_pages >= 0 and unit > 0
        self.num_pages = int(num_pages)
        self.unit = float(unit)
        self._alloc: Dict[int, int] = {}
        self.used = 0
        self.peak_used = 0

    def pages_for(self, demand: float) -> int:
        return max(int(-(-demand // self.unit)), 1)

    @property
    def free_pages(self) -> int:
        return self.num_pages - self.used

    def can_alloc(self, n_pages: int) -> bool:
        return n_pages <= self.free_pages

    def alloc(self, rid: int, n_pages: int):
        assert rid not in self._alloc, rid
        assert self.can_alloc(n_pages), (rid, n_pages, self.free_pages)
        self._alloc[rid] = n_pages
        self.used += n_pages
        self.peak_used = max(self.peak_used, self.used)

    def free(self, rid: int) -> int:
        n = self._alloc.pop(rid)
        self.used -= n
        return n
