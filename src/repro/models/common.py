"""Shared model-building blocks + logical-axis sharding context.

Sharding design: every parameter is created through `param()` with *logical*
axis names; a thread-level context installed by the launcher maps logical
axes -> mesh axes with divisibility-aware fallback. With no context active
(unit tests, single device) everything is a no-op, so model code never
mentions a mesh.
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical-axis sharding context
# ---------------------------------------------------------------------------

_tls = threading.local()


class ShardingRules:
    """logical axis -> ordered list of candidate mesh axes (or None)."""

    def __init__(self, mesh, rules: Dict[str, Sequence[Optional[str]]]):
        self.mesh = mesh
        self.rules = rules

    def resolve(self, logical: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        used = set()
        out = []
        for dim, name in zip(shape, logical):
            pick = None
            for cand in self.rules.get(name, (None,)) if name else (None,):
                if cand is None:
                    break
                axes = cand if isinstance(cand, tuple) else (cand,)
                if any(a in used for a in axes):
                    continue
                size = math.prod(self.mesh.shape[a] for a in axes)
                if dim % size == 0:
                    pick = cand
                    used.update(axes)
                    break
            out.append(pick)
        while out and out[-1] is None:
            out.pop()
        return P(*out)


@contextlib.contextmanager
def sharding_ctx(rules: Optional[ShardingRules]):
    prev = getattr(_tls, "rules", None)
    _tls.rules = rules
    try:
        yield
    finally:
        _tls.rules = prev


def current_rules() -> Optional[ShardingRules]:
    return getattr(_tls, "rules", None)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain activation sharding (no-op without an active context)."""
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.resolve(logical, x.shape)
    return jax.lax.with_sharding_constraint(x, jax.sharding.NamedSharding(rules.mesh, spec))


# ---------------------------------------------------------------------------
# Param creation with logical axes metadata
# ---------------------------------------------------------------------------

class Box:
    """A param leaf carrying its logical axes until the tree is split."""

    __slots__ = ("value", "axes")

    def __init__(self, value, axes):
        self.value = value
        self.axes = tuple(axes)


def param(key, shape, axes, dtype=jnp.float32, scale: Optional[float] = None,
          init: str = "normal") -> Box:
    assert len(shape) == len(axes), (shape, axes)
    if init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    else:
        if scale is None:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            scale = 1.0 / math.sqrt(fan_in)
        v = (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    return Box(v, axes)


def split_boxes(tree) -> Tuple[Any, Any]:
    """(params, axes) from a pytree with Box leaves."""
    params = jax.tree.map(lambda b: b.value, tree,
                          is_leaf=lambda x: isinstance(x, Box))
    axes = jax.tree.map(lambda b: b.axes, tree,
                        is_leaf=lambda x: isinstance(x, Box))
    return params, axes


def eval_axes(init_fn, *args) -> Any:
    """Get the axes pytree without allocating params (eval_shape the init)."""

    def shaped(*a):
        tree = init_fn(*a)
        return jax.tree.map(lambda b: b.axes, tree,
                            is_leaf=lambda x: isinstance(x, Box))

    # init is pure python on Box metadata; run it with a dummy key via
    # eval_shape so no arrays materialize.
    out = {}

    def wrap(*a):
        nonlocal out
        tree = init_fn(*a)
        params, axes = split_boxes(tree)
        out = axes
        return params

    jax.eval_shape(wrap, *args)
    return out


def keygen(key):
    while True:
        key, sub = jax.random.split(key)
        yield sub


# ---------------------------------------------------------------------------
# Norms / activations / RoPE
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)  # gemma-style (1+w)


def layernorm(x, w, b, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def apply_norm(x, p, cfg):
    if cfg.norm_type == "layernorm":
        return layernorm(x, p["w"], p["b"], cfg.norm_eps)
    return rmsnorm(x, p["w"], cfg.norm_eps)


def norm_params(key, d, cfg, axes=("embed",)):
    if cfg.norm_type == "layernorm":
        return {"w": param(key, (d,), axes, init="ones"),
                "b": param(key, (d,), axes, init="zeros")}
    return {"w": param(key, (d,), axes, init="zeros")}  # (1+w) form


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def rope_freqs(head_dim: int, fraction: float, theta: float):
    rot = int(head_dim * fraction)
    rot -= rot % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, theta, fraction=1.0, interleaved=False):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv, rot = rope_freqs(hd, fraction, theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    dt = x.dtype
    xr, xp = x[..., :rot].astype(jnp.float32), x[..., rot:]
    if interleaved:
        x0, x1 = xr[..., 0::2], xr[..., 1::2]
        r0 = x0 * cos - x1 * sin
        r1 = x1 * cos + x0 * sin
        xr = jnp.stack([r0, r1], axis=-1).reshape(xr.shape)
    else:
        half = rot // 2
        x0, x1 = xr[..., :half], xr[..., half:]
        xr = jnp.concatenate([x0 * cos - x1 * sin, x1 * cos + x0 * sin], axis=-1)
    return jnp.concatenate([xr.astype(dt), xp], axis=-1) if rot < hd else xr.astype(dt)


def embed_lookup(table, tokens):
    """Embedding lookup; with the `onehot_embed` opt active (decode paths),
    uses a one-hot contraction so GSPMD partitions the vocab-sharded table
    with a psum of (B, d) partials instead of all-gathering the table
    (Megatron vocab-parallel embedding, beyond-paper for serving)."""
    rules = current_rules()
    if rules is not None and getattr(rules, "onehot_embed", False):
        V = table.shape[0]
        onehot = jax.nn.one_hot(tokens, V, dtype=table.dtype)
        return onehot @ table
    return table[tokens]


def softcap(logits, cap: float):
    if cap and cap > 0:
        return jnp.tanh(logits / cap) * cap
    return logits
