"""Encoder–decoder model (seamless-m4t backbone). The audio frontend is a
stub per the assignment: `input_specs()` supplies precomputed frame
embeddings (B, T_src, d) directly to the encoder.

prefill = encoder pass + cross-KV projection + decoder prefill over the
target prefix; decode = decoder step (self-KV cache grows, cross-KV fixed).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .attention import decode_attend, flash_reference
from .common import apply_norm, embed_lookup, keygen, norm_params, param, shard
from .moe import dense_ffn_apply, dense_ffn_params
from .transformer import (attn_decode, attn_full, attn_params, stack_init,
                          _qkv)


def _xattn_params(keys, cfg):
    d = cfg.d_model
    return {
        "wq": param(next(keys), (d, cfg.num_heads, cfg.head_dim),
                    ("embed", "heads", None)),
        "wk": param(next(keys), (d, cfg.num_kv_heads, cfg.head_dim),
                    ("kv_embed", "kv_heads", None)),
        "wv": param(next(keys), (d, cfg.num_kv_heads, cfg.head_dim),
                    ("kv_embed", "kv_heads", None)),
        "wo": param(next(keys), (cfg.num_heads, cfg.head_dim, d),
                    ("heads", None, "embed")),
    }


def init(key, cfg):
    keys = keygen(key)
    d = cfg.d_model
    return {
        "embed": param(next(keys), (cfg.vocab_size, d), ("vocab", "embed"),
                       scale=cfg.d_model ** -0.5),
        "enc": stack_init(lambda: {
            "ln1": norm_params(next(keys), d, cfg),
            "attn": attn_params(keys, cfg),
            "ln2": norm_params(next(keys), d, cfg),
            "ffn": dense_ffn_params(keys, d, cfg.d_ff),
        }, cfg.encoder_layers),
        "enc_norm": norm_params(next(keys), d, cfg),
        "dec": stack_init(lambda: {
            "ln1": norm_params(next(keys), d, cfg),
            "attn": attn_params(keys, cfg),
            "lnx": norm_params(next(keys), d, cfg),
            "xattn": _xattn_params(keys, cfg),
            "ln2": norm_params(next(keys), d, cfg),
            "ffn": dense_ffn_params(keys, d, cfg.d_ff),
        }, cfg.num_layers),
        "final_norm": norm_params(next(keys), d, cfg),
        "lm_head": param(next(keys), (d, cfg.vocab_size), ("embed", "vocab")),
    }


def encode(params, src_embeds, cfg, attn_blocks=(512, 512)):
    """src_embeds: (B, T, d) frame embeddings -> encoder output (B, T, d)."""
    x = shard(src_embeds, "batch", None, "embed_act")
    B, T, _ = x.shape
    positions = jnp.arange(T)[None, :].astype(jnp.int32)

    def body(x, pl):
        h = apply_norm(x, pl["ln1"], cfg)
        q, k, v = _qkv(pl["attn"], h, cfg, positions, cfg.rope_theta)
        o = flash_reference(q, k, v, causal=False,
                            block_q=attn_blocks[0], block_kv=attn_blocks[1])
        x = x + jnp.einsum("bshk,hkd->bsd", o, pl["attn"]["wo"].astype(o.dtype))
        h = apply_norm(x, pl["ln2"], cfg)
        x = x + dense_ffn_apply(pl["ffn"], h, cfg)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return apply_norm(x, params["enc_norm"], cfg)


def _cross_kv(pl, enc_out, cfg):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, pl["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, pl["wv"].astype(enc_out.dtype))
    return k, v


def _decoder_forward(params, tgt_tokens, enc_out, cfg, attn_blocks,
                     return_cache=False, max_len=None):
    x = params["embed"][tgt_tokens]
    x = shard(x, "batch", None, "embed_act")
    B, S, _ = x.shape
    T = enc_out.shape[1]
    positions = jnp.arange(S)[None, :].astype(jnp.int32)

    def body(x, pl):
        h = apply_norm(x, pl["ln1"], cfg)
        a, kv = attn_full(pl["attn"], h, cfg, "dense", positions, attn_blocks)
        x = x + a
        h = apply_norm(x, pl["lnx"], cfg)
        q = jnp.einsum("bsd,dhk->bshk", h, pl["xattn"]["wq"].astype(h.dtype))
        xk, xv = _cross_kv(pl["xattn"], enc_out, cfg)
        o = flash_reference(q, xk, xv, causal=False,
                            block_q=attn_blocks[0], block_kv=attn_blocks[1])
        x = x + jnp.einsum("bshk,hkd->bsd", o, pl["xattn"]["wo"].astype(o.dtype))
        h = apply_norm(x, pl["ln2"], cfg)
        x = x + dense_ffn_apply(pl["ffn"], h, cfg)
        if return_cache:
            extras = (kv, (xk, xv))
        else:
            extras = ((jnp.zeros((), x.dtype),) * 2,) * 2
        return x, extras

    x, (kv, xkv) = jax.lax.scan(body, x, params["dec"])
    x = apply_norm(x, params["final_norm"], cfg)
    if return_cache:
        x = x[:, -1:]          # last-position logits only at prefill
    logits = x @ params["lm_head"].astype(x.dtype)
    logits = shard(logits, "batch", None, "vocab")
    cache = None
    if return_cache:
        k, v = kv
        target = max_len if max_len is not None else S
        if S < target:
            pad = [(0, 0), (0, 0), (0, target - S), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        cache = {"k": k, "v": v, "xk": xkv[0], "xv": xkv[1],
                 "pos": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def forward(params, batch, cfg, *, remat=False, attn_blocks=(512, 512),
            return_cache=False, max_len=None):
    """batch: {"src_embeds": (B,T,d), "tokens": (B,S)}"""
    enc_out = encode(params, batch["src_embeds"], cfg, attn_blocks)
    logits, cache = _decoder_forward(params, batch["tokens"], enc_out, cfg,
                                     attn_blocks, return_cache, max_len)
    return logits, cache, 0.0


def prefill(params, batch, cfg, *, attn_blocks=(512, 512), max_len=None):
    logits, cache, _ = forward(params, batch, cfg, attn_blocks=attn_blocks,
                               return_cache=True, max_len=max_len)
    return logits[:, -1], cache


def decode_step(params, cache, tokens, cfg):
    x = embed_lookup(params["embed"], tokens)
    x = shard(x, "batch", "embed_act")
    B = x.shape[0]
    pos = cache["pos"]

    def body(x, xs):
        pl, kc, vc, xk, xv = xs
        h = apply_norm(x[:, None], pl["ln1"], cfg)[:, 0]
        a, kc, vc = attn_decode(pl["attn"], h, cfg, "dense", kc, vc, pos)
        x = x + a
        h = apply_norm(x[:, None], pl["lnx"], cfg)[:, 0]
        q = jnp.einsum("bd,dhk->bhk", h, pl["xattn"]["wq"].astype(h.dtype))
        o = decode_attend(q, xk, xv, jnp.full((B,), xk.shape[1], jnp.int32))
        x = x + jnp.einsum("bhk,hkd->bd", o, pl["xattn"]["wo"].astype(o.dtype))
        h = apply_norm(x[:, None], pl["ln2"], cfg)[:, 0]
        x = x + dense_ffn_apply(pl["ffn"], h[:, None], cfg)[:, 0]
        return x, (kc, vc)

    x, (kc, vc) = jax.lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"], cache["xk"], cache["xv"]))
    x = apply_norm(x[:, None], params["final_norm"], cfg)[:, 0]
    logits = x @ params["lm_head"].astype(x.dtype)
    return logits, dict(cache, k=kc, v=vc, pos=pos + 1)


def cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
                src_len: int = 4096):
    L = cfg.num_layers
    kv = jax.ShapeDtypeStruct((L, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype)
    xkv = jax.ShapeDtypeStruct((L, batch, src_len, cfg.num_kv_heads, cfg.head_dim), dtype)
    return {"k": kv, "v": kv, "xk": xkv, "xv": xkv,
            "pos": jax.ShapeDtypeStruct((batch,), jnp.int32)}


def cache_logical_axes(cfg, batch: int = 0, max_len: int = 0):
    ax = ("layers", "kv_batch", "kv_seq", "kv_heads", None)
    return {"k": ax, "v": ax, "xk": ax, "xv": ax, "pos": ("kv_batch",)}
