"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

`ssd_chunked` is the block-decomposition algorithm (diagonal within-chunk
attention-like term + low-rank inter-chunk recurrence) — the TPU-friendly
formulation: all heavy ops are einsums over (chunk, chunk) tiles sized for
the MXU, with a short lax.scan across chunks for the state recurrence.

`ssd_step` is the O(1) decode recurrence (the "KV cache" of an SSM is the
constant-size state — DistServe's KV-migration cost collapses accordingly).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import param, rmsnorm, shard


def _segsum(a):
    """Lower-triangular pairwise cumulative sums.

    a: (..., Q) -> (..., Q, Q) where out[..., t, s] = sum_{s < r <= t} a[r]
    (0 on diagonal, -inf above).
    """
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, D, chunk: int,
                h0=None) -> Tuple[jax.Array, jax.Array]:
    """SSD forward.

    x: (b, S, nh, hd); dt: (b, S, nh); A: (nh,) negative;
    B, C: (b, S, G, N); D: (nh,). Returns (y (b,S,nh,hd), h_final (b,nh,hd,N)).
    """
    b, S, nh, hd = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = nh // G
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    f32 = jnp.float32
    xc = x.reshape(b, nc, chunk, nh, hd).astype(f32)
    dtc = dt.reshape(b, nc, chunk, nh).astype(f32)
    Bc = B.reshape(b, nc, chunk, G, N).astype(f32)
    Cc = C.reshape(b, nc, chunk, G, N).astype(f32)

    a = dtc * A.astype(f32)                                     # (b,nc,Q,nh)
    a_cum = jnp.cumsum(a, axis=2)                               # within-chunk
    xdt = xc * dtc[..., None]                                   # x * dt

    # --- 1. diagonal (within-chunk) term -------------------------------
    L = jnp.exp(_segsum(jnp.moveaxis(a, -1, 2)))                # (b,nc,nh,Q,Q)
    # scores: C_t . B_s  (group-shared)
    CB = jnp.einsum("bcqgn,bcsgn->bcgqs", Cc, Bc)               # (b,nc,G,Q,Q)
    CB = jnp.repeat(CB, rep, axis=2)                            # (b,nc,nh,Q,Q)
    M = CB * L
    y_diag = jnp.einsum("bchqs,bcshd->bcqhd", M, xdt)

    # --- 2. per-chunk end states ---------------------------------------
    decay_to_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)         # (b,nc,Q,nh)
    Bh = jnp.repeat(Bc, rep, axis=3)                            # (b,nc,Q,nh,N)
    S_c = jnp.einsum("bcqhn,bcqh,bcqhd->bchdn",
                     Bh, decay_to_end, xdt)                     # (b,nc,nh,hd,N)

    # --- 3. inter-chunk recurrence (scan over chunks) -------------------
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])                   # (b,nc,nh)
    if h0 is None:
        h0 = jnp.zeros((b, nh, hd, N), f32)

    def step(h, inp):
        s_c, dec = inp                                          # (b,nh,hd,N),(b,nh)
        h_prev = h
        h = h * dec[..., None, None] + s_c
        return h, h_prev

    (h_final, h_prevs) = jax.lax.scan(
        step, h0.astype(f32),
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                       # (b,nc,nh,hd,N)

    # --- 4. off-diagonal contribution from carried-in state -------------
    state_decay = jnp.exp(a_cum)                                # (b,nc,Q,nh)
    Ch = jnp.repeat(Cc, rep, axis=3)                            # (b,nc,Q,nh,N)
    y_off = jnp.einsum("bcqhn,bcqh,bchdn->bcqhd",
                       Ch, state_decay, h_prevs)

    y = (y_diag + y_off).reshape(b, S, nh, hd)
    y = y + x.astype(f32) * D.astype(f32)[None, None, :, None]
    return y.astype(x.dtype), h_final


def ssd_step(h, x_t, dt_t, A, B_t, C_t, D):
    """Single decode step. h: (b,nh,hd,N); x_t: (b,nh,hd); dt_t: (b,nh);
    B_t, C_t: (b,G,N). Returns (h', y (b,nh,hd))."""
    b, nh, hd = x_t.shape
    G = B_t.shape[1]
    rep = nh // G
    f32 = jnp.float32
    dec = jnp.exp(dt_t.astype(f32) * A.astype(f32))             # (b,nh)
    Bh = jnp.repeat(B_t.astype(f32), rep, axis=1)               # (b,nh,N)
    Ch = jnp.repeat(C_t.astype(f32), rep, axis=1)
    xdt = x_t.astype(f32) * dt_t.astype(f32)[..., None]         # (b,nh,hd)
    h = h * dec[..., None, None] + xdt[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhdn,bhn->bhd", h, Ch) + x_t.astype(f32) * D.astype(f32)[None, :, None]
    return h, y.astype(x_t.dtype)


def ssd_reference(x, dt, A, B, C, D, h0=None):
    """Naive sequential recurrence oracle (tests only)."""
    b, S, nh, hd = x.shape
    h = jnp.zeros((b, nh, hd, B.shape[-1]), jnp.float32) if h0 is None else h0
    ys = []
    for t in range(S):
        h, y = ssd_step(h, x[:, t], dt[:, t], A, B[:, t], C[:, t], D)
        ys.append(y)
    return jnp.stack(ys, axis=1), h


# ---------------------------------------------------------------------------
# Full Mamba2 block (in_proj -> causal conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------

def mamba_params(keys, cfg) -> Dict:
    d = cfg.d_model
    s = cfg.ssm
    d_in = s.expand * d
    nh = d_in // s.head_dim
    gn = s.ngroups * s.state_dim
    dt_init = jnp.log(jnp.expm1(jnp.exp(
        jax.random.uniform(next(keys), (nh,), jnp.float32) *
        (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))))
    cw = 1.0 / s.conv_width ** 0.5
    return {
        # per-component in_proj so TP sharding never cuts across segments
        "wz": param(next(keys), (d, d_in), ("embed", "ssm_inner")),
        "wx": param(next(keys), (d, d_in), ("embed", "ssm_inner")),
        "wB": param(next(keys), (d, gn), ("embed", "state")),
        "wC": param(next(keys), (d, gn), ("embed", "state")),
        "wdt": param(next(keys), (d, nh), ("embed", "heads")),
        "conv_x": param(next(keys), (s.conv_width, d_in), (None, "ssm_inner"), scale=cw),
        "conv_xb": param(next(keys), (d_in,), ("ssm_inner",), init="zeros"),
        "conv_B": param(next(keys), (s.conv_width, gn), (None, "state"), scale=cw),
        "conv_Bb": param(next(keys), (gn,), ("state",), init="zeros"),
        "conv_C": param(next(keys), (s.conv_width, gn), (None, "state"), scale=cw),
        "conv_Cb": param(next(keys), (gn,), ("state",), init="zeros"),
        "A_log": Boxed(jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32) % 15 + 1.0), ("heads",)),
        "D": param(next(keys), (nh,), ("heads",), init="ones"),
        "dt_bias": Boxed(dt_init, ("heads",)),
        "norm_w": param(next(keys), (d_in,), ("ssm_inner",), init="zeros"),
        "out_proj": param(next(keys), (d_in, d), ("ssm_inner", "embed")),
    }


def Boxed(v, axes):
    from .common import Box
    return Box(v, axes)


def _causal_conv(x, w, b, state0, S):
    """Depthwise causal conv. x: (B, S, C); w: (W, C); state0: (B, W-1, C)."""
    xp = jnp.concatenate([state0, x], axis=1)
    W = w.shape[0]
    y = sum(xp[:, i:i + S] * w[i] for i in range(W))
    return jax.nn.silu(y + b), xp[:, S:]


def mamba_apply(p, x, cfg, h0=None, conv0=None):
    """Full-sequence (train/prefill). x: (B, S, d).

    Returns (y (B,S,d), (ssm_state, conv_state_dict))."""
    Bsz, S, d = x.shape
    s = cfg.ssm
    d_in = s.expand * d
    nh = d_in // s.head_dim
    gn = s.ngroups * s.state_dim
    z = x @ p["wz"]
    xs = x @ p["wx"]
    Bm = x @ p["wB"]
    Cm = x @ p["wC"]
    dt = x @ p["wdt"]
    if conv0 is None:
        zz = lambda c: jnp.zeros((Bsz, s.conv_width - 1, c), x.dtype)
        conv0 = {"x": zz(d_in), "B": zz(gn), "C": zz(gn)}
    xs, st_x = _causal_conv(xs, p["conv_x"], p["conv_xb"], conv0["x"], S)
    Bm, st_B = _causal_conv(Bm, p["conv_B"], p["conv_Bb"], conv0["B"], S)
    Cm, st_C = _causal_conv(Cm, p["conv_C"], p["conv_Cb"], conv0["C"], S)
    conv_state = {"x": st_x, "B": st_B, "C": st_C}

    xh = xs.reshape(Bsz, S, nh, s.head_dim)
    Bh = Bm.reshape(Bsz, S, s.ngroups, s.state_dim)
    Ch = Cm.reshape(Bsz, S, s.ngroups, s.state_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    chunk = min(s.chunk_size, S)
    pad = (-S) % chunk
    if pad:
        # padded steps are identities: dt=0 -> no decay, no input
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    y, h = ssd_chunked(xh, dt, A, Bh, Ch, p["D"], chunk, h0=h0)
    y = y[:, :S].reshape(Bsz, S, d_in)
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["out_proj"], (h, conv_state)


def mamba_step(p, x_t, cfg, state):
    """Decode step. x_t: (B, d); state = (ssm_state, conv_state_dict)."""
    h, conv_state = state
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim

    def conv1(v, w, b, st):
        win = jnp.concatenate([st, v[:, None]], axis=1)
        y = jnp.einsum("bwc,wc->bc", win, w)
        return jax.nn.silu(y + b), win[:, 1:]

    z = x_t @ p["wz"]
    xs, st_x = conv1(x_t @ p["wx"], p["conv_x"], p["conv_xb"], conv_state["x"])
    Bm, st_B = conv1(x_t @ p["wB"], p["conv_B"], p["conv_Bb"], conv_state["B"])
    Cm, st_C = conv1(x_t @ p["wC"], p["conv_C"], p["conv_Cb"], conv_state["C"])
    dt = jax.nn.softplus((x_t @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(-1, nh, s.head_dim)
    Bh = Bm.reshape(-1, s.ngroups, s.state_dim)
    Ch = Cm.reshape(-1, s.ngroups, s.state_dim)
    h, y = ssd_step(h, xh, dt, A, Bh, Ch, p["D"])
    y = y.reshape(-1, d_in)
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["out_proj"], (h, {"x": st_x, "B": st_B, "C": st_C})


def mamba_state_specs(cfg, batch: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    gn = s.ngroups * s.state_dim
    W = s.conv_width - 1
    conv = {"x": jax.ShapeDtypeStruct((batch, W, d_in), dtype),
            "B": jax.ShapeDtypeStruct((batch, W, gn), dtype),
            "C": jax.ShapeDtypeStruct((batch, W, gn), dtype)}
    return (
        jax.ShapeDtypeStruct((batch, nh, s.head_dim, s.state_dim), jnp.float32),
        conv,
    )
