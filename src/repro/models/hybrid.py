"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block applied
every `hybrid_attn_every` layers (weights shared across invocations, each
invocation keeps its own KV cache) [arXiv:2411.15242].

Layout: n_groups = num_layers // every groups of (every mamba blocks +
shared-attn invocation), plus a tail of leftover mamba blocks.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import (apply_norm, embed_lookup, keygen, norm_params, param,
                     shard, split_boxes)
from .moe import dense_ffn_apply, dense_ffn_params
from .ssd import (mamba_apply, mamba_params, mamba_state_specs, mamba_step)
from .transformer import attn_decode, attn_full, attn_params, stack_init, unembed


def _plan(cfg) -> Tuple[int, int, int]:
    every = cfg.hybrid_attn_every
    n_groups = cfg.num_layers // every
    tail = cfg.num_layers - n_groups * every
    return every, n_groups, tail


def init(key, cfg):
    keys = keygen(key)
    every, n_groups, tail = _plan(cfg)
    p: Dict[str, Any] = {
        "embed": param(next(keys), (cfg.vocab_size, cfg.d_model),
                       ("vocab", "embed"), scale=cfg.d_model ** -0.5),
        "final_norm": norm_params(next(keys), cfg.d_model, cfg),
        "lm_head": param(next(keys), (cfg.d_model, cfg.vocab_size),
                         ("embed", "vocab")),
        "groups": _reshape_groups(
            stack_init(lambda: {"m": mamba_params(keys, cfg),
                                "ln": norm_params(next(keys), cfg.d_model, cfg)},
                       n_groups * every), n_groups, every),
        "shared": {
            "ln1": norm_params(next(keys), cfg.d_model, cfg),
            "attn": attn_params(keys, cfg),
            "ln2": norm_params(next(keys), cfg.d_model, cfg),
            "ffn": dense_ffn_params(keys, cfg.d_model, cfg.d_ff),
        },
    }
    if tail:
        p["tail"] = stack_init(lambda: {"m": mamba_params(keys, cfg),
                                        "ln": norm_params(next(keys), cfg.d_model, cfg)},
                               tail)
    return p


def _reshape_groups(tree, n_groups, every):
    from .common import Box

    def r(b):
        return Box(b.value.reshape(n_groups, every, *b.value.shape[1:]),
                   ("groups",) + b.axes)

    return jax.tree.map(r, tree, is_leaf=lambda x: isinstance(x, Box))


def _mamba_block(pl, x, cfg, state):
    h = apply_norm(x, pl["ln"], cfg)
    y, state = mamba_apply(pl["m"], h, cfg, h0=state[0], conv0=state[1])
    return x + y, state


def _mamba_block_step(pl, x, cfg, state):
    h = apply_norm(x[:, None], pl["ln"], cfg)[:, 0]
    y, state = mamba_step(pl["m"], h, cfg, state)
    return x + y, state


def _shared_block(ps, x, cfg, positions, attn_blocks):
    h = apply_norm(x, ps["ln1"], cfg)
    a, kv = attn_full(ps["attn"], h, cfg, "dense", positions, attn_blocks)
    x = x + a
    h = apply_norm(x, ps["ln2"], cfg)
    return x + dense_ffn_apply(ps["ffn"], h, cfg), kv


def forward(params, tokens, cfg, *, remat=False, attn_blocks=(512, 512),
            return_cache=False, max_len=None, frontend_embeds=None):
    every, n_groups, tail = _plan(cfg)
    x = params["embed"][tokens]
    x = shard(x, "batch", None, "embed_act")
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :].astype(jnp.int32)

    def group_body(x, pg):
        def inner(x, pl):
            x, st = _mamba_block(pl, x, cfg, (None, None))
            return x, st
        if remat:
            inner = jax.checkpoint(inner, policy=jax.checkpoint_policies.nothing_saveable)
        x, states = jax.lax.scan(inner, x, pg)
        x, kv = _shared_block(params["shared"], x, cfg, positions, attn_blocks)
        if not return_cache:
            states = (jnp.zeros((), x.dtype), jnp.zeros((), x.dtype))
            kv = (jnp.zeros((), x.dtype),) * 2
        return x, (states, kv)

    if remat:
        group_body = jax.checkpoint(group_body, policy=jax.checkpoint_policies.nothing_saveable)
    x, (g_states, g_kv) = jax.lax.scan(group_body, x, params["groups"])

    t_states = None
    if tail:
        def inner(x, pl):
            x, st = _mamba_block(pl, x, cfg, (None, None))
            if not return_cache:
                st = (jnp.zeros((), x.dtype), jnp.zeros((), x.dtype))
            return x, st
        x, t_states = jax.lax.scan(inner, x, params["tail"])

    x = apply_norm(x, params["final_norm"], cfg)
    if return_cache:
        x = x[:, -1:]          # last-position logits only at prefill
    logits = x @ params["lm_head"].astype(x.dtype)
    logits = shard(logits, "batch", None, "vocab")

    cache = None
    if return_cache:
        target = max_len if max_len is not None else S
        k, v = g_kv
        if S < target:
            pad = [(0, 0), (0, 0), (0, target - S), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        cache = {
            "ssm": g_states[0], "conv": g_states[1],           # (G, E, B, ...)
            "k": k, "v": v,                                    # (G, B, T, kv, hd)
            "pos": jnp.full((B,), S, jnp.int32),
        }
        if tail:
            cache["tail_ssm"], cache["tail_conv"] = t_states
    return logits, cache, 0.0


def prefill(params, tokens, cfg, *, attn_blocks=(512, 512), max_len=None,
            frontend_embeds=None):
    logits, cache, _ = forward(params, tokens, cfg, attn_blocks=attn_blocks,
                               return_cache=True, max_len=max_len)
    return logits[:, -1], cache


def decode_step(params, cache, tokens, cfg):
    every, n_groups, tail = _plan(cfg)
    x = embed_lookup(params["embed"], tokens)
    x = shard(x, "batch", "embed_act")
    pos = cache["pos"]

    def group_body(x, xs):
        pg, ssm, conv, kc, vc = xs

        def inner(x_st, pl_states):
            x, = x_st
            pl, s0, c0 = pl_states
            x, st = _mamba_block_step(pl, x, cfg, (s0, c0))
            return (x,), st
        (x,), (ssm, conv) = jax.lax.scan(inner, (x,), (pg, ssm, conv))
        ps = params["shared"]
        h = apply_norm(x[:, None], ps["ln1"], cfg)[:, 0]
        a, kc, vc = attn_decode(ps["attn"], h, cfg, "dense", kc, vc, pos)
        x = x + a
        h = apply_norm(x[:, None], ps["ln2"], cfg)[:, 0]
        x = x + dense_ffn_apply(ps["ffn"], h[:, None], cfg)[:, 0]
        return x, (ssm, conv, kc, vc)

    x, (ssm, conv, kc, vc) = jax.lax.scan(
        group_body, x,
        (params["groups"], cache["ssm"], cache["conv"], cache["k"], cache["v"]))
    new_cache = dict(cache, ssm=ssm, conv=conv, k=kc, v=vc, pos=pos + 1)

    if tail:
        def inner(x_st, pl_states):
            x, = x_st
            pl, s0, c0 = pl_states
            x, st = _mamba_block_step(pl, x, cfg, (s0, c0))
            return (x,), st
        (x,), (tssm, tconv) = jax.lax.scan(
            inner, (x,), (params["tail"], cache["tail_ssm"], cache["tail_conv"]))
        new_cache["tail_ssm"], new_cache["tail_conv"] = tssm, tconv

    x = apply_norm(x[:, None], params["final_norm"], cfg)[:, 0]
    logits = x @ params["lm_head"].astype(x.dtype)
    return logits, new_cache


def cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    every, n_groups, tail = _plan(cfg)
    ssm, conv = mamba_state_specs(cfg, batch, dtype)
    kv = jax.ShapeDtypeStruct(
        (n_groups, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype)
    stk = lambda pre, s: jax.ShapeDtypeStruct(pre + s.shape, s.dtype)
    out = {
        "ssm": stk((n_groups, every), ssm),
        "conv": jax.tree.map(lambda s: stk((n_groups, every), s), conv),
        "k": kv, "v": kv,
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }
    if tail:
        out["tail_ssm"] = stk((tail,), ssm)
        out["tail_conv"] = jax.tree.map(lambda s: stk((tail,), s), conv)
    return out


def cache_logical_axes(cfg, batch: int = 0, max_len: int = 0):
    every, n_groups, tail = _plan(cfg)
    conv = {"x": ("groups", "layers", "kv_batch", None, "ssm_inner"),
            "B": ("groups", "layers", "kv_batch", None, "state"),
            "C": ("groups", "layers", "kv_batch", None, "state")}
    out = {
        "ssm": ("groups", "layers", "kv_batch", "heads", None, None),
        "conv": conv,
        "k": ("groups", "kv_batch", "kv_seq", "kv_heads", None),
        "v": ("groups", "kv_batch", "kv_seq", "kv_heads", None),
        "pos": ("kv_batch",),
    }
    if tail:
        tconv = {"x": ("layers", "kv_batch", None, "ssm_inner"),
                 "B": ("layers", "kv_batch", None, "state"),
                 "C": ("layers", "kv_batch", None, "state")}
        out["tail_ssm"] = ("layers", "kv_batch", "heads", None, None)
        out["tail_conv"] = tconv
    return out
