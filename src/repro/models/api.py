"""Unified model facade: one interface per family for engines/launchers.

batch dicts:
  LM families:  {"tokens": (B, S) i32 [, "frontend_embeds": (B, P, d)]}
  encdec:       {"src_embeds": (B, T, d), "tokens": (B, S) i32}
decode tokens: (B,) i32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from . import encdec, hybrid, mamba_lm, transformer
from .common import Box, split_boxes


def _mod(cfg):
    return {
        "dense": transformer, "moe": transformer, "vlm": transformer,
        "ssm": mamba_lm, "hybrid": hybrid, "encdec": encdec,
    }[cfg.family]


@dataclasses.dataclass
class Model:
    cfg: Any

    # ---- params ----------------------------------------------------
    def init(self, key):
        params, _ = split_boxes(_mod(self.cfg).init(key, self.cfg))
        return params

    def init_with_axes(self, key):
        return split_boxes(_mod(self.cfg).init(key, self.cfg))

    def param_axes(self):
        """Logical-axes pytree without allocating (eval_shape the init)."""
        axes = {}

        def runner(key):
            nonlocal axes
            params, axes_ = split_boxes(_mod(self.cfg).init(key, self.cfg))
            axes = axes_
            return params

        shapes = jax.eval_shape(runner, jax.random.PRNGKey(0))
        return shapes, axes

    def cast(self, params, dtype):
        return jax.tree.map(
            lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
            params)

    # ---- compute ----------------------------------------------------
    def _fe(self, batch):
        return batch.get("frontend_embeds")

    def forward(self, params, batch, *, remat=False, attn_blocks=(512, 512)):
        """Full-sequence logits (training). Returns (logits, aux)."""
        m = _mod(self.cfg)
        if self.cfg.family == "encdec":
            logits, _, aux = m.forward(params, batch, self.cfg, remat=remat,
                                       attn_blocks=attn_blocks)
        else:
            logits, _, aux = m.forward(params, batch["tokens"], self.cfg,
                                       remat=remat, attn_blocks=attn_blocks,
                                       frontend_embeds=self._fe(batch))
        return logits, aux

    def prefill(self, params, batch, *, max_len: int, attn_blocks=(512, 512)):
        m = _mod(self.cfg)
        if self.cfg.family == "encdec":
            return m.prefill(params, batch, self.cfg, max_len=max_len,
                             attn_blocks=attn_blocks)
        return m.prefill(params, batch["tokens"], self.cfg, max_len=max_len,
                         attn_blocks=attn_blocks, frontend_embeds=self._fe(batch))

    def decode_step(self, params, cache, tokens):
        return _mod(self.cfg).decode_step(params, cache, tokens, self.cfg)

    def decode_step_paged(self, params, cache, tokens):
        if not supports_paged(self.cfg):
            raise NotImplementedError(
                f"paged KV decode unsupported for {self.cfg.name} "
                f"(family={self.cfg.family})")
        return _mod(self.cfg).decode_step_paged(params, cache, tokens,
                                                self.cfg)

    # ---- specs -------------------------------------------------------
    def cache_specs(self, batch: int, max_len: int, dtype=jnp.bfloat16, **kw):
        return _mod(self.cfg).cache_specs(self.cfg, batch, max_len, dtype, **kw)

    def paged_cache_specs(self, batch: int, num_pages: int, page_size: int,
                          dtype=jnp.bfloat16, max_len=None):
        if not supports_paged(self.cfg):
            raise NotImplementedError(
                f"paged KV cache unsupported for {self.cfg.name}")
        return _mod(self.cfg).paged_cache_specs(
            self.cfg, batch, num_pages, page_size, dtype, max_len=max_len)

    def cache_logical_axes(self):
        return _mod(self.cfg).cache_logical_axes(self.cfg)


def supports_paged(cfg) -> bool:
    """Paged KV decode covers plain causal attention: dense/GQA (incl. MoE
    FFNs and VLM backbones) without sliding windows. SSM/hybrid state and
    ring-packed window caches stay on the dense slab path."""
    return (cfg.family in ("dense", "moe", "vlm")
            and cfg.sliding_window == 0
            and not cfg.local_global_ratio)


def build_model(cfg) -> Model:
    return Model(cfg)
