"""Attention implementations.

`flash_reference` is the pure-jnp oracle of the Pallas flash kernel: a
lax.scan over a *static* list of (q_block, kv_block) tiles (only tiles
intersecting the causal/sliding-window band are visited, so HLO FLOPs track
the kernel's), with online-softmax accumulation. It is what the multi-pod
dry-run lowers, because Pallas TPU kernels cannot lower on the CPU
placeholder backend.

`decode_attend` is the single-new-token path against a (possibly ring) KV
cache.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import shard

NEG_INF = -2.3819763e38  # jnp.finfo(f32).min-ish, matches flash kernels


def _band_tiles(n_q: int, n_kv: int, block_q: int, block_kv: int,
                causal: bool, window: int) -> list[Tuple[int, int]]:
    """Static tile schedule: tiles (i, j) intersecting the attention band."""
    tiles = []
    for i in range(n_q):
        q_lo, q_hi = i * block_q, (i + 1) * block_q - 1
        for j in range(n_kv):
            k_lo, k_hi = j * block_kv, (j + 1) * block_kv - 1
            if causal and k_lo > q_hi:
                continue
            if window and k_hi < q_lo - window + 1:
                continue
            tiles.append((i, j))
    return tiles


def flash_reference(q, k, v, *, causal=True, window: int = 0,
                    block_q: int = 512, block_kv: int = 512,
                    scale: Optional[float] = None,
                    logit_softcap: float = 0.0,
                    prefix_len: Optional[jax.Array] = None):
    """q: (B, Sq, H, hd); k/v: (B, Skv, Hkv, hd). GQA by head-group repeat.

    When Sq < Skv the leading Skv - Sq kv positions are a prefix every
    query sees (offset causal mask). `prefix_len` (scalar) additionally
    marks only the first `prefix_len` of those positions valid — the rest
    is padding (e.g. a bucketed dense gather over trash pages) and is
    masked out.

    Returns (B, Sq, H, hd).
    """
    B, Sq, H, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    assert H % Hkv == 0
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    # pad to block multiples (static)
    pq = (-Sq) % block_q
    pkv = (-Skv) % block_kv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    Sqp, Skvp = Sq + pq, Skv + pkv
    n_q, n_kv = Sqp // block_q, Skvp // block_kv

    tiles = _band_tiles(n_q, n_kv, block_q, block_kv, causal and Sq == Skv, window)
    tile_arr = jnp.asarray(np.array(tiles, dtype=np.int32))  # (T, 2)

    # accumulators in f32
    acc = jnp.zeros((B, Sqp, H, hd), jnp.float32)
    m = jnp.full((B, Sqp, H), NEG_INF, jnp.float32)
    l = jnp.zeros((B, Sqp, H), jnp.float32)

    q_idx = jnp.arange(block_q)
    kv_idx = jnp.arange(block_kv)

    def body(carry, tile):
        acc, m, l = carry
        ti, tj = tile[0], tile[1]
        qs = jax.lax.dynamic_slice_in_dim(q, ti * block_q, block_q, axis=1)
        ks = jax.lax.dynamic_slice_in_dim(k, tj * block_kv, block_kv, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, tj * block_kv, block_kv, axis=1)
        # (B, bq, H, hd) x (B, bkv, Hkv, hd) -> (B, H, bq, bkv)
        qs4 = qs.reshape(B, block_q, Hkv, G, hd)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qs4.astype(jnp.float32),
                       ks.astype(jnp.float32)) * scale
        if logit_softcap:
            s = jnp.tanh(s / logit_softcap) * logit_softcap
        # mask within tile
        qpos = ti * block_q + q_idx            # (bq,)
        kpos = tj * block_kv + kv_idx          # (bkv,)
        mask = kpos[None, :] <= Skv - Sq + qpos[:, None] if (causal and True) else jnp.ones((block_q, block_kv), bool)
        if not causal:
            mask = jnp.ones((block_q, block_kv), bool)
        if window:
            mask = mask & (kpos[None, :] > Skv - Sq + qpos[:, None] - window)
        mask = mask & (kpos[None, :] < Skv)    # kv padding
        if prefix_len is not None:
            mask = mask & ((kpos[None, :] < prefix_len)
                           | (kpos[None, :] >= Skv - Sq))
        s = jnp.where(mask[None, None, None], s, NEG_INF)

        # reshape helpers: s is (B, Hkv, G, bq, bkv)
        s_max = s.max(axis=-1)                                   # (B,Hkv,G,bq)
        s_max = jnp.moveaxis(s_max, 3, 1).reshape(B, block_q, H)  # (B,bq,H)
        m_blk = jax.lax.dynamic_slice_in_dim(m, ti * block_q, block_q, 1)
        l_blk = jax.lax.dynamic_slice_in_dim(l, ti * block_q, block_q, 1)
        a_blk = jax.lax.dynamic_slice_in_dim(acc, ti * block_q, block_q, 1)
        m_new = jnp.maximum(m_blk, s_max)
        # p: (B,Hkv,G,bq,bkv)
        m_for_s = jnp.moveaxis(m_new.reshape(B, block_q, Hkv, G), 1, 3)
        p = jnp.exp(s - m_for_s[..., None])
        corr = jnp.exp(m_blk - m_new)                             # (B,bq,H)
        l_new = l_blk * corr + jnp.moveaxis(p.sum(-1), 3, 1).reshape(B, block_q, H)
        pv = jnp.einsum("bkgqs,bskh->bqkgh", p, vs.astype(jnp.float32))
        a_new = a_blk * corr[..., None] + pv.reshape(B, block_q, H, hd)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, a_new, ti * block_q, 1)
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, ti * block_q, 1)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_new, ti * block_q, 1)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(body, (acc, m, l), tile_arr)
    out = acc / jnp.maximum(l, 1e-37)[..., None]
    return out[:, :Sq].astype(q.dtype)


def dense_attention(q, k, v, *, causal=True, window: int = 0,
                    scale: Optional[float] = None, logit_softcap: float = 0.0):
    """Naive O(S^2) oracle used only in tests on tiny shapes."""
    B, Sq, H, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qs = q.reshape(B, Sq, Hkv, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qs.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if logit_softcap:
        s = jnp.tanh(s / logit_softcap) * logit_softcap
    qpos = jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos[None, :] <= Skv - Sq + qpos[:, None]
    if window:
        mask &= kpos[None, :] > Skv - Sq + qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def decode_attend(q, k_cache, v_cache, kv_len, *, window: int = 0,
                  scale: Optional[float] = None, logit_softcap: float = 0.0,
                  ring_pos: Optional[jax.Array] = None):
    """One-token attention against the cache.

    q: (B, H, hd); caches: (B, Smax, Hkv, hd); kv_len: scalar or (B,) valid
    length. For ring caches (sliding window) the cache holds the last
    `window` tokens in rotation and masking is by slot validity only.
    """
    B, H, hd = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qs = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qs.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    if logit_softcap:
        s = jnp.tanh(s / logit_softcap) * logit_softcap
    slot = jnp.arange(Smax)
    kv_len = jnp.asarray(kv_len)
    lens = kv_len[..., None] if kv_len.ndim else kv_len[None, None]
    valid = slot[None, :] < lens                       # (B, Smax) or (1,Smax)
    if window and ring_pos is None:
        valid &= slot[None, :] >= lens - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    s = s - s.max(axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)
