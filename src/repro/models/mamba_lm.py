"""Pure Mamba2 language model (attention-free) [arXiv:2405.21060]."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .common import apply_norm, embed_lookup, keygen, norm_params, param, shard
from .ssd import mamba_apply, mamba_params, mamba_state_specs, mamba_step
from .transformer import stack_init


def init(key, cfg):
    keys = keygen(key)
    return {
        "embed": param(next(keys), (cfg.vocab_size, cfg.d_model),
                       ("vocab", "embed"), scale=cfg.d_model ** -0.5),
        "layers": stack_init(lambda: {
            "ln": norm_params(next(keys), cfg.d_model, cfg),
            "m": mamba_params(keys, cfg),
        }, cfg.num_layers),
        "final_norm": norm_params(next(keys), cfg.d_model, cfg),
    }


def forward(params, tokens, cfg, *, remat=False, return_cache=False,
            max_len=None, attn_blocks=None, frontend_embeds=None):
    x = params["embed"][tokens]
    x = shard(x, "batch", None, "embed_act")

    def body(x, pl):
        h = apply_norm(x, pl["ln"], cfg)
        y, state = mamba_apply(pl["m"], h, cfg)
        if not return_cache:
            state = (jnp.zeros((), x.dtype), jnp.zeros((), x.dtype))
        return x + y, state

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, states = jax.lax.scan(body, x, params["layers"])
    x = apply_norm(x, params["final_norm"], cfg)
    if return_cache:
        x = x[:, -1:]          # last-position logits only at prefill
    logits = x @ params["embed"].T.astype(x.dtype)   # tied
    logits = shard(logits, "batch", None, "vocab")
    cache = None
    if return_cache:
        cache = {"ssm": states[0], "conv": states[1],
                 "pos": jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)}
    return logits, cache, 0.0


def prefill(params, tokens, cfg, *, max_len=None, attn_blocks=None,
            frontend_embeds=None):
    logits, cache, _ = forward(params, tokens, cfg, return_cache=True)
    return logits[:, -1], cache


def decode_step(params, cache, tokens, cfg):
    x = embed_lookup(params["embed"], tokens)
    x = shard(x, "batch", "embed_act")

    def body(x_t, xs):
        x, = x_t
        pl, s, c = xs
        h = apply_norm(x[:, None], pl["ln"], cfg)[:, 0]
        y, (s, c) = mamba_step(pl["m"], h, cfg, (s, c))
        return (x + y,), (s, c)

    (x,), (s, c) = jax.lax.scan(body, (x,),
                                (params["layers"], cache["ssm"], cache["conv"]))
    x = apply_norm(x[:, None], params["final_norm"], cfg)[:, 0]
    logits = x @ params["embed"].T.astype(x.dtype)
    return logits, dict(cache, ssm=s, conv=c, pos=cache["pos"] + 1)


def cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    ssm, conv = mamba_state_specs(cfg, batch, dtype)
    L = cfg.num_layers
    return {
        "ssm": jax.ShapeDtypeStruct((L,) + ssm.shape, ssm.dtype),
        "conv": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((L,) + s.shape, s.dtype), conv),
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def cache_logical_axes(cfg, batch: int = 0, max_len: int = 0):
    conv = {"x": ("layers", "kv_batch", None, "ssm_inner"),
            "B": ("layers", "kv_batch", None, "state"),
            "C": ("layers", "kv_batch", None, "state")}
    return {"ssm": ("layers", "kv_batch", "heads", None, None),
            "conv": conv, "pos": ("kv_batch",)}
