"""Mixture-of-Experts FFN (top-k routing, shared experts, first-k-dense).

Dispatch is gather/scatter with a static per-expert capacity
C = ceil(T*k/E * capacity_factor): tokens are routed to (E, C, d) expert
buffers, batched-einsum'd through expert weights, and scatter-combined with
router weights. FLOPs = cf * T * k * ffn_flops — faithful to the sparse
compute the paper's engine would run, and GSPMD-shardable.

Two dispatch paths:
  * `moe_apply` — plain pjit. GSPMD handles the data-dependent scatter by
    gathering activations across the batch axes: correct but collective-
    heavy at scale (measured 118 s/step collective for mixtral train_4k).
  * `moe_apply_shard_map` — beyond-paper optimization: the token->expert
    scatter/gather runs *locally per data shard* under shard_map (manual on
    the batch axes, auto on "model"), with FSDP-sharded expert weights
    all-gathered once per layer. Eliminates the activation gathers; see
    EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import Box, act_fn, current_rules, param, shard


def moe_params(keys, cfg) -> Dict:
    d, ff = cfg.d_model, cfg.d_ff
    m = cfg.moe
    E = m.num_experts
    p = {
        "router": param(next(keys), (d, E), ("embed", "expert")),
        "wi": param(next(keys), (E, d, 2 * ff), ("expert", "embed", "mlp")),
        "wo": param(next(keys), (E, ff, d), ("expert", "mlp", "embed")),
    }
    if m.num_shared_experts:
        sf = ff * m.num_shared_experts
        p["shared_wi"] = param(next(keys), (d, 2 * sf), ("embed", "mlp"))
        p["shared_wo"] = param(next(keys), (sf, d), ("mlp", "embed"))
    return p


def moe_apply(p, x, cfg, capacity_factor: float = 0.0):
    """x: (B, S, d) -> (B, S, d). Dispatch implementation picked from the
    active sharding rules: `moe_grouped` (GShard-style shard-local groups,
    pure pjit) > `moe_shard_map` (manual; hits an XLA-CPU AD bug under
    grad, kept for TPU/inference) > plain pjit."""
    rules = current_rules()
    if rules is not None and getattr(rules, "moe_grouped", False):
        return moe_apply_grouped(p, x, cfg, rules, capacity_factor)
    if rules is not None and getattr(rules, "moe_shard_map", False):
        return moe_apply_shard_map(p, x, cfg, rules, capacity_factor)
    return _moe_apply_pjit(p, x, cfg, capacity_factor)


def moe_apply_grouped(p, x, cfg, rules, capacity_factor: float = 0.0):
    """Beyond-paper dispatch v2: tokens reshaped into G groups aligned with
    the batch shards; routing/scatter/combine vmapped per group, so every
    gather/scatter is *group-local* and GSPMD partitions the G axis over
    the batch mesh axes with no cross-shard dispatch traffic — expert FFN
    einsums still shard over "model"/FSDP as usual."""
    mesh = rules.mesh
    shards = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            shards *= mesh.shape[a]
    B, S, d = x.shape
    T = B * S
    G = math.gcd(T, shards)
    xg = x.reshape(G, T // G, d)
    xg = shard(xg, "batch", None, "embed_act")

    # NOTE (§Perf log): forcing an explicit FSDP weight gather here
    # (shard(wi, P(None,None,"model"))) was tried and REFUTED — the
    # replication constraint propagated into the vmapped scatter and blew
    # collective traffic from 18.6 to 42.8 TB/chip/step. GSPMD keeps the
    # better schedule when the einsum operands are left unconstrained.
    core = partial(_routed_core, cfg=cfg, capacity_factor=capacity_factor,
                   constrain=False)
    out, aux = jax.vmap(core, in_axes=(0, None, None, None))(
        xg, p["router"], p["wi"], p["wo"])
    out = out.reshape(B, S, d)
    if cfg.moe.num_shared_experts:
        out = out + _shared_part(p, x.reshape(T, d), cfg).reshape(x.shape)
    return out, jnp.mean(aux)


def _routed_core(xf, router, wi, wo, cfg=None, capacity_factor: float = 0.0,
                 constrain: bool = True):
    """Top-k routed experts on flat tokens. Returns (out (T, d), aux)."""
    T, d = xf.shape
    m = cfg.moe
    E, k = m.num_experts, m.num_experts_per_tok
    act = act_fn(cfg.mlp_activation)
    cf = capacity_factor or m.capacity_factor or 2.0

    logits = (xf.astype(jnp.float32) @ router.astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    topw, topi = jax.lax.top_k(gates, k)                         # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    C = int(math.ceil(T * k / E * cf))
    C = max(C, 8)
    flat_e = topi.reshape(-1)                                    # (T*k,)
    # position of each routed token within its expert's buffer
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (T*k, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)             # exclusive
    slot = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    overflow = slot >= C                                         # GShard-style drop
    dst = jnp.where(overflow, E * C, flat_e * C + slot)          # sentinel OOB

    tok_idx = jnp.repeat(jnp.arange(T), k)
    buf = jnp.zeros((E * C, d), xf.dtype).at[dst].set(xf[tok_idx], mode="drop")
    buf = buf.reshape(E, C, d)
    if constrain:
        buf = shard(buf, "expert", None, "embed_act")

    h = jnp.einsum("ecd,edf->ecf", buf, wi)                      # (E, C, 2ff)
    g, u = jnp.split(h, 2, axis=-1)
    h = act(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, wo).reshape(E * C, d)      # (E*C, d)

    w = topw.reshape(-1).astype(xf.dtype)                        # (T*k,)
    w = jnp.where(overflow, 0, w)
    gathered = y[jnp.minimum(dst, E * C - 1)] * w[:, None]       # (T*k, d)
    out = jnp.zeros((T, d), xf.dtype).at[tok_idx].add(gathered)
    aux = _load_balance_loss(gates, topi, E)
    return out, aux


def _shared_part(p, xf, cfg):
    act = act_fn(cfg.mlp_activation)
    h = xf @ p["shared_wi"]
    g, u = jnp.split(h, 2, axis=-1)
    return (act(g) * u) @ p["shared_wo"]


def _moe_apply_pjit(p, x, cfg, capacity_factor: float = 0.0):
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    out, aux = _routed_core(xf, p["router"], p["wi"], p["wo"], cfg,
                            capacity_factor)
    if cfg.moe.num_shared_experts:
        out = out + _shared_part(p, xf, cfg)
    return out.reshape(B, S, d), aux


def _manual_entries(rules, logical, shape, manual):
    """Resolved spec entries restricted to the manual axes."""
    spec = rules.resolve(logical, shape)
    entries = []
    for e in tuple(spec) + (None,) * (len(shape) - len(spec)):
        if e is None:
            entries.append(None)
            continue
        ax = e if isinstance(e, tuple) else (e,)
        keep = tuple(a for a in ax if a in manual)
        entries.append(keep if len(keep) > 1 else (keep[0] if keep else None))
    return entries


def _gather_manual(v, entries):
    """all_gather any manually-sharded dims back to full size (tiled)."""
    for dim, e in enumerate(entries):
        if e is None:
            continue
        for ax in (e if isinstance(e, tuple) else (e,)):
            v = jax.lax.all_gather(v, ax, axis=dim, tiled=True)
    return v


def moe_apply_shard_map(p, x, cfg, rules, capacity_factor: float = 0.0):
    """Beyond-paper dispatch: scatter/gather stays LOCAL per batch shard
    (manual over the batch axes; "model" remains auto for the expert FFN
    einsums). Expert weights arrive FSDP-sharded and are all-gathered once
    per layer — the same traffic dense FSDP layers pay."""
    mesh = rules.mesh
    manual = frozenset(a for a in ("pod", "data") if a in mesh.shape)
    B, S, d = x.shape
    x_ent = _manual_entries(rules, ("batch", None, "embed_act"), x.shape, manual)
    r_ent = _manual_entries(rules, ("embed", "expert"), p["router"].shape, manual)
    wi_ent = _manual_entries(rules, ("expert", "embed", "mlp"), p["wi"].shape, manual)
    wo_ent = _manual_entries(rules, ("expert", "mlp", "embed"), p["wo"].shape, manual)
    x_spec = P(*x_ent)

    def body(xl, router, wi, wo):
        router = _gather_manual(router, r_ent)
        wi = _gather_manual(wi, wi_ent)
        wo = _gather_manual(wo, wo_ent)
        Bl, Sl, _ = xl.shape
        out, aux = _routed_core(xl.reshape(Bl * Sl, d), router, wi, wo, cfg,
                                capacity_factor, constrain=False)
        aux = jax.lax.pmean(aux, tuple(sorted(manual)))
        return out.reshape(xl.shape), aux

    fn = jax.shard_map(body, mesh=mesh, axis_names=manual,
                       in_specs=(x_spec, P(*r_ent), P(*wi_ent), P(*wo_ent)),
                       out_specs=(x_spec, P()), check_vma=False)
    out, aux = fn(x, p["router"], p["wi"], p["wo"])
    if cfg.moe.num_shared_experts:
        xf = x.reshape(B * S, d)
        out = out + _shared_part(p, xf, cfg).reshape(x.shape)
    return out, aux


def _load_balance_loss(gates, topi, E):
    """Switch-style aux loss (fraction-routed x mean gate)."""
    T, k = topi.shape
    fr = jnp.zeros(E, jnp.float32).at[topi.reshape(-1)].add(1.0) / (T * k)
    pe = gates.mean(axis=0)
    return E * jnp.sum(fr * pe)


def dense_ffn_params(keys, d, ff):
    return {
        "wi": param(next(keys), (d, 2 * ff), ("embed", "mlp")),
        "wo": param(next(keys), (ff, d), ("mlp", "embed")),
    }


def dense_ffn_apply(p, x, cfg):
    act = act_fn(cfg.mlp_activation)
    h = x @ p["wi"]
    g, u = jnp.split(h, 2, axis=-1)
    return (act(g) * u) @ p["wo"]
