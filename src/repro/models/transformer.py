"""Decoder-only transformer covering dense / MoE / VLM / local-global archs.

Layers are grouped into *segments* of homogeneous block kind (run-length
encoded from the per-layer pattern, e.g. gemma3's 5-local:1-global). Params
of each segment are stacked on a leading "layers" axis and executed with
lax.scan, keeping the lowered HLO O(1) in depth — essential for compiling
56–80-layer configs on the dry-run host.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import decode_attend, flash_reference
from .common import (Box, act_fn, apply_norm, apply_rope, embed_lookup,
                     keygen, norm_params, param, rmsnorm, shard, split_boxes)
from .moe import dense_ffn_apply, dense_ffn_params, moe_apply, moe_params

LOCAL_ROPE_THETA = 10000.0  # gemma3 local layers


# ---------------------------------------------------------------------------
# segment plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str      # "dense" | "moe" | "local" | "global"
    n: int


def layer_plan(cfg) -> List[Segment]:
    kinds = []
    for i in range(cfg.num_layers):
        if cfg.family == "moe":
            kind = "dense" if i < cfg.moe.first_k_dense else "moe"
        elif cfg.local_global_ratio:
            r = cfg.local_global_ratio
            kind = "global" if (i % (r + 1)) == r else "local"
        elif cfg.sliding_window:
            kind = "local"
        else:
            kind = "dense"
        kinds.append(kind)
    segs: List[Segment] = []
    for k in kinds:
        if segs and segs[-1].kind == k:
            segs[-1] = Segment(k, segs[-1].n + 1)
        else:
            segs.append(Segment(k, 1))
    return segs


def _is_windowed(kind: str, cfg) -> bool:
    return kind == "local" and cfg.sliding_window > 0


def _rope_theta(kind: str, cfg) -> float:
    if cfg.local_global_ratio and kind == "local":
        return LOCAL_ROPE_THETA
    return cfg.rope_theta


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def stack_init(fn, n: int):
    trees = [fn() for _ in range(n)]

    def merge(*boxes):
        v = jnp.stack([b.value for b in boxes])
        return Box(v, ("layers",) + boxes[0].axes)

    return jax.tree.map(merge, *trees, is_leaf=lambda x: isinstance(x, Box))


def attn_params(keys, cfg):
    d = cfg.d_model
    p = {
        "wq": param(next(keys), (d, cfg.num_heads, cfg.head_dim),
                    ("embed", "heads", None)),
        "wk": param(next(keys), (d, cfg.num_kv_heads, cfg.head_dim),
                    ("kv_embed", "kv_heads", None)),
        "wv": param(next(keys), (d, cfg.num_kv_heads, cfg.head_dim),
                    ("kv_embed", "kv_heads", None)),
        "wo": param(next(keys), (cfg.num_heads, cfg.head_dim, d),
                    ("heads", None, "embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = param(next(keys), (cfg.head_dim,), (None,), init="zeros")
        p["k_norm"] = param(next(keys), (cfg.head_dim,), (None,), init="zeros")
    return p


def layer_params(keys, cfg, kind: str):
    p = {
        "ln1": norm_params(next(keys), cfg.d_model, cfg),
        "attn": attn_params(keys, cfg),
        "ln2": norm_params(next(keys), cfg.d_model, cfg),
    }
    if kind == "moe":
        p["moe"] = moe_params(keys, cfg)
    else:
        ff = cfg.moe.dense_d_ff or cfg.d_ff if cfg.family == "moe" else cfg.d_ff
        p["ffn"] = dense_ffn_params(keys, cfg.d_model, ff)
    return p


def init(key, cfg):
    keys = keygen(key)
    d = cfg.d_model
    p: Dict[str, Any] = {
        "embed": param(next(keys), (cfg.vocab_size, d), ("vocab", "embed"),
                       scale=cfg.d_model ** -0.5),
        "final_norm": norm_params(next(keys), d, cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = param(next(keys), (d, cfg.vocab_size), ("embed", "vocab"))
    for i, seg in enumerate(layer_plan(cfg)):
        p[f"seg{i}"] = stack_init(lambda: layer_params(keys, cfg, seg.kind), seg.n)
    return p


# ---------------------------------------------------------------------------
# attention block application
# ---------------------------------------------------------------------------

def _qkv(p, x, cfg, positions, theta):
    B, S, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, theta, cfg.rope_fraction, cfg.rope_interleaved)
    k = apply_rope(k, positions, theta, cfg.rope_fraction, cfg.rope_interleaved)
    return q, k, v


def attn_full(p, x, cfg, kind, positions, attn_blocks=(512, 512),
              prefix=None, prefix_len=None, paged_prefix=None):
    """Full-sequence attention (train / prefill). Returns (out, (k, v)).

    `prefix` is an optional (k, v) pair of already-roped cached KV for
    positions before this chunk (shape (B, P, Hkv, hd)): queries attend
    over [prefix, self] with the causal offset handled by
    `flash_reference`'s Sq < Skv masking; `prefix_len` (scalar) marks how
    many of those P positions are live when the gather was padded to a
    bucket. `paged_prefix` = (k_pages, v_pages, block_table, prefix_lens)
    instead reads the prefix straight from the paged pool via the fused
    `prefix_prefill` kernel — no dense prefix is ever materialized. The
    returned cache carries only this chunk's KV — the prefix stays where
    it was cached."""
    window = cfg.sliding_window if _is_windowed(kind, cfg) else 0
    assert prefix is None or window == 0, "prefix reuse needs full attention"
    assert paged_prefix is None or window == 0
    q, k, v = _qkv(p, x, cfg, positions, _rope_theta(kind, cfg))
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    if paged_prefix is not None:
        from ..kernels.prefix_prefill.ops import prefix_prefill_op
        kp_l, vp_l, table, plens = paged_prefix
        o = prefix_prefill_op(q, k, v, kp_l, vp_l, table, plens,
                              block_q=attn_blocks[0],
                              block_kv=attn_blocks[1],
                              softcap=cfg.attn_logit_softcap)
        return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype)), (k, v)
    ka, va = k, v
    if prefix is not None:
        pk, pv = prefix
        ka = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
        va = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
    o = flash_reference(q, ka, va, causal=True, window=window,
                        block_q=attn_blocks[0], block_kv=attn_blocks[1],
                        logit_softcap=cfg.attn_logit_softcap,
                        prefix_len=prefix_len)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype)), (k, v)


def attn_decode(p, x, cfg, kind, k_cache, v_cache, pos):
    """Single-token attention. x: (B, d); pos: (B,) current write index.
    Returns (out, k_cache', v_cache')."""
    B, d = x.shape
    window = cfg.sliding_window if _is_windowed(kind, cfg) else 0
    q, k, v = _qkv(p, x[:, None], cfg, pos[:, None], _rope_theta(kind, cfg))
    q, k, v = q[:, 0], k[:, 0], v[:, 0]
    Smax = k_cache.shape[1]
    widx = (pos % Smax) if window else jnp.minimum(pos, Smax - 1)
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, widx].set(k.astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, widx].set(v.astype(v_cache.dtype))
    kv_len = jnp.minimum(pos + 1, Smax)
    o = decode_attend(q, k_cache, v_cache, kv_len,
                      window=0,  # ring cache already bounds the window
                      logit_softcap=cfg.attn_logit_softcap,
                      ring_pos=pos if window else None)
    return jnp.einsum("bhk,hkd->bd", o, p["wo"].astype(o.dtype)), k_cache, v_cache


def _ffn(pl, x, cfg, kind):
    if kind == "moe":
        out, aux = moe_apply(pl["moe"], x, cfg)
        return out, aux
    return dense_ffn_apply(pl["ffn"], x, cfg), 0.0


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg, frontend_embeds=None):
    x = params["embed"][tokens]  # vocab-sharded gather
    if cfg.embedding_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    return x


def _layer_body(x, pl, cfg, kind, positions, attn_blocks, prefix=None,
                prefix_len=None, paged_prefix=None):
    h = apply_norm(x, pl["ln1"], cfg)
    a, kv = attn_full(pl["attn"], h, cfg, kind, positions, attn_blocks,
                      prefix=prefix, prefix_len=prefix_len,
                      paged_prefix=paged_prefix)
    x = x + a
    h = apply_norm(x, pl["ln2"], cfg)
    f, aux = _ffn(pl, h, cfg, kind)
    x = x + f
    x = shard(x, "batch", None, "embed_act")
    return x, kv, aux


def forward(params, tokens, cfg, *, frontend_embeds=None, remat=False,
            attn_blocks=(512, 512), return_cache=False, max_len=None,
            prefix_kv=None, prefix_pages=None, prefix_table=None,
            prefix_len=None, pos_offset=0, last_pos=None):
    """Full-sequence forward. tokens: (B, S_text). Returns (logits, cache, aux).

    Prefix reuse (serving prefix cache): `prefix_kv` maps segment names to
    {"k", "v"} arrays of shape (layers, B, P, Hkv, hd) holding the cached,
    already-roped KV of the first P prompt positions; `tokens` then covers
    only the uncached suffix and `pos_offset` (= P) shifts its rope
    positions. When the gather was padded to a bucket, `prefix_len`
    (scalar or (B,)) marks how many of the P positions are live.

    Fused paged path: `prefix_pages` maps segment names to {"k", "v"}
    *page pools* of shape (layers, num_pages, page_size, Hkv, hd) and
    `prefix_table` (B, npp) i32 addresses the prefix pages directly —
    attention runs the fused `prefix_prefill` kernel, never gathering the
    prefix densely. `prefix_len` then must be given ((B,) i32 live prefix
    tokens; trash-padded table slots are masked).

    `last_pos` picks which position's logits to return when
    `return_cache` (defaults to the final one — callers that right-pad
    pass the last *real* index)."""
    x = embed_tokens(params, tokens, cfg, frontend_embeds)
    x = shard(x, "batch", None, "embed_act")
    B, S, _ = x.shape
    positions = (jnp.asarray(pos_offset, jnp.int32)
                 + jnp.arange(S, dtype=jnp.int32))[None, :]
    if prefix_pages is not None:
        assert prefix_table is not None and prefix_len is not None
        plens = jnp.broadcast_to(jnp.asarray(prefix_len, jnp.int32), (B,))
    aux_total = 0.0
    cache: Dict[str, Any] = {}
    for i, seg in enumerate(layer_plan(cfg)):
        pkv = prefix_kv.get(f"seg{i}") if prefix_kv is not None else None
        ppg = prefix_pages.get(f"seg{i}") if prefix_pages is not None else None

        def body(x, layer, _kind=seg.kind, _pkv=pkv, _ppg=ppg):
            prefix = paged = None
            if _pkv is not None:
                pl, pk_l, pv_l = layer
                prefix = (pk_l, pv_l)
            elif _ppg is not None:
                pl, kp_l, vp_l = layer
                paged = (kp_l, vp_l, prefix_table, plens)
            else:
                pl = layer
            x, kv, aux = _layer_body(x, pl, cfg, _kind, positions, attn_blocks,
                                     prefix=prefix, prefix_len=prefix_len,
                                     paged_prefix=paged)
            if not return_cache:
                kv = (jnp.zeros((), x.dtype),) * 2  # don't carry KV in train
            return x, (kv, aux)
        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable,
                static_argnums=())
        if pkv is not None:
            xs = (params[f"seg{i}"], pkv["k"], pkv["v"])
        elif ppg is not None:
            xs = (params[f"seg{i}"], ppg["k"], ppg["v"])
        else:
            xs = params[f"seg{i}"]
        x, (kvs, auxs) = jax.lax.scan(body, x, xs)
        aux_total = aux_total + jnp.sum(auxs)
        if return_cache:
            k_seg, v_seg = kvs
            target = S if max_len is None else max_len
            if _is_windowed(seg.kind, cfg):
                target = min(cfg.sliding_window, target)
            if S > target:
                # ring-pack the trailing `target` positions
                idx = (jnp.arange(S - target, S) % target)
                k_seg = jnp.zeros_like(k_seg[:, :, :target]).at[:, :, idx].set(k_seg[:, :, -target:])
                v_seg = jnp.zeros_like(v_seg[:, :, :target]).at[:, :, idx].set(v_seg[:, :, -target:])
            elif S < target:
                pad = [(0, 0), (0, 0), (0, target - S), (0, 0), (0, 0)]
                k_seg, v_seg = jnp.pad(k_seg, pad), jnp.pad(v_seg, pad)
            cache[f"seg{i}"] = {"k": k_seg, "v": v_seg}
    x = apply_norm(x, params["final_norm"], cfg)
    if return_cache:
        # prefill only needs the last position's logits — computing the
        # full (B,S,V) tensor would cost ~V/d extra memory (§Perf)
        if last_pos is None:
            x_last = x[:, -1]
        else:
            lp = jnp.broadcast_to(jnp.asarray(last_pos, jnp.int32), (B,))
            x_last = jnp.take_along_axis(x, lp[:, None, None], axis=1)[:, 0]
        logits = unembed(params, x_last, cfg)[:, None]
        cache["pos"] = jnp.full((B,), S, jnp.int32) + jnp.asarray(
            pos_offset, jnp.int32)
    else:
        logits = unembed(params, x, cfg)
    return logits, cache, aux_total


def unembed(params, x, cfg):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ w.astype(x.dtype)
    # arity-aware constraint: decode logits are (B, V), train/prefill (B,S,V)
    ax = ("batch", None, "vocab") if logits.ndim == 3 else ("batch", "vocab")
    return shard(logits, *ax)


def prefill(params, tokens, cfg, *, frontend_embeds=None,
            attn_blocks=(512, 512), max_len=None):
    """Returns (last-token logits (B, V), cache sized for max_len)."""
    logits, cache, _ = forward(params, tokens, cfg,
                               frontend_embeds=frontend_embeds,
                               attn_blocks=attn_blocks, return_cache=True,
                               max_len=max_len)
    return logits[:, -1], cache


def decode_step(params, cache, tokens, cfg):
    """tokens: (B,) int32. Returns (logits (B, V), cache')."""
    x = embed_lookup(params["embed"], tokens)
    if cfg.embedding_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = shard(x, "batch", "embed_act")
    pos = cache["pos"]
    new_cache: Dict[str, Any] = {}
    for i, seg in enumerate(layer_plan(cfg)):
        kc, vc = cache[f"seg{i}"]["k"], cache[f"seg{i}"]["v"]

        def body(x, layer, _kind=seg.kind):
            pl, kc_l, vc_l = layer
            h = apply_norm(x[:, None], pl["ln1"], cfg)[:, 0]
            a, kc_l, vc_l = attn_decode(pl["attn"], h, cfg, _kind, kc_l, vc_l, pos)
            x = x + a
            h = apply_norm(x[:, None], pl["ln2"], cfg)[:, 0]
            f, _ = _ffn(pl, h[:, None], cfg, _kind)
            return x + f[:, 0], (kc_l, vc_l)

        x, (kc, vc) = jax.lax.scan(body, x, (params[f"seg{i}"], kc, vc))
        new_cache[f"seg{i}"] = {"k": kc, "v": vc}
    x = apply_norm(x[:, None], params["final_norm"], cfg)[:, 0]
    logits = unembed(params, x, cfg)
    new_cache["pos"] = pos + 1
    return logits, new_cache


def decode_step_paged(params, cache, tokens, cfg):
    """Paged-KV decode step. tokens: (B,) int32. Returns (logits, cache').

    The cache holds per-segment page pools `(layers, num_pages, page_size,
    Hkv, hd)` shared across sequences, plus one block table `(B,
    pages_per_seq)` used by every layer: logical page j of sequence b lives
    in physical page `block_tables[b, j]` of *each* layer's pool. The new
    token's K/V is scattered into page `pos // page_size`, offset `pos %
    page_size`, then attention runs through `paged_decode_op` (Pallas on
    TPU, jnp oracle on CPU). Only non-windowed attention segments are
    supported — callers gate on `supports_paged`.
    """
    x = embed_lookup(params["embed"], tokens)
    if cfg.embedding_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = shard(x, "batch", "embed_act")
    from ..kernels.paged_decode.ops import paged_decode_op, paged_insert_op
    pos = cache["pos"]
    table = cache["block_tables"]
    B = tokens.shape[0]
    max_pps = table.shape[1]
    bidx = jnp.arange(B)
    new_cache: Dict[str, Any] = {}
    for i, seg in enumerate(layer_plan(cfg)):
        kc, vc = cache[f"seg{i}"]["k"], cache[f"seg{i}"]["v"]
        page_size = kc.shape[2]
        # freed/idle slots keep pos growing into the reserved trash page 0;
        # clamp so the page walk stays in-table and the write stays benign
        wpos = jnp.minimum(pos, max_pps * page_size - 1)
        pidx = table[bidx, wpos // page_size]
        off = wpos % page_size
        lens = jnp.minimum(pos + 1, max_pps * page_size)

        def body(x, layer, _kind=seg.kind):
            pl, kc_l, vc_l = layer
            h = apply_norm(x[:, None], pl["ln1"], cfg)[:, 0]
            q, k, v = _qkv(pl["attn"], h[:, None], cfg, pos[:, None],
                           _rope_theta(_kind, cfg))
            q, k, v = q[:, 0], k[:, 0], v[:, 0]
            # splice through the paged_insert kernel: the fresh token's KV
            # feeds attention without a dense detour (ref path is the same
            # .at[pidx, off].set scatter, so tokens stay byte-identical)
            kc_l, vc_l = paged_insert_op(kc_l, vc_l, k, v, pidx, off)
            o = paged_decode_op(q, kc_l, vc_l, table, lens,
                                softcap=cfg.attn_logit_softcap)
            a = jnp.einsum("bhk,hkd->bd", o, pl["attn"]["wo"].astype(o.dtype))
            x = x + a
            h = apply_norm(x[:, None], pl["ln2"], cfg)[:, 0]
            f, _ = _ffn(pl, h[:, None], cfg, _kind)
            return x + f[:, 0], (kc_l, vc_l)

        x, (kc, vc) = jax.lax.scan(body, x, (params[f"seg{i}"], kc, vc))
        new_cache[f"seg{i}"] = {"k": kc, "v": vc}
    x = apply_norm(x[:, None], params["final_norm"], cfg)[:, 0]
    logits = unembed(params, x, cfg)
    new_cache["pos"] = pos + 1
    new_cache["block_tables"] = table
    return logits, new_cache


def paged_cache_specs(cfg, batch: int, num_pages: int, page_size: int,
                      dtype=jnp.bfloat16, max_len: Optional[int] = None):
    """ShapeDtypeStructs for a paged KV cache.

    Per attention segment: k/v pools `(layers, num_pages, page_size, Hkv,
    hd)`. `block_tables` is `(batch, pages_per_seq)` where pages_per_seq =
    ceil(max_len / page_size); unassigned entries point at the reserved
    trash page 0. `pos` is the per-slot write cursor.
    """
    max_len = max_len if max_len is not None else num_pages * page_size
    pps = -(-max_len // page_size)
    out: Dict[str, Any] = {}
    for i, seg in enumerate(layer_plan(cfg)):
        shp = (seg.n, num_pages, page_size, cfg.num_kv_heads, cfg.head_dim)
        out[f"seg{i}"] = {"k": jax.ShapeDtypeStruct(shp, dtype),
                          "v": jax.ShapeDtypeStruct(shp, dtype)}
    out["block_tables"] = jax.ShapeDtypeStruct((batch, pps), jnp.int32)
    out["pos"] = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return out


def cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """ShapeDtypeStructs for the KV cache (dry-run decode inputs)."""
    out: Dict[str, Any] = {}
    for i, seg in enumerate(layer_plan(cfg)):
        S = min(cfg.sliding_window, max_len) if _is_windowed(seg.kind, cfg) else max_len
        shp = (seg.n, batch, S, cfg.num_kv_heads, cfg.head_dim)
        out[f"seg{i}"] = {"k": jax.ShapeDtypeStruct(shp, dtype),
                          "v": jax.ShapeDtypeStruct(shp, dtype)}
    out["pos"] = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return out


def cache_logical_axes(cfg, batch: int = 0, max_len: int = 0):
    """Logical axes matching cache_specs (same tree structure)."""
    out: Dict[str, Any] = {}
    for i, _seg in enumerate(layer_plan(cfg)):
        ax = ("layers", "kv_batch", "kv_seq", "kv_heads", None)
        out[f"seg{i}"] = {"k": ax, "v": ax}
    out["pos"] = ("kv_batch",)
    return out
