"""Placement search walkthrough (paper §4): run Algorithm 1 and Algorithm 2
for each application workload, print the chosen parallelism per phase and
the resulting per-chip goodput — the paper's Appendix B table analogue.

    PYTHONPATH=src python examples/placement_search.py [--apps chatbot-small]
"""
import argparse
import sys

sys.path.insert(0, "benchmarks")

from benchmarks.common import APPS, app_setup  # noqa: E402
from repro.core.placement import (algo1_high_affinity,  # noqa: E402
                                  algo2_low_affinity, vllm_pp_search)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--apps", default="chatbot-small,code")
    ap.add_argument("--n-requests", type=int, default=150)
    args = ap.parse_args()
    for app in args.apps.split(","):
        cfg, lm, spec, ref = app_setup(app)
        print(f"=== {app} ({cfg.name}), SLO ttft={spec.slo_ttft * 1e3:.0f}ms "
              f"tpot={spec.slo_tpot * 1e3:.1f}ms")
        p1 = algo1_high_affinity(lm, spec, rate=8.0, n_node=2, m_per_node=8,
                                 n_requests=args.n_requests)
        print("  Alg1 (high affinity):", p1.summary())
        p2 = algo2_low_affinity(lm, spec, rate=8.0, n_node=2, m_per_node=8,
                                n_requests=args.n_requests)
        print("  Alg2 (low affinity): ", p2.summary())
        par, g = vllm_pp_search(lm, spec, rate=8.0, n_node=2, m_per_node=8,
                                n_requests=args.n_requests)
        print(f"  vLLM++ best colocated: tp={par.tp} pp={par.pp} "
              f"goodput/chip={g:.2f}")


if __name__ == "__main__":
    main()
