"""Quickstart: build a model, run the DistServe placement search, and serve
a small batch of requests on the live disaggregated runtime (CPU).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config
from repro.core import hw
from repro.core.latency_model import LatencyModel
from repro.core.placement import algo2_low_affinity
from repro.core.workload import SHAREGPT, Request, derive_slos
from repro.models.api import build_model
from repro.serving.cluster import DisaggCluster


def main():
    # 1. Placement search on the production model (simulator-backed).
    cfg_prod = get_config("yi-6b")
    lm = LatencyModel(cfg_prod, hw.V5E)
    spec = derive_slos(SHAREGPT, lm)
    placement = algo2_low_affinity(lm, spec, rate=8.0, n_node=1,
                                   m_per_node=8, n_requests=120)
    print("placement chosen by Algorithm 2:", placement.summary())

    # 2. Live serving demo with the smoke-scale config on CPU, using the
    #    same prefill:decode instance split the search chose.
    cfg = get_config("yi-6b-smoke")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    cluster = DisaggCluster(cfg, params,
                            n_prefill=max(placement.n_prefill, 1),
                            n_decode=max(placement.n_decode, 1),
                            max_batch=4, max_len=96, lm_tokens=64)
    reqs = [Request(i, i * 0.02, 10 + (i % 5) * 4, 6) for i in range(8)]
    results = cluster.run(reqs)
    for rid, r in sorted(results.items()):
        print(f"req {rid}: ttft={r.ttft * 1e3:6.1f} ms  "
              f"tpot={r.tpot * 1e3:6.1f} ms  tokens={r.tokens[-6:]}")
    print(f"KV migrated: {cluster.tx.total_bytes / 1e6:.2f} MB "
          f"across {len(cluster.tx.times)} pulls")


if __name__ == "__main__":
    main()
