"""Train a ~100M-scale model for a few hundred steps on CPU with
checkpoint/restart (kill it mid-run and re-invoke: it resumes).

    PYTHONPATH=src python examples/train_small.py --steps 300
"""
import argparse

from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="gemma3-1b-smoke")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    args = ap.parse_args()
    losses = run(args.arch, args.steps, args.batch, args.seq,
                 args.ckpt_dir, ckpt_every=50, lr=1e-3, log_every=10)
    print(f"first-10 mean loss {sum(losses[:10]) / 10:.3f} -> "
          f"last-10 mean loss {sum(losses[-10:]) / 10:.3f}")


if __name__ == "__main__":
    main()
