"""End-to-end online serving driver for the request-lifecycle API:
stream tokens from a live DisaggCluster (`submit` -> iterate -> `cancel`),
track SLO attainment online with `SLOTracker`, compare against the
colocated baseline on the same trace, run a shared-prefix multi-turn
chat through the radix prefix cache, and drill a mid-run decode-instance
failure.

    PYTHONPATH=src python examples/serve_disaggregated.py [--arch yi-6b-smoke]
        [--trace out.json]   # Perfetto/Chrome trace + SLO attribution

With ``--trace``, the multi-turn prefix-cache scenario runs with the
request-lifecycle tracer on: the full span timeline (queue / chunked
prefill / streamed migration / decode lanes, flow arrows per request) is
written as Chrome-trace JSON loadable in Perfetto or chrome://tracing,
and the top-3 SLO-violating requests print their TTFT/TPOT attribution.
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.goodput import SLOTracker
from repro.core.telemetry import (MetricsRegistry, Tracer, save_chrome_trace)
from repro.core.workload import (Request, WorkloadSpec, sample_multi_turn,
                                 with_cancellations)
from repro.models.api import build_model
from repro.serving.api import SamplingParams
from repro.serving.cluster import ColocatedCluster, DisaggCluster

SPEC = WorkloadSpec("demo", 2.2, 0.4, (4, 24), 1.6, 0.3, (3, 8),
                    slo_ttft=2.0, slo_tpot=0.05)


def trace(n=12, rate=30.0, seed=0):
    rng = np.random.default_rng(seed)
    arrive = np.cumsum(rng.exponential(1.0 / rate, n))
    return [Request(i, float(arrive[i]), int(rng.integers(8, 40)),
                    int(rng.integers(4, 10))) for i in range(n)]


def chat_trace(cfg, n=8, seed=0):
    """Multi-turn sessions sharing a 16-token system prompt."""
    spec = WorkloadSpec("chat", 2.2, 0.4, (4, 24), 1.6, 0.3, (3, 8),
                        slo_ttft=1.0, slo_tpot=1.0,
                        sys_len=16, turns=2, share=0.8)
    return sample_multi_turn(spec, rate=2.0, n=n, seed=seed,
                             vocab=cfg.vocab_size, think_s=30.0)


def summarize(name, res):
    served = [r for r in res.values() if r.finish_reason != "cancelled"]
    if not served:
        print(f"{name:12s} served=0")
        return
    ttfts = sorted(r.ttft for r in served)
    tpots = sorted(r.tpot for r in served)
    p90 = lambda xs: xs[int(0.9 * (len(xs) - 1))]
    n_cancel = len(res) - len(served)
    print(f"{name:12s} served={len(served)}  cancelled={n_cancel}  "
          f"p50/p90 ttft={ttfts[len(ttfts) // 2] * 1e3:.0f}/"
          f"{p90(ttfts) * 1e3:.0f} ms  "
          f"p50/p90 tpot={tpots[len(tpots) // 2] * 1e3:.0f}/"
          f"{p90(tpots) * 1e3:.0f} ms")


def streaming_quickstart(cfg, params):
    """The serving-API loop: submit, stream token events, cancel."""
    tracker = SLOTracker(SPEC)
    dc = DisaggCluster(cfg, params, n_prefill=2, n_decode=1,
                       max_batch=4, max_len=96, lm_tokens=64,
                       tracker=tracker)
    # stream one request token by token (drives the virtual clock)
    h = dc.submit(Request(0, 0.0, 16, 8),
                  sampling=SamplingParams(max_tokens=8))
    print("streaming req 0:", end=" ", flush=True)
    for ev in h.tokens():
        print(f"{ev.token}@{ev.t * 1e3:.0f}ms", end=" ")
    print(f"-> {h.result().finish_reason}")

    # open-loop burst (rids continue past the streamed request);
    # abandon one request mid-flight
    burst = trace(10, seed=1)
    for r in burst:
        r.rid += 1
    handles = [dc.submit(r) for r in burst]
    victim = handles[4]
    dc.run_until(victim.state.request.arrive + 0.05)
    victim.cancel()
    res = dc.drain()
    summarize("disagg", res)
    assert victim.status.name == "CANCELLED"
    s = tracker.summary()
    print(f"  online SLO: attain={s['attain']:.2f} "
          f"(ttft {s['ttft_attain']:.2f} / tpot {s['tpot_attain']:.2f})  "
          f"finished={s['finished']:.0f} cancelled={s['cancelled']:.0f}  "
          f"worst itl={s['worst_itl'] * 1e3:.1f} ms")
    return res


def chunked_demo(cfg, params):
    """Chunked prefill: one long prompt no longer head-of-line-blocks the
    short ones, and each finished chunk's KV streams to decode while later
    chunks are still computing."""
    reqs = [Request(0, 0.0, 120, 4),            # long prompt, many chunks
            Request(1, 0.0, 18, 4), Request(2, 0.0, 40, 4)]

    def go(chunk):
        dc = DisaggCluster(cfg, params, n_prefill=1, n_decode=1,
                           max_batch=4, max_len=256, paged=True,
                           page_size=16, chunk_tokens=chunk, seed=0)
        return dc, dc.run([Request(r.rid, r.arrive, r.in_len, r.out_len)
                           for r in reqs])

    base, res0 = go(None)
    chnk, res1 = go(32)
    identical = all(res1[r].tokens == res0[r].tokens for r in res0)
    print(f"chunked      tokens_identical={identical}  "
          f"prefill steps {base.prefill[0].steps} -> {chnk.prefill[0].steps} "
          f"(long prompt chunk-interleaved with the short ones)")
    print(f"  streaming: streamed_pulls={chnk.tx.streamed_pulls}  "
          f"stream_saved_s={chnk.tx.stream_saved_s:.2e}  "
          f"(smoke model is weight-bound; the short-prompt TTFT gain shows "
          f"on real-scale models — benchmarks/chunked_prefill.py sim rows)")
    assert identical, "chunked prefill must be token-identical"


def fleet_demo(cfg, params):
    """Fleet router: two live replicas behind prefix-affinity routing with
    tight overload gates — a burst overflows the router queue, so some
    requests are shed (finish_reason "shed", no tokens) while the admitted
    ones stream normally; routing decisions and per-replica stats print."""
    from repro.serving.router import FleetRouter, OverloadDetector

    tracker = SLOTracker(SPEC)
    backends = [DisaggCluster(cfg, params, n_prefill=1, n_decode=1,
                              max_batch=2, max_len=96, lm_tokens=64,
                              prefix_cache=True, seed=i)
                for i in range(2)]
    router = FleetRouter(backends, policy="prefix_affinity",
                         detector=OverloadDetector(max_inflight=2,
                                                   max_queue=3),
                         tracker=tracker)
    burst = chat_trace(cfg, n=10, seed=3)
    for i, r in enumerate(burst):        # compress arrivals into a burst
        r.arrive = i * 0.002
    handles = [router.submit(r) for r in burst]
    router.drain()
    shed = [h for h in handles if h.result().finish_reason == "shed"]
    served = [h for h in handles if h.result().finish_reason != "shed"]
    assert all(not h.result().tokens for h in shed), "shed ran no work"
    routes = [d for d in router.decisions if d[0] == "route"]
    print(f"fleet        served={len(served)}  shed={len(shed)}  "
          f"routes={[(rid, rep) for _, rid, rep, _ in routes]}")
    for i, rep in enumerate(router.replicas):
        print(f"  replica{i}: routed={rep.routed} finished={rep.finished}")
    s = tracker.summary()
    print(f"  fleet SLO: attain={s['attain']:.2f}  "
          f"finished={s['finished']:.0f} shed={s['shed']:.0f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b-smoke")
    ap.add_argument("--trace", default=None, metavar="OUT_JSON",
                    help="write a Perfetto/Chrome trace of the multi-turn "
                         "scenario and print top-3 SLO violators with "
                         "latency attribution")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    # 1. streaming quickstart on the lifecycle API
    streaming_quickstart(cfg, params)

    # 2. colocated baseline on a fresh copy of the same kind of trace
    colo = ColocatedCluster(cfg, params, n_engines=3, max_batch=4, max_len=96)
    summarize("colocated", colo.run(trace()))

    # 3. shared-prefix multi-turn chat through the radix prefix cache,
    #    with a fraction of requests abandoned mid-flight (cancellation
    #    must not leak shared pages or pins)
    # short abandon delays: virtual service times are milliseconds at
    # smoke scale, so the cancels must land while requests are in flight
    ct = with_cancellations(chat_trace(cfg), frac=0.3, seed=5,
                            mean_wait_s=0.02)
    tracer = Tracer() if args.trace else None
    metrics = MetricsRegistry() if args.trace else None
    # deliberately tight SLOs so the attribution report has violations
    # to rank at smoke scale
    chat_slo = WorkloadSpec("chat-slo", 2.2, 0.4, (4, 24), 1.6, 0.3, (3, 8),
                            slo_ttft=5e-4, slo_tpot=5e-5)
    slo = SLOTracker(chat_slo, tracer=tracer) if args.trace else None
    pc = DisaggCluster(cfg, params, n_prefill=1, n_decode=1, max_batch=4,
                       max_len=128, lm_tokens=96, prefix_cache=True,
                       chunk_tokens=16, tracer=tracer, metrics=metrics,
                       tracker=slo)
    res = pc.run(ct)
    summarize("prefix-cache", res)
    if args.trace:
        save_chrome_trace(args.trace, tracer, metrics=metrics)
        print(f"  trace: {len(tracer.spans)} spans / "
              f"{len(tracer.instants)} instants across "
              f"{len(tracer.lanes())} lanes -> {args.trace}")
        print("  top SLO violators (ttft/tpot attribution):")
        for v in slo.top_violations(3):
            print("   ", v.format())
    hit = sum(r.prefix_hit for r in res.values())
    dhit = sum(r.decode_hit for r in res.values())
    prompt = sum(r.in_len for r in ct)
    stats = pc.prefix_stats()
    print(f"  prefix reuse: {hit}/{prompt} prompt tokens prefilled from "
          f"cache, {dhit} transfer tokens skipped")
    for side in ("prefill", "decode"):
        s = stats[side]
        print(f"  {side:7s} trees: hit_tokens={s.get('hit_tokens', 0):.0f} "
              f"shared_pages={s.get('matched_pages', 0):.0f} "
              f"inserted_pages={s.get('inserted_pages', 0):.0f} "
              f"evictions={s.get('evicted_pages', 0):.0f}")

    # 4. chunked prefill: HOL relief + per-chunk streaming migration
    chunked_demo(cfg, params)

    # 5. fleet router: two replicas, prefix-affinity routing, shed on burst
    fleet_demo(cfg, params)

    # 6. failover drill: kill decode instance 1 at t=0.1s
    t = trace()
    ft = DisaggCluster(cfg, params, n_prefill=1, n_decode=2,
                       max_batch=4, max_len=96, lm_tokens=64)
    res = ft.run([Request(r.rid, r.arrive, r.in_len, r.out_len) for r in t],
                 fail_decode_at=(0.1, 1))
    summarize("failover", res)
    assert len(res) == len(t), "failover must not lose requests"
    print("failover drill: all requests recovered after decode-instance loss")


if __name__ == "__main__":
    main()
