"""End-to-end serving driver: DistServe vs colocated on the SAME request
trace, a shared-prefix multi-turn run through the radix prefix cache, and
a mid-run decode-instance failure to exercise failover.

    PYTHONPATH=src python examples/serve_disaggregated.py [--arch yi-6b-smoke]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.workload import Request, WorkloadSpec, sample_multi_turn
from repro.models.api import build_model
from repro.serving.cluster import ColocatedCluster, DisaggCluster


def trace(n=12, rate=30.0, seed=0):
    rng = np.random.default_rng(seed)
    arrive = np.cumsum(rng.exponential(1.0 / rate, n))
    return [Request(i, float(arrive[i]), int(rng.integers(8, 40)),
                    int(rng.integers(4, 10))) for i in range(n)]


def chat_trace(cfg, n=8, seed=0):
    """Multi-turn sessions sharing a 16-token system prompt."""
    spec = WorkloadSpec("chat", 2.2, 0.4, (4, 24), 1.6, 0.3, (3, 8),
                        slo_ttft=1.0, slo_tpot=1.0,
                        sys_len=16, turns=2, share=0.8)
    return sample_multi_turn(spec, rate=2.0, n=n, seed=seed,
                             vocab=cfg.vocab_size, think_s=30.0)


def summarize(name, res):
    ttfts = sorted(r.ttft for r in res.values())
    tpots = sorted(r.tpot for r in res.values())
    p90 = lambda xs: xs[int(0.9 * (len(xs) - 1))]
    print(f"{name:12s} served={len(res)}  p50/p90 ttft="
          f"{ttfts[len(ttfts) // 2] * 1e3:.0f}/{p90(ttfts) * 1e3:.0f} ms  "
          f"p50/p90 tpot={tpots[len(tpots) // 2] * 1e3:.0f}/"
          f"{p90(tpots) * 1e3:.0f} ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b-smoke")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    t = trace()
    disagg = DisaggCluster(cfg, params, n_prefill=2, n_decode=1,
                           max_batch=4, max_len=96, lm_tokens=64)
    summarize("disagg", disagg.run([Request(r.rid, r.arrive, r.in_len,
                                            r.out_len) for r in t]))

    colo = ColocatedCluster(cfg, params, n_engines=3, max_batch=4, max_len=96)
    summarize("colocated", colo.run([Request(r.rid, r.arrive, r.in_len,
                                             r.out_len) for r in t]))

    # shared-prefix multi-turn chat through the radix prefix cache
    ct = chat_trace(cfg)
    pc = DisaggCluster(cfg, params, n_prefill=1, n_decode=1, max_batch=4,
                       max_len=128, lm_tokens=96, prefix_cache=True)
    res = pc.run(ct)
    summarize("prefix-cache", res)
    hit = sum(r.prefix_hit for r in res.values())
    dhit = sum(r.decode_hit for r in res.values())
    prompt = sum(r.in_len for r in ct)
    stats = pc.prefix_stats()
    print(f"  prefix reuse: {hit}/{prompt} prompt tokens prefilled from "
          f"cache, {dhit} transfer tokens skipped")
    for side in ("prefill", "decode"):
        s = stats[side]
        print(f"  {side:7s} trees: hit_tokens={s.get('hit_tokens', 0):.0f} "
              f"shared_pages={s.get('matched_pages', 0):.0f} "
              f"inserted_pages={s.get('inserted_pages', 0):.0f} "
              f"evictions={s.get('evicted_pages', 0):.0f}")

    # failover drill: kill decode instance 1 at t=0.1s
    ft = DisaggCluster(cfg, params, n_prefill=1, n_decode=2,
                       max_batch=4, max_len=96, lm_tokens=64)
    res = ft.run([Request(r.rid, r.arrive, r.in_len, r.out_len) for r in t],
                 fail_decode_at=(0.1, 1))
    summarize("failover", res)
    assert len(res) == len(t), "failover must not lose requests"
    print("failover drill: all requests recovered after decode-instance loss")


if __name__ == "__main__":
    main()
