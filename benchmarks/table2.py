"""Table 2: simulator accuracy against the REAL system.

The live JAX engine (yi-6b-smoke on CPU) is profiled to calibrate an
empirical latency model; the discrete-event simulator then predicts SLO
attainment for the same request trace, compared against the live
virtual-clock run of the actual cluster — for both vLLM-like and
DistServe-Low layouts (mirroring the paper's table)."""
from __future__ import annotations

import copy
from typing import List

import jax
import numpy as np

from repro.configs import get_config
from repro.core import hw
from repro.core.latency_model import LatencyModel, Parallelism
from repro.core.simulator import (InstanceConfig, simulate_colocated,
                                  simulate_disaggregated, summarize)
from repro.core.workload import Request, WorkloadSpec
from repro.models.api import build_model
from repro.serving.cluster import ColocatedCluster, DisaggCluster
from repro.serving.engine import Engine, Sequence

from .common import emit, timed


class EmpiricalLatencyModel(LatencyModel):
    """Latency model fit from live engine measurements (CPU chip)."""

    def fit(self, engine: Engine, lens=(16, 32, 64), bs=(1, 2, 4),
            reps: int = 5):
        import numpy as np
        xs, ys = [], []
        for L in lens:
            seq = Sequence(0, list(np.random.randint(1, 100, L)), 1)
            engine.prefill_request(seq)                  # compile
            dt = min(engine.prefill_request(seq)[2] for _ in range(reps))
            xs.append(L)
            ys.append(dt)
        A = np.stack([xs, np.ones(len(xs))], 1)
        coef, *_ = np.linalg.lstsq(A, np.array(ys), rcond=None)
        self._pre_a = float(max(coef[0], 1e-7))
        self._pre_b = float(max(coef[1], 0))
        # decode: measure at batch sizes (min over reps beats CPU jitter)
        dys = []
        for B in bs:
            seqs = []
            for i in range(B):
                s = Sequence(i, list(np.random.randint(1, 100, 8)), 10 ** 6)
                _, blob, _ = engine.prefill_request(s)
                engine.insert_kv(s, blob)
                seqs.append(s)
            engine.decode_step(seqs)                     # warm
            dt = min(engine.decode_step(seqs) for _ in range(reps))
            dys.append(dt)
            for s in seqs:
                engine.release(s)
        A = np.stack([bs, np.ones(len(bs))], 1)
        coef, *_ = np.linalg.lstsq(A, np.array(dys), rcond=None)
        self._dec_a = float(max(coef[0], 0.0))
        self._dec_b = float(max(coef[1], 1e-5))
        return self

    def prefill_time(self, lens, par):
        return self._pre_a * float(sum(lens)) + self._pre_b

    def decode_time(self, batch, ctx_tokens, par):
        return self._dec_a * float(batch) + self._dec_b

    def kv_transfer_time(self, prompt_len, bandwidth):
        return 1e-6


def _trace(n, rate, seed=0) -> List[Request]:
    rng = np.random.default_rng(seed)
    arrive = np.cumsum(rng.exponential(1.0 / rate, n))
    ins = rng.integers(8, 48, n)
    outs = rng.integers(4, 12, n)
    return [Request(i, float(arrive[i]), int(ins[i]), int(outs[i]))
            for i in range(n)]


def run(rates=(200.0, 400.0, 800.0), n: int = 60):
    cfg = get_config("yi-6b-smoke")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    probe = Engine(cfg, params, max_batch=4, max_len=96)
    elm, us = timed(EmpiricalLatencyModel(cfg, hw.V5E).fit, probe)
    spec = WorkloadSpec("table2", 0, 0, (8, 48), 0, 0, (4, 12),
                        slo_ttft=2.0 * elm.prefill_time([48], None),
                        slo_tpot=1.5 * elm.decode_time(4, 0, None))
    emit("table2.calibration", us,
         f"prefill_us_per_tok={elm._pre_a * 1e6:.0f};"
         f"decode_us_per_seq={elm._dec_a * 1e6:.0f};"
         f"slo_ttft={spec.slo_ttft * 1e3:.0f}ms;slo_tpot={spec.slo_tpot * 1e3:.1f}ms")

    for rate in rates:
        trace = _trace(n, rate)
        # --- real runs (virtual clock over measured step times); warm the
        # jit caches first so compile time doesn't pollute measured TTFT ---
        warm = [Request(10_000 + i, i * 0.001, 8 + 8 * i, 3) for i in range(5)]
        dc = DisaggCluster(cfg, params, n_prefill=1, n_decode=1, max_batch=4,
                           max_len=96, lm_tokens=64)
        dc.run(copy.deepcopy(warm))
        real_d = dc.run(copy.deepcopy(trace))
        cc = ColocatedCluster(cfg, params, n_engines=1, max_batch=4,
                              max_len=96)
        cc.run(copy.deepcopy(warm))
        real_c = cc.run(copy.deepcopy(trace))

        def attain(res):
            ok = sum(1 for r in res.values()
                     if r.ttft <= spec.slo_ttft and r.tpot <= spec.slo_tpot)
            return ok / max(len(res), 1)

        # --- simulator predictions on the same trace ---
        sim_d, _ = simulate_disaggregated(
            copy.deepcopy(trace), elm,
            InstanceConfig(Parallelism(1, 1), 1),
            InstanceConfig(Parallelism(1, 1), 1),
            transfer_bw=1e15, lm_tokens=64, max_decode_batch=4)
        sim_c, _ = simulate_colocated(
            copy.deepcopy(trace), elm,
            InstanceConfig(Parallelism(1, 1), 1),
            max_batch=4, max_prefill_tokens=64)
        a_sim_d = summarize(sim_d, spec, warmup_frac=0.0).attain
        a_sim_c = summarize(sim_c, spec, warmup_frac=0.0).attain
        a_real_d, a_real_c = attain(real_d), attain(real_c)
        emit(f"table2.rate{rate}", 0.0,
             f"vllm_real={a_real_c:.2f};vllm_sim={a_sim_c:.2f};"
             f"dist_real={a_real_d:.2f};dist_sim={a_sim_d:.2f};"
             f"err_vllm={abs(a_real_c - a_sim_c):.3f};"
             f"err_dist={abs(a_real_d - a_sim_d):.3f}")
