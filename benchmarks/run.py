"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see each module for what the
derived field packs). ``--quick`` trims sweeps for CI-ish runs.

Every run also snapshots the headline numbers (roofline + paged_kv +
prefix_cache + serving_api rows) into ``BENCH_<pr>.json`` so re-anchors
can diff speed trends across PRs; ``--bench-out`` overrides the path.

Schema v2 additionally stamps provenance: the git sha the snapshot was
taken at and per-benchmark wall-times (``wall_s``), so a trajectory diff
can say exactly which commit produced which numbers. v1 snapshots (older
PRs) are still accepted by ``check_bench``.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

BENCH_SCHEMA = 2
PR = 10
HEADLINE = ("roofline", "paged_kv", "prefix_cache", "serving_api", "chunked",
            "router", "agg_disagg")


def git_sha() -> str:
    """Current commit sha (short), or 'unknown' outside a git checkout."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            check=True).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def calibrate(reps: int = 5) -> float:
    """Fixed reference workload (us, best-of-N): numpy GEMM + python loop.

    Snapshots are written by different sessions on differently-loaded
    machines; raw wall-clock rows are not comparable across them. The
    calibration row measures the machine itself, so `check_bench` can
    scale one snapshot's rows to the other's machine before diffing.
    """
    import numpy as np
    a = np.random.default_rng(0).standard_normal((384, 384))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        (a @ a).sum()
        acc = 0
        for i in range(200_000):
            acc += i & 7
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _parse_derived(derived: str):
    out = {}
    for kv in derived.split(";"):
        if "=" not in kv:
            continue
        k, v = kv.split("=", 1)
        try:
            out[k] = float(v.rstrip("x"))
        except ValueError:
            out[k] = v
    return out


def best_rows(rows):
    """Collapse duplicate row names to the fastest sample (--best-of)."""
    best = {}
    for row in rows:
        name, us, _ = row.split(",", 2)
        if name not in best or float(us) < float(best[name].split(",", 2)[1]):
            best[name] = row
    return list(best.values())


def bench_snapshot(rows, quick: bool, wall_s=None):
    """Fold the emitted CSV rows into the BENCH_<pr>.json schema."""
    data = {"schema": BENCH_SCHEMA, "pr": PR, "quick": quick,
            "git_sha": git_sha(), "wall_s": dict(wall_s or {}),
            "calib_us": calibrate(), "headline": {k: {} for k in HEADLINE}}
    for row in rows:
        name, us, derived = row.split(",", 2)
        sect = name.split(".")[0]
        if sect in data["headline"]:
            data["headline"][sect][name] = {
                "us_per_call": float(us), **_parse_derived(derived)}
    return data


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,fig2,fig4,fig8,fig9,fig11,fig12,"
                         "table2,roofline,paged_kv,prefix_cache,serving_api,"
                         "chunked,router,agg_disagg")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--best-of", type=int, default=1,
                    help="run the job list N times and snapshot each row's "
                         "fastest sample; single samples on a shared box "
                         "jitter past the trajectory gate's tolerance")
    ap.add_argument("--bench-out", default=f"BENCH_{PR}.json")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from . import (agg_disagg, chunked_prefill, fig1, fig2, fig4, fig8,
                   fig11, fig12, paged_kv, prefix_cache, roofline, router,
                   serving_api, table2)
    from .common import emit

    n_req = 150 if args.quick else 250
    jobs = []
    if not only or "fig1" in only:
        jobs.append(("fig1", lambda: fig1.run("chatbot-small")))
    if not only or "fig2" in only:
        jobs.append(("fig2", lambda: fig2.run("chatbot-small")))
    if not only or "fig4" in only:
        jobs.append(("fig4", lambda: fig4.run("chatbot-large")))
    if not only or "fig8" in only:
        jobs.append(("fig8.chatbot-small",
                     lambda: fig8.run("chatbot-small", n_requests=n_req)))
        if not args.quick:
            jobs.append(("fig8.chatbot-large",
                         lambda: fig8.run("chatbot-large", n_requests=n_req)))
            jobs.append(("fig8.moe",
                         lambda: fig8.run("moe-chatbot", n_requests=n_req)))
    if not only or "fig9" in only:
        jobs.append(("fig9.code",
                     lambda: fig8.run("code", n_requests=n_req)))
        jobs.append(("fig9.summarization",
                     lambda: fig8.run("summarization", n_requests=n_req)))
    if not only or "fig11" in only:
        jobs.append(("fig11", lambda: fig11.run("chatbot-small",
                                                n_requests=n_req)))
    if not only or "fig12" in only:
        jobs.append(("fig12", lambda: fig12.run()))
    if not only or "table2" in only:
        jobs.append(("table2", lambda: table2.run()))
    if not only or "paged_kv" in only:
        jobs.append(("paged_kv", lambda: paged_kv.run()))
    if not only or "prefix_cache" in only:
        jobs.append(("prefix_cache",
                     lambda: prefix_cache.run(quick=args.quick)))
    if not only or "serving_api" in only:
        jobs.append(("serving_api",
                     lambda: serving_api.run(quick=args.quick)))
    if not only or "chunked" in only:
        jobs.append(("chunked",
                     lambda: chunked_prefill.run(quick=args.quick)))
    if not only or "router" in only:
        jobs.append(("router", lambda: router.run(quick=args.quick)))
    if not only or "agg_disagg" in only:
        jobs.append(("agg_disagg",
                     lambda: agg_disagg.run(quick=args.quick)))
    if not only or "roofline" in only:
        jobs.append(("roofline", roofline.run))

    t_all = time.time()
    failures = 0
    wall_s = {}
    for _rep in range(max(1, args.best_of)):
        for name, job in jobs:
            t0 = time.time()
            try:
                job()
                emit(f"{name}.done", (time.time() - t0) * 1e6, "ok")
            except Exception:  # noqa: BLE001
                failures += 1
                traceback.print_exc()
                emit(f"{name}.done", (time.time() - t0) * 1e6, "FAILED")
            dt = round(time.time() - t0, 3)
            wall_s[name] = min(wall_s.get(name, dt), dt)
    wall_s["total"] = round(time.time() - t_all, 3)
    emit("benchmarks.total", (time.time() - t_all) * 1e6,
         f"jobs={len(jobs)};failures={failures}")
    from .common import ROWS
    with open(args.bench_out, "w") as f:
        json.dump(bench_snapshot(best_rows(ROWS), args.quick, wall_s), f,
                  indent=1)
        f.write("\n")
    print(f"wrote {args.bench_out}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == '__main__':
    main()
