"""Fig. 1: P90 TTFT / TPOT vs per-chip rate — colocated full serving vs a
prefill-only system vs a decode-only system (the paper's motivating gap)."""
from __future__ import annotations

from repro.core.goodput import attainment_at_rate, max_goodput
from repro.core.latency_model import Parallelism
from repro.core.simulator import (InstanceConfig, simulate_colocated,
                                  simulate_disaggregated)

from .common import app_setup, emit, timed


def run(app: str = "chatbot-small", points=(0.5, 1, 2, 4, 8, 16)):
    cfg, lm, spec, ref = app_setup(app)
    par = Parallelism(ref, 1)

    def colo(reqs):
        return simulate_colocated(reqs, lm, InstanceConfig(par, 1))

    def prefill_only(reqs):
        return simulate_disaggregated(reqs, lm, InstanceConfig(par, 1),
                                      InstanceConfig(par, 1), phase="prefill")

    def decode_only(reqs):
        return simulate_disaggregated(reqs, lm, InstanceConfig(par, 1),
                                      InstanceConfig(par, 1), phase="decode")

    for rate in points:
        total = rate * ref
        (rc, us) = timed(attainment_at_rate, colo, spec, total, 400)
        rp, _ = timed(attainment_at_rate, prefill_only, spec, total, 400)
        rd, _ = timed(attainment_at_rate, decode_only, spec, total, 400)
        emit(f"fig1.{app}.rate{rate}", us,
             f"colo_p90ttft={rc.p90_ttft:.3f};colo_p90tpot={rc.p90_tpot:.4f};"
             f"prefill_p90ttft={rp.p90_ttft:.3f};"
             f"decode_p90tpot={rd.p90_tpot:.4f}")

    # headline: per-chip goodput of each mode (paper: 1.6 vs 5.6 & 10 rps)
    g_colo, us = timed(max_goodput, colo, spec, ref, n_requests=300)
    g_pre, _ = timed(max_goodput, prefill_only, spec, ref, n_requests=300)
    g_dec, _ = timed(max_goodput, decode_only, spec, ref, n_requests=300)
    emit(f"fig1.{app}.goodput", us,
         f"colo={g_colo.per_chip:.2f};prefill_only={g_pre.per_chip:.2f};"
         f"decode_only={g_dec.per_chip:.2f};"
         f"split_gain={(g_pre.per_chip + g_dec.per_chip) / max(2 * g_colo.per_chip, 1e-9):.2f}")
