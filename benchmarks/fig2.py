"""Fig. 2: prefill-decode interference — execution time of one batch as
decode batch size grows, with and without one piggybacked prefill."""
from __future__ import annotations

from repro.core.latency_model import Parallelism

from .common import app_setup, emit, timed


def run(app: str = "chatbot-small",
        batch_sizes=(1, 4, 16, 32, 64, 128),
        prefill_lens=(128, 512, 1024)):
    cfg, lm, spec, ref = app_setup(app)
    par = Parallelism(ref, 1)
    ctx = 512
    for B in batch_sizes:
        t_dec, us = timed(lm.decode_time, B, B * ctx, par)
        row = [f"decode_only={t_dec * 1e3:.2f}ms"]
        for L in prefill_lens:
            # colocated iteration = prefill of L plus the decode batch's
            # bandwidth demand (paper Fig. 2: batch with one prefill req)
            t_mix = lm.prefill_time([L], par) + t_dec
            row.append(f"with_prefill{L}={t_mix * 1e3:.2f}ms"
                       f"(x{t_mix / t_dec:.1f})")
        emit(f"fig2.{app}.B{B}", us, ";".join(row))
