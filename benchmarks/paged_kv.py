"""Paged-KV runtime microbenchmarks (live engines, smoke-size on CPU).

Measures what the paged refactor is for:
  * insert cost: block-table splice into the page pool vs the dense
    full-slab merge, per prompt length (the splice should stay flat-ish;
    the slab merge rewrites max_batch x max_len every insert).
  * burst backpressure: a page-starved decode instance must park finished
    prefills on the prefill side and drain them as pages free.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_config
from repro.core.workload import Request
from repro.models.api import build_model
from repro.serving.cluster import DisaggCluster
from repro.serving.engine import Engine, Sequence

from .common import emit, timed


def _insert_cost(eng: Engine, in_len: int, reps: int = 5) -> float:
    rng = np.random.default_rng(0)
    times = []
    for rep in range(reps):
        s = Sequence(rep, rng.integers(1, eng.cfg.vocab_size,
                                       in_len).tolist(), 8)
        first, blob, _ = eng.prefill_request(s)
        s.tokens.append(first)
        s.produced += 1
        def ins():
            eng.insert_kv(s, blob)
            jax.block_until_ready(eng._cache)   # count device work, not
                                                # just async dispatch
        _, us = timed(ins)
        times.append(us)
        eng.release(s)
    return float(np.median(times))


def run(arch: str = "yi-6b-smoke", in_lens=(12, 28, 60)):
    cfg = get_config(arch)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    paged = Engine(cfg, params, max_batch=8, max_len=128, page_size=16)
    dense = Engine(cfg, params, max_batch=8, max_len=128, paged=False)
    for L in in_lens:
        us_p = _insert_cost(paged, L)
        us_d = _insert_cost(dense, L)
        emit(f"paged_kv.insert.L{L}", us_p,
             f"dense_us={us_d:.1f};pages={paged._kv.pages_for(L)};"
             f"speedup={us_d / max(us_p, 1e-9):.2f}x")

    # burst backpressure on a starved pool (4 pages/seq, 4 resident)
    reqs = [Request(i, i * 0.001, 10, 5) for i in range(8)]
    dc = DisaggCluster(cfg, params, n_prefill=1, n_decode=1, max_batch=8,
                       max_len=64, lm_tokens=48, page_size=4,
                       decode_num_pages=17)
    (_, us) = timed(dc.run, reqs)
    emit("paged_kv.backpressure", us,
         f"parked_peak_bytes={dc.tx.peak_parked_bytes};"
         f"peak_pages={dc.decode[0]._kv.peak_used_pages};"
         f"chunks={dc.tx.total_chunks}")
