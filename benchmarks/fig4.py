"""Fig. 4 + Eq. 1-3: average TTFT under 2-way intra-op vs inter-op
parallelism for the prefill phase — simulator vs the M/D/1 closed forms."""
from __future__ import annotations

import numpy as np

from repro.core.latency_model import Parallelism
from repro.core.simulator import InstanceConfig, simulate_disaggregated
from repro.core.workload import Request

from .common import app_setup, emit, timed


def _uniform(rate, n, L, seed=0):
    rng = np.random.default_rng(seed)
    arrive = np.cumsum(rng.exponential(1.0 / rate, n))
    return [Request(i, float(arrive[i]), L, 1) for i in range(n)]


def run(app: str = "chatbot-large", L: int = 512,
        utils=(0.2, 0.4, 0.6, 0.8)):
    cfg, lm, spec, ref = app_setup(app)
    base = Parallelism(max(ref // 2, 1), 1)     # "one GPU" analogue
    intra = Parallelism(base.tp * 2, 1)
    inter = Parallelism(base.tp, 2)

    D = lm.prefill_time([L], base)
    Ds_intra = lm.prefill_time([L], intra)
    K = D / Ds_intra                             # speedup coefficient

    for util in utils:
        rate = util / D

        def sim(par):
            reqs = _uniform(rate, 2500, L)
            reqs, _ = simulate_disaggregated(
                reqs, lm, InstanceConfig(par, 1), InstanceConfig(par, 1),
                lm_tokens=L, phase="prefill")
            return float(np.mean([r.ttft for r in reqs]))

        (t_intra, us) = timed(sim, intra)
        t_inter = sim(inter)
        R = rate
        eq2 = D + R * D * D / (4 * (2 - R * D))                   # inter-op
        eq3 = D / K + R * D * D / (2 * K * (K - R * D)) if K > R * D else float("inf")
        emit(f"fig4.{app}.util{util}", us,
             f"K={K:.2f};sim_intra={t_intra * 1e3:.1f}ms;eq3={eq3 * 1e3:.1f}ms;"
             f"sim_inter={t_inter * 1e3:.1f}ms;eq2={eq2 * 1e3:.1f}ms;"
             f"winner={'intra' if t_intra < t_inter else 'inter'}")
