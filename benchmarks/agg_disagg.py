"""Aggregation-vs-disaggregation benchmark: what dynamic re-roling buys.

A bursty trace interleaves a prefill-bound phase (long prompts, short
answers, compressed arrivals) with a decode-bound phase (short prompts,
long generations) — and lands a second prefill burst *while* those
generations are still streaming. That overlap is the regime the paper
is about: a static colocated fleet (all instances mixed) pays
prefill/decode interference on every engine exactly when the TPOT SLO
has no slack, and a static disaggregated split must commit to one
prefill:decode ratio for both regimes. The dynamic mode starts from a
balanced disaggregated split and lets `RoleController` re-role
instances at runtime from the overload signal (prefill queue depth vs
decode KV pressure), spilling only bounded absorption chunks onto the
decode tier — so decode iterations stay clean while the burst drains.

SLOs are anchored on the model x chip via `derive_slos`: TTFT gets 4x
headroom over the anchored target (bursts queue), TPOT keeps the
anchored loaded-iteration target (stringent, per the paper) — so any
sustained interference on a decode engine breaches its requests.

Rows report per-mode SLO attainment plus TTFT/TPOT p99 on the same
trace (mean over seeds in full mode); the dynamic row also carries its
flip/absorb counts and the attainment margin over the best static mode
(positive = re-roling beat every static placement).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core import hw
from repro.core.goodput import SLOTracker
from repro.core.latency_model import LatencyModel, Parallelism
from repro.core.replan import RoleController
from repro.core.simulator import SimServingBackend
from repro.core.workload import Request, WorkloadSpec, derive_slos
from repro.serving.api import percentile

from .common import emit, get_config, timed

PAR = Parallelism(1, 1)
N_PER_PHASE = 60        # fixed: the arrival *rate* is the calibrated
                        # saturation point; scaling n would change it
TTFT_HEADROOM = 4.0

# two-regime mixture the SLOs are anchored on: prompts from the burst
# phases dominate the TTFT tail, outputs from the decode phase the TPOT
BURSTY = WorkloadSpec("bursty", 6.0, 0.5, (32, 1024), 4.0, 0.6, (4, 384),
                      slo_ttft=0.4, slo_tpot=0.1)


def _phase(rng, rid0: int, t0: float, span: float, n: int,
           in_mu: float, in_clip: Tuple[int, int],
           out_mu: float, out_clip: Tuple[int, int]) -> List[Request]:
    arrive = t0 + np.sort(rng.uniform(0.0, span, size=n))
    in_lens = np.clip(rng.lognormal(in_mu, 0.4, n).astype(int), *in_clip)
    out_lens = np.clip(rng.lognormal(out_mu, 0.4, n).astype(int), *out_clip)
    return [Request(rid0 + i, float(arrive[i]), int(in_lens[i]),
                    int(out_lens[i])) for i in range(n)]


def bursty_trace(n_per_phase: int, seed: int = 0) -> List[Request]:
    """prefill burst -> decode-heavy phase -> second prefill burst that
    lands while the decode phase's generations are still streaming (the
    overlap is what makes mode choice matter)."""
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    # long prompts, terse answers, compressed arrival window
    reqs += _phase(rng, 0, 0.0, 2.5, n_per_phase,
                   6.5, (256, 1024), 2.0, (4, 12))
    # short prompts, long generations
    reqs += _phase(rng, n_per_phase, 6.0, 3.0, n_per_phase,
                   4.0, (32, 128), 5.4, (192, 320))
    reqs += _phase(rng, 2 * n_per_phase, 10.5, 2.5, n_per_phase,
                   6.5, (256, 1024), 2.0, (4, 12))
    return reqs


def _serve(lm, spec, reqs, roles, *, controller: bool = False, **kw):
    reqs = [dataclasses.replace(r) for r in reqs]
    tracker = SLOTracker(spec)
    be = SimServingBackend(lm, [(r, PAR) for r in roles],
                           tracker=tracker, lm_tokens=2048,
                           max_decode_batch=32, chunk_tokens=256,
                           num_decode_pages=256, **kw)
    ctrl = RoleController(be, prefill_high=1024.0, prefill_low=128.0,
                          kv_high=0.8, kv_low=0.5,
                          cooldown_s=2.0) if controller else None

    def go():
        for r in reqs:
            be.submit(r)
        if ctrl is not None:
            horizon = max(r.arrive for r in reqs) + 12.0
            t = 0.0
            while t < horizon:
                t += 0.5
                be.run_until(t)
                ctrl.tick(t)
        be.drain()

    _, us = timed(go)
    served = [r for r in reqs if r.finish_reason == "length"]
    rep = tracker.report()
    return dict(attain=rep.attain,
                ttft_p99=percentile(sorted(r.ttft for r in served), 0.99),
                tpot_p99=percentile(sorted(r.tpot for r in served), 0.99),
                flips=len(ctrl.flips) if ctrl else 0,
                absorbed=int(be.extras().get("absorbed", 0)),
                us=us)


def _mean(runs, key):
    return sum(r[key] for r in runs) / len(runs)


def run(arch: str = "yi-6b", quick: bool = False):
    cfg = get_config(arch)
    lm = LatencyModel(cfg, hw.V5E)
    spec = derive_slos(BURSTY, lm)
    spec = dataclasses.replace(spec, slo_ttft=spec.slo_ttft * TTFT_HEADROOM)
    seeds = (0,) if quick else (0, 1, 2)
    traces = [bursty_trace(N_PER_PHASE, seed=s) for s in seeds]

    def sweep(roles, **kw):
        return [_serve(lm, spec, reqs, roles, **kw) for reqs in traces]

    best_static = -1.0
    # ---- static disaggregated splits ---------------------------------
    for n_p in (1, 2, 3):
        roles = ["prefill"] * n_p + ["decode"] * (4 - n_p)
        runs = sweep(roles)
        attain = _mean(runs, "attain")
        best_static = max(best_static, attain)
        emit(f"agg_disagg.disagg_{n_p}p{4 - n_p}d",
             _mean(runs, "us") / len(traces[0]),
             f"attain={attain:.3f};"
             f"ttft_p99_ms={_mean(runs, 'ttft_p99') * 1e3:.1f};"
             f"tpot_p99_ms={_mean(runs, 'tpot_p99') * 1e3:.2f}")

    # ---- static colocated (all instances mixed) ----------------------
    runs = sweep(["mixed"] * 4)
    attain = _mean(runs, "attain")
    best_static = max(best_static, attain)
    emit("agg_disagg.colocated", _mean(runs, "us") / len(traces[0]),
         f"attain={attain:.3f};"
         f"ttft_p99_ms={_mean(runs, 'ttft_p99') * 1e3:.1f};"
         f"tpot_p99_ms={_mean(runs, 'tpot_p99') * 1e3:.2f}")

    # ---- dynamic: balanced start + runtime re-roling + absorption ----
    runs = sweep(["prefill", "prefill", "decode", "decode"],
                 controller=True, absorb_tokens=4096)
    attain = _mean(runs, "attain")
    emit("agg_disagg.dynamic", _mean(runs, "us") / len(traces[0]),
         f"attain={attain:.3f};"
         f"ttft_p99_ms={_mean(runs, 'ttft_p99') * 1e3:.1f};"
         f"tpot_p99_ms={_mean(runs, 'tpot_p99') * 1e3:.2f};"
         f"flips={_mean(runs, 'flips'):.1f};"
         f"absorbed={_mean(runs, 'absorbed'):.1f};"
         f"margin={attain - best_static:+.3f}")
