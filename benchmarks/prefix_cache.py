"""Prefix-cache benchmarks: what shared-prefix KV reuse buys.

Two sweeps over the prefix-sharing factor:
  * live (smoke-size engines, CPU): multi-turn / shared-system-prompt
    trace through `DisaggCluster` with the radix cache on vs off —
    reports token-weighted hit rate, prefill compute saved (tokens
    through the kernel, which is what the suffix-only prefill skips),
    prefill->decode transfer bytes saved, and TTFT p50/p99.
  * simulator (paper-size model on the analytical latency model): the
    same trace shape at scale — prefill busy-seconds and wire bytes with
    the cache modeled vs not, which is what the placement search sees.

At high hit rates both prefill compute and transfer bytes should drop
roughly in proportion to the sharing factor.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.configs import get_config
from repro.core import hw
from repro.core.latency_model import LatencyModel, Parallelism
from repro.core.simulator import (InstanceConfig, _percentile,
                                  simulate_disaggregated)
from repro.core.workload import SHAREGPT, WorkloadSpec, sample_multi_turn
from repro.models.api import build_model
from repro.serving.cluster import DisaggCluster

from .common import emit, timed


def _live_trace(cfg, share: float, n: int, seed: int = 0):
    spec = WorkloadSpec("bench", 2.2, 0.4, (4, 24), 1.6, 0.3, (3, 8),
                        slo_ttft=1.0, slo_tpot=1.0,
                        sys_len=16, turns=2, share=share)
    return sample_multi_turn(spec, rate=2.0, n=n, seed=seed,
                             vocab=cfg.vocab_size, think_s=30.0)


def _clone(reqs):
    return [dataclasses.replace(r) for r in reqs]


def run(arch: str = "yi-6b-smoke", shares=(0.0, 0.5, 0.9),
        quick: bool = False):
    cfg = get_config(arch)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    n = 6 if quick else 10
    shares = shares[:2] if quick else shares

    for share in shares:
        reqs = _live_trace(cfg, share, n)
        runs = {}
        for on in (False, True):
            # best-of-2: single samples of the CPU live path jitter well
            # past the trajectory gate's tolerance (GC, jit warmup)
            best = None
            for _ in range(2):
                dc = DisaggCluster(cfg, params, n_prefill=1, n_decode=1,
                                   max_batch=8, max_len=128, lm_tokens=96,
                                   prefix_cache=on)
                res, us = timed(dc.run, _clone(reqs))
                best = us if best is None else min(best, us)
            runs[on] = (dc, res, best)
        dc_on, res_on, us_on = runs[True]
        dc_off, res_off, _ = runs[False]
        # reuse must not change the tokens served
        assert all(res_on[r].tokens == res_off[r].tokens for r in res_on)
        pre_on = sum(e.prefill_tokens for e in dc_on.prefill)
        pre_off = sum(e.prefill_tokens for e in dc_off.prefill)
        hit = sum(e.prefix_hit_tokens for e in dc_on.prefill)
        ttfts = [r.ttft for r in res_on.values()]
        emit(f"prefix_cache.live.share{share}", us_on,
             f"hit_rate={hit / max(hit + pre_on, 1):.3f};"
             f"prefill_tok_saved={1 - pre_on / max(pre_off, 1):.3f};"
             f"tx_bytes_saved={1 - dc_on.tx.total_bytes / max(dc_off.tx.total_bytes, 1):.3f};"
             f"ttft_p50_ms={_percentile(ttfts, 0.5) * 1e3:.1f};"
             f"ttft_p99_ms={_percentile(ttfts, 0.99) * 1e3:.1f}")

    # ---- simulator sweep (paper-size model, analytical latencies) -----
    big = get_config("yi-6b")
    lm = LatencyModel(big, hw.V5E)
    n_sim = 40 if quick else 120
    spec = dataclasses.replace(SHAREGPT, in_clip=(4, 1024), sys_len=256,
                               turns=3)
    for share in shares:
        sspec = dataclasses.replace(spec, share=share)
        reqs = sample_multi_turn(sspec, rate=2.0, n=n_sim, seed=1)
        out = {}
        us = 0.0
        for on in (False, True):
            # best-of-3: the pure-Python sim is fast enough that a single
            # sample is mostly scheduler/GC noise
            best = None
            for _ in range(3):
                (rr, extras), dt = timed(
                    simulate_disaggregated,
                    _clone(reqs), lm, InstanceConfig(Parallelism(1, 1), 2),
                    InstanceConfig(Parallelism(1, 1), 1), prefix_cache=on)
                best = dt if best is None else min(best, dt)
            out[on] = (rr, extras)
            us += best
        _, ex_on = out[True]
        _, ex_off = out[False]
        pfx = ex_on["prefix"]
        emit(f"prefix_cache.sim.share{share}", us,
             f"hit_rate={pfx['hit_tokens'] / max(pfx['prompt_tokens'], 1):.3f};"
             f"prefill_busy_saved={1 - ex_on['breakdown']['prefill_busy_s'] / max(ex_off['breakdown']['prefill_busy_s'], 1e-12):.3f};"
             f"tx_bytes_saved={1 - ex_on['kv_bytes'] / max(ex_off['kv_bytes'], 1):.3f}")
