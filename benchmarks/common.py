"""Shared benchmark plumbing.

Model mapping (paper -> assigned archs on TPU v5e):
  OPT-13B chatbot      -> yi-6b        (same serving class on 16 GB chips)
  OPT-66B code/summar. -> phi3-medium-14b
  OPT-175B chatbot     -> internvl2-76b (largest assigned dense backbone)
plus mixtral-8x22b for the beyond-paper MoE serving row.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

from repro.configs import get_config
from repro.core import hw
from repro.core.latency_model import LatencyModel, Parallelism
from repro.core.workload import (HUMANEVAL, LONGBENCH, SHAREGPT, WorkloadSpec,
                                 derive_slos, reference_tp)

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


APPS = {
    "chatbot-small": ("yi-6b", SHAREGPT),
    "chatbot-large": ("internvl2-76b", SHAREGPT),
    "code": ("phi3-medium-14b", HUMANEVAL),
    "summarization": ("phi3-medium-14b", LONGBENCH),
    "moe-chatbot": ("mixtral-8x22b", SHAREGPT),
}


def app_setup(app: str):
    arch, base_spec = APPS[app]
    cfg = get_config(arch)
    lm = LatencyModel(cfg, hw.V5E)
    spec = derive_slos(base_spec, lm)
    ref = reference_tp(lm)
    return cfg, lm, spec, ref
