"""Fig. 11 ablation: vLLM, vLLM++ (parallelism-searched colocated),
DistServe-Low (Alg. 2) and DistServe-High (Alg. 1) on the chatbot app."""
from __future__ import annotations

from repro.core.goodput import max_goodput
from repro.core.latency_model import Parallelism
from repro.core.placement import (algo1_high_affinity, algo2_low_affinity,
                                  ratio_counts, vllm_pp_search)
from repro.core.simulator import (InstanceConfig, simulate_colocated,
                                  simulate_disaggregated)

from .common import app_setup, emit, timed


def run(app: str = "chatbot-small", n_requests: int = 250):
    cfg, lm, spec, ref = app_setup(app)

    # vLLM (reference parallelism, per the paper's per-model fixed setting)
    def vllm(reqs):
        return simulate_colocated(reqs, lm,
                                  InstanceConfig(Parallelism(ref, 1), 1))
    g_vllm, us = timed(max_goodput, vllm, spec, ref, n_requests=n_requests)
    emit(f"fig11.{app}.vllm", us, f"goodput_per_chip={g_vllm.per_chip:.2f}")

    # vLLM++ — search colocated parallelism
    (par_pp, g_pp), us = timed(vllm_pp_search, lm, spec, rate=8.0,
                               n_node=2, m_per_node=8,
                               n_requests=n_requests)
    emit(f"fig11.{app}.vllm_pp", us,
         f"goodput_per_chip={g_pp:.2f};tp={par_pp.tp};pp={par_pp.pp}")

    # DistServe-Low (Alg. 2) — final_slo=False: the timing compares
    # *search* cost against vllm_pp, which pays no closing-validation sim
    pl_low, us = timed(algo2_low_affinity, lm, spec, rate=8.0, n_node=2,
                       m_per_node=8, n_requests=n_requests,
                       final_slo=False)
    emit(f"fig11.{app}.dist_low", us,
         f"goodput_per_chip={pl_low.prefill.goodput_per_chip:.2f};"
         f"ptp={pl_low.prefill.par.tp};dtp={pl_low.decode.par.tp}")

    # DistServe-High (Alg. 1)
    pl_high, us = timed(algo1_high_affinity, lm, spec, rate=8.0, n_node=2,
                        m_per_node=8, n_requests=n_requests,
                        final_slo=False)
    # joint goodput at the Alg.-1 replication ratio
    n, m = ratio_counts(pl_high.prefill.goodput_per_chip,
                        pl_high.decode.goodput_per_chip,
                        pl_high.prefill.par.num_chips,
                        pl_high.decode.par.num_chips)

    def dist_high(reqs):
        return simulate_disaggregated(
            reqs, lm, InstanceConfig(pl_high.prefill.par, n),
            InstanceConfig(pl_high.decode.par, m),
            transfer_bw=pl_high.kv_bandwidth)
    chips = (n * pl_high.prefill.par.num_chips
             + m * pl_high.decode.par.num_chips)
    g_high, _ = timed(max_goodput, dist_high, spec, chips,
                      n_requests=n_requests)
    emit(f"fig11.{app}.dist_high", us,
         f"goodput_per_chip={g_high.per_chip:.2f};"
         f"ptp={pl_high.prefill.par.tp};ppp={pl_high.prefill.par.pp};"
         f"dtp={pl_high.decode.par.tp};dpp={pl_high.decode.par.pp}")
    emit(f"fig11.{app}.summary", 0.0,
         f"vllm={g_vllm.per_chip:.2f};vllm_pp={g_pp:.2f};"
         f"low={pl_low.prefill.goodput_per_chip:.2f};high={g_high.per_chip:.2f}")
