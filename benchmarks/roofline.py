"""Roofline analysis from the dry-run artifacts (§Roofline deliverable).

Per (arch x shape x mesh) cell:
  compute term    = HLO_dot_FLOPs / (chips x 197e12)
  memory term     = structural bytes / (chips x 819e9)
  collective term = per-chip wire bytes: ICI / (links x 50e9) + DCN / 6.25e9
FLOPs/bytes come from the trip-count-corrected jaxpr walk (global, divided
by chip count); collective bytes from the trip-weighted HLO parse (already
per-chip). MODEL_FLOPS = 6*N*D (train) / 2*N_active*D (inference) for the
usefulness ratio.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config, get_shape
from repro.core import hw
from repro.core.latency_model import LatencyModel

CHIP = hw.V5E


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    lm = LatencyModel(cfg)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        # fwd+bwd = 3x matmul flops (2N per token fwd) + attention
        gemm = 3 * lm.gemm_flops_per_token() * tokens
        attn = 3 * lm.attn_flops([S] * B)
        return gemm + attn
    if shape.kind == "prefill":
        tokens = B * S
        return lm.gemm_flops_per_token() * tokens + lm.attn_flops([S] * B)
    # decode: one token per sequence + attention over the cache
    gemm = lm.gemm_flops_per_token() * B
    if cfg.family != "ssm":
        n_attn = cfg.num_layers
        if cfg.family == "hybrid":
            n_attn = cfg.num_layers // max(cfg.hybrid_attn_every, 1)
        if cfg.local_global_ratio:
            r = cfg.local_global_ratio
            n_glob = cfg.num_layers // (r + 1)
            n_loc = cfg.num_layers - n_glob
            gemm += 4 * cfg.q_dim * B * (n_loc * min(S, cfg.sliding_window)
                                         + n_glob * S)
        else:
            eff = min(S, cfg.sliding_window) if cfg.sliding_window else S
            gemm += 4 * cfg.q_dim * eff * B * n_attn
    return gemm


def roofline_row(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    chips = rec.get("n_devices", 512 if rec["multi_pod"] else 256)
    jc = rec["cost_corrected"]
    coll = rec["collectives_corrected"]
    t_compute = jc["dot_flops"] / chips / CHIP.peak_flops_bf16
    t_memory = (jc["struct_bytes"] / chips) / CHIP.hbm_bw
    ici_bw = CHIP.ici_bw * CHIP.ici_links
    t_coll = coll["ici_bytes"] / ici_bw + coll["dcn_bytes"] / CHIP.dcn_bw
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "pod2" if rec["multi_pod"] else "pod1",
        "mode": rec["mode"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "step_s_bound": max(terms.values()),
        "model_flops": mf,
        "hlo_flops": jc["dot_flops"],
        "useful_ratio": mf / max(jc["dot_flops"], 1.0),
        "roofline_frac": (t_compute / max(terms.values())
                          if max(terms.values()) > 0 else 0.0),
        "peak_gb": (rec["memory"]["peak_bytes"] or 0) / 1e9,
        "arg_gb": (rec["memory"]["argument_bytes"] or 0) / 1e9,
    }


def analyze(path: str = "experiments/dryrun_all.json",
            out: str = "experiments/roofline.json") -> List[Dict]:
    recs = json.load(open(path))
    rows = [r for r in (roofline_row(rec) for rec in recs) if r]
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def render_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute(s) | memory(s) | collective(s) |"
           " dominant | useful | roofline |\n|---|---|---|---|---|---|---|---|---|\n")
    body = []
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        body.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
            f"| {r['t_collective_s']:.2e} | **{r['dominant']}** "
            f"| {min(r['useful_ratio'], 9.99):.2f} "
            f"| {r['roofline_frac']:.2f} |")
    return hdr + "\n".join(body)


def prefix_overlap_row(arch: str = "yi-6b", prefix_len: int = 1024,
                       suffix_len: int = 256, bw: float = 25e9) -> Dict:
    """Analytic "Raw speed" cell: what the fused prefix-prefill kernel and
    per-layer streaming admission buy, in structural HBM bytes and wire
    seconds (deterministic — no dry-run artifact needed).

    Dense-gather fallback traffic on the prefix KV term is 3x the fused
    kernel's: the gather reads the pool pages, writes the dense
    (L, P, Hkv, hd) blob, and flash attention reads that blob back; the
    fused kernel's block-table-indexed loads touch the pool pages once.
    Per-layer streaming shrinks the exposed transfer stall from the full
    blob wire time to one layer-slice of it (decode admits at
    first-layer-landed; the rest overlaps per-layer compute).
    """
    cfg = get_config(arch)
    lm = LatencyModel(cfg, CHIP)
    kvb = cfg.kv_bytes_per_token(2)
    pre, suf = prefix_len * kvb, suffix_len * kvb
    dense, fused = 3 * pre + suf, pre + suf
    n = prefix_len + suffix_len
    t_full = lm.kv_transfer_time(n, bw)
    t_first = lm.kv_transfer_first_layer_time(n, bw)
    return {
        "arch": arch, "prefix_len": prefix_len, "suffix_len": suffix_len,
        "prefix_hbm_bytes_dense": float(dense),
        "prefix_hbm_bytes_fused": float(fused),
        "fused_speedup": dense / fused,
        "transfer_bw": bw,
        "stall_serial_s": t_full,
        "stall_streamed_s": t_first,
        "stall_reduction": t_full / max(t_first, 1e-30),
    }


def chunked_prefill_row(arch: str = "yi-6b", long_len: int = 2000,
                        short_len: int = 64, chunk: int = 128,
                        bw: float = 25e9) -> Dict:
    """Analytic chunked-prefill cell (deterministic, no artifact needed).

    HOL term: a short prompt queued behind a long one waits the full long
    prefill one-shot, but only one chunk under chunk-granular
    round-robin. Streaming term: per-chunk parking overlaps every chunk
    except the last with prefill compute, so the exposed wire shrinks
    from the whole prompt's KV to the last chunk's segment (further /L by
    per-layer admission)."""
    from repro.core.latency_model import Parallelism
    cfg = get_config(arch)
    lm = LatencyModel(cfg, CHIP)
    par = Parallelism(1, 1)
    t_long = lm.prefill_time([long_len], par)
    t_chunk = lm.prefill_chunk_time([(chunk, 0)], par)
    t_short = lm.prefill_time([short_len], par)
    ttft_serial = t_long + t_short
    ttft_chunked = t_chunk + t_short
    t_full = lm.kv_transfer_time(long_len, bw)
    last = long_len % chunk or chunk
    w_last = lm.kv_transfer_time(long_len, bw) \
        - lm.kv_transfer_time(long_len - last, bw)
    L = max(cfg.num_layers, 1)
    exposed = w_last / L
    return {
        "arch": arch, "long_len": long_len, "short_len": short_len,
        "chunk": chunk,
        "ttft_short_serial_s": ttft_serial,
        "ttft_short_chunked_s": ttft_chunked,
        "hol_gain": ttft_serial / max(ttft_chunked, 1e-30),
        "stall_serial_s": t_full,
        "stall_chunked_s": exposed,
        "stall_reduction": t_full / max(exposed, 1e-30),
    }


def run():
    from .common import emit
    r = prefix_overlap_row()
    emit(f"roofline.prefix_fused.{r['arch']}", 0.0,
         f"prefix={r['prefix_len']};suffix={r['suffix_len']};"
         f"dense_bytes={r['prefix_hbm_bytes_dense']:.3e};"
         f"fused_bytes={r['prefix_hbm_bytes_fused']:.3e};"
         f"speedup={r['fused_speedup']:.2f}")
    emit(f"roofline.layer_overlap.{r['arch']}", 0.0,
         f"serial_s={r['stall_serial_s']:.4e};"
         f"streamed_s={r['stall_streamed_s']:.4e};"
         f"reduction={r['stall_reduction']:.2f}")
    c = chunked_prefill_row()
    emit(f"roofline.chunked_hol.{c['arch']}", 0.0,
         f"long={c['long_len']};short={c['short_len']};chunk={c['chunk']};"
         f"ttft_serial_s={c['ttft_short_serial_s']:.4e};"
         f"ttft_chunked_s={c['ttft_short_chunked_s']:.4e};"
         f"speedup={c['hol_gain']:.2f}")
    emit(f"roofline.chunked_stream.{c['arch']}", 0.0,
         f"serial_s={c['stall_serial_s']:.4e};"
         f"chunked_s={c['stall_chunked_s']:.4e};"
         f"reduction={c['stall_reduction']:.2f}")
    if not os.path.exists("experiments/dryrun_all.json"):
        emit("roofline.skip", 0.0, "no dryrun artifact")
        return
    rows = analyze()
    for r in rows:
        if r["mesh"] == "pod1":
            emit(f"roofline.{r['arch']}.{r['shape']}", 0.0,
                 f"compute={r['t_compute_s']:.2e};memory={r['t_memory_s']:.2e};"
                 f"collective={r['t_collective_s']:.2e};dom={r['dominant']};"
                 f"useful={r['useful_ratio']:.2f};frac={r['roofline_frac']:.2f}")


if __name__ == "__main__":
    rows = analyze()
    print(render_markdown(rows))
