"""Fleet-router benchmarks: what routing policy buys at fleet scale.

Policy sweep on a skewed-prefix fleet trace (multi-turn sessions, most of
them opening with one shared system prompt) through a 4-replica simulated
disaggregated fleet: per policy, the token-weighted prefix hit rate the
replicas' radix trees actually served (the router's trie only *predicts*
locality — the replicas measure it), the shed rate, TTFT p99 and SLO
attainment. Prefix affinity should concentrate sessions and beat
shortest-queue on hit rate; shortest-queue should win on load spread.

The second section pins the overload story: the same fleet pushed past
capacity with shedding on (TTFT-headroom deadline in the router queue)
vs off — admitted requests keep materially higher SLO attainment when
the router sheds the requests that could no longer meet their deadline.
"""
from __future__ import annotations

import dataclasses

from repro.core import hw
from repro.core.goodput import SLOTracker
from repro.core.latency_model import LatencyModel, Parallelism
from repro.core.simulator import InstanceConfig, SimDisaggBackend
from repro.core.workload import WorkloadSpec, sample_multi_turn
from repro.serving.api import percentile
from repro.serving.router import FleetRouter, OverloadDetector

from .common import emit, get_config, timed

PAR = Parallelism(1, 1)
POLICY_SWEEP = ("prefix_affinity", "session", "shortest_queue",
                "least_loaded")


def _spec(slo_ttft: float = 0.6, slo_tpot: float = 0.1) -> WorkloadSpec:
    # skewed-prefix chat fleet: 4-turn sessions, 90% opening with the one
    # shared system prompt, prompts long enough that locality matters
    return WorkloadSpec("fleet-chat", 4.6, 0.5, (32, 768), 3.4, 0.5, (8, 64),
                        slo_ttft=slo_ttft, slo_tpot=slo_tpot,
                        sys_len=256, turns=4, share=0.9)


def _trace(spec, rate: float, n: int, vocab: int, seed: int = 7):
    return sample_multi_turn(spec, rate=rate, n=n, seed=seed, vocab=vocab,
                             think_s=2.0)


def _fleet(lm, n_replicas: int):
    return [SimDisaggBackend(lm, InstanceConfig(PAR, 1),
                             InstanceConfig(PAR, 1), lm_tokens=2048,
                             max_decode_batch=32, prefix_cache=True)
            for _ in range(n_replicas)]


def _run(lm, spec, reqs, policy: str, detector: OverloadDetector,
         n_replicas: int = 4):
    reqs = [dataclasses.replace(r) for r in reqs]
    tracker = SLOTracker(spec)
    router = FleetRouter(_fleet(lm, n_replicas), policy=policy,
                         detector=detector, tracker=tracker)
    def go():
        for r in reqs:
            router.submit(r)
        router.drain()
    _, us = timed(go)
    return router, tracker, reqs, us


def run(arch: str = "yi-6b", quick: bool = False):
    cfg = get_config(arch)
    lm = LatencyModel(cfg, hw.V5E)
    spec = _spec()
    n = 240 if quick else 600

    # ---- policy sweep: loaded but under capacity ----------------------
    rate = 40.0
    reqs0 = _trace(spec, rate, n, cfg.vocab_size)
    det = OverloadDetector(max_inflight=24)
    for policy in POLICY_SWEEP:
        router, tracker, reqs, us = _run(lm, spec, reqs0, policy, det)
        rep = tracker.report()
        served = [r for r in reqs if r.finish_reason == "length"]
        hit = sum(r.prefix_hit for r in served)
        toks = sum(r.in_len for r in served)
        ttfts = sorted(r.ttft for r in served)
        emit(f"router.{policy}", us / max(len(reqs), 1),
             f"hit_rate={hit / max(toks, 1):.3f};"
             f"shed_rate={router.shed_count / len(reqs):.3f};"
             f"ttft_p99_ms={percentile(ttfts, 0.99) * 1e3:.1f};"
             f"attain={rep.attain:.3f}")

    # ---- overload: shed-vs-noshed attainment of admitted requests -----
    rate_hot = 160.0
    reqs1 = _trace(spec, rate_hot, n, cfg.vocab_size, seed=11)
    det_shed = OverloadDetector.from_slo(spec.slo_ttft, headroom=0.5,
                                         max_inflight=8)
    det_none = OverloadDetector(max_inflight=8)
    r_shed, t_shed, _, us = _run(lm, spec, reqs1, "shortest_queue", det_shed,
                                 n_replicas=2)
    r_none, t_none, _, _ = _run(lm, spec, reqs1, "shortest_queue", det_none,
                                n_replicas=2)
    rs, rn = t_shed.report(), t_none.report()
    emit("router.shed_slo", us / max(n, 1),
         f"attain_shed={rs.attain:.3f};attain_noshed={rn.attain:.3f};"
         f"shed_rate={r_shed.shed_count / len(reqs1):.3f};"
         f"shed={r_shed.shed_count}")
