"""Fig. 12: placement-algorithm running time vs #chips per instance."""
from __future__ import annotations

import time

from repro.core.placement import algo1_high_affinity, algo2_low_affinity

from .common import app_setup, emit


def run(app: str = "chatbot-small", node_counts=(1, 2, 4),
        n_requests: int = 120):
    cfg, lm, spec, ref = app_setup(app)
    for n in node_counts:
        t0 = time.perf_counter()
        # final_slo=False: this figure measures *search* time only
        algo1_high_affinity(lm, spec, rate=8.0, n_node=n, m_per_node=8,
                            n_requests=n_requests, final_slo=False)
        t_high = time.perf_counter() - t0
        t0 = time.perf_counter()
        algo2_low_affinity(lm, spec, rate=8.0, n_node=n, m_per_node=8,
                           n_requests=n_requests, final_slo=False)
        t_low = time.perf_counter() - t0
        emit(f"fig12.{app}.chips{n * 8}", (t_high + t_low) * 1e6,
             f"alg1_s={t_high:.2f};alg2_s={t_low:.2f}")
