"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

  PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import json
from typing import Dict, List

from .roofline import roofline_row

GiB = 1e9


def dryrun_table(path: str) -> str:
    recs = json.load(open(path))
    hdr = ("| arch | shape | mesh | mode | compile(s) | peak GB/chip | "
           "args GB/chip | status |\n|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"], x["multi_pod"])):
        mesh = "2x16x16" if r["multi_pod"] else "16x16"
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | - | - | - | - "
                        f"| {r['status']} ({r.get('reason', '')[:40]}…) |")
            continue
        mem = r["memory"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | {r['mode']} "
            f"| {r['compile_s']} | {(mem['peak_bytes'] or 0) / GiB:.1f} "
            f"| {(mem['argument_bytes'] or 0) / GiB:.1f} | ok |")
    return hdr + "\n".join(rows)


def roofline_table(path: str, mesh: str = "pod1") -> str:
    recs = json.load(open(path))
    rows = [roofline_row(r) for r in recs]
    rows = [r for r in rows if r and r["mesh"] == mesh]
    hdr = ("| arch | shape | compute(s) | memory(s) | collective(s) | "
           "dominant | step bound(s) | MODEL/HLO | peak GB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = []
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} "
            f"| {r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} "
            f"| **{r['dominant']}** | {r['step_s_bound']:.2e} "
            f"| {min(r['useful_ratio'], 9.99):.2f} | {r['peak_gb']:.1f} |")
    return hdr + "\n".join(out)


def compare_table(base_path: str, opt_path: str) -> str:
    """Baseline vs optimized dominant-term comparison (pod1)."""
    def load(p):
        return {(r["arch"], r["shape"]): roofline_row(r)
                for r in json.load(open(p))
                if r.get("status") == "ok" and not r["multi_pod"]}
    b, o = load(base_path), load(opt_path)
    hdr = ("| arch | shape | baseline bound(s) | optimized bound(s) | "
           "speedup | baseline dom | optimized dom |\n"
           "|---|---|---|---|---|---|---|\n")
    rows = []
    for k in sorted(b):
        if k not in o:
            continue
        rb, ro = b[k], o[k]
        sp = rb["step_s_bound"] / max(ro["step_s_bound"], 1e-30)
        rows.append(f"| {k[0]} | {k[1]} | {rb['step_s_bound']:.2e} "
                    f"| {ro['step_s_bound']:.2e} | {sp:.2f}x "
                    f"| {rb['dominant']} | {ro['dominant']} |")
    return hdr + "\n".join(rows)


if __name__ == "__main__":
    print("## Dry-run\n")
    print(dryrun_table("experiments/dryrun_all.json"))
    print("\n## Roofline (single pod)\n")
    print(roofline_table("experiments/dryrun_all.json"))
    print("\n## Baseline vs optimized\n")
    print(compare_table("experiments/dryrun_baseline.json",
                        "experiments/dryrun_all.json"))
