"""Fig. 8/9 engine: SLO attainment vs per-chip rate and vs SLO scale —
DistServe (placement-searched) against vLLM (reference parallelism) for a
given application. Reports the 90%-attainment crossings and the ratios the
paper headlines (up to 4.48x rate, 10.2x tighter SLO)."""
from __future__ import annotations

from typing import Optional

from repro.core.goodput import attainment_at_rate, max_goodput, min_slo_scale
from repro.core.latency_model import Parallelism
from repro.core.placement import (_phase_goodput, algo1_high_affinity,
                                  algo2_low_affinity, ratio_counts,
                                  vllm_pp_search)
from repro.core.simulator import (InstanceConfig, simulate_colocated,
                                  simulate_disaggregated)

from .common import app_setup, emit, timed

# per-app rate grids (req/s per chip) — summarization prompts are ~20x
# longer, so its sustainable rates are ~20x lower (paper Fig. 9b).
APP_RATES = {
    "summarization": (0.05, 0.1, 0.2, 0.3, 0.5),
    "moe-chatbot": (0.25, 0.5, 1, 2, 4),
    "chatbot-large": (0.25, 0.5, 1, 2, 4),
}
DEFAULT_RATES = (0.5, 1, 2, 4, 8)


def build_systems(app: str, n_node: int = 2, m_per_node: int = 8,
                  n_requests: int = 250):
    cfg, lm, spec, ref = app_setup(app)
    # DistServe placement: Alg. 2 (testbed default) for models that fit a
    # prefill+decode pair per node; Alg. 1 (high affinity) for 70B+ models
    # whose decode needs the full node width (the paper's Dist-High case).
    big = lm.param_bytes() > 0.5 * m_per_node * lm.chip.hbm_bytes
    search = algo1_high_affinity if big else algo2_low_affinity
    pl = search(lm, spec, rate=8.0, n_node=n_node,
                m_per_node=m_per_node, n_requests=n_requests,
                final_slo=False)    # only the config is consumed here
    p_par, d_par = pl.prefill.par, pl.decode.par
    gp = _phase_goodput(lm, p_par, spec, "prefill", target=0.9,
                        n_requests=min(n_requests, 150),
                        transfer_bw=pl.kv_bandwidth)
    gd = _phase_goodput(lm, d_par, spec, "decode", target=0.9,
                        n_requests=min(n_requests, 150),
                        transfer_bw=pl.kv_bandwidth)
    n, m = ratio_counts(gp, gd, p_par.num_chips, d_par.num_chips)
    pair = n * p_par.num_chips + m * d_par.num_chips

    def dist(reqs):
        return simulate_disaggregated(
            reqs, lm, InstanceConfig(p_par, n), InstanceConfig(d_par, m),
            transfer_bw=pl.kv_bandwidth)

    # vLLM baseline: intra-op capped at the node (tp<=8), PP for capacity
    vtp = min(ref, m_per_node)
    vpp = max(-(-ref // vtp), 1)
    vllm_par = Parallelism(vtp, vpp)
    n_engines = max(round(pair / vllm_par.num_chips), 1)

    def vllm(reqs):
        return simulate_colocated(reqs, lm,
                                  InstanceConfig(vllm_par, n_engines))

    chips_v = vllm_par.num_chips * n_engines
    pl.n_prefill, pl.n_decode = n, m
    return cfg, lm, spec, dist, pair, vllm, chips_v, pl


def run(app: str = "chatbot-small", rates=None,
        slo_scales=(0.25, 0.5, 1.0, 2.0), n_requests: int = 250):
    rates = rates or APP_RATES.get(app, DEFAULT_RATES)
    # 70B/140B-class models cannot host a prefill+decode pair inside one
    # 8-chip node (the paper's OPT-175B situation) — give Alg. 2 more
    # inter-op stages to split across (paper §4.2).
    n_node = {"chatbot-large": 4, "moe-chatbot": 6}.get(app, 2)
    (cfg, lm, spec, dist, chips_d, vllm, chips_v, pl), us0 = timed(
        build_systems, app, n_node, 8, n_requests)
    emit(f"fig8.{app}.placement", us0,
         f"prefill_tp={pl.prefill.par.tp};prefill_pp={pl.prefill.par.pp};"
         f"x{pl.n_prefill};decode_tp={pl.decode.par.tp};"
         f"decode_pp={pl.decode.par.pp};x{pl.n_decode}")

    # row 1: attainment vs per-chip rate
    for r in rates:
        a_d, us = timed(attainment_at_rate, dist, spec, r * chips_d,
                        n_requests)
        a_v, _ = timed(attainment_at_rate, vllm, spec, r * chips_v,
                       n_requests)
        emit(f"fig8.{app}.rate{r}", us,
             f"dist_attain={a_d.attain:.3f};dist_ttft={a_d.ttft_attain:.3f};"
             f"dist_tpot={a_d.tpot_attain:.3f};vllm_attain={a_v.attain:.3f};"
             f"vllm_ttft={a_v.ttft_attain:.3f};vllm_tpot={a_v.tpot_attain:.3f}")

    # headline goodput ratio
    g_d, us = timed(max_goodput, dist, spec, chips_d, n_requests=n_requests)
    g_v, _ = timed(max_goodput, vllm, spec, chips_v, n_requests=n_requests)
    ratio = g_d.per_chip / max(g_v.per_chip, 1e-9)
    emit(f"fig8.{app}.goodput", us,
         f"dist={g_d.per_chip:.2f}rps_per_chip;vllm={g_v.per_chip:.2f};"
         f"ratio={ratio:.2f}x")

    # row 2: attainment vs SLO scale at a fixed mid rate
    mid_rate = max(g_v.per_chip, 0.2)
    for s in slo_scales:
        a_d, us = timed(attainment_at_rate, dist, spec, mid_rate * chips_d,
                        n_requests, 0, s)
        a_v, _ = timed(attainment_at_rate, vllm, spec, mid_rate * chips_v,
                       n_requests, 0, s)
        emit(f"fig8.{app}.sloscale{s}", us,
             f"dist_attain={a_d.attain:.3f};vllm_attain={a_v.attain:.3f}")
    s_d, us = timed(min_slo_scale, dist, spec, mid_rate * chips_d,
                    n_requests=n_requests)
    s_v, _ = timed(min_slo_scale, vllm, spec, mid_rate * chips_v,
                   n_requests=n_requests)
    emit(f"fig8.{app}.minslo", us,
         f"dist={s_d:.2f};vllm={s_v:.2f};"
         f"tighter={s_v / max(s_d, 1e-9):.2f}x")
    return ratio
