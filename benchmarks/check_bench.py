"""Schema check for BENCH_<pr>.json perf-trajectory snapshots.

Usage: python -m benchmarks.check_bench BENCH_*.json

Validates every file against the schema `benchmarks.run.bench_snapshot`
writes: top-level keys, a known schema version, and non-empty headline
sections with numeric `us_per_call` rows — so re-anchors can trust the
trajectory files enough to diff them across PRs.
"""
from __future__ import annotations

import json
import sys

from .run import BENCH_SCHEMA, HEADLINE

REQUIRED_TOP = ("schema", "pr", "quick", "headline")


def check(path: str) -> list:
    errs = []
    try:
        data = json.load(open(path))
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e})"]
    for k in REQUIRED_TOP:
        if k not in data:
            errs.append(f"{path}: missing top-level key '{k}'")
    if errs:
        return errs
    if data["schema"] != BENCH_SCHEMA:
        errs.append(f"{path}: schema {data['schema']} != {BENCH_SCHEMA}")
    if not isinstance(data["pr"], int) or data["pr"] < 1:
        errs.append(f"{path}: bad pr number {data['pr']!r}")
    for sect in HEADLINE:
        rows = data["headline"].get(sect)
        if not rows:
            errs.append(f"{path}: headline section '{sect}' empty/missing")
            continue
        for name, row in rows.items():
            if not isinstance(row.get("us_per_call"), (int, float)):
                errs.append(f"{path}: {name} lacks numeric us_per_call")
    return errs


def main(paths) -> int:
    if not paths:
        print("usage: python -m benchmarks.check_bench BENCH_*.json")
        return 2
    errs = [e for p in paths for e in check(p)]
    for e in errs:
        print(e)
    if not errs:
        print(f"{len(paths)} bench snapshot(s) ok")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
