"""Schema + regression check for BENCH_<pr>.json perf-trajectory snapshots.

Usage: python -m benchmarks.check_bench BENCH_*.json [--tol 0.10]

Validates every file against the schema `benchmarks.run.bench_snapshot`
writes: top-level keys, a known schema version, and non-empty headline
sections with numeric `us_per_call` rows. Headline sections introduced
by later PRs may be absent from older snapshots (a snapshot is checked
against the section set of its own era, i.e. only pr >= current must
carry them all).

When given more than one file, the snapshots are sorted by PR number and
consecutive pairs are diffed: a shared headline row whose `us_per_call`
grows by more than the tolerance (default 10%), or whose
`speedup`/`reduction` derived metric shrinks by more than it, fails the
check.

Snapshots are written by different sessions on different machines, so
raw wall-clock is not comparable across them: us_per_call rows are
compared only when both snapshots carry a `calib_us` machine-speed
calibration (`benchmarks.run.calibrate`), scaled by the calibration
ratio. Derived gain metrics are checked for `roofline.*` rows always
(they are analytic, machine-independent) and for other rows only when
the prior row's wall-clock is >= 1 ms (sub-ms ratios are timer noise).
Wall-clock `.done` totals are exempt, and snapshots with mismatched
`quick` flags are not diffed (different workload sizes).
"""
from __future__ import annotations

import json
import sys

from .run import BENCH_SCHEMA, HEADLINE, PR

ACCEPTED_SCHEMAS = (1, BENCH_SCHEMA)    # v1: pre-provenance snapshots
REQUIRED_TOP = ("schema", "pr", "quick", "headline")
REQUIRED_V2 = ("git_sha", "wall_s")     # provenance stamps (schema 2)
GAIN_KEYS = ("speedup", "reduction")    # derived metrics: higher is better
MIN_US = 1000.0                         # ignore sub-ms rows (timer noise)
DEFAULT_TOL = 0.10


def check(path: str) -> list:
    errs = []
    try:
        data = json.load(open(path))
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e})"]
    for k in REQUIRED_TOP:
        if k not in data:
            errs.append(f"{path}: missing top-level key '{k}'")
    if errs:
        return errs
    if data["schema"] not in ACCEPTED_SCHEMAS:
        errs.append(f"{path}: schema {data['schema']} not in "
                    f"{ACCEPTED_SCHEMAS}")
    if not isinstance(data["pr"], int) or data["pr"] < 1:
        errs.append(f"{path}: bad pr number {data['pr']!r}")
        return errs
    if data["schema"] >= 2:
        for k in REQUIRED_V2:
            if k not in data:
                errs.append(f"{path}: schema 2 snapshot missing '{k}'")
        sha = data.get("git_sha")
        if sha is not None and not (isinstance(sha, str) and sha):
            errs.append(f"{path}: bad git_sha {sha!r}")
        ws = data.get("wall_s")
        if ws is not None and not (isinstance(ws, dict) and all(
                isinstance(v, (int, float)) for v in ws.values())):
            errs.append(f"{path}: wall_s must map benchmark -> seconds")
    elif data["pr"] >= PR:
        errs.append(f"{path}: PR {data['pr']} snapshots must use "
                    f"schema {BENCH_SCHEMA} (provenance stamps)")
    calib = data.get("calib_us")
    if data["pr"] >= PR and not (isinstance(calib, (int, float))
                                 and calib > 0):
        errs.append(f"{path}: missing machine calibration 'calib_us'")
    for sect in HEADLINE:
        rows = data["headline"].get(sect)
        if not rows:
            # sections added by later PRs are allowed to be absent from
            # older snapshots; the current PR must carry them all
            if data["pr"] >= PR or sect in data["headline"]:
                errs.append(f"{path}: headline section '{sect}' "
                            f"empty/missing")
            continue
        for name, row in rows.items():
            if not isinstance(row.get("us_per_call"), (int, float)):
                errs.append(f"{path}: {name} lacks numeric us_per_call")
    return errs


def diff(prev, cur, tol: float = DEFAULT_TOL) -> list:
    """Regressions of `cur` relative to `prev` on shared headline rows."""
    errs = []
    tag = f"PR{prev['pr']} -> PR{cur['pr']}"
    if cur.get("git_sha") and cur["git_sha"] != "unknown":
        tag += f" @{cur['git_sha']}"
    if prev.get("quick") != cur.get("quick"):
        return errs          # different workload sizes: nothing comparable
    c0, c1 = prev.get("calib_us"), cur.get("calib_us")
    # wall-clock rows are only comparable when both snapshots recorded the
    # machine-speed calibration; scale prev's rows onto cur's machine
    scale = (c1 / c0 if isinstance(c0, (int, float)) and c0 > 0
             and isinstance(c1, (int, float)) and c1 > 0 else None)
    for sect, rows in cur["headline"].items():
        prows = prev["headline"].get(sect) or {}
        for name, row in rows.items():
            p = prows.get(name)
            if p is None or name.endswith(".done"):
                continue
            us0, us1 = p.get("us_per_call"), row.get("us_per_call")
            us_ok = (isinstance(us0, (int, float))
                     and isinstance(us1, (int, float)))
            # flag only when the raw AND machine-adjusted wall-clock both
            # regressed: the calibration itself is a noisy measurement on
            # a shared machine, and a ratio-only comparison turns rows
            # whose raw time *improved* into false alarms
            if (scale is not None and us_ok and us0 >= MIN_US
                    and us1 > us0 * (1 + tol)
                    and us1 > us0 * scale * (1 + tol)):
                errs.append(f"{tag}: {name} us_per_call regressed "
                            f"{us0:.1f} -> {us1:.1f} "
                            f"(+{us1 / us0 - 1:.0%} raw, "
                            f"+{us1 / (us0 * scale) - 1:.0%} "
                            f"machine-adjusted)")
            # analytic roofline ratios are machine-independent; measured
            # ratios need a >= 1 ms base or they are timer noise
            gate_gains = (name.startswith("roofline.")
                          or (us_ok and us0 >= MIN_US))
            if not gate_gains:
                continue
            for k in GAIN_KEYS:
                g0, g1 = p.get(k), row.get(k)
                if (isinstance(g0, (int, float))
                        and isinstance(g1, (int, float))
                        and g1 < g0 * (1 - tol)):
                    errs.append(f"{tag}: {name} {k} regressed "
                                f"{g0:.2f} -> {g1:.2f} "
                                f"({g1 / g0 - 1:.0%})")
    return errs


def main(argv) -> int:
    tol = DEFAULT_TOL
    paths = []
    it = iter(argv)
    for a in it:
        if a == "--tol":
            tol = float(next(it, DEFAULT_TOL))
        else:
            paths.append(a)
    if not paths:
        print("usage: python -m benchmarks.check_bench BENCH_*.json "
              "[--tol 0.10]")
        return 2
    errs = [e for p in paths for e in check(p)]
    if not errs and len(paths) > 1:
        snaps = sorted((json.load(open(p)) for p in paths),
                       key=lambda d: d["pr"])
        for prev, cur in zip(snaps, snaps[1:]):
            errs.extend(diff(prev, cur, tol))
    for e in errs:
        print(e)
    if not errs:
        what = f"{len(paths)} bench snapshot(s) ok"
        if len(paths) > 1:
            what += f" (trajectory diff within {tol:.0%})"
        print(what)
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
