"""Chunked prefill under mixed long/short traffic (§HOL fix).

One-shot prefill head-of-line-blocks short prompts behind long ones;
chunk-granular round-robin bounds a short prompt's wait to one chunk and
streams each finished chunk's KV while later chunks compute. The
simulator rows sweep chunk on/off on the yi-6b latency model (the
deterministic short-prompt TTFT-p99 claim); the live row drives the real
smoke-model cluster with chunking on and checks token identity plus the
realized streaming stats.
"""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core import hw
from repro.core.latency_model import LatencyModel, Parallelism
from repro.core.simulator import InstanceConfig, simulate_disaggregated
from repro.core.workload import Request

from .common import emit, timed

ARCH = "yi-6b"
CHUNK = 128
LM_TOKENS = 512
SHORT_CUT = 512         # prompts below this count as "short" for TTFT


def _mixed_trace(n: int, seed: int = 0):
    """80% short (64-256 tok) / 20% long (2500-3500 tok) prompts, arrival
    rate below saturation so the short-prompt TTFT tail measures HOL
    blocking, not queueing backlog."""
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(0.3))
        if rng.random() < 0.2:
            in_len = int(rng.integers(2500, 3500))
        else:
            in_len = int(rng.integers(64, 256))
        reqs.append(Request(i, t, in_len, int(rng.integers(8, 32))))
    return reqs


def _p99(xs):
    return float(np.percentile(np.asarray(xs), 99))


def _sim_rows(n: int):
    lm = LatencyModel(get_config(ARCH), hw.V5E)
    P = InstanceConfig(Parallelism(1, 1), 1)
    D = InstanceConfig(Parallelism(1, 1), 1)

    def go(chunk):
        return simulate_disaggregated(_mixed_trace(n), lm, P, D,
                                      lm_tokens=LM_TOKENS,
                                      chunk_tokens=chunk)
    (r0, ex0), us0 = timed(go, None)
    (r1, ex1), us1 = timed(go, CHUNK)
    ttft0 = [r.first_token - r.arrive for r in r0 if r.in_len < SHORT_CUT]
    ttft1 = [r.first_token - r.arrive for r in r1 if r.in_len < SHORT_CUT]
    p99_0, p99_1 = _p99(ttft0), _p99(ttft1)
    p50_0 = float(np.median(ttft0))
    p50_1 = float(np.median(ttft1))
    emit("chunked.sim.ttft_short", us0 + us1,
         f"n={len(ttft1)};p99_base_ms={p99_0 * 1e3:.2f};"
         f"p99_chunked_ms={p99_1 * 1e3:.2f};"
         f"speedup={p99_0 / max(p99_1, 1e-12):.2f};"
         f"p50_gain={p50_0 / max(p50_1, 1e-12):.2f}")
    # chunks reassemble to the same KV: total wire bytes must not move
    emit("chunked.sim.stream", 0.0,
         f"streamed_pulls={ex1['streamed_pulls']};"
         f"stream_saved_s={ex1['kv_stream_saved_s']:.4e};"
         f"kv_bytes_ratio={ex1['kv_bytes'] / max(ex0['kv_bytes'], 1e-12):.4f}")
    return p99_0, p99_1


def _live_row():
    import jax

    from repro.models.api import build_model
    from repro.serving.cluster import DisaggCluster

    cfg = get_config("yi-6b-smoke")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    reqs = [Request(0, 0.0, 100, 4), Request(1, 0.0, 17, 5),
            Request(2, 0.0, 64, 3), Request(3, 0.0, 33, 4)]

    def go(chunk):
        dc = DisaggCluster(cfg, params, n_prefill=1, n_decode=1,
                           max_len=256, paged=True, page_size=16,
                           chunk_tokens=chunk, seed=0)
        return dc, dc.run(list(reqs))
    (dc0, r0), us0 = timed(go, None)
    (dc1, r1), us1 = timed(go, 32)
    identical = all(r1[rid].tokens == r0[rid].tokens for rid in r0)
    emit("chunked.live", us1,
         f"base_us={us0:.1f};tokens_identical={identical};"
         f"streamed_pulls={dc1.tx.streamed_pulls};"
         f"stream_saved_s={dc1.tx.stream_saved_s:.4e};"
         f"chunks={dc1.prefill[0].steps}")


def run(quick: bool = False):
    n = 100 if quick else 300
    _sim_rows(n)
    _live_row()
