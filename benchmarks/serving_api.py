"""Serving-API path benchmark: drive the request-lifecycle protocol
(`submit` -> streaming token events -> `cancel`/`drain`) end to end on
both worlds — the live smoke-scale DisaggCluster and the analytical
SimDisaggBackend — with online SLOTracker scoring and a cancellation mix.

Emits:
  serving_api.live.<metric>  — live cluster under streaming + cancels
  serving_api.sim.<metric>   — simulator under the same protocol
metrics: submit-to-drain wall time per request, attainment, cancel counts,
the ITL tail (p99/max) that per-token timestamps expose, and — from the
lifecycle tracer — per-request latency attribution columns (queue time
and migration/transfer time next to TTFT/TPOT).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import hw
from repro.core.goodput import SLOTracker
from repro.core.latency_model import LatencyModel, Parallelism
from repro.core.simulator import (InstanceConfig, SimDisaggBackend,
                                  summarize)
from repro.core.telemetry import Tracer, attribute_request
from repro.core.workload import Request, WorkloadSpec, with_cancellations
from repro.models.api import build_model
from repro.serving.api import percentile
from repro.serving.cluster import DisaggCluster

from .common import emit

SPEC = WorkloadSpec("api-bench", 2.5, 0.5, (8, 48), 1.8, 0.3, (4, 10),
                    slo_ttft=2.0, slo_tpot=0.05)


def _trace(n, rate, seed=0, cancel_frac=0.2):
    rng = np.random.default_rng(seed)
    arrive = np.cumsum(rng.exponential(1.0 / rate, n))
    reqs = [Request(i, float(arrive[i]), int(rng.integers(8, 48)),
                    int(rng.integers(4, 10))) for i in range(n)]
    return with_cancellations(reqs, frac=cancel_frac, seed=seed,
                              mean_wait_s=0.3)


def _drive(backend, reqs, tag):
    t0 = time.perf_counter()
    handles = [backend.submit(r) for r in reqs]
    backend.drain()
    wall = time.perf_counter() - t0
    cancelled = sum(h.status.name == "CANCELLED" for h in handles)
    finished = sum(h.status.name == "FINISHED" for h in handles)
    itl = sorted(d for h in handles if h.done
                 for d in h.state.itl())
    p99 = percentile(itl, 0.99)     # same method summarize uses, so the
                                    # live and sim rows are comparable
    emit(f"serving_api.{tag}", wall / max(len(reqs), 1) * 1e6,
         f"finished={finished};cancelled={cancelled};"
         f"itl_p99_ms={p99 * 1e3:.2f};"
         f"itl_max_ms={(itl[-1] if itl else 0.0) * 1e3:.2f}")
    return handles


def _emit_attr(tracer: Tracer, reqs, tag: str):
    """Attribution-derived latency columns, next to the TTFT/TPOT medians:
    where a request's time to first token actually went (queue vs prefill)
    and how long its KV migration + admission took."""
    atts = [a for a in (attribute_request(tracer, r.rid) for r in reqs)
            if a is not None and a.terminal == "FINISHED" and a.n_tokens]
    if not atts:
        return
    med = lambda xs: percentile(sorted(xs), 0.5)
    xfer = [a.migrate_s + a.admit_s for a in atts]
    pref = [a.prefill_compute_s + a.prefill_stall_s for a in atts]
    emit(f"serving_api.{tag}.attr", 0.0,
         f"ttft_ms={med([a.ttft for a in atts]) * 1e3:.2f};"
         f"tpot_ms={med([a.tpot for a in atts]) * 1e3:.3f};"
         f"queue_ms={med([a.queue_s for a in atts]) * 1e3:.2f};"
         f"xfer_ms={med(xfer) * 1e3:.2f};"
         f"prefill_ms={med(pref) * 1e3:.2f}")


def run(quick: bool = False):
    n = 10 if quick else 24
    # live: smoke-scale engines on CPU
    cfg = get_config("yi-6b-smoke")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    tracker = SLOTracker(SPEC)
    live_tr = Tracer()
    dc = DisaggCluster(cfg, params, n_prefill=2, n_decode=1, max_batch=4,
                       max_len=96, lm_tokens=64, tracker=tracker,
                       tracer=live_tr)
    live_reqs = _trace(n, rate=20.0, seed=0)
    _drive(dc, live_reqs, "live")
    s = tracker.summary()
    emit("serving_api.live.slo", 0.0,
         f"attain={s['attain']};worst_itl_ms={s['worst_itl'] * 1e3:.2f}")
    _emit_attr(live_tr, live_reqs, "live")

    # sim: the same protocol against the latency model, bigger trace
    lm = LatencyModel(get_config("yi-6b"), hw.V5E)
    sim_tracker = SLOTracker(SPEC)
    sim_tr = Tracer()
    sim = SimDisaggBackend(lm, InstanceConfig(Parallelism(1, 1), 2),
                           InstanceConfig(Parallelism(1, 1), 1),
                           tracker=sim_tracker, tracer=sim_tr)
    sim_reqs = _trace(10 * n, rate=8.0, seed=1)
    _drive(sim, sim_reqs, "sim")
    res = summarize(sim_reqs, SPEC, extra=sim.extras(), warmup_frac=0.0)
    emit("serving_api.sim.slo", 0.0,
         f"attain={res.attain:.3f};cancelled={res.n_cancelled};"
         f"itl_p99_ms={res.p99_itl * 1e3:.3f};"
         f"itl_max_ms={res.max_itl * 1e3:.3f}")
    _emit_attr(sim_tr, sim_reqs, "sim")


if __name__ == "__main__":
    run(quick=True)
