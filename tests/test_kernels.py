"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_prefill.kernel import flash_prefill
from repro.kernels.flash_prefill.ref import flash_prefill_ref
from repro.kernels.paged_decode.kernel import paged_decode, paged_insert
from repro.kernels.paged_decode.ref import paged_decode_ref, paged_insert_ref
from repro.kernels.prefix_prefill.kernel import prefix_prefill
from repro.kernels.prefix_prefill.ref import prefix_prefill_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref, ssd_scan_sequential

TOLS = {jnp.float32: dict(atol=5e-5, rtol=5e-5),
        jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    # (B, H, Hkv, Sq, Skv, hd, causal, window)
    (2, 4, 2, 128, 128, 64, True, 0),
    (1, 8, 1, 96, 256, 64, True, 0),
    (2, 4, 4, 128, 128, 128, False, 0),
    (1, 4, 2, 256, 256, 64, True, 64),
    (1, 2, 2, 64, 64, 32, True, 0),
])
def test_flash_prefill_sweep(shape, dtype):
    B, H, Hkv, Sq, Skv, hd, causal, window = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Skv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Skv, hd), jnp.float32).astype(dtype)
    ref = flash_prefill_ref(q, k, v, causal=causal, window=window)
    out = flash_prefill(q, k, v, causal=causal, window=window,
                        block_q=64, block_kv=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    # (B, H, Hkv, hd, num_pages, page, pages_per_seq)
    (2, 8, 2, 64, 16, 16, 4),
    (3, 4, 4, 128, 32, 8, 8),
    (1, 16, 1, 64, 8, 32, 2),
])
def test_paged_decode_sweep(shape, dtype):
    B, H, Hkv, hd, pages, page, pps = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (pages, page, Hkv, hd), jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (pages, page, Hkv, hd), jnp.float32).astype(dtype)
    table = jax.random.permutation(ks[0], pages)[:B * pps].reshape(B, pps)
    table = table.astype(jnp.int32)
    lens = jnp.array([1 + (11 * i + 7) % (pps * page) for i in range(B)],
                     jnp.int32)
    ref = paged_decode_ref(q, kp, vp, table, lens)
    out = paged_decode(q, kp, vp, table, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOLS[dtype])


def test_paged_decode_full_page_boundary():
    """lens exactly on page boundaries."""
    B, H, Hkv, hd, pages, page, pps = 2, 4, 2, 64, 8, 16, 3
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (pages, page, Hkv, hd))
    vp = jax.random.normal(ks[2], (pages, page, Hkv, hd))
    table = jnp.arange(B * pps, dtype=jnp.int32).reshape(B, pps)
    lens = jnp.array([page, pps * page], jnp.int32)
    ref = paged_decode_ref(q, kp, vp, table, lens)
    out = paged_decode(q, kp, vp, table, lens, interpret=True)
    np.testing.assert_allclose(out, ref, atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    # (B, H, Hkv, Sq, hd, num_pages, page, npp)
    (2, 4, 2, 64, 64, 16, 16, 3),      # GQA 2:1
    (1, 8, 1, 96, 64, 32, 8, 6),       # MQA, ragged q blocks
    (2, 4, 4, 128, 128, 16, 16, 2),    # MHA, hd 128
    (1, 2, 2, 32, 32, 8, 32, 1),       # single prefix page
])
def test_prefix_prefill_sweep(shape, dtype):
    B, H, Hkv, Sq, hd, pages, page, npp = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Sq, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Sq, hd), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[3], (pages, page, Hkv, hd), jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[4], (pages, page, Hkv, hd), jnp.float32).astype(dtype)
    table = jax.random.permutation(ks[0], pages)[:B * npp].reshape(B, npp)
    table = table.astype(jnp.int32)
    # ragged prefix lengths (incl. a partially-filled last page)
    plens = jnp.array([1 + (7 * i + 5) % (npp * page) for i in range(B)],
                      jnp.int32)
    ref = prefix_prefill_ref(q, k, v, kp, vp, table, plens)
    out = prefix_prefill(q, k, v, kp, vp, table, plens,
                         block_q=32, block_kv=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOLS[dtype])


def test_prefix_prefill_ragged_suffix_and_full_pages():
    """suffix_lens masking + prefix_lens exactly on page boundaries + a
    trash-padded table slot beyond the live prefix."""
    B, H, Hkv, Sq, hd, pages, page, npp = 2, 4, 2, 48, 64, 12, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    q = jax.random.normal(ks[0], (B, H, Sq, hd))
    k = jax.random.normal(ks[1], (B, Hkv, Sq, hd))
    v = jax.random.normal(ks[2], (B, Hkv, Sq, hd))
    kp = jax.random.normal(ks[3], (pages, page, Hkv, hd))
    vp = jax.random.normal(ks[4], (pages, page, Hkv, hd))
    table = jnp.arange(B * npp, dtype=jnp.int32).reshape(B, npp)
    # row 0: full pages; row 1: live prefix ends mid-table (pages beyond
    # plen are trash-padded and must be masked, not attended)
    table = table.at[1, 2:].set(0)
    plens = jnp.array([npp * page, 2 * page], jnp.int32)
    slens = jnp.array([Sq, Sq - 9], jnp.int32)
    ref = prefix_prefill_ref(q, k, v, kp, vp, table, plens, slens)
    out = prefix_prefill(q, k, v, kp, vp, table, plens, slens,
                         block_q=16, block_kv=16, interpret=True)
    np.testing.assert_allclose(out, ref, atol=5e-5, rtol=5e-5)


def test_prefix_prefill_matches_flash_with_dense_prefix():
    """Cross-oracle: fused paged-prefix attention == flash attention over
    the dense concat [prefix ++ suffix] with the offset causal mask."""
    B, H, Hkv, Sq, hd, page, npp = 1, 4, 2, 32, 64, 8, 3
    P = npp * page
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (B, H, Sq, hd))
    k = jax.random.normal(ks[1], (B, Hkv, Sq, hd))
    v = jax.random.normal(ks[2], (B, Hkv, Sq, hd))
    kp = jax.random.normal(ks[3], (npp, page, Hkv, hd))
    vp = jax.random.normal(ks[4], (npp, page, Hkv, hd))
    table = jnp.arange(npp, dtype=jnp.int32)[None]
    plens = jnp.array([P], jnp.int32)
    out = prefix_prefill(q, k, v, kp, vp, table, plens,
                         block_q=16, block_kv=16, interpret=True)
    k_dense = jnp.concatenate(
        [kp.reshape(1, P, Hkv, hd).transpose(0, 2, 1, 3), k], axis=2)
    v_dense = jnp.concatenate(
        [vp.reshape(1, P, Hkv, hd).transpose(0, 2, 1, 3), v], axis=2)
    want = flash_prefill_ref(q, k_dense, v_dense, causal=True)
    np.testing.assert_allclose(out, want, atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    # (B, H, Hkv, hd, num_pages, page, pages_per_seq)
    (2, 8, 2, 64, 16, 16, 4),
    (3, 4, 4, 128, 32, 8, 8),
])
def test_paged_decode_dbuf_parity(shape, dtype):
    """Async-copy double-buffered page walk == the BlockSpec-pipelined
    kernel's oracle, pools in compiler-chosen memory, ragged lens."""
    B, H, Hkv, hd, pages, page, pps = shape
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (pages, page, Hkv, hd),
                           jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (pages, page, Hkv, hd),
                           jnp.float32).astype(dtype)
    table = jax.random.permutation(ks[0], pages)[:B * pps].reshape(B, pps)
    table = table.astype(jnp.int32)
    lens = jnp.array([1 + (11 * i + 7) % (pps * page) for i in range(B)],
                     jnp.int32)
    ref = paged_decode_ref(q, kp, vp, table, lens)
    out = paged_decode(q, kp, vp, table, lens, dbuf=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOLS[dtype])


def test_prefix_prefill_dbuf_parity():
    """Double-buffered paged-prefix loads == oracle, incl. ragged prefix,
    ragged suffix, and a trash-padded table slot."""
    B, H, Hkv, Sq, hd, pages, page, npp = 2, 4, 2, 48, 64, 12, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    q = jax.random.normal(ks[0], (B, H, Sq, hd))
    k = jax.random.normal(ks[1], (B, Hkv, Sq, hd))
    v = jax.random.normal(ks[2], (B, Hkv, Sq, hd))
    kp = jax.random.normal(ks[3], (pages, page, Hkv, hd))
    vp = jax.random.normal(ks[4], (pages, page, Hkv, hd))
    table = jnp.arange(B * npp, dtype=jnp.int32).reshape(B, npp)
    table = table.at[1, 2:].set(0)
    plens = jnp.array([npp * page, 2 * page], jnp.int32)
    slens = jnp.array([Sq, Sq - 9], jnp.int32)
    ref = prefix_prefill_ref(q, k, v, kp, vp, table, plens, slens)
    out = prefix_prefill(q, k, v, kp, vp, table, plens, slens,
                         block_q=16, block_kv=16, dbuf=True, interpret=True)
    np.testing.assert_allclose(out, ref, atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_insert_parity(dtype):
    """Kernel splice == the dense .at[pidx, off].set oracle, including a
    duplicate trash-page target (garbage by design, shapes must hold)."""
    B, Hkv, hd, pages, page = 4, 2, 64, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    kp = jax.random.normal(ks[0], (pages, page, Hkv, hd), jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[1], (pages, page, Hkv, hd), jnp.float32).astype(dtype)
    kn = jax.random.normal(ks[2], (B, Hkv, hd))
    vn = jax.random.normal(ks[3], (B, Hkv, hd))
    pidx = jnp.array([3, 1, 7, 5], jnp.int32)
    off = jnp.array([0, 7, 15, 3], jnp.int32)
    rk, rv = paged_insert_ref(kp, vp, kn, vn, pidx, off)
    ok, ov = paged_insert(kp, vp, kn, vn, pidx, off, interpret=True)
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(ov), np.asarray(rv))
    # untouched pages bit-identical to the originals
    untouched = [p for p in range(pages) if p not in set(pidx.tolist())]
    np.testing.assert_array_equal(np.asarray(ok)[untouched],
                                  np.asarray(kp)[untouched])


@pytest.mark.parametrize("shape", [
    # (b, S, nh, hd, G, N, chunk)
    (2, 64, 4, 8, 2, 16, 16),
    (1, 128, 2, 64, 1, 128, 32),
    (2, 96, 4, 16, 4, 32, 32),
])
def test_ssd_scan_sweep(shape):
    b, S, nh, hd, G, N, chunk = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, S, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, nh))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    B = jax.random.normal(ks[3], (b, S, G, N))
    C = jax.random.normal(ks[4], (b, S, G, N))
    D = jax.random.normal(ks[0], (nh,))
    y_seq, h_seq = ssd_scan_sequential(x, dt, A, B, C, D)
    y, h = ssd_scan(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    np.testing.assert_allclose(y, y_seq, atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(h, h_seq, atol=3e-4, rtol=3e-4)
    # kernel also matches the model-side chunked reference
    y_ref, h_ref = ssd_scan_ref(x, dt, A, B, C, D, chunk=chunk)
    np.testing.assert_allclose(y, y_ref, atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(h, h_ref, atol=3e-4, rtol=3e-4)


def test_ops_wrappers_dispatch_ref_on_cpu():
    from repro.kernels.flash_prefill.ops import flash_prefill_op
    from repro.kernels.paged_decode.ops import paged_decode_op
    from repro.kernels.ssd_scan.ops import ssd_scan_op
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 32, 4, 16))
    k = jax.random.normal(ks[1], (1, 32, 2, 16))
    v = jax.random.normal(ks[2], (1, 32, 2, 16))
    out = flash_prefill_op(q, k, v)           # auto -> ref on CPU
    assert out.shape == q.shape
    qd = jax.random.normal(ks[0], (1, 4, 16))
    kp = jax.random.normal(ks[1], (4, 8, 2, 16))
    vp = jax.random.normal(ks[2], (4, 8, 2, 16))
    table = jnp.zeros((1, 2), jnp.int32)
    out = paged_decode_op(qd, kp, vp, table, jnp.array([5], jnp.int32))
    assert out.shape == (1, 4, 16)
    x = jax.random.normal(ks[0], (1, 32, 2, 8))
    dt = jnp.ones((1, 32, 2)) * 0.1
    y, h = ssd_scan_op(x, dt, -jnp.ones((2,)), jax.random.normal(ks[1], (1, 32, 1, 8)),
                       jax.random.normal(ks[2], (1, 32, 1, 8)), jnp.ones((2,)),
                       chunk=16)
    assert y.shape == x.shape
