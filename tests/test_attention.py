import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (decode_attend, dense_attention,
                                    flash_reference)


@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("gqa", [(8, 2), (4, 4), (8, 1)])
def test_flash_reference_matches_dense(window, causal, gqa):
    H, Hkv = gqa
    if window and not causal:
        pytest.skip("window implies causal")
    B, S, hd = 2, 96, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    ref = dense_attention(q, k, v, causal=causal, window=window)
    out = flash_reference(q, k, v, causal=causal, window=window,
                          block_q=32, block_kv=32)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


def test_flash_reference_uneven_lengths():
    B, S, H, hd = 1, 70, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, 96, H, hd))
    v = jax.random.normal(ks[2], (B, 96, H, hd))
    ref = dense_attention(q, k, v, causal=False)
    out = flash_reference(q, k, v, causal=False, block_q=32, block_kv=32)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


def test_flash_reference_softcap():
    B, S, H, hd = 2, 64, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, 2, hd))
    v = jax.random.normal(ks[2], (B, S, 2, hd))
    ref = dense_attention(q, k, v, causal=True, logit_softcap=20.0)
    out = flash_reference(q, k, v, causal=True, logit_softcap=20.0,
                          block_q=16, block_kv=16)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


def test_decode_attend_masks_by_length():
    B, S, H, Hkv, hd = 2, 48, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    lens = jnp.array([13, 48])
    out = decode_attend(q, k, v, lens)
    for b in range(B):
        ref = dense_attention(q[b:b + 1, None], k[b:b + 1, :lens[b]],
                              v[b:b + 1, :lens[b]], causal=False)
        np.testing.assert_allclose(out[b], ref[0, 0], atol=3e-5, rtol=3e-5)


def test_decode_attend_ignores_tail_garbage():
    """Tokens beyond `lens` must not affect the output (engine invariant)."""
    B, S, H, hd = 1, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    lens = jnp.array([10])
    out1 = decode_attend(q, k, v, lens)
    k2 = k.at[:, 10:].set(999.0)
    v2 = v.at[:, 10:].set(-999.0)
    out2 = decode_attend(q, k2, v2, lens)
    np.testing.assert_allclose(out1, out2, atol=1e-6)
