"""Unified request-lifecycle tracing: tracer semantics, sim == live span
parity on the virtual clock, span conservation under cancellation fuzz,
tracer-off identity, Chrome-trace export + schema validation, metrics
registry, and TTFT/TPOT attribution feeding the SLO tracker.

The parity pin is the load-bearing one: with a deterministic
`EngineCharge` replacing measured kernel times, the live `DisaggCluster`
and `SimDisaggBackend` must emit the SAME span schema at the SAME
virtual-clock floats for a pinned multi-turn trace with chunked prefill
and streamed migration on. The one structural divergence is the decode
step span's start: the live cluster forms the batch at pull time while
the simulator joins at transfer_first — step spans therefore compare by
(count, end-time) only; phase/compute/wire spans and token instants
compare exactly.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import hw
from repro.core.goodput import SLOTracker
from repro.core.latency_model import EngineCharge, LatencyModel, Parallelism
from repro.core.simulator import InstanceConfig, SimDisaggBackend
from repro.core.telemetry import (MetricsRegistry, NULL_TRACER, Tracer,
                                  attribute_request, to_chrome_trace,
                                  validate_chrome_trace)
from repro.core.workload import Request, WorkloadSpec, with_cancellations
from repro.models.api import build_model
from repro.serving.cluster import DisaggCluster

CFG = get_config("yi-6b-smoke")
LM = LatencyModel(CFG, hw.V5E)
PAR = Parallelism(1, 1)
SLOW_BW = 1e3       # B/s: wire time dwarfs compute, exercising streaming


@pytest.fixture(scope="module")
def params():
    return build_model(CFG).init(jax.random.PRNGKey(0))


# ---------------- tracer unit semantics ------------------------------------

def test_span_lifecycle_and_double_close():
    tr = Tracer()
    sp = tr.begin("compute", "chunk", 1.0, "prefill0", rid=7)
    assert sp.open and tr.open_spans() == [sp]
    tr.end(sp, 2.0, tokens=32)
    assert not sp.open and sp.dur == 1.0 and sp.args["tokens"] == 32
    with pytest.raises(ValueError):
        tr.end(sp, 3.0)                 # every span closes exactly once
    with pytest.raises(ValueError):
        tr.end(tr.begin("x", "y", 5.0, "l"), 4.0)   # time travel


def test_phase_machine_reentry_and_terminal():
    tr = Tracer()
    tr.phase(1, "queued", 0.0, "prefill0")
    tr.phase(1, "prefilling", 1.0, "prefill0")
    tr.phase(1, "prefilling", 2.0, "prefill0")  # chunked re-queue: no-op
    tr.phase(1, "decoding", 3.0, "decode0")
    tr.finish_phase(1, 4.0, "FINISHED")
    names = [(s.name, s.t0, s.t1) for s in tr.for_rid(1)]
    assert names == [("queued", 0.0, 1.0), ("prefilling", 1.0, 3.0),
                     ("decoding", 3.0, 4.0)]
    assert tr.spans[-1].events[-1].name == "FINISHED"
    assert not tr.open_spans()
    assert tr.terminals[1] == ("FINISHED", 4.0)


def test_null_tracer_is_falsy_noop():
    assert not NULL_TRACER and NULL_TRACER.enabled is False
    NULL_TRACER.phase(1, "queued", 0.0, "x")    # all no-ops, no state
    NULL_TRACER.complete("a", "b", 0.0, 1.0, "l")
    NULL_TRACER.finish_phase(1, 1.0, "FINISHED")


# ---------------- chrome-trace export + schema checker ---------------------

def test_chrome_trace_roundtrip_validates():
    tr = Tracer()
    tr.phase(1, "queued", 0.0, "prefill0")
    tr.phase(1, "prefilling", 1.0, "prefill0")
    tr.complete("compute", "chunk", 1.0, 2.0, "prefill0", rid=1, tokens=32)
    tr.phase(1, "migrating", 2.0, "decode0")
    tr.complete("wire", "kv_stream", 2.0, 3.0, "wire:0->0", rid=1,
                bytes=4096)
    tr.phase(1, "decoding", 3.0, "decode0")
    tr.event("token", 3.5, rid=1, i=0)
    tr.finish_phase(1, 4.0, "FINISHED")
    doc = to_chrome_trace(tr)
    doc = json.loads(json.dumps(doc))       # survives JSON round-trip
    assert validate_chrome_trace(doc) == []
    evs = doc["traceEvents"]
    # one process lane per instance/wire, flow arrows follow the request
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in evs)
    assert any(e["ph"] == "s" for e in evs) and any(
        e["ph"] == "f" for e in evs)
    # globally sorted timestamps
    ts = [e["ts"] for e in evs if e["ph"] != "M"]
    assert ts == sorted(ts)


def test_validator_rejects_corrupt_traces():
    tr = Tracer()
    tr.complete("compute", "chunk", 0.0, 1.0, "prefill0", rid=1)
    good = to_chrome_trace(tr)
    assert validate_chrome_trace(good) == []
    xi = next(i for i, e in enumerate(good["traceEvents"])
              if e["ph"] == "X")
    bad = json.loads(json.dumps(good))
    bad["traceEvents"][xi]["ts"] = -5.0
    assert validate_chrome_trace(bad)
    bad2 = json.loads(json.dumps(good))
    bad2["traceEvents"][xi]["ph"] = "Z"
    assert validate_chrome_trace(bad2)
    bad3 = json.loads(json.dumps(good))
    bad3["traceEvents"].append({"ph": "B", "name": "orphan", "ts": 9.0,
                                "pid": 1, "tid": 1})
    assert any("unclosed" in e or "orphan" in e
               for e in validate_chrome_trace(bad3))
    assert validate_chrome_trace({"not": "a trace"})


def test_wall_clock_stamps_opt_in_and_validate():
    """`Tracer(wall_clock=...)` stamps spans/instants with wall marks;
    virtual time stays the span identity and the exporter schema-checks
    the marks (wall_t1 >= wall_t0, numeric)."""
    ticks = iter([10.0, 10.25, 10.5, 11.0])
    tr = Tracer(wall_clock=lambda: next(ticks))
    tr.complete("compute", "chunk", 0.0, 1.0, "prefill0", rid=1)
    tr.event("token", 0.5, rid=1, i=0)
    sp = tr.spans[0]
    assert (sp.wall_t0, sp.wall_t1) == (10.0, 10.25)
    assert sp.t0 == 0.0 and sp.t1 == 1.0          # virtual clock untouched
    assert tr.instants[0].wall_t == 10.5
    doc = json.loads(json.dumps(to_chrome_trace(tr)))
    assert validate_chrome_trace(doc) == []
    x = next(e for e in doc["traceEvents"] if e["ph"] == "X")
    assert x["args"]["wall_t0"] == 10.0 and x["args"]["wall_t1"] == 10.25
    # a regressive wall interval is a schema error
    bad = json.loads(json.dumps(doc))
    xi = next(i for i, e in enumerate(bad["traceEvents"])
              if e["ph"] == "X")
    bad["traceEvents"][xi]["args"]["wall_t1"] = 9.0
    assert validate_chrome_trace(bad)
    # without the hook nothing is stamped
    off = Tracer()
    off.complete("compute", "chunk", 0.0, 1.0, "prefill0", rid=1)
    assert off.spans[0].wall_t0 is None and off.spans[0].wall_t1 is None


# ---------------- metrics registry -----------------------------------------

def test_metrics_registry_snapshot_and_prometheus():
    m = MetricsRegistry()
    m.counter("requests_finished")
    m.counter("requests_finished", 2)
    m.gauge("queue.depth", 7)
    for v in (0.1, 0.2, 0.3):
        m.observe("ttft_s", v)
    m.register(lambda: {"kv.used_pages": 5.0})
    snap = m.snapshot()
    assert snap["requests_finished"] == 3.0
    assert snap["queue.depth"] == 7.0
    assert snap["kv.used_pages"] == 5.0
    assert snap["ttft_s_count"] == 3.0
    assert snap["ttft_s_sum"] == pytest.approx(0.6)
    assert snap["ttft_s_max"] == pytest.approx(0.3)
    text = m.prometheus()
    assert "repro_requests_finished 3" in text
    assert "repro_queue_depth 7" in text
    assert "repro_kv_used_pages 5" in text


# ---------------- the parity pin: live == sim spans ------------------------

def _multiturn_trace():
    """Pinned 3-turn conversation: each turn's prompt extends the last
    (shared radix prefixes), long enough that chunk_tokens=32 splits every
    prefill, arrivals spaced so turns run serially (decode batch stays 1
    and the step-span divergence below stays confined to start times)."""
    rng = np.random.default_rng(42)
    sys_p = tuple(int(x) for x in rng.integers(1, CFG.vocab_size, 32))
    gap = 120.0         # >> any wire/compute time at SLOW_BW smoke scale
    reqs, prompt = [], sys_p
    for turn in range(3):
        user = tuple(int(x) for x in rng.integers(1, CFG.vocab_size, 16))
        prompt = prompt + user
        reqs.append(Request(turn, turn * gap, len(prompt), 4,
                            tokens=prompt))
        prompt = prompt + (7, 7, 7, 7)      # stand-in for the reply
    return reqs


def _span_sig(tr, cats=("phase", "compute", "wire")):
    return sorted((s.cat, s.name, s.lane, s.rid, s.t0, s.t1)
                  for s in tr.spans if s.cat in cats)


def test_live_and_sim_emit_identical_spans(params):
    """Same schema, same lanes, same virtual-clock floats: phase, compute
    and wire spans (plus token instants and route decisions) from the
    live cluster under an `EngineCharge` match the simulator's exactly on
    a pinned multi-turn chunked+streamed trace."""
    tr_live, tr_sim = Tracer(), Tracer()
    live = DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_batch=2,
                         max_len=256, lm_tokens=128, chunk_tokens=32,
                         transfer_bandwidth=SLOW_BW, prefix_cache=True,
                         tracer=tr_live, charge=EngineCharge(LM, PAR))
    live.run(_multiturn_trace())
    sim = SimDisaggBackend(LM, InstanceConfig(PAR, 1),
                           InstanceConfig(PAR, 1), transfer_bw=SLOW_BW,
                           lm_tokens=128, chunk_tokens=32,
                           prefix_cache=True, tracer=tr_sim)
    for r in _multiturn_trace():
        sim.submit(r)
    sim.drain()

    a, b = _span_sig(tr_live), _span_sig(tr_sim)
    assert len(a) == len(b), (len(a), len(b))
    for sa, sb in zip(a, b):
        assert sa[:4] == sb[:4], (sa, sb)           # cat/name/lane/rid
        assert sa[4] == pytest.approx(sb[4], rel=1e-9, abs=1e-12), (sa, sb)
        assert sa[5] == pytest.approx(sb[5], rel=1e-9, abs=1e-12), (sa, sb)
    # chunked prefill and streamed migration actually happened
    assert any(s[1] == "chunk" for s in a)
    assert any(s[0] == "wire" and s[1] == "kv_stream" for s in a)
    # prefix reuse surfaced: later turns report non-zero hits both sides
    assert live.dispatcher.decisions == sim.disp.decisions
    # decode step spans: same count and end-times (start times differ by
    # construction — live batches at pull, sim at transfer_first)
    st_a = sorted((s.lane, s.t1) for s in tr_live.spans if s.cat == "step")
    st_b = sorted((s.lane, s.t1) for s in tr_sim.spans if s.cat == "step")
    assert len(st_a) == len(st_b)
    for (la, ta), (lb, tb) in zip(st_a, st_b):
        assert la == lb and ta == pytest.approx(tb, rel=1e-9)
    # token instants: same count and virtual times per request
    for rid in range(3):
        tok_a = [i.t for i in tr_live.tokens_for(rid)]
        tok_b = [i.t for i in tr_sim.tokens_for(rid)]
        assert len(tok_a) == len(tok_b) == 4
        assert tok_a == pytest.approx(tok_b, rel=1e-9)
        assert tr_live.terminals[rid][0] == "FINISHED"
        assert tr_sim.terminals[rid][0] == "FINISHED"
    # both traces export to valid Chrome JSON
    assert validate_chrome_trace(to_chrome_trace(tr_live)) == []
    assert validate_chrome_trace(to_chrome_trace(tr_sim)) == []


# ---------------- span conservation under cancellation fuzz ----------------

def test_span_conservation_cancel_fuzz(params):
    """Every opened span closes exactly once; cancelled requests end in a
    CANCELLED terminal regardless of which lifecycle stage the cancel
    lands in (queued / mid-chunk / parked / pending-admit / decoding)."""
    rng = np.random.default_rng(0)
    sys_p = tuple(rng.integers(1, CFG.vocab_size, 16).tolist())
    for trial in range(2):
        rr = np.random.default_rng(300 + trial)
        reqs = []
        for i in range(10):
            u = tuple(rr.integers(1, CFG.vocab_size,
                                  int(rr.integers(4, 20))).tolist())
            reqs.append(Request(i, i * 0.02, 16 + len(u), 4,
                                tokens=sys_p + u))
        reqs = with_cancellations(reqs, frac=0.5, seed=trial,
                                  mean_wait_s=0.3)
        tr = Tracer()
        dc = DisaggCluster(CFG, params, n_prefill=2, n_decode=1,
                           max_batch=4, max_len=64, lm_tokens=48,
                           chunk_tokens=16, prefix_cache=True,
                           decode_num_pages=3 * (64 // 16) + 1,
                           tracer=tr)
        res = dc.run(reqs)
        assert not tr.open_spans(), \
            [(s.cat, s.name, s.rid) for s in tr.open_spans()]
        for rid, r in res.items():
            term, _ = tr.terminals[rid]
            if r.finish_reason == "cancelled":
                assert term == "CANCELLED", (rid, term)
            else:
                assert term == "FINISHED", (rid, term)
        doc = to_chrome_trace(tr)
        assert validate_chrome_trace(doc) == []


def test_sim_span_conservation_cancel_fuzz():
    rng = np.random.default_rng(1)
    reqs = [Request(i, float(i) * 0.05, int(rng.integers(16, 400)),
                    int(rng.integers(4, 40))) for i in range(40)]
    reqs = with_cancellations(reqs, frac=0.4, seed=2, mean_wait_s=0.01)
    tr = Tracer()
    sim = SimDisaggBackend(LM, InstanceConfig(PAR, 1),
                           InstanceConfig(PAR, 1), tracer=tr)
    for r in reqs:
        sim.submit(r)
    sim.drain()
    assert not tr.open_spans()
    n_cancelled = sum(r.finish_reason == "cancelled" for r in reqs)
    assert n_cancelled > 0
    for r in reqs:
        term, _ = tr.terminals[r.rid]
        assert term == ("CANCELLED" if r.finish_reason == "cancelled"
                        else "FINISHED")
    assert validate_chrome_trace(to_chrome_trace(tr)) == []


# ---------------- tracer-off identity --------------------------------------

def test_tracer_off_is_default_and_identical(params):
    """Tracing must be observation only: with a deterministic charge, a
    traced run and an untraced run produce byte-identical tokens, float-
    identical virtual times, and the same routing decisions. Tracer off
    is the default (NULL_TRACER)."""
    def run(tracer):
        dc = DisaggCluster(CFG, params, n_prefill=1, n_decode=1,
                           max_batch=2, max_len=256, lm_tokens=128,
                           chunk_tokens=32, transfer_bandwidth=SLOW_BW,
                           prefix_cache=True, tracer=tracer,
                           charge=EngineCharge(LM, PAR))
        res = dc.run(_multiturn_trace())
        return dc, res
    dc0, res0 = run(None)
    assert dc0.tracer is NULL_TRACER
    dc1, res1 = run(Tracer())
    assert sorted(res0) == sorted(res1)
    for rid in res0:
        assert res0[rid].tokens == res1[rid].tokens
        assert res0[rid].token_times == res1[rid].token_times
        assert res0[rid].finish_reason == res1[rid].finish_reason
    assert dc0.dispatcher.decisions == dc1.dispatcher.decisions


def test_colocated_backends_emit_spans(params):
    """Both colocated backends (live + sim) speak the same span schema on
    `engine{i}` lanes: queued -> prefilling -> decoding phases, per-batch
    prefill_batch compute spans, decode_step step spans, FINISHED
    terminals, and a valid Chrome-trace export."""
    from repro.serving.cluster import ColocatedCluster
    from repro.core.simulator import SimColocatedBackend

    def check(tr, n):
        assert not tr.open_spans()
        for rid in range(n):
            names = {s.name for s in tr.for_rid(rid) if s.cat == "phase"}
            assert {"queued", "prefilling", "decoding"} <= names
            assert tr.terminals[rid][0] == "FINISHED"
            assert len(tr.tokens_for(rid)) == 4
        assert all(s.lane.startswith("engine") for s in tr.spans)
        assert any(s.name == "prefill_batch" for s in tr.spans)
        assert any(s.cat == "step" for s in tr.spans)
        assert validate_chrome_trace(to_chrome_trace(tr)) == []

    reqs = [Request(i, i * 0.01, 12 + 4 * i, 4) for i in range(3)]
    tr_live = Tracer()
    cc = ColocatedCluster(CFG, params, n_engines=1, max_batch=4,
                          max_len=64, tracer=tr_live)
    cc.run([Request(r.rid, r.arrive, r.in_len, r.out_len) for r in reqs])
    check(tr_live, 3)

    tr_sim = Tracer()
    sim = SimColocatedBackend(LM, InstanceConfig(PAR, 1), tracer=tr_sim)
    for r in reqs:
        sim.submit(r)
    sim.drain()
    check(tr_sim, 3)


def test_sim_tracer_off_identity():
    def run(tracer):
        reqs = [Request(i, i * 0.1, 64 + 16 * i, 6) for i in range(6)]
        sim = SimDisaggBackend(LM, InstanceConfig(PAR, 1),
                               InstanceConfig(PAR, 1), tracer=tracer)
        for r in reqs:
            sim.submit(r)
        sim.drain()
        return reqs
    r0, r1 = run(None), run(Tracer())
    for a, b in zip(r0, r1):
        assert (a.first_token, a.finish) == (b.first_token, b.finish)
        assert a.finish_reason == b.finish_reason


# ---------------- attribution + SLO annotation -----------------------------

def test_attribution_decomposes_ttft_and_tpot(params):
    tr = Tracer()
    dc = DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_batch=2,
                       max_len=256, lm_tokens=128, chunk_tokens=32,
                       transfer_bandwidth=SLOW_BW, prefix_cache=True,
                       tracer=tr, charge=EngineCharge(LM, PAR))
    reqs = _multiturn_trace()
    dc.run(reqs)
    for r in reqs:
        att = attribute_request(tr, r.rid)
        assert att is not None
        # TTFT parts cover arrive -> first token (within float slop)
        ttft = r.first_token - r.arrive
        assert sum(att.ttft_parts().values()) == pytest.approx(
            ttft, rel=1e-6, abs=1e-9)
        assert att.dominant_ttft in att.ttft_parts()
        assert att.n_tokens == 4
        if att.n_tokens > 1:
            assert att.tpot_parts()["step_compute"] >= 0
            assert att.tpot_parts()["batch_wait"] >= 0
        assert "ttft" in att.format()


def test_slo_tracker_annotates_violations(params):
    """A tight SLO turns every request into a violation; with a tracer
    attached each violation carries its attribution and the dominant
    TTFT term (the slow wire makes migration dominate here)."""
    spec = WorkloadSpec("w", 5.0, 1.0, (4, 512), 4.0, 0.5, (4, 64),
                        slo_ttft=1e-6, slo_tpot=1e-9)
    tr = Tracer()
    tracker = SLOTracker(spec, tracer=tr)
    dc = DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_batch=2,
                       max_len=256, lm_tokens=128, chunk_tokens=32,
                       transfer_bandwidth=SLOW_BW, prefix_cache=True,
                       tracer=tr, charge=EngineCharge(LM, PAR),
                       tracker=tracker)
    dc.run(_multiturn_trace())
    assert len(tracker.violations) == 3
    top = tracker.top_violations(2)
    assert len(top) == 2
    assert top[0].severity >= top[1].severity
    for v in top:
        assert v.attribution is not None
        assert v.attribution.dominant_ttft in v.attribution.ttft_parts()
        assert "ttft" in v.format()


# ---------------- per-request trace sampling -------------------------------

def test_sampling_decision_is_deterministic_and_partial():
    tr = Tracer(sample_rate=0.5, sample_seed=3)
    picks = [tr.sampled(rid) for rid in range(400)]
    assert picks == [tr.sampled(rid) for rid in range(400)]
    frac = sum(picks) / len(picks)
    assert 0.35 < frac < 0.65           # roughly the requested rate
    # a different seed samples a different subset at the same rate
    other = [Tracer(sample_rate=0.5, sample_seed=4).sampled(r)
             for r in range(400)]
    assert other != picks
    assert all(Tracer(sample_rate=1.0).sampled(r) for r in range(32))
    assert not any(Tracer(sample_rate=0.0).sampled(r) for r in range(32))
    assert Tracer(sample_rate=0.0).sampled(None)    # rid-less: always kept


def test_sampling_keeps_instants_and_terminals_drops_spans():
    tr = Tracer(sample_rate=0.4, sample_seed=1)
    sim = SimDisaggBackend(LM, InstanceConfig(PAR, 1),
                           InstanceConfig(PAR, 1), tracer=tr)
    reqs = [Request(i, i * 0.05, 32 + 8 * i, 5) for i in range(20)]
    for r in reqs:
        sim.submit(r)
    sim.drain()
    kept = {r.rid for r in reqs if tr.sampled(r.rid)}
    assert 0 < len(kept) < len(reqs)    # both kinds present at this seed
    for r in reqs:
        if r.rid in kept:
            assert tr.for_rid(r.rid), r.rid
        else:
            assert not tr.for_rid(r.rid), r.rid
        # instants-only data survives for everyone: tokens + terminal
        assert len(tr.tokens_for(r.rid)) == r.out_len
        assert tr.terminals[r.rid][0] == "FINISHED"
    assert tr.open_spans() == []
    # the thinned trace still exports as a valid chrome trace
    doc = to_chrome_trace(tr)
    assert validate_chrome_trace(doc) == []


def test_sampling_never_changes_tokens_or_routing():
    """The satellite pin: sampling only filters what is recorded — a
    fleet run at sample_rate 1.0 and 0.1 must produce identical tokens,
    timings, and routing decisions."""
    from repro.core.workload import sample_multi_turn
    from repro.serving.router import FleetRouter, OverloadDetector

    def fleet_run(rate):
        spec = WorkloadSpec("s", 3.0, 0.4, (8, 64), 2.0, 0.3, (4, 16),
                            slo_ttft=1.0, slo_tpot=1.0,
                            sys_len=16, turns=2, share=0.8)
        reqs = sample_multi_turn(spec, rate=50.0, n=40, seed=9,
                                 vocab=1000, think_s=0.5)
        tr = Tracer(sample_rate=rate)
        router = FleetRouter(
            [SimDisaggBackend(LM, InstanceConfig(PAR, 1),
                              InstanceConfig(PAR, 1), prefix_cache=True,
                              tracer=tr) for _ in range(2)],
            policy="prefix_affinity", tracer=tr,
            detector=OverloadDetector(max_inflight=4, max_queue=8,
                                      shed_after_s=0.2))
        for r in reqs:
            router.submit(r)
        res = router.drain()
        return tr, router.decisions, res

    tr_all, dec_all, res_all = fleet_run(1.0)
    tr_thin, dec_thin, res_thin = fleet_run(0.1)
    assert dec_all == dec_thin
    assert set(res_all) == set(res_thin)
    for rid in res_all:
        assert res_all[rid].tokens == res_thin[rid].tokens
        assert res_all[rid].finish == res_thin[rid].finish
        assert res_all[rid].finish_reason == res_thin[rid].finish_reason
    assert len(tr_thin.spans) < len(tr_all.spans)   # it did thin the trace
    assert tr_thin.terminals == tr_all.terminals    # but lost no terminals
