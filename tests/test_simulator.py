"""Simulator validation, including the paper's M/D/1 queueing model (Eq. 1)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import hw
from repro.core.latency_model import LatencyModel, Parallelism
from repro.core.simulator import (InstanceConfig, simulate_colocated,
                                  simulate_disaggregated, summarize)
from repro.core.workload import (SHAREGPT, Request, WorkloadSpec, derive_slos,
                                 sample_requests)

CFG = get_config("yi-6b")
LM = LatencyModel(CFG, hw.V5E)


def _uniform_requests(rate, n, in_len, seed=0):
    rng = np.random.default_rng(seed)
    arrive = np.cumsum(rng.exponential(1.0 / rate, n))
    return [Request(i, float(arrive[i]), in_len, 1) for i in range(n)]


@pytest.mark.parametrize("util", [0.3, 0.6, 0.8])
def test_md1_queue_matches_closed_form(util):
    """Paper Eq. 1: Avg_TTFT = D + R D^2 / (2 (1 - R D)) for uniform
    prompts, FCFS, no batching."""
    par = Parallelism(1, 1)
    L = 512
    D = LM.prefill_time([L], par)
    rate = util / D
    reqs = _uniform_requests(rate, 3000, L)
    reqs, _ = simulate_disaggregated(
        reqs, LM, InstanceConfig(par, 1), InstanceConfig(par, 1),
        lm_tokens=L,  # budget == one request -> no batching
        phase="prefill")
    ttfts = [r.ttft for r in reqs if r.finish >= 0]
    avg = float(np.mean(ttfts))
    expect = D + rate * D * D / (2 * (1 - rate * D))
    assert avg == pytest.approx(expect, rel=0.12), (avg, expect)


def test_all_requests_finish():
    spec = derive_slos(SHAREGPT, LM)
    reqs = sample_requests(spec, 5.0, 200, seed=1)
    reqs, _ = simulate_disaggregated(
        reqs, LM, InstanceConfig(Parallelism(2, 1), 1),
        InstanceConfig(Parallelism(2, 1), 1))
    assert all(r.finish >= 0 for r in reqs)
    assert all(r.first_token >= r.arrive for r in reqs)
    assert all(r.finish >= r.first_token for r in reqs)


def test_colocated_all_finish_and_interference():
    """Adding prefill load must slow decode (paper Fig. 2 direction)."""
    spec = derive_slos(SHAREGPT, LM)
    par = Parallelism(2, 1)
    lo = sample_requests(spec, 1.0, 120, seed=2)
    hi = sample_requests(spec, 20.0, 400, seed=2)
    lo, _ = simulate_colocated(lo, LM, InstanceConfig(par, 1))
    hi, _ = simulate_colocated(hi, LM, InstanceConfig(par, 1))
    r_lo = summarize(lo, spec)
    r_hi = summarize(hi, spec)
    assert all(r.finish >= 0 for r in hi)
    assert r_hi.p90_tpot > r_lo.p90_tpot  # interference grows with load


def test_disagg_beats_colocated_at_reference_setting():
    """The paper's headline direction under stringent SLOs."""
    from repro.core.goodput import max_goodput
    spec = derive_slos(SHAREGPT, LM)

    def colo(reqs):
        return simulate_colocated(reqs, LM, InstanceConfig(Parallelism(2, 1), 4))

    def disagg(reqs):
        return simulate_disaggregated(
            reqs, LM, InstanceConfig(Parallelism(4, 1), 1),
            InstanceConfig(Parallelism(2, 1), 2), transfer_bw=50e9)

    g_colo = max_goodput(colo, spec, 8, n_requests=300)
    g_dis = max_goodput(disagg, spec, 8, n_requests=300)
    assert g_dis.per_chip > 1.5 * g_colo.per_chip


def test_decode_phase_tpot_flat_with_pp():
    """PP scales decode throughput; TPOT stays near the microbatch time."""
    spec = derive_slos(SHAREGPT, LM)
    reqs = sample_requests(spec, 4.0, 200, seed=3)
    reqs, _ = simulate_disaggregated(
        reqs, LM, InstanceConfig(Parallelism(2, 1), 2),
        InstanceConfig(Parallelism(2, 2), 1), phase="both")
    res = summarize(reqs, spec)
    assert res.p90_tpot < spec.slo_tpot * 2


def test_kv_transfer_accounting():
    spec = derive_slos(SHAREGPT, LM)
    reqs = sample_requests(spec, 2.0, 100, seed=4)
    reqs, extras = simulate_disaggregated(
        reqs, LM, InstanceConfig(Parallelism(2, 1), 1),
        InstanceConfig(Parallelism(2, 1), 1), transfer_bw=50e9)
    assert extras["kv_total"] > 0
    # paper Fig. 10: transfer is a tiny fraction of total processing
    total_busy = extras["breakdown"]["prefill_busy_s"] + \
        extras["breakdown"]["decode_busy_s"]
    assert extras["kv_total"] < 0.05 * total_busy
