"""Chunk-granular paged prefill: bounded chunks attend over prior chunks'
KV in pool pages, fresh KV is written in place (no dense blob on the hot
path), finished chunks stream to the decode side as they land, and the
simulator charges the identical schedule. Pins: chunked == unchunked
token identity, HOL relief for short prompts, live == sim streamed
charge parity, and leak-free cancellation of partial prefills."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import hw
from repro.core.kv_transfer import TransferManager, kv_bytes
from repro.core.latency_model import LatencyModel, Parallelism
from repro.core.scheduler import FCFSQueue
from repro.core.simulator import (InstanceConfig, SimDisaggBackend,
                                  simulate_disaggregated)
from repro.core.workload import Request, with_cancellations
from repro.models.api import build_model
from repro.serving.cluster import DisaggCluster
from repro.serving.engine import Engine, KVBlob, Sequence, release_blob
from repro.serving.kv_cache import TRASH_PAGE

CFG = get_config("yi-6b-smoke")
LM = LatencyModel(CFG, hw.V5E)
L = CFG.num_layers
SLOW_BW = 1e3       # B/s: wire time dwarfs any measured compute time


@pytest.fixture(scope="module")
def params():
    return build_model(CFG).init(jax.random.PRNGKey(0))


def _assert_no_leaks(dc: DisaggCluster):
    """The checker family from test_serving_api, extended with the
    chunked-prefill surfaces: no resumable partials, no parked chunk
    segments, no granted-but-never-pulled reservations, no open
    streams."""
    assert not dc.tx.parked, "parked transfers leaked"
    assert not dc.tx.partial, "parked chunk segments leaked"
    assert not dc.tx._granted, "stream grants leaked"
    assert not dc._stream, "streamed routes leaked"
    for e in (*dc.prefill, *dc.decode):
        assert not e._partial, "resumable partial prefill leaked"
        assert len(e._slot_free) == e.max_batch, "batch slot leaked"
        if e._kv is None:
            continue
        kv = e._kv
        free = set(kv._free)
        assert len(free) + len(kv._refcnt) == kv.num_pages - 1
        assert free.isdisjoint(kv._refcnt)
        tree_pages = (e.prefix_cache.pages_in_tree()
                      if e.prefix_caching else [])
        assert free.isdisjoint(tree_pages)
        assert kv.used_pages == len(set(tree_pages)), \
            (kv.used_pages, len(set(tree_pages)))
        assert not kv._tables, f"block tables leaked: {kv._tables}"


# ---------------- scheduler: chunk-budget batches --------------------------

def test_form_batch_charges_chunk_budget():
    """With chunk_tokens, a long prompt charges only one chunk against
    the token budget, so it no longer monopolizes the batch."""
    q = FCFSQueue(token_of=lambda r: r.in_len)
    long, short = Request(0, 0.0, 100, 4), Request(1, 0.0, 16, 4)
    q.push(long)
    q.push(short)
    # unchunked: the 100-token prompt blows the 48-token budget alone
    assert q.form_batch(48) == [long]
    assert q.form_batch(48) == [short]
    q.push(long)
    q.push(short)
    # chunked: charges min(100, 32) + 16 <= 48 -> both fit one batch
    assert q.form_batch(48, chunk_tokens=32) == [long, short]
    # a resumable partial re-queues with a smaller token_of
    q.token_of = lambda r: max(r.in_len - 68, 0)
    q.push(long)
    assert q.form_batch(48, chunk_tokens=32) == [long]


def test_form_batch_skips_blocked_head_to_resumable():
    """A head-of-queue item that fails `can_take` (no free pages for a
    new reservation) must not strand resumable partials queued behind it
    — their reservations free only by finishing. New items are never
    reordered; the blocked head keeps its FCFS priority."""
    q = FCFSQueue(token_of=lambda r: r.in_len)
    blocked, part = Request(0, 0.0, 100, 4), Request(1, 0.0, 60, 4)
    started = {1}                       # rid 1 already holds its pages
    can_take = lambda r: r.rid in started
    resumable = lambda r: r.rid in started
    q.push(blocked)
    q.push(part)
    # without the escape hatch the queue wedges behind the blocked head
    assert q.form_batch(48, chunk_tokens=32, can_take=can_take) == []
    # with it, the in-flight partial drains past the head
    assert q.form_batch(48, chunk_tokens=32, can_take=can_take,
                        resumable=resumable) == [part]
    assert q.items == [blocked]
    assert q.queued_tokens == 100
    # nothing resumable behind the head: still empty, not a crash
    assert q.form_batch(48, chunk_tokens=32, can_take=can_take,
                        resumable=resumable) == []


# ---------------- transfer manager: per-segment streamed schedule ----------

def test_pull_streamed_degenerates_to_layered():
    """A single whole-blob park pulls on the identical per-layer
    schedule as pull_layered (same floats)."""
    tx = TransferManager(100.0, n_layers=4)
    tx.park_partial(0, 400, 1.0)
    tx.park(0, "blob", 400, 1.0)
    blob, t_first, t_full = tx.pull_streamed(0, 1.0)
    assert blob == "blob"
    assert (t_first, t_full) == (2.0, 5.0)
    assert tx.streamed_pulls == 1


def test_pull_streamed_segment_schedule():
    """Segments cross the wire serially, each no earlier than its ready
    time; admission waits only for the first layer of the LAST chunk."""
    tx = TransferManager(100.0, n_layers=4)
    tx.park_partial(0, 400, 1.0)        # ready 1.0, 4 s of wire
    tx.park_partial(0, 200, 2.0)        # ready 2.0, 2 s of wire
    tx.park(0, "blob", 600, 3.0)
    _, t_first, t_full = tx.pull_streamed(0, 3.0)
    # floor = pull time 3.0: seg1 -> 7.0, seg2 -> 9.0
    assert t_full == pytest.approx(9.0)
    # first layer of the last segment: 9 - 2 + 2/4
    assert t_first == pytest.approx(7.5)


def test_pull_streamed_grant_floor_backdates_wire():
    """A page grant lets parked segments start crossing before the pull:
    the schedule floors at the grant time, not the pull time."""
    tx = TransferManager(100.0, n_layers=4)
    tx.grant(0, 0.5)
    tx.park_partial(0, 400, 1.0)
    tx.park_partial(0, 200, 2.0)
    tx.park(0, "blob", 600, 3.0)
    _, t_first, t_full = tx.pull_streamed(0, 3.0)
    # floor 0.5: seg1 starts at its ready time 1.0 -> 5.0, seg2 -> 7.0
    assert t_full == pytest.approx(7.0)
    assert t_first == pytest.approx(5.5)
    assert tx.stream_saved_s > 0


def test_pull_streamed_trims_decode_resident_prefix():
    """When the decode side already holds a prefix, the ship size is
    smaller than the parked segments' sum: the overlap is trimmed off the
    front (oldest chunks), never the last chunk's admission gate."""
    tx = TransferManager(100.0, n_layers=4)
    tx.park_partial(1, 300, 0.0)
    tx.park_partial(1, 300, 1.0)
    tx.park(1, "blob", 450, 2.0)        # decode already holds 150 B
    _, t_first, t_full = tx.pull_streamed(1, 2.0)
    # seg1 trimmed to 150 B: 2.0 -> 3.5; seg2 full 3 s: -> 6.5
    assert t_full == pytest.approx(6.5)
    assert t_first == pytest.approx(6.5 - 3.0 + 3.0 / 4)


# ---------------- engine: chunked == one-shot prefill ----------------------

def test_engine_chunked_prefill_matches_oneshot(params):
    """The chunked state machine (paged context attention + in-place page
    writes) produces the same first token and the same wire KV as the
    one-shot prefill, for chunk sizes incl. non-multiples of the page
    size (non-final chunks round down to whole pages)."""
    rng = np.random.default_rng(0)
    toks = rng.integers(1, CFG.vocab_size, 50).tolist()
    base = Engine(CFG, params, max_batch=2, max_len=64, page_size=16)
    first_ref, blob_ref, _ = base.prefill_request(Sequence(0, list(toks), 4))
    cache_ref, n_ref = blob_ref
    assert n_ref == 50

    ps = 16
    for chunk in (16, 24, 40):
        eng = Engine(CFG, params, max_batch=2, max_len=64, page_size=ps)
        seq = Sequence(1, list(toks), 4)
        assert eng.can_start_chunked(seq)
        done, first, chunks = False, None, 0
        while not done:
            done, first, blob, _dt, c = eng.prefill_chunk(seq, chunk)
            chunks += 1
            if not done:
                # non-final chunks always end on a page boundary
                assert c == (c // ps) * ps and c >= ps
                assert seq.prefilled % ps == 0
        # non-final chunks round down to whole pages; the final chunk
        # takes the ragged tail: 16 -> 16*3+2, 24 -> 16+16+18, 40 -> 32+18
        assert chunks == {16: 4, 24: 3, 40: 2}[chunk]
        assert seq.prefilled == 50
        assert first == first_ref, chunk
        # the blob is fully page-backed: no dense KV was materialized
        assert isinstance(blob, KVBlob)
        assert blob.prefix_tokens == blob.n_tok == 50
        wire, n_tok = eng.materialize_wire(blob)
        assert n_tok == 50
        for name, seg in wire.items():
            ref = np.asarray(cache_ref[name]["k"][:, :, :50])
            np.testing.assert_allclose(np.asarray(seg["k"][:, :, :50]), ref,
                                       atol=1e-3, rtol=1e-3)
            refv = np.asarray(cache_ref[name]["v"][:, :, :50])
            np.testing.assert_allclose(np.asarray(seg["v"][:, :, :50]), refv,
                                       atol=1e-3, rtol=1e-3)
        # nothing left resident: pool fully drained
        assert not eng._partial
        assert eng._kv.used_pages == 0


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "chatglm3-6b",
                                  "moonshot-v1-16b-a3b"])
def test_engine_chunked_matches_oneshot_across_archs(arch):
    """Chunked == one-shot on every paged-capable arch family the engine
    serves token-only (dense, GQA, MoE); yi-6b is covered above and the
    VLM backbone needs frontend embeds the serving engine doesn't model."""
    cfg = get_config(arch + "-smoke")
    prms = build_model(cfg).init(jax.random.PRNGKey(0))
    toks = np.random.default_rng(1).integers(
        1, cfg.vocab_size, 20).tolist()
    base = Engine(cfg, prms, max_batch=2, max_len=32, page_size=8)
    first_ref, (cache_ref, n_ref), _ = base.prefill_request(
        Sequence(0, list(toks), 2))
    assert n_ref == 20

    eng = Engine(cfg, prms, max_batch=2, max_len=32, page_size=8)
    seq = Sequence(1, list(toks), 2)
    done, first = False, None
    while not done:                      # chunk 6 < page 8: rounds up to 8
        done, first, blob, _dt, _c = eng.prefill_chunk(seq, 6)
    assert first == first_ref
    wire, n_tok = eng.materialize_wire(blob)
    assert n_tok == 20
    for name, seg in wire.items():
        np.testing.assert_allclose(
            np.asarray(seg["k"][:, :, :20]),
            np.asarray(cache_ref[name]["k"][:, :, :20]),
            atol=1e-3, rtol=1e-3)
    release_blob(blob)
    assert eng._kv.used_pages == 0


# ---------------- cluster: chunked == unchunked tokens ---------------------

def _mixed_reqs():
    return [Request(0, 0.0, 100, 4), Request(1, 0.0, 17, 5),
            Request(2, 0.0, 64, 3), Request(3, 0.0, 33, 4)]


def _run_cluster(params, chunk, prefix=False):
    dc = DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_len=256,
                       paged=True, page_size=16, chunk_tokens=chunk,
                       prefix_cache=prefix, seed=0)
    res = dc.run(_mixed_reqs())
    _assert_no_leaks(dc)
    return res, dc


@pytest.mark.parametrize("chunk", [16, 24, 48])
def test_cluster_chunked_tokens_identical(params, chunk):
    """End-to-end: chunked prefill + per-chunk streaming migration is a
    timing-only change — token-for-token identical to the one-shot
    paged path, incl. chunk sizes that don't divide the page size."""
    base, _ = _run_cluster(params, None)
    got, dc = _run_cluster(params, chunk)
    assert set(got) == set(base)
    for rid in base:
        assert got[rid].tokens == base[rid].tokens, (chunk, rid)
    # multi-chunk prompts really streamed (not the legacy blob path)
    assert dc.tx.streamed_pulls > 0


def test_cluster_blocked_head_never_deadlocks_prefill(params):
    """Regression: with the prefill pool sized for two in-flight chunked
    prompts, a third prompt rotating to the head of the queue cannot
    reserve its residency. The resumable partials queued behind it must
    still drain (freeing their pages at pull time) instead of wedging the
    engine forever — previously form_batch returned [] on the blocked
    head with no retry scheduled, and the event loop emptied with every
    request stuck mid-prefill."""
    dc = DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_len=64,
                       paged=True, page_size=16, chunk_tokens=16,
                       prefill_num_pages=7, seed=0)   # 6 usable = 2 prompts
    res = dc.run([Request(i, 0.0, 48, 3) for i in range(3)])
    assert len(res) == 3
    for rid in range(3):
        assert res[rid].finish_reason == "length", rid
        assert len(res[rid].token_times) == 3
    _assert_no_leaks(dc)


def test_finalize_stream_defers_across_decode_failover(params):
    """Regression: a decode failure processed at the same timestamp as a
    queued finalize_stream re-routes the stream (pops the route, queues a
    fresh predispatch). The finalize handler must defer until the new
    route lands instead of KeyError-ing on the missing entry."""
    dc = DisaggCluster(CFG, params, n_prefill=1, n_decode=2, max_len=128,
                       chunk_tokens=16, transfer_bandwidth=SLOW_BW, seed=0)
    dc.submit(Request(0, 0.0, 48, 3))
    # run until the final chunk queued its finalize_stream event
    while not any(e[2] == "finalize_stream" for e in dc._ev._q):
        assert dc.step(), "stream never reached its final chunk"
    t_fin = next(e[0] for e in dc._ev._q if e[2] == "finalize_stream")
    di, _src, _skip = dc._stream[0]
    # the failure handler runs first at that same timestamp (its
    # predispatch lands *behind* the queued finalize)
    dc._on_fail_decode(di, t_fin)
    assert 0 not in dc._stream
    res = dc.drain()
    assert res[0].finish_reason == "length"
    assert len(res[0].token_times) == 3
    # the re-routed stream left nothing behind (the dead engine's
    # written-off reservation aside)
    assert not dc.tx.parked and not dc.tx.partial
    assert not dc.tx._granted and not dc._stream


def test_cluster_chunked_tokens_identical_with_prefix_cache(params):
    """Chunk 0 consumes the radix-tree hit (clamped to whole pages) and
    later chunks extend it: reuse + chunking together stay invisible in
    the output."""
    base, _ = _run_cluster(params, None, prefix=True)
    got, dc = _run_cluster(params, 32, prefix=True)
    for rid in base:
        assert got[rid].tokens == base[rid].tokens, rid
    assert dc.tx.streamed_pulls > 0


# ---------------- HOL relief (simulator, deterministic floats) -------------

def _hol_trace():
    return [Request(0, 0.0, 2000, 8), Request(1, 0.0, 64, 8)]


def test_sim_chunked_relieves_head_of_line_blocking():
    """A 2000-token prompt ahead of a 64-token one (budget < long prompt,
    so the long one runs alone unchunked): chunk-granular round-robin
    bounds the short prompt's wait to one chunk, cutting its TTFT by far
    more than the 2x the paper-level claim needs. (Uses the full yi-6b
    latency model: the smoke config is weight-bound, where a chunk costs
    as much as a full prefill and chunking can't help by construction.)"""
    lm = LatencyModel(get_config("yi-6b"), hw.V5E)
    P = InstanceConfig(Parallelism(1, 1), 1)
    D = InstanceConfig(Parallelism(1, 1), 1)
    r0, _ = simulate_disaggregated(_hol_trace(), lm, P, D, lm_tokens=512)
    r1, ex = simulate_disaggregated(_hol_trace(), lm, P, D, lm_tokens=512,
                                    chunk_tokens=128)
    ttft_base = next(r for r in r0 if r.rid == 1).first_token
    ttft_chnk = next(r for r in r1 if r.rid == 1).first_token
    assert ttft_chnk < 0.5 * ttft_base          # observed: ~6.7x better
    assert ex["streamed_pulls"] >= 1
    # every request still completes, long prompt included
    assert all(r.finish >= 0 for r in r1)


def test_sim_chunked_conserves_wire_bytes():
    """Chunks reassemble to the same KV: total migrated bytes are
    identical chunked vs unchunked (only the schedule changes)."""
    P = InstanceConfig(Parallelism(1, 1), 1)
    D = InstanceConfig(Parallelism(1, 1), 1)
    _, ex0 = simulate_disaggregated(_mixed_reqs(), LM, P, D,
                                    transfer_bw=1e3, lm_tokens=256)
    _, ex1 = simulate_disaggregated(_mixed_reqs(), LM, P, D,
                                    transfer_bw=1e3, lm_tokens=256,
                                    chunk_tokens=32)
    assert ex1["kv_bytes"] == pytest.approx(ex0["kv_bytes"], rel=1e-9)
    assert ex1["streamed_pulls"] >= 1
    assert ex1["kv_stream_saved_s"] >= 0


# ---------------- live == sim streamed charge parity -----------------------

def test_live_and_sim_chunked_charge_parity(params):
    """The streamed admission charge is the same float quantity in both
    worlds: segment bytes come from the identical kv-bytes deltas, and
    both admit at the first layer of the LAST chunk — exposed wire is
    w_last * (L-1)/L, with w_last the final chunk's segment."""
    live = DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_batch=2,
                         max_len=128, lm_tokens=96, chunk_tokens=32,
                         transfer_bandwidth=SLOW_BW)
    sim = SimDisaggBackend(LM, InstanceConfig(Parallelism(1, 1), 1),
                           InstanceConfig(Parallelism(1, 1), 1),
                           transfer_bw=SLOW_BW, lm_tokens=96,
                           chunk_tokens=32)
    reqs_l = [Request(0, 0.0, 80, 4)]           # chunks 32 + 32 + 16
    live.run(reqs_l)
    hs = [sim.submit(Request(0, 0.0, 80, 4))]
    sim.drain()
    rl, rs = reqs_l[0], hs[0].state.request
    # both sides parked the same three segment deltas -> same last wire
    w_last = (kv_bytes(CFG, 80) - kv_bytes(CFG, 64)) / SLOW_BW
    exposed = w_last - w_last / L
    assert rl.transfer_done - rl.decode_admit == pytest.approx(exposed,
                                                               rel=1e-9)
    assert rs.transfer_done - rs.decode_admit == pytest.approx(exposed,
                                                               rel=1e-9)
    assert rl.transfer_done - rl.decode_admit == pytest.approx(
        rs.transfer_done - rs.decode_admit, rel=1e-9)
    assert rl.decode_admit < rl.transfer_done
    assert live.tx.streamed_pulls == sim.tx.streamed_pulls == 1
    # earlier chunks crossed during prefill compute: overlap was realized
    assert live.tx.stream_saved_s > 0
    assert sim.tx.stream_saved_s > 0
    _assert_no_leaks(live)


# ---------------- cancellation: partial prefills never leak ----------------

def test_engine_partial_abort_fuzz_invariants(params):
    """Seeded fuzz over the chunked state machine: random interleavings
    of start / advance-one-chunk / abort / finish (with the radix tree
    in play) hold the allocator invariants at every step and drain the
    pool completely at the end."""
    rng = np.random.default_rng(7)
    eng = Engine(CFG, params, max_batch=4, max_len=32, page_size=4,
                 prefix_cache=True)
    kv = eng._kv
    sys_p = rng.integers(1, CFG.vocab_size, 8).tolist()
    active = {}
    next_rid = 0
    for _ in range(40):
        op = int(rng.integers(0, 4))
        if op == 0 or not active:               # start a new partial
            n = int(rng.integers(5, 30))
            toks = sys_p + rng.integers(1, CFG.vocab_size, n).tolist()
            seq = Sequence(next_rid, toks[:31], 4)
            if eng.can_start_chunked(seq):
                done, _f, blob, _dt, _c = eng.prefill_chunk(seq, 6)
                if done:
                    release_blob(blob)
                else:
                    active[next_rid] = seq
                next_rid += 1
        elif op in (1, 2):                      # advance a random partial
            rid = list(active)[int(rng.integers(0, len(active)))]
            seq = active[rid]
            done, _f, blob, _dt, _c = eng.prefill_chunk(seq, 6)
            if done:
                release_blob(blob)
                del active[rid]
        else:                                   # abort a random partial
            rid = list(active)[int(rng.integers(0, len(active)))]
            eng.abort_partial(active.pop(rid))

        free = set(kv._free)
        assert TRASH_PAGE not in free
        assert len(free) + len(kv._refcnt) == kv.num_pages - 1
        assert free.isdisjoint(kv._refcnt)
        tree_pages = eng.prefix_cache.pages_in_tree()
        assert free.isdisjoint(tree_pages)
        for rid, seq in active.items():         # partial tables stay live
            assert free.isdisjoint(kv.block_table(rid))
            assert seq.prefilled == eng._partial[rid].done
    for rid in list(active):
        eng.abort_partial(active.pop(rid))
    eng.prefix_cache.evict(10 ** 6)
    assert kv.free_pages == kv.num_pages - 1, "pages leaked"


def test_chunked_cancel_fuzz_no_leaks(params):
    """Random cancels across a bursty trace with chunking ON: cancels
    land mid-chunk (PREFILLING-with-progress), on parked segments, on
    granted-but-unfinished streams, and mid-decode. Invariants must hold
    and the cluster stays serviceable."""
    rng = np.random.default_rng(0)
    sys_p = tuple(rng.integers(1, CFG.vocab_size, 16).tolist())
    for trial in range(2):
        rr = np.random.default_rng(200 + trial)
        reqs = []
        for i in range(10):
            u = tuple(rr.integers(1, CFG.vocab_size,
                                  int(rr.integers(4, 20))).tolist())
            reqs.append(Request(i, i * 0.02, 16 + len(u), 4,
                                tokens=sys_p + u))
        reqs = with_cancellations(reqs, frac=0.5, seed=trial,
                                  mean_wait_s=0.3)
        dc = DisaggCluster(CFG, params, n_prefill=2, n_decode=1,
                           max_batch=4, max_len=64, lm_tokens=48,
                           chunk_tokens=16, prefix_cache=True,
                           decode_num_pages=3 * (64 // 16) + 1)
        res = dc.run(reqs)
        assert len(res) == 10
        for rid, r in res.items():
            if r.finish_reason != "cancelled":
                assert r.finish_reason in ("length", "stop")
                assert len(r.token_times) == 4
        _assert_no_leaks(dc)
        # the cluster stays serviceable: fresh traffic completes
        post = [Request(100 + i, 0.0, 12, 3) for i in range(3)]
        for r in post:
            dc.submit(r, t=dc.now)
        res2 = dc.drain()
        assert all(res2[100 + i].finish_reason == "length"
                   for i in range(3))
        _assert_no_leaks(dc)
