"""End-to-end behaviour tests for the paper's system: the full DistServe
pipeline — placement search -> live disaggregated cluster -> SLO metrics —
plus dry-run machinery units (no 512-device spawn here)."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, get_shape, long_context_ok
from repro.configs.shapes import input_specs
from repro.core import hw
from repro.core.latency_model import LatencyModel
from repro.core.workload import SHAREGPT, Request, derive_slos, sample_requests
from repro.launch.dryrun import parse_collectives, pick_mode
from repro.models.api import build_model
from repro.serving.cluster import DisaggCluster


def test_full_pipeline_smoke():
    """Placement decision (simulator) drives a live cluster layout; the
    cluster serves real traffic end to end."""
    cfg = get_config("yi-6b-smoke")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    # pretend the search chose 2 prefill + 1 decode (ratio from the paper)
    cluster = DisaggCluster(cfg, params, n_prefill=2, n_decode=1,
                            max_batch=4, max_len=64, lm_tokens=48)
    reqs = [Request(i, i * 0.02, 8 + i % 6, 4) for i in range(10)]
    res = cluster.run(reqs)
    assert len(res) == 10
    ttfts = [r.ttft for r in res.values()]
    tpots = [r.tpot for r in res.values()]
    assert all(t > 0 for t in ttfts)
    assert all(t >= 0 for t in tpots)


def test_input_specs_cover_all_cells():
    for name, cfg in ARCHS.items():
        for shape in SHAPES:
            if shape.name == "long_500k" and not long_context_ok(cfg):
                continue
            specs = input_specs(cfg, shape)
            leaves = jax.tree.leaves(specs)
            assert leaves, (name, shape.name)
            assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)


def test_long500k_skip_policy():
    skipped = {n for n, c in ARCHS.items() if not long_context_ok(c)}
    assert skipped == {"moonshot-v1-16b-a3b", "phi3-medium-14b", "yi-6b",
                       "chatglm3-6b", "internvl2-76b",
                       "seamless-m4t-large-v2"}


def test_pick_mode():
    assert pick_mode("yi-6b", "train") == "train"
    assert pick_mode("yi-6b", "decode") == "serve"
    # 2D weight sharding only amortizes at prefill; decode is pure TP with
    # the KV cache sharded over (data x model) (§Perf)
    assert pick_mode("mixtral-8x22b", "decode") == "serve"
    assert pick_mode("mixtral-8x22b", "prefill") == "serve_2d"
    assert pick_mode("internvl2-76b", "prefill") == "serve_2d"


def test_parse_collectives_on_crafted_hlo():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024] %x), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar.1 = f32[512]{0} all-reduce(f32[512] %y), replica_groups={{0,1}}, to_apply=%add
  %rs = f32[64]{0} reduce-scatter(f32[128] %z), replica_groups={{0,256}}, dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(bf16[8,8] %w), source_target_pairs={{0,1}}
"""
    out = parse_collectives(hlo, n_pod_boundary=256)
    assert out["n_ops"] == 4
    assert out["by_kind"]["all-gather"] == pytest.approx(
        16 * 1024 * 2 * 3 / 4)
    assert out["by_kind"]["all-reduce"] == pytest.approx(512 * 4 * 2 * 0.5)
    # reduce-scatter group {0,256} spans the pod boundary -> DCN
    assert out["dcn_bytes"] > 0
    assert out["ici_bytes"] > 0


def test_slo_derivation_orders():
    lm = LatencyModel(get_config("yi-6b"), hw.V5E)
    spec = derive_slos(SHAREGPT, lm)
    assert 0.001 < spec.slo_tpot < spec.slo_ttft < 10.0


def test_workload_sampler_respects_clips():
    reqs = sample_requests(SHAREGPT, 5.0, 500, seed=0)
    assert all(SHAREGPT.in_clip[0] <= r.in_len <= SHAREGPT.in_clip[1]
               for r in reqs)
    assert all(SHAREGPT.out_clip[0] <= r.out_len <= SHAREGPT.out_clip[1]
               for r in reqs)
    span = reqs[-1].arrive
    assert span == pytest.approx(500 / 5.0, rel=0.3)
