"""Training substrate: loss improves on learnable data, checkpoint restart
reproduces the exact trajectory, optimizer math."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.api import build_model
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, SyntheticTokens
from repro.training.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                      clip_by_global_norm, global_norm)
from repro.training.train_step import make_train_step

CFG = get_config("gemma3-1b-smoke")


def test_loss_decreases_on_learnable_stream():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, AdamWConfig(lr=2e-3, warmup_steps=5),
                                   remat=False, attn_blocks=(16, 16)),
                   donate_argnums=(0, 1))
    opt = adamw_init(params)
    data = SyntheticTokens(DataConfig(CFG.vocab_size, 8, 32))
    losses = []
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_checkpoint_restart_exact_trajectory():
    model = build_model(CFG)
    data = SyntheticTokens(DataConfig(CFG.vocab_size, 4, 24))
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3),
                                   remat=False, attn_blocks=(8, 8)))

    def run(params, opt, a, b):
        for i in range(a, b):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            params, opt, m = step(params, opt, batch)
        return params, opt, float(m["loss"])

    p0 = model.init(jax.random.PRNGKey(0))
    o0 = adamw_init(p0)
    # straight run 0..6
    p_a, o_a, loss_a = run(p0, o0, 0, 6)
    # run 0..3, checkpoint, restore, run 3..6
    p_b, o_b, _ = run(p0, o0, 0, 3)
    with tempfile.TemporaryDirectory() as td:
        ckpt.save(f"{td}/step_3", 3, p_b, o_b)
        s, p_c, o_c, _ = ckpt.restore(f"{td}/step_3", p_b, o_b)
    assert s == 3
    p_d, o_d, loss_d = run(p_c, o_c, 3, 6)
    assert loss_d == loss_a
    for x, y in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_d)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_adamw_moves_toward_minimum():
    params = {"w": jnp.array([10.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.5, weight_decay=0.0, warmup_steps=1)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}  # d/dw w^2
        params, opt = adamw_update(params, grads, opt, cfg)
    assert abs(float(params["w"][0])) < 0.5


def test_global_norm_clip():
    g = {"a": jnp.ones((4,)) * 3.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == 6.0
    assert float(global_norm(clipped)) < 1.0 + 1e-5


def test_data_pipeline_deterministic_and_sharded():
    d1 = SyntheticTokens(DataConfig(100, 8, 16), host_id=0, num_hosts=2)
    d2 = SyntheticTokens(DataConfig(100, 8, 16), host_id=1, num_hosts=2)
    b1a = d1.batch_at(5)
    b1b = d1.batch_at(5)
    np.testing.assert_array_equal(b1a["tokens"], b1b["tokens"])
    assert b1a["tokens"].shape == (4, 16)  # 8 global / 2 hosts
    assert not np.array_equal(b1a["tokens"], d2.batch_at(5)["tokens"])
