"""Fault-tolerance + replanning unit behaviour."""
import numpy as np

from repro.core.fault import (HeartbeatMonitor, SchedulerCheckpoint,
                              plan_failover)
from repro.core.replan import WorkloadProfiler, Replanner, drifted
from repro.core.workload import Request, SHAREGPT, sample_requests


def test_heartbeat_sweep_marks_dead():
    t = [0.0]
    mon = HeartbeatMonitor(timeout=1.0, now=lambda: t[0])
    mon.register("a")
    mon.register("b")
    t[0] = 0.5
    mon.beat("a")
    t[0] = 1.2
    dead = mon.sweep()
    assert dead == ["b"]
    assert mon.alive_ids() == {"a"}
    mon.beat("b")           # rejoin (elastic)
    assert mon.alive_ids() == {"a", "b"}


def test_failover_plan_policies():
    p = plan_failover("prefill", queued=[1, 2], running=[], parked=[3])
    assert p.redispatch == [1, 2] and p.reprefill == [3]
    d = plan_failover("decode", queued=[], running=[4, 5], parked=[])
    assert d.reprefill == [4, 5] and d.redispatch == []


def test_scheduler_checkpoint_roundtrip():
    state = {"queue": [1, 2, 3], "dispatch": {"1": "prefill0"}}
    raw = SchedulerCheckpoint.dump(state)
    assert SchedulerCheckpoint.load(raw) == state


def test_profiler_and_drift():
    prof = WorkloadProfiler()
    for r in sample_requests(SHAREGPT, 5.0, 128, seed=0):
        prof.observe(r)
    s1 = prof.stats()
    assert s1 is not None and abs(s1.rate - 5.0) / 5.0 < 0.4
    s2 = type(s1)(rate=s1.rate * 2, mean_in=s1.mean_in,
                  mean_out=s1.mean_out, n=s1.n)
    assert drifted(s1, s2)
    s3 = type(s1)(rate=s1.rate * 1.05, mean_in=s1.mean_in,
                  mean_out=s1.mean_out, n=s1.n)
    assert not drifted(s1, s3)


def test_replanner_triggers_on_shift():
    calls = []

    def search(spec, rate):
        calls.append((spec.name, rate))
        return "placement"

    rp = Replanner(search, slo_ttft=0.2, slo_tpot=0.05, check_every=64)
    for r in sample_requests(SHAREGPT, 2.0, 128, seed=1):
        rp.observe(r)
    assert rp.baseline is not None
    # shift: 5x the rate (arrivals compressed)
    shifted = sample_requests(SHAREGPT, 10.0, 256, seed=2)
    t0 = rp.profiler.window[-1].arrive
    for r in shifted:
        r.arrive += t0
        rp.observe(r)
    assert rp.replans >= 1
    assert rp.current_placement == "placement"
