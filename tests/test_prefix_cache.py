"""Shared-prefix KV reuse: radix tree semantics, refcounted page sharing,
token-identical outputs with the cache on vs off, and simulator-vs-live
prefix-hit routing parity."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import hw
from repro.core.latency_model import LatencyModel, Parallelism
from repro.core.simulator import InstanceConfig, simulate_disaggregated
from repro.core.workload import (Request, WorkloadSpec, sample_multi_turn,
                                 sample_requests)
from repro.models.api import build_model
from repro.serving.cluster import DisaggCluster
from repro.serving.kv_cache import KVCacheManager, TRASH_PAGE
from repro.serving.prefix_cache import RadixPrefixCache

CFG = get_config("yi-6b-smoke")


@pytest.fixture(scope="module")
def params():
    return build_model(CFG).init(jax.random.PRNGKey(0))


# ---------------- radix tree ----------------------------------------------

def test_radix_tree_page_granular_match_and_split():
    t = RadixPrefixCache(page_size=4)
    a = list(range(100, 112))                   # 3 pages
    t.insert(a)
    assert t.peek(a) == 12
    assert t.peek(a + [1, 2]) == 12             # deeper query, same match
    assert t.peek(a[:7]) == 4                   # partial page never matches
    assert t.peek([9] + a[1:]) == 0
    # diverge after page 1 -> edge splits at the page boundary
    b = a[:4] + [7, 7, 7, 7, 8, 8, 8, 8]
    t.insert(b)
    assert t.peek(b) == 12
    assert t.peek(a) == 12
    hit, pages = t.match(a)
    hit_b, pages_b = t.match(b)
    assert hit == hit_b == 12
    assert pages[0] == pages_b[0]               # shared first page
    assert set(pages[1:]).isdisjoint(pages_b[1:])
    # re-inserting an existing path adopts nothing
    assert t.insert(a) == 0


def test_radix_tree_lru_eviction_order():
    t = RadixPrefixCache(page_size=2)
    t.insert([1, 1])
    t.insert([2, 2])
    t.insert([3, 3])
    t.match([1, 1])                             # 1 is now most recent
    freed = t.evict(1)
    assert len(freed) == 1
    assert t.peek([2, 2]) == 0                  # LRU victim
    assert t.peek([1, 1]) == 2 and t.peek([3, 3]) == 2


def test_tree_eviction_respects_external_refs():
    kv = KVCacheManager(9, 4, max_len=16)
    t = RadixPrefixCache(page_size=4, allocator=kv)
    ta = kv.alloc(0, 8)                         # 2 pages
    t.insert(list(range(8)), ta)                # tree acquires both
    assert all(kv.ref(p) == 2 for p in ta)
    kv.free(0)                                  # only the tree holds them
    assert all(kv.ref(p) == 1 for p in ta)
    hit, pages = t.match(list(range(8)))
    kv.acquire(pages)                           # an active sequence pins it
    assert t.evict(10) == []                    # nothing evictable
    kv.release(pages)
    freed = t.evict(10)
    assert sorted(freed) == sorted(ta)          # now the subtree goes
    assert kv.free_pages == 8                   # pages are back in the pool


# ---------------- refcounted KVCacheManager -------------------------------

def test_kv_manager_shared_alloc_and_release():
    kv = KVCacheManager(9, 4, max_len=32)       # 8 usable pages
    ta = kv.alloc(0, 12)                        # 3 fresh pages
    assert [kv.ref(p) for p in ta] == [1, 1, 1]
    tb = kv.alloc(1, 12, shared=ta[:2])         # share 2, 1 fresh
    assert tb[:2] == ta[:2]
    assert kv.ref(ta[0]) == 2 and kv.ref(ta[2]) == 1
    assert kv.used_pages == 4
    assert kv.can_admit(12, n_shared=2) and not kv.can_admit(32)
    # releasing A keeps the shared pages alive for B
    assert kv.free(0) == 1                      # only A's private page freed
    assert kv.ref(tb[0]) == 1
    assert kv.free(1) == 3
    assert kv.free_pages == 8 and kv.used_pages == 0


def test_kv_manager_copy_on_write():
    kv = KVCacheManager(9, 4, max_len=32)
    ta = kv.alloc(0, 8)
    tb = kv.alloc(1, 8, shared=[ta[0]])
    assert kv.cow(0, 1) is None                 # private page: write in place
    old, new = kv.cow(1, 0)                     # shared page: private copy
    assert old == ta[0] and new not in ta
    assert kv.block_table(1)[0] == new
    assert kv.ref(old) == 1 and kv.ref(new) == 1
    kv.free(0)
    kv.free(1)
    assert kv.free_pages == 8


# ---------------- allocator invariants (property test) --------------------

try:        # hypothesis-gated: optional dep (see CHANGES.md PR 1)
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:
    st = None


def _check_invariants_under(ops, ps):
    """Drive random alloc(+shared prefix)/free/evict/insert interleavings:
    the free list stays disjoint from every live block table and from the
    tree, and page counts are conserved."""
    num_pages = 33
    kv = KVCacheManager(num_pages, ps, max_len=8 * ps)
    tree = RadixPrefixCache(ps, allocator=kv)
    rng = np.random.default_rng(0)
    live = {}                   # rid -> token prefix list
    next_rid = 0
    for kind, n_tok, evict_n in ops:
        kind = kind % 4
        n_tok = min(n_tok, 8 * ps - 1)      # engine asserts S < max_len
        if kind in (0, 1):      # alloc (prefix-matched), maybe insert
            toks = rng.integers(0, 3, size=n_tok).tolist()
            hit, pages = tree.match(toks)
            hit = min(hit, ((n_tok - 1) // ps) * ps)
            pages = pages[:hit // ps]
            kv.acquire(pages)       # pin before eviction can run (engine
                                    # order: match -> pin -> evict -> alloc)
            if kv.pages_for(n_tok) - len(pages) > kv.free_pages:
                tree.evict(kv.pages_for(n_tok) - len(pages) - kv.free_pages)
            if kv.pages_for(n_tok) - len(pages) <= kv.free_pages:
                table = kv.alloc(next_rid, n_tok, shared=pages)
                live[next_rid] = toks
                if kind == 0:
                    tree.insert(toks[:(n_tok // ps) * ps],
                                table[:n_tok // ps])
                next_rid += 1
            kv.release(pages)       # unpin (block table holds its own ref)
        elif kind == 2 and live:        # free a random live sequence
            rid = list(live)[n_tok % len(live)]
            kv.free(rid)
            del live[rid]
        elif kind == 3:
            tree.evict(evict_n)

        # ---- invariants ------------------------------------------------
        free = set(kv._free)
        assert TRASH_PAGE not in free
        tree_pages = tree.pages_in_tree()
        assert len(set(tree_pages)) == len(tree_pages)
        tabled = set()
        for rid in live:
            tabled |= set(kv.block_table(rid))
        assert free.isdisjoint(tabled), "freed page still in a block table"
        assert free.isdisjoint(tree_pages), "freed page still in the tree"
        # conservation: every non-trash page is free xor refcounted
        assert len(free) + len(kv._refcnt) == num_pages - 1
        assert free.isdisjoint(kv._refcnt)
        # refcounts bound the observable owners
        for p, c in kv._refcnt.items():
            owners = sum(p in set(kv.block_table(r)) for r in live)
            owners += tree_pages.count(p)
            assert c >= owners, (p, c, owners)
    for rid in list(live):
        kv.free(rid)
    tree.evict(10 ** 6)
    assert kv.free_pages == num_pages - 1, "pages leaked"


if st is not None:
    @given(st.lists(st.tuples(st.integers(0, 5), st.integers(1, 40),
                              st.integers(0, 3)),
                    min_size=1, max_size=60),
           st.integers(2, 7))
    @settings(max_examples=60, deadline=None)
    def test_pages_conserved_under_random_interleavings(ops, ps):
        _check_invariants_under(ops, ps)


def test_pages_conserved_seeded_fuzz():
    """Deterministic stand-in for the hypothesis property test so the
    invariants are exercised even without the optional dep."""
    rng = np.random.default_rng(42)
    for ps in (2, 3, 5):
        for _ in range(12):
            ops = [(int(rng.integers(0, 6)), int(rng.integers(1, 41)),
                    int(rng.integers(0, 4)))
                   for _ in range(int(rng.integers(1, 60)))]
            _check_invariants_under(ops, ps)


# ---------------- token equality: cache on == cache off -------------------

def _shared_prefix_trace(n=6, seed=1):
    rr = np.random.default_rng(seed)
    sys_p = tuple(rr.integers(1, CFG.vocab_size, 16).tolist())
    out = []
    for i in range(n):
        u = tuple(rr.integers(1, CFG.vocab_size, 5 + i).tolist())
        out.append(Request(i, i * 0.5, 16 + len(u), 5, tokens=sys_p + u))
    return out


def test_prefix_cache_tokens_match_cache_off(params):
    """Reuse must be invisible in the output: suffix-only prefill over
    shared pages + suffix-only transfer must produce token-identical
    results (extends the paged==dense equality family)."""
    on = DisaggCluster(CFG, params, n_prefill=2, n_decode=2, max_batch=4,
                       max_len=64, lm_tokens=48, prefix_cache=True)
    off = DisaggCluster(CFG, params, n_prefill=2, n_decode=2, max_batch=4,
                        max_len=64, lm_tokens=48)
    r_on = on.run(_shared_prefix_trace())
    r_off = off.run(_shared_prefix_trace())
    assert set(r_on) == set(r_off)
    for rid in r_on:
        assert r_on[rid].tokens == r_off[rid].tokens, rid
    # the cache actually engaged: hits recorded, compute + bytes saved
    assert sum(r.prefix_hit for r in r_on.values()) > 0
    assert sum(r.decode_hit for r in r_on.values()) > 0
    assert (sum(e.prefill_tokens for e in on.prefill)
            < sum(e.prefill_tokens for e in off.prefill))
    assert on.tx.total_bytes < off.tx.total_bytes
    stats = on.prefix_stats()
    assert stats["prefill"]["hit_tokens"] > 0
    assert stats["decode"]["matched_pages"] > 0


def test_decode_pool_pressure_reclaims_tree_pages(params):
    """Prompts with distinct full pages make the decode tree retain one
    extra page per request; a pool sized for ~3 residents must reclaim
    LRU subtrees under admission pressure (never deadlock the pull loop)
    and outputs must stay correct."""
    def trace(seed=2):
        rr = np.random.default_rng(seed)
        sys_p = tuple(rr.integers(1, CFG.vocab_size, 16).tolist())
        return [Request(i, i * 0.5, 36, 4,
                        tokens=sys_p
                        + tuple(rr.integers(1, CFG.vocab_size, 20).tolist()))
                for i in range(8)]
    base = DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_batch=8,
                         max_len=64, lm_tokens=48)
    tight = DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_batch=8,
                          max_len=64, lm_tokens=48, prefix_cache=True,
                          decode_num_pages=10)      # 9 usable pages
    r_base = base.run(trace())
    r_tight = tight.run(trace())
    assert len(r_tight) == 8
    for rid in r_tight:
        assert r_tight[rid].tokens == r_base[rid].tokens, rid
    assert tight.decode[0].prefix_cache.stats.evicted_pages > 0
    # after drain, only tree-retained pages remain allocated
    assert tight.decode[0]._kv.used_pages == \
        tight.decode[0].prefix_cache.num_pages()


def test_admission_liveness_under_bursty_pins(params):
    """Bursty mixed-prefix traffic against a tight decode pool: prefix
    pins taken for later-queued requests must never wedge the head's
    admission (the cluster's liveness fallback drops pins and falls back
    to full-blob transfer). Every request must complete."""
    rr = np.random.default_rng(5)
    prompts = [tuple(rr.integers(1, CFG.vocab_size, 36).tolist())
               for _ in range(3)]
    reqs = [Request(i, i * 0.01, 36, 4, tokens=prompts[i % 3])
            for i in range(9)]
    dc = DisaggCluster(CFG, params, n_prefill=2, n_decode=1, max_batch=8,
                       max_len=64, lm_tokens=48, prefix_cache=True,
                       decode_num_pages=8)          # 7 usable pages
    res = dc.run(reqs)
    assert len(res) == 9
    assert all(r.finish >= 0 for r in res.values())
    assert not dc.tx.parked                         # nothing stranded


def test_prefix_cache_survives_pool_pressure(params):
    """A prefill pool too small to retain every prefix must evict LRU
    subtrees (or fall back to stitching) and still serve correct tokens."""
    reqs = _shared_prefix_trace(n=8)
    tight = DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_batch=4,
                          max_len=64, lm_tokens=48, prefix_cache=True,
                          prefill_num_pages=9)     # 8 usable pages
    loose = DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_batch=4,
                          max_len=64, lm_tokens=48)
    r1 = tight.run(_shared_prefix_trace(n=8))
    r2 = loose.run(_shared_prefix_trace(n=8))
    assert len(r1) == len(reqs)
    for rid in r1:
        assert r1[rid].tokens == r2[rid].tokens, rid


def test_fused_prefix_tokens_match_dense_fallback(params):
    """The fused prefix_prefill kernel path (default) and the dense
    gather-then-flash fallback are the same math: a multi-turn shared
    prefix trace must produce token-identical outputs with hits engaged
    on both sides."""
    fused = DisaggCluster(CFG, params, n_prefill=2, n_decode=2, max_batch=4,
                          max_len=64, lm_tokens=48, prefix_cache=True)
    dense = DisaggCluster(CFG, params, n_prefill=2, n_decode=2, max_batch=4,
                          max_len=64, lm_tokens=48, prefix_cache=True,
                          fused_prefix=False)
    assert all(e.fused_prefix for e in fused.prefill)
    assert not any(e.fused_prefix for e in dense.prefill)
    r_f = fused.run(_shared_prefix_trace())
    r_d = dense.run(_shared_prefix_trace())
    assert set(r_f) == set(r_d)
    for rid in r_f:
        assert r_f[rid].tokens == r_d[rid].tokens, rid
    # both really took the prefix path, not full recompute
    assert sum(r.prefix_hit for r in r_f.values()) > 0
    assert sum(r.prefix_hit for r in r_f.values()) == \
        sum(r.prefix_hit for r in r_d.values())


def test_jit_cache_bounded_by_pow2_buckets(params):
    """Distinct prefix page counts must collapse onto O(log pages) jit
    entries — unbounded per-length compilation is the failure mode this
    pins (one compile per distinct prefix length in long-running
    serving)."""
    from repro.serving.engine import Engine
    eng = Engine(CFG, params, max_batch=4, max_len=64, page_size=4,
                 prefix_cache=True)
    pps = 16                                    # 64 / 4 pages per sequence
    assert eng._bucket_pages(0) == 0
    for n in range(1, pps + 1):
        b = eng._bucket_pages(n)
        assert n <= b <= pps
        assert b == pps or (b & (b - 1)) == 0   # pow2, capped at pps
    # drive every distinct count through both compile caches
    for n in range(1, pps + 1):
        eng._get_gather_fn(eng._bucket_pages(n))
        eng._get_fused_suffix_fn(16, eng._bucket_pages(n))
    assert len(eng._gather_fn) <= 5             # {1, 2, 4, 8, 16}
    assert len(eng._fused_fn) <= 5


# ---------------- simulator vs live: prefix-hit routing -------------------

def _multi_turn_trace():
    """3 sessions burst their first turns (load spreads them over the
    prefill fleet), later turns arrive spaced and must follow their
    session's cached prefix (affinity routing, hit > 0)."""
    rr = np.random.default_rng(7)
    reqs = []
    hist = []
    for s in range(3):
        prompt = tuple(rr.integers(1, CFG.vocab_size, 18 + 4 * s).tolist())
        hist.append(prompt)
        reqs.append(Request(len(reqs), 0.0, len(prompt), 4, tokens=prompt))
    for turn in range(2):
        for s in range(3):
            grown = hist[s] + tuple(
                rr.integers(1, CFG.vocab_size, 7 + 2 * s).tolist())
            hist[s] = grown
            reqs.append(Request(len(reqs), 50.0 * (turn + 1) + s,
                                len(grown), 4, tokens=grown))
    return reqs


def test_sim_and_live_report_same_prefix_hit_routing(params):
    """The simulator's prefix model and the live engines' radix trees run
    the same code: every prefill routing decision — instance AND hit
    length — must agree on a multi-turn trace."""
    lm = LatencyModel(CFG, hw.V5E)
    _, extras = simulate_disaggregated(
        _multi_turn_trace(), lm, InstanceConfig(Parallelism(1, 1), 3),
        InstanceConfig(Parallelism(1, 1), 1))
    sim = extras["decisions"]

    dc = DisaggCluster(CFG, params, n_prefill=3, n_decode=1, max_batch=8,
                       max_len=128, lm_tokens=64, prefix_cache=True)
    res = dc.run(_multi_turn_trace())
    live = dc.dispatcher.decisions

    assert len(res) == 9
    sim_pre = [d for d in sim if d[0] == "prefill"]
    live_pre = [d for d in live if d[0] == "prefill"]
    assert sim_pre == live_pre
    # later turns really followed their prefix to distinct instances
    affine = [(idx, hit) for _, _, idx, hit in sim_pre[3:] if hit > 0]
    assert len(affine) == 6
    assert len({idx for idx, _ in affine}) == 3
    # decode side (single instance): shipped-suffix hit lengths also agree
    sim_dec = [d for d in sim if d[0] == "decode"]
    live_dec = [d for d in live if d[0] == "decode"]
    assert sorted(sim_dec) == sorted(live_dec)
    assert extras["prefix"]["hit_tokens"] == \
        sum(r.prefix_hit for r in res.values())


# ---------------- workload generator --------------------------------------

def test_multi_turn_generator_shapes():
    spec = WorkloadSpec("w", 2.0, 0.5, (4, 64), 1.5, 0.3, (2, 8),
                        slo_ttft=1.0, slo_tpot=1.0,
                        sys_len=8, turns=3, share=1.0)
    reqs = sample_multi_turn(spec, rate=3.0, n=12, seed=0, vocab=100)
    assert len(reqs) == 12
    assert all(r.tokens is not None and len(r.tokens) == r.in_len
               for r in reqs)
    assert all(reqs[i].arrive <= reqs[i + 1].arrive
               for i in range(len(reqs) - 1))
    assert [r.rid for r in reqs] == list(range(12))
    # share=1.0 -> every session opens with the same system prompt
    firsts = {r.tokens[:8] for r in reqs}
    assert len(firsts) == 1
    # sample_requests delegates when the spec carries prefix fields
    via = sample_requests(spec, 3.0, 12, seed=0)
    assert via[0].tokens is not None


def test_simulator_models_prefix_savings():
    """Prefill busy time and wire bytes must drop when the cache is
    modeled — the signal the placement goodput search consumes."""
    lm = LatencyModel(get_config("yi-6b"), hw.V5E)
    spec = dataclasses.replace(
        WorkloadSpec("w", 5.0, 1.0, (4, 1024), 4.0, 0.5, (4, 64),
                     slo_ttft=1.0, slo_tpot=1.0),
        sys_len=256, turns=3, share=0.9)
    reqs = sample_multi_turn(spec, rate=2.0, n=60, seed=3)

    def go(on):
        return simulate_disaggregated(
            [dataclasses.replace(r) for r in reqs], lm,
            InstanceConfig(Parallelism(1, 1), 1),
            InstanceConfig(Parallelism(1, 1), 1), prefix_cache=on)
    _, ex_on = go(True)
    _, ex_off = go(False)
    assert all(r.finish >= 0 for r in go(True)[0])
    hit_rate = ex_on["prefix"]["hit_tokens"] / ex_on["prefix"]["prompt_tokens"]
    assert hit_rate > 0.4
    busy_on = ex_on["breakdown"]["prefill_busy_s"]
    busy_off = ex_off["breakdown"]["prefill_busy_s"]
    assert busy_on < 0.75 * busy_off
    assert ex_on["kv_bytes"] < 0.75 * ex_off["kv_bytes"]
