"""Hypothesis property tests on system invariants."""
import math

import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.configs import get_config
from repro.core import hw
from repro.core.latency_model import LatencyModel, Parallelism
from repro.core.scheduler import FCFSQueue, least_loaded, shortest_queue
from repro.core.kv_transfer import kv_bytes

CFG = get_config("yi-6b")
LM = LatencyModel(CFG, hw.V5E)
MOE = get_config("mixtral-8x22b")
SSM = get_config("mamba2-2.7b")


# ---------------- latency model ------------------------------------------

@given(st.integers(16, 8192), st.integers(16, 8192))
@settings(max_examples=40, deadline=None)
def test_prefill_time_monotone_in_tokens(a, b):
    lo, hi = sorted((a, b))
    par = Parallelism(1, 1)
    assert LM.prefill_time([lo], par) <= LM.prefill_time([hi], par) + 1e-12


@given(st.integers(1, 512), st.integers(1, 512), st.integers(0, 20))
@settings(max_examples=40, deadline=None)
def test_decode_time_monotone_in_batch_and_ctx(b1, b2, ctx_k):
    lo, hi = sorted((b1, b2))
    par = Parallelism(1, 1)
    ctx = ctx_k * 1024
    assert (LM.decode_time(lo, ctx, par)
            <= LM.decode_time(hi, ctx, par) + 1e-12)
    assert (LM.decode_time(lo, ctx, par)
            <= LM.decode_time(lo, ctx + 4096, par) + 1e-12)


@given(st.sampled_from([1, 2, 4, 8]), st.integers(64, 4096))
@settings(max_examples=30, deadline=None)
def test_tp_never_slows_prefill(tp, tokens):
    t1 = LM.prefill_time([tokens], Parallelism(1, 1))
    t2 = LM.prefill_time([tokens], Parallelism(tp, 1))
    assert t2 <= t1 * 1.05


@given(st.integers(1, 64), st.integers(128, 32768))
@settings(max_examples=30, deadline=None)
def test_moe_active_params_bounded(batch, _):
    full = MOE.num_params() * 2
    active = LatencyModel(MOE, hw.V5E).active_param_bytes(batch)
    assert active <= full * 1.001
    assert active >= full * 0.05


@given(st.integers(1, 32768))
@settings(max_examples=30, deadline=None)
def test_kv_bytes_families(prompt):
    dense = kv_bytes(get_config("phi3-medium-14b"), prompt)
    assert dense == get_config("phi3-medium-14b").kv_bytes_per_token() * prompt
    # SSM state is constant in prompt length
    assert kv_bytes(SSM, prompt) == kv_bytes(SSM, 1)
    # SWA caps at the window
    mix = get_config("mixtral-8x22b")
    assert kv_bytes(mix, prompt) <= kv_bytes(mix, mix.sliding_window)


# ---------------- scheduler ----------------------------------------------

@given(st.lists(st.integers(1, 2000), min_size=1, max_size=40),
       st.integers(64, 4096))
@settings(max_examples=60, deadline=None)
def test_fcfs_batch_budget_and_order(lens, budget):
    q = FCFSQueue(token_of=lambda x: x[1])
    for i, l in enumerate(lens):
        q.push((i, l))
    seen = []
    while len(q):
        batch = q.form_batch(budget)
        assert batch, "batch never empty while queue nonempty"
        tok = sum(b[1] for b in batch)
        # only an oversized head may exceed the budget, and then alone
        if tok > budget:
            assert len(batch) == 1
        seen.extend(b[0] for b in batch)
    assert seen == sorted(seen), "FCFS order must be preserved"


@given(st.lists(st.integers(0, 100), min_size=1, max_size=16))
@settings(max_examples=40, deadline=None)
def test_least_loaded_picks_min(loads):
    assert loads[least_loaded(loads)] == min(loads)


# ---------------- checkpoint roundtrip (randomized trees) -----------------

@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_checkpoint_roundtrip_random(seed):
    import tempfile
    import jax.numpy as jnp
    from repro.training import checkpoint as ckpt
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.normal(size=(3, 5)).astype(np.float32)),
            "b": {"c": jnp.asarray(rng.integers(0, 10, (4,), dtype=np.int32)),
                  "d": jnp.asarray(rng.normal(size=(2, 2)).astype(np.float32))}}
    with tempfile.TemporaryDirectory() as td:
        ckpt.save(f"{td}/step_1", 1, tree)
        step, restored, _, _ = ckpt.restore(f"{td}/step_1", tree)
    assert step == 1
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------- partitioning rules --------------------------------------

@given(st.lists(st.sampled_from([1, 2, 3, 4, 6, 8, 16, 32, 48, 64, 100,
                                 128, 256, 1024]),
                min_size=1, max_size=4))
@settings(max_examples=60, deadline=None)
def test_partition_rules_valid_specs(dims):
    """Resolved specs never shard a non-divisible dim and never reuse a
    mesh axis within one spec."""
    from repro.launch.partitioning import make_rules
    import jax as _jax

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    rules = make_rules(FakeMesh(), "train")
    logical = ["batch", "embed", "mlp", "heads"][: len(dims)]
    spec = rules.resolve(logical, dims)
    used = []
    for entry, dim in zip(tuple(spec) + (None,) * (len(dims) - len(spec)), dims):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = math.prod(FakeMesh.shape[a] for a in axes)
        assert dim % size == 0
        for a in axes:
            assert a not in used
            used.append(a)
