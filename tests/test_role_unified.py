"""Role-unified serving: per-instance prefill/decode/mixed roles as
runtime state, in both worlds.

Pins, in rough order of load-bearing-ness:

- legacy shim identity: the disagg/colocated entrypoints are role
  vectors over the unified backends and schedule byte-identically;
- sim == live parity (live under the deterministic `EngineCharge`)
  for the *dynamic* paths — a mid-run role flip and chunked-prefill
  absorption — compared on per-request token timestamps (the decision
  *indices* legitimately differ across worlds while an instance drains:
  the live fleet keeps failed/draining instances in the candidate list
  with an `alive` mask, the sim filters them out);
- role flips never leak KV: a drain-completed decode->prefill flip
  asserts an empty page pool, and a randomized flip fuzz on the live
  cluster checks the allocator invariants after drain;
- `RoleController` hysteresis: backlog flips a decode instance to
  prefill, KV pressure flips one back, cooldown and floors hold;
- `mode_search` returns the best role vector and `fleet_search
  (search_modes=True)` + `elastic_callback` re-role a live fleet;
- hierarchical fleets: a router-of-routers is a `ServingBackend` like
  any other — deterministic decisions, same results as its flat
  equivalent, leak-free drain.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import hw
from repro.core.latency_model import EngineCharge, LatencyModel, Parallelism
from repro.core.placement import ModePlacement, mode_candidates, mode_search
from repro.core.replan import RoleController
from repro.core.simulator import (InstanceConfig, SimColocatedBackend,
                                  SimDisaggBackend, SimServingBackend,
                                  simulate_roles)
from repro.core.telemetry import MetricsRegistry
from repro.core.workload import SHAREGPT, Request
from repro.models.api import build_model
from repro.serving.cluster import (ColocatedCluster, DisaggCluster,
                                   ServingCluster)
from repro.serving.router import (FleetPlan, FleetRouter, OverloadDetector,
                                  elastic_callback, fleet_search,
                                  replica_kv_utilization)

CFG = get_config("yi-6b-smoke")
LM = LatencyModel(CFG, hw.V5E)          # smoke scale: paired with live
LM_FULL = LatencyModel(get_config("yi-6b"), hw.V5E)     # sim-only
PAR = Parallelism(1, 1)
SLOW_BW = 1e3


@pytest.fixture(scope="module")
def params():
    return build_model(CFG).init(jax.random.PRNGKey(0))


def _trace(n=6, gap=3.0, in_len=48, out_len=4):
    return [Request(i, i * gap, in_len, out_len) for i in range(n)]


def _submit_run(backend, reqs, flips=()):
    """Run a trace with optional timed role flips ((t, g, role), ...)."""
    for r in reqs:
        backend.submit(dataclasses.replace(r))
    for t, g, role in sorted(flips):
        backend.run_until(t)
        backend.set_role(g, role, now=t)
    backend.drain()
    return backend


def _token_times(backend):
    return {rid: [e.t for e in st.events]
            for rid, st in backend.states.items()}


def _assert_live_no_leaks(c: ServingCluster):
    assert not c.tx.parked, "parked transfers leaked"
    for e in (*c.prefill, *c.decode, *c.engines):
        assert len(e._slot_free) == e.max_batch, "batch slot leaked"
        if e._kv is None:
            continue
        kv = e._kv
        free = set(kv._free)
        assert len(free) + len(kv._refcnt) == kv.num_pages - 1
        assert free.isdisjoint(kv._refcnt)
        tree_pages = (e.prefix_cache.pages_in_tree()
                      if e.prefix_caching else [])
        assert kv.used_pages == len(set(tree_pages))
        assert not kv._tables, f"block tables leaked: {kv._tables}"


# ---------------- legacy shims == role vectors -----------------------------

def test_sim_disagg_shim_is_role_vector():
    reqs = _trace(8, gap=0.4, out_len=8)
    legacy = _submit_run(SimDisaggBackend(
        LM_FULL, InstanceConfig(PAR, 2), InstanceConfig(PAR, 2),
        transfer_bw=SLOW_BW), reqs)
    unified = _submit_run(SimServingBackend(
        LM_FULL, [("prefill", PAR)] * 2 + [("decode", PAR)] * 2,
        transfer_bw=SLOW_BW), reqs)
    assert _token_times(legacy) == _token_times(unified)
    assert legacy.disp.decisions == unified.disp.decisions


def test_sim_colocated_shim_is_all_mixed():
    reqs = _trace(8, gap=0.4, out_len=8)
    legacy = _submit_run(SimColocatedBackend(
        LM_FULL, InstanceConfig(PAR, 2)), reqs)
    unified = _submit_run(SimServingBackend(
        LM_FULL, [("mixed", PAR)] * 2, prefix_cache=False), reqs)
    assert _token_times(legacy) == _token_times(unified)


def test_live_disagg_shim_is_role_vector(params):
    reqs = _trace(4, gap=2.0)
    kw = dict(max_len=128, lm_tokens=128, transfer_bandwidth=SLOW_BW,
              charge=EngineCharge(LM, PAR), seed=0)
    legacy = _submit_run(DisaggCluster(CFG, params, n_prefill=1,
                                       n_decode=1, **kw), reqs)
    unified = _submit_run(ServingCluster(CFG, params,
                                         ["prefill", "decode"], **kw), reqs)
    assert _token_times(legacy) == _token_times(unified)
    assert legacy.dispatcher.decisions == unified.dispatcher.decisions
    for rid, res in legacy.results.items():
        assert res.tokens == unified.results[rid].tokens, rid
    _assert_live_no_leaks(legacy)
    _assert_live_no_leaks(unified)


def test_live_colocated_shim_is_all_mixed(params):
    reqs = _trace(4, gap=2.0)
    kw = dict(max_len=128, charge=EngineCharge(LM, PAR), seed=0)
    legacy = _submit_run(ColocatedCluster(CFG, params, n_engines=2, **kw),
                         reqs)
    unified = _submit_run(ServingCluster(CFG, params, ["mixed", "mixed"],
                                         **kw), reqs)
    assert _token_times(legacy) == _token_times(unified)
    for rid, res in legacy.results.items():
        assert res.tokens == unified.results[rid].tokens, rid


# ---------------- dynamic paths: sim == live under EngineCharge ------------

FLIP_KW = dict(lm_tokens=128, chunk_tokens=32, max_prefill_tokens=512)


def _live_flip(params, roles, reqs, flips, **kw):
    c = ServingCluster(CFG, params, list(roles), max_len=128,
                       transfer_bandwidth=SLOW_BW,
                       charge=EngineCharge(LM, PAR), seed=0,
                       **FLIP_KW, **kw)
    return _submit_run(c, reqs, flips)


def _sim_flip(roles, reqs, flips, **kw):
    b = SimServingBackend(LM, [(r, PAR) for r in roles],
                          transfer_bw=SLOW_BW, **FLIP_KW, **kw)
    return _submit_run(b, reqs, flips)


def test_reroling_parity_sim_vs_live(params):
    """decode->prefill (drains, pool must empty) then prefill->decode
    (immediate) mid-trace: both worlds emit float-identical token
    timestamps, and the role-change logs line up."""
    reqs = _trace(6, gap=4.0)
    flips = [(9.0, 2, "prefill"), (17.0, 0, "decode")]
    live = _live_flip(params, ["prefill", "decode", "decode"], reqs, flips)
    sim = _sim_flip(["prefill", "decode", "decode"], reqs, flips)
    assert live.roles == sim.roles == ["decode", "decode", "prefill"]
    assert _token_times(live) == _token_times(sim)
    assert ([(t, role) for t, _lane, role in live.extras()["role_events"]]
            == [(t, role) for t, _lane, role in sim.extras()["role_events"]])
    assert all(st.done for st in live.states.values())
    for res in live.results.values():
        assert res.finish_reason == "length"
    _assert_live_no_leaks(live)


def test_absorption_parity_sim_vs_live(params):
    """Prefill saturation spills whole prompts to the decode instance,
    which chunk-prefills them in place: same absorbed count, same
    timestamps in both worlds."""
    reqs = [Request(0, 0.0, 96, 4), Request(1, 0.0, 96, 4),
            Request(2, 0.0, 64, 4), Request(3, 8.0, 48, 4)]
    live = _live_flip(params, ["prefill", "decode"], reqs, (),
                      absorb_tokens=64)
    sim = _sim_flip(["prefill", "decode"], reqs, (), absorb_tokens=64)
    assert live.extras().get("absorbed", 0) > 0
    assert live.extras().get("absorbed") == sim.extras().get("absorbed")
    absorbs = [d for d in live.dispatcher.decisions if d[0] == "absorb"]
    assert absorbs and absorbs == [d for d in sim.disp.decisions
                                   if d[0] == "absorb"]
    assert _token_times(live) == _token_times(sim)
    _assert_live_no_leaks(live)


# ---------------- role flips never leak pages ------------------------------

def test_decode_flip_empties_pool_sim():
    be = SimServingBackend(LM_FULL, [("prefill", PAR), ("decode", PAR),
                                     ("decode", PAR)], transfer_bw=SLOW_BW)
    for r in _trace(6, gap=0.5, out_len=16):
        be.submit(r)
    be.run_until(2.0)
    be.set_role(1, "prefill")           # mid-decode: drains in place
    be.drain()
    assert be.roles[1] == "prefill"
    for d in be.D:
        assert d.pool.used == 0
    assert all(s.done for s in be.states.values())


@pytest.mark.parametrize("seed", [0, 1])
def test_role_flip_fuzz_no_leaks_live(params, seed):
    """Randomized mid-run flips on a live 3-instance fleet: every
    request still finishes, every page comes back."""
    rng = np.random.default_rng(seed)
    roles = ["prefill", "decode", "decode"]
    c = ServingCluster(CFG, params, roles, max_len=128,
                       transfer_bandwidth=SLOW_BW,
                       charge=EngineCharge(LM, PAR), seed=seed,
                       **FLIP_KW, absorb_tokens=256)
    reqs = [Request(i, float(rng.uniform(0, 12.0)), int(rng.integers(24, 72)),
                    int(rng.integers(2, 6))) for i in range(6)]
    for r in sorted(reqs, key=lambda r: r.arrive):
        c.submit(r)
    for t in sorted(rng.uniform(1.0, 20.0, size=3)):
        c.run_until(float(t))
        g = int(rng.integers(0, 3))
        role = ["prefill", "decode", "mixed"][int(rng.integers(0, 3))]
        try:
            c.set_role(g, role, now=float(t))
        except ValueError:
            pass                        # flip would strand arrivals: skipped
    c.drain()
    assert all(st.done for st in c.states.values())
    for res in c.results.values():
        assert res.finish_reason == "length"
    _assert_live_no_leaks(c)


# ---------------- RoleController hysteresis --------------------------------

def test_role_controller_flips_on_backlog_and_respects_floors():
    be = SimServingBackend(LM_FULL, [("prefill", PAR), ("decode", PAR),
                                     ("decode", PAR)],
                           chunk_tokens=160, absorb_tokens=1 << 30)
    rc = RoleController(be, prefill_high=500.0, cooldown_s=0.5,
                        min_decode=1)
    for i in range(20):
        be.submit(Request(i, 0.0, 700, 4))
    be.run_until(0.01)
    now = be._ev.now
    assert rc.tick(now) == (2, "prefill")       # backlog: donate a decode
    assert rc.tick(now + 0.1) is None           # cooldown
    assert rc.tick(now + 5.0) is None           # min_decode floor holds
    assert rc.flips[0][3] == "prefill_backlog"
    be.drain()
    assert be.roles == ["prefill", "decode", "prefill"]
    assert all(s.done for s in be.states.values())


def test_role_controller_flips_back_on_kv_pressure():
    class FakeBackend:
        roles = ["prefill", "prefill", "decode"]

        def __init__(self):
            self.calls = []

        def pressure(self):
            return {"prefill_queued_tokens": 0.0, "decode_kv_util": 0.95,
                    "prefill_inflight": 0.0, "decode_load": 6.0,
                    "mixed_load": 0.0, "n_prefill": 2.0, "n_decode": 1.0,
                    "n_mixed": 0.0}

        def set_role(self, g, role, now=None):
            self.calls.append((g, role))

    be = FakeBackend()
    rc = RoleController(be, kv_high=0.85, min_prefill=1)
    assert rc.tick(0.0) == (1, "decode")        # highest-index prefill
    assert be.calls == [(1, "decode")]
    assert rc.flips[0][3] == "kv_pressure"
    assert rc.tick(10.0) is None                # g=1 still pending-draining


# ---------------- mode-per-instance placement search -----------------------

def test_mode_candidates_cover_all_modes():
    cands = mode_candidates(4)
    modes = [m for m, _ in cands]
    assert "disagg" in modes and "colocated" in modes and "mixed-1" in modes
    for _, roles in cands:
        assert len(roles) == 4
        # every vector can accept arrivals and sink prefill output
        assert any(r in ("prefill", "mixed") for r in roles)
        assert ("prefill" not in roles) or ("decode" in roles)


def test_mode_search_picks_feasible_vector():
    mp = mode_search(LM_FULL, SHAREGPT, rate=1.0, par=PAR, n_instances=2,
                     n_requests=40, chunk_tokens=160)
    assert isinstance(mp, ModePlacement)
    assert len(mp.roles) == 2 and 0.0 <= mp.attain <= 1.0
    assert mp.summary()["mode"] == mp.mode
    # the chosen vector actually simulates clean
    reqs = _trace(4, gap=1.0)
    _, extras = simulate_roles(reqs, LM_FULL, PAR, mp.roles)
    assert all(r.finish is not None for r in reqs)


def test_auto_chunk_tokens_fits_overhead_budget():
    """Model-derived chunk size: a page multiple whose chunked schedule
    on the reference prompt stays inside the overhead budget, and a
    looser budget never forces a bigger chunk."""
    for lm in (LM, LM_FULL):
        c = lm.auto_chunk_tokens(PAR)
        assert c % 16 == 0 and 16 <= c <= 2048
        base = lm.prefill_time([2048], PAR)
        total, ctx = 0.0, 0
        while ctx < 2048:
            new = min(c, 2048 - ctx)
            total += lm.prefill_chunk_time([(new, ctx)], PAR)
            ctx += new
        assert total <= 1.1 * base + 1e-9 or c == 2048
        assert lm.auto_chunk_tokens(PAR, overhead_frac=0.3) <= c


def test_fleet_search_modes_rerole_via_elastic_callback():
    def mk(i):
        return SimServingBackend(LM_FULL, [("prefill", PAR),
                                           ("decode", PAR)],
                                 chunk_tokens=160)
    router = FleetRouter([mk(0), mk(1)], policy="least_loaded")
    search = fleet_search(LM_FULL, InstanceConfig(PAR, 1),
                          InstanceConfig(PAR, 1), n_requests=40,
                          search_modes=True, chunk_tokens=160)
    plan = search(SHAREGPT, 1.0)
    assert plan.roles is not None and len(plan.roles) == 2
    want = ["mixed", "mixed"]
    elastic_callback(mk)(router, FleetPlan(2, 1.0, 1.0, roles=want))
    for rep in router.replicas:
        assert rep.backend.roles == want


# ---------------- KV-pressure overload signal ------------------------------

def test_replica_kv_utilization_registry_and_fallback():
    reg = MetricsRegistry()
    be = SimServingBackend(LM_FULL, [("prefill", PAR), ("decode", PAR)],
                           metrics=reg)
    be.submit(Request(0, 0.0, 64, 2000))
    while be.states[0].status.name != "DECODING":
        assert be.step()
    direct = be.kv_utilization()
    assert direct > 0.0
    # registry path (the scrape an autoscaler sees) agrees with the
    # backend's own signal
    assert replica_kv_utilization(be) == pytest.approx(direct)
    be2 = SimServingBackend(LM_FULL, [("prefill", PAR), ("decode", PAR)])
    be2.submit(Request(0, 0.0, 64, 2000))
    while be2.states[0].status.name != "DECODING":
        assert be2.step()
    assert replica_kv_utilization(be2) == pytest.approx(direct)

    det = OverloadDetector(max_kv_util=direct / 2)
    router = FleetRouter([be, be2], policy="least_loaded", detector=det)
    assert det.overloaded(router.replicas[0])
    det2 = OverloadDetector(max_kv_util=1.0)
    assert not det2.overloaded(router.replicas[0])


def test_kv_gated_router_redirects_to_cold_replica():
    """With one replica KV-saturated by a long generation, the detector
    steers new arrivals to the other replica."""
    hot = SimServingBackend(LM_FULL, [("prefill", PAR), ("decode", PAR)])
    cold = SimServingBackend(LM_FULL, [("prefill", PAR), ("decode", PAR)])
    hot.submit(Request(0, 0.0, 64, 4000))
    while hot.states[0].status.name != "DECODING":
        assert hot.step()
    util = hot.kv_utilization()
    router = FleetRouter([hot, cold], policy="least_loaded",
                         detector=OverloadDetector(max_kv_util=util))
    req = Request(1, hot._ev.now, 32, 4)
    router.submit(req, hot._ev.now)
    router.drain()
    routes = [d for d in router.decisions if d[0] == "route"]
    assert routes == [("route", 1, 1, 0)]


# ---------------- hierarchical fleets --------------------------------------

def _leaf(n, **kw):
    kw.setdefault("lm_tokens", 2048)
    kw.setdefault("max_decode_batch", 32)
    return FleetRouter(
        [SimDisaggBackend(LM_FULL, InstanceConfig(PAR, 1),
                          InstanceConfig(PAR, 1), **kw) for _ in range(n)],
        policy="least_loaded", detector=OverloadDetector(max_inflight=8))


def _run_router(router, reqs):
    for r in reqs:
        router.submit(dataclasses.replace(r))
    return router.drain()


def test_hierarchical_fleet_matches_itself_and_drains_clean():
    """A router of routers behaves as one backend: deterministic
    decisions across identical builds, every request finishes with the
    same timestamps, and the leaves drain leak-free."""
    reqs = _trace(12, gap=0.3, out_len=8)

    def build():
        return FleetRouter([_leaf(2), _leaf(2)], policy="least_loaded",
                           detector=OverloadDetector(max_inflight=16))
    a, b = build(), build()
    res_a, res_b = _run_router(a, reqs), _run_router(b, reqs)
    assert a.decisions and a.decisions == b.decisions
    assert set(res_a) == {r.rid for r in reqs}
    for rid in res_a:
        assert res_a[rid].ttft == res_b[rid].ttft
        assert res_a[rid].finish == res_b[rid].finish
        assert res_a[rid].finish_reason == "length"
    for leaf in (rep.backend for rep in a.replicas):
        assert isinstance(leaf, FleetRouter)
        assert not len(leaf._rqueue)
        for rep in leaf.replicas:
            assert rep.inflight == 0
            assert not rep.backend.tx.parked
            assert rep.backend.kv_utilization() == 0.0


def test_hierarchical_fleet_slo_matches_flat_equivalent():
    """Two levels of least-loaded over 4 identical idle replicas serve a
    sparse trace exactly like the flat 4-replica fleet: same TTFT/finish
    per request (routing differs only in how the indices decompose)."""
    reqs = _trace(8, gap=6.0, out_len=8)
    deep = FleetRouter([_leaf(2), _leaf(2)], policy="least_loaded")
    flat = _leaf(4)
    res_d, res_f = _run_router(deep, reqs), _run_router(flat, reqs)
    for rid in res_f:
        assert res_d[rid].ttft == res_f[rid].ttft
        assert res_d[rid].finish == res_f[rid].finish
