"""Fleet router: sim == live routing-decision identity on a pinned
multi-turn trace, prefix-affinity locality beating shortest-queue,
overload shedding protecting admitted-request attainment, leak-free
shed/cancel fuzz across a live fleet, session stickiness, elastic
replanning, and the ServingBackend protocol contract.

The identity pin is the load-bearing one: the router's load signals are
its own dispatch/harvest bookkeeping (never replica introspection), so a
fleet of `SimDisaggBackend`s and a fleet of live `DisaggCluster`s (with
the deterministic `EngineCharge`) must replay the same trace into the
identical `decisions` list at float-identical times — the same
discipline `DisaggDispatcher` pins inside one cluster.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import hw
from repro.core.goodput import SLOTracker
from repro.core.latency_model import EngineCharge, LatencyModel, Parallelism
from repro.core.replan import Replanner
from repro.core.simulator import InstanceConfig, SimDisaggBackend
from repro.core.telemetry import MetricsRegistry, Tracer, attribute_request
from repro.core.workload import (Request, WorkloadSpec, sample_multi_turn,
                                 with_cancellations)
from repro.models.api import build_model
from repro.serving.api import (FINISH_SHED, RequestStatus, ServingBackend)
from repro.serving.cluster import DisaggCluster
from repro.serving.router import (FleetPlan, FleetRouter, OverloadDetector,
                                  TokenHashTrie, aggregate_snapshots,
                                  elastic_callback, make_policy)

CFG = get_config("yi-6b-smoke")
LM = LatencyModel(CFG, hw.V5E)      # smoke scale: paired with live clusters
LM_FULL = LatencyModel(get_config("yi-6b"), hw.V5E)     # sim-only fleets
PAR = Parallelism(1, 1)
SLOW_BW = 1e3


@pytest.fixture(scope="module")
def params():
    return build_model(CFG).init(jax.random.PRNGKey(0))


def _assert_no_leaks(dc: DisaggCluster):
    """Allocator invariants after drain (same checker as
    test_serving_api): every page free xor refcounted, only the prefix
    tree may retain pages, all batch slots back, nothing parked."""
    assert not dc.tx.parked, "parked transfers leaked"
    for e in (*dc.prefill, *dc.decode):
        assert len(e._slot_free) == e.max_batch, "batch slot leaked"
        if e._kv is None:
            continue
        kv = e._kv
        free = set(kv._free)
        assert len(free) + len(kv._refcnt) == kv.num_pages - 1
        assert free.isdisjoint(kv._refcnt)
        tree_pages = (e.prefix_cache.pages_in_tree()
                      if e.prefix_caching else [])
        assert free.isdisjoint(tree_pages)
        assert kv.used_pages == len(set(tree_pages))
        assert not kv._tables, f"block tables leaked: {kv._tables}"


def _sim_fleet(n, **kw):
    kw.setdefault("lm_tokens", 2048)
    kw.setdefault("max_decode_batch", 32)
    kw.setdefault("prefix_cache", True)
    return [SimDisaggBackend(LM_FULL, InstanceConfig(PAR, 1),
                             InstanceConfig(PAR, 1), **kw)
            for _ in range(n)]


SKEWED = WorkloadSpec("fleet-chat", 4.6, 0.5, (32, 768), 3.4, 0.5, (8, 64),
                      slo_ttft=0.6, slo_tpot=0.1,
                      sys_len=256, turns=4, share=0.9)


def _skewed_trace(rate, n, seed=7):
    return sample_multi_turn(SKEWED, rate=rate, n=n, seed=seed,
                             vocab=CFG.vocab_size, think_s=2.0)


# ---------------- protocol + trie units ------------------------------------

def test_router_satisfies_protocol():
    router = FleetRouter(_sim_fleet(2))
    assert isinstance(router, ServingBackend)


def test_trie_match_insert_drop():
    trie = TokenHashTrie(page_tokens=4)
    a = list(range(12))                 # 3 pages
    trie.insert(a, replica=0)
    trie.insert(a[:8] + [99, 98, 97, 96], replica=1)    # shares 2 pages
    hits = trie.match(a)
    assert hits[0] == 12 and hits[1] == 8
    assert trie.match(a[:7]) == {0: 4, 1: 4}    # sub-page tail ignored
    assert trie.match([5, 5, 5, 5]) == {}
    trie.drop_replica(0)
    assert 0 not in trie.match(a)
    assert trie.match(a)[1] == 8


def test_trie_eviction_bounds_nodes():
    trie = TokenHashTrie(page_tokens=1, max_nodes=64)
    for i in range(200):
        trie.insert([i, i + 1000], replica=0)
    assert trie.nodes <= 64
    # recently-inserted prefixes survive the LRU pruning
    assert trie.match([199, 1199])


# ---------------- acceptance (a): sim == live decisions --------------------

def _pinned_fleet_trace():
    """Two interleaved 3-turn sessions with explicit token ids (growing
    shared history), arrivals far enough apart that both worlds see the
    same queue states at every decision point."""
    rng = np.random.default_rng(42)
    reqs = []
    for sess in range(2):
        prompt = tuple(int(x) for x in rng.integers(1, CFG.vocab_size, 32))
        for turn in range(3):
            user = tuple(int(x) for x in rng.integers(1, CFG.vocab_size, 16))
            prompt = prompt + user
            reqs.append(Request(sess * 3 + turn, sess * 7.0 + turn * 60.0,
                                len(prompt), 4, tokens=prompt))
            prompt = prompt + (7, 7, 7, 7)
    reqs.sort(key=lambda r: r.arrive)
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


def _run_fleet(backends):
    router = FleetRouter(backends, policy="prefix_affinity",
                         detector=OverloadDetector(max_inflight=2))
    for r in _pinned_fleet_trace():
        router.submit(r)
    return router, router.drain()


def test_sim_vs_live_routing_decisions_identical(params):
    live = [DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_batch=2,
                          max_len=256, lm_tokens=128, chunk_tokens=32,
                          transfer_bandwidth=SLOW_BW, prefix_cache=True,
                          charge=EngineCharge(LM, PAR), seed=i)
            for i in range(2)]
    sim = [SimDisaggBackend(LM, InstanceConfig(PAR, 1),
                            InstanceConfig(PAR, 1), transfer_bw=SLOW_BW,
                            lm_tokens=128, chunk_tokens=32,
                            prefix_cache=True)
           for _ in range(2)]
    rl, resl = _run_fleet(live)
    rs, ress = _run_fleet(sim)
    assert rl.decisions, "trace produced no routing decisions"
    assert rl.decisions == rs.decisions
    # affinity actually fired: later turns rode their session's replica
    assert any(hit > 0 for kind, _, _, hit in rl.decisions
               if kind == "route")
    assert set(resl) == set(ress)
    for rid in resl:
        assert resl[rid].ttft == ress[rid].ttft, rid
        assert resl[rid].finish == ress[rid].finish, rid
        assert resl[rid].finish_reason == ress[rid].finish_reason
    for dc in live:
        _assert_no_leaks(dc)


# ---------------- acceptance (b): affinity wins on hit rate ----------------

def _hit_rate(policy):
    reqs = [dataclasses.replace(r) for r in _skewed_trace(rate=40.0, n=240)]
    router = FleetRouter(_sim_fleet(4), policy=policy,
                         detector=OverloadDetector(max_inflight=24))
    for r in reqs:
        router.submit(r)
    router.drain()
    served = [r for r in reqs if r.finish_reason == "length"]
    assert len(served) == len(reqs)
    return sum(r.prefix_hit for r in served) / sum(r.in_len for r in served)


def test_prefix_affinity_beats_shortest_queue_on_hit_rate():
    aff, sq = _hit_rate("prefix_affinity"), _hit_rate("shortest_queue")
    assert aff > sq + 0.05, (aff, sq)
    assert aff > 0.3        # the skewed trace is genuinely cacheable


# ---------------- acceptance (c): shedding protects attainment -------------

def _overloaded_run(detector, reqs):
    reqs = [dataclasses.replace(r) for r in reqs]
    tracker = SLOTracker(SKEWED)
    router = FleetRouter(_sim_fleet(2), policy="shortest_queue",
                         detector=detector, tracker=tracker)
    for r in reqs:
        router.submit(r)
    router.drain()
    return router, tracker.report(), reqs


def test_shed_under_overload_beats_no_shed_attainment():
    reqs = _skewed_trace(rate=160.0, n=240, seed=11)
    shed_det = OverloadDetector.from_slo(SKEWED.slo_ttft, headroom=0.5,
                                         max_inflight=8)
    r_shed, rep_shed, reqs_s = _overloaded_run(shed_det, reqs)
    r_none, rep_none, _ = _overloaded_run(
        OverloadDetector(max_inflight=8), reqs)
    assert r_shed.shed_count > 0 and r_none.shed_count == 0
    assert rep_shed.shed == r_shed.shed_count    # tracker counts them apart
    # admitted requests keep materially higher SLO attainment
    assert rep_shed.attain > rep_none.attain + 0.1, \
        (rep_shed.attain, rep_none.attain)
    # shed = leak-free cancel before any work: no tokens, terminal status
    for rid, res in r_shed.results.items():
        if res.finish_reason == FINISH_SHED:
            assert not res.tokens
    assert all(r_shed.states[rid].status is RequestStatus.CANCELLED
               for rid in r_shed.results
               if r_shed.results[rid].finish_reason == FINISH_SHED)


# ---------------- satellite: shed/cancel fuzz over a live fleet ------------

@pytest.mark.parametrize("seed", [0, 1])
def test_fleet_shed_cancel_fuzz_no_leaks(params, seed):
    """Seeded fuzz: a live 2-replica fleet under a burst with mid-flight
    cancellations and tight overload gates (router queueing + shedding
    both exercised). Every replica must pass the allocator-invariant
    checker, every request must go terminal, and the router tracer's
    spans must conserve (no span left open, a terminal per request)."""
    spec = WorkloadSpec("fuzz", 2.2, 0.4, (4, 24), 1.6, 0.3, (3, 8),
                        slo_ttft=1.0, slo_tpot=1.0,
                        sys_len=16, turns=2, share=0.8)
    reqs = sample_multi_turn(spec, rate=2.0, n=10, seed=seed,
                             vocab=CFG.vocab_size, think_s=30.0)
    rng = np.random.default_rng(seed)
    for i, r in enumerate(reqs):        # burst-compress to force queueing
        r.arrive = i * 0.002
    reqs = with_cancellations(reqs, frac=0.3, seed=seed + 5,
                              mean_wait_s=0.02)
    tracer = Tracer()
    fleet = [DisaggCluster(CFG, params, n_prefill=1, n_decode=1,
                           max_batch=2, max_len=96, lm_tokens=64,
                           prefix_cache=True, seed=i)
             for i in range(2)]
    router = FleetRouter(
        fleet, policy="prefix_affinity", tracer=tracer,
        detector=OverloadDetector(max_inflight=2, max_queue=3,
                                  shed_after_s=0.05))
    for r in reqs:
        router.submit(r)
    res = router.drain()
    assert set(res) == {r.rid for r in reqs}, "requests lost"
    for rid, r in res.items():
        assert router.states[rid].done
        if r.finish_reason == FINISH_SHED:
            assert not r.tokens
    for dc in fleet:
        _assert_no_leaks(dc)
    # span conservation on the router tracer
    assert tracer.open_spans() == []
    assert set(tracer.terminals) == set(res)
    kinds = {k for k, *_ in router.decisions}
    assert "route" in kinds     # fuzz exercised actual routing too


# ---------------- session affinity + router-queue attribution --------------

def test_session_affinity_is_sticky():
    reqs = _skewed_trace(rate=30.0, n=60)
    router = FleetRouter(_sim_fleet(3), policy="session",
                         detector=OverloadDetector(max_inflight=32))
    for r in [dataclasses.replace(r) for r in reqs]:
        router.submit(r)
    router.drain()
    routed = {rid: rep for kind, rid, rep, _ in router.decisions
              if kind == "route"}
    by_head = {}
    for r in reqs:
        by_head.setdefault(tuple(r.tokens[:16]), set()).add(routed[r.rid])
    multi = [v for v in by_head.values() if len(v) > 1]
    assert not multi, f"sessions split across replicas: {multi}"
    assert len({next(iter(v)) for v in by_head.values()}) > 1, \
        "stickiness degenerated to a single replica"


def test_router_queue_wait_is_attributed():
    """With one deliberately saturated replica, a queued request's TTFT
    attribution must carry the router wait as its own term."""
    tracer = Tracer()
    # replicas share the router's tracer: the replica's own queued phase
    # closes router_queued, so the TTFT terms tile with no gap
    router = FleetRouter(_sim_fleet(1, tracer=tracer),
                         policy="shortest_queue",
                         detector=OverloadDetector(max_inflight=1),
                         tracer=tracer)
    t0 = Request(0, 0.0, 512, 32)
    t1 = Request(1, 0.001, 64, 8)       # arrives while 0 occupies the gate
    router.submit(t0)
    router.submit(t1)
    router.drain()
    att = attribute_request(tracer, 1)
    assert att.router_queue_s > 0.0
    assert "router_queue" in att.ttft_parts()
    assert abs(sum(att.ttft_parts().values()) - att.ttft) < 1e-6


def test_shed_deadline_fires_from_ttft_headroom():
    det = OverloadDetector.from_slo(0.4, headroom=0.5, max_inflight=1)
    assert det.shed_after_s == pytest.approx(0.2)
    router = FleetRouter(_sim_fleet(1), policy="least_loaded", detector=det)
    router.submit(Request(0, 0.0, 4096, 256))       # hogs the only replica
    router.submit(Request(1, 0.001, 64, 8))         # queues past deadline
    res = router.drain()
    assert res[1].finish_reason == FINISH_SHED
    assert res[1].finish == pytest.approx(0.001 + 0.2)
    assert res[0].finish_reason == "length"


# ---------------- cancellation through the router --------------------------

def test_cancel_routed_and_queued_requests():
    router = FleetRouter(_sim_fleet(1), policy="least_loaded",
                         detector=OverloadDetector(max_inflight=1))
    h0 = router.submit(Request(0, 0.0, 1024, 128))
    h1 = router.submit(Request(1, 0.001, 64, 8))    # router-queued
    router.run_until(0.01)
    router.cancel(0, router.now)        # routed: delegates to the replica
    router.cancel(1, router.now)        # queued: router releases the slot
    router.drain()
    assert h0.status is RequestStatus.CANCELLED
    assert h1.status is RequestStatus.CANCELLED
    assert h1.result().tokens == []
    assert not router._rqueue.items and not router._routed


# ---------------- elastic replanning ---------------------------------------

def test_elastic_replan_grows_fleet_on_drift():
    """Workload drift through the router's `Replanner` fires `on_replan`,
    and `elastic_callback` grows the fleet to the plan's replica count
    (idempotent if drift triggers more than once)."""
    fired = []
    router = FleetRouter(
        _sim_fleet(1), policy="least_loaded",
        replanner=Replanner(lambda spec, rate: FleetPlan(3, rate, 1.0),
                            slo_ttft=0.4, slo_tpot=0.1, check_every=16),
        on_replan=lambda rt, plan: (
            fired.append(plan),
            elastic_callback(lambda i: _sim_fleet(1)[0])(rt, plan)))
    # phase 1: steady 10/s short prompts (sets the profiler baseline)
    rid = 0
    for i in range(32):
        router.submit(Request(rid, rid * 0.1, 32, 4)); rid += 1
    router.drain()
    assert not fired and router.fleet_size == 1
    # phase 2: rate x4 with 8x prompts -> drift -> replan -> grow to 3
    t = rid * 0.1
    for i in range(32):
        router.submit(Request(rid, t + i * 0.025, 256, 4)); rid += 1
    router.drain()
    assert fired and all(p.replicas == 3 for p in fired)
    assert router.fleet_size == 3 and len(router.replicas) == 3
    assert len(router.results) == rid           # growth lost nothing
    for rep in router.replicas:
        assert rep.inflight == 0 and not rep.rids


def test_elastic_callback_shrinks_newest_first():
    router = FleetRouter(_sim_fleet(3), policy="least_loaded")
    elastic_callback(lambda i: _sim_fleet(1)[0])(router, FleetPlan(1, 0, 1.0))
    assert router.fleet_size == 1
    assert router.replicas[0].routable          # oldest survives
    assert all(not r.alive for r in router.replicas[1:])   # idle -> dead


def test_drain_replica_finishes_inflight_then_dies():
    router = FleetRouter(_sim_fleet(2), policy="least_loaded")
    h = router.submit(Request(0, 0.0, 256, 16))
    router.run_until(1e-4)              # routed, still in flight
    src = router._routed[0]
    router.drain_replica(src)
    rep = router.replicas[src]
    assert rep.draining and rep.alive   # still steppable
    router.submit(Request(1, router.now + 1e-4, 64, 8))
    res = router.drain()
    assert res[0].finish_reason == "length"     # drained replica finished it
    assert not rep.alive
    routed1 = next(rep for k, rid, rep, _ in router.decisions
                   if k == "route" and rid == 1)
    assert routed1 != src               # nothing new routed to it


# ---------------- metrics + aggregation ------------------------------------

def test_router_metrics_and_fleet_aggregation():
    metrics = MetricsRegistry()
    router = FleetRouter(_sim_fleet(2), policy="shortest_queue",
                         detector=OverloadDetector(max_inflight=1,
                                                   max_queue=2),
                         metrics=metrics)
    for i in range(8):
        router.submit(Request(i, i * 1e-4, 512, 16))
    router.drain()
    snap = metrics.snapshot()
    assert snap["router.shed_total"] == router.shed_count > 0
    assert snap["requests_shed"] == router.shed_count
    assert snap["router.replicas_alive"] == 2.0
    assert (snap["router.replica0.finished"]
            + snap["router.replica1.finished"]
            == len(router.results) - router.shed_count)

    agg = aggregate_snapshots({"replica0": {"queue.depth": 2.0, "x": 1.0},
                               "replica1": {"queue.depth": 3.0}})
    assert agg["replica0.queue.depth"] == 2.0
    assert agg["fleet.queue.depth"] == 5.0
    assert agg["fleet.x"] == 1.0


def test_make_policy_rejects_unknown():
    with pytest.raises(KeyError):
        make_policy("round_robin_nope")
