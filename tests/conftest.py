import os

# Tests run on the single real CPU device; only the dry-run spawns the
# 512-device placeholder topology (in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "float32")
