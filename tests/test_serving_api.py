"""Request-lifecycle serving API: one backend protocol for live clusters
and simulators, streaming handles, stop conditions, SLO tracking, and
leak-free cancellation at every lifecycle stage."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import hw
from repro.core.goodput import SLOTracker
from repro.core.latency_model import LatencyModel, Parallelism
from repro.core.simulator import (InstanceConfig, SimColocatedBackend,
                                  SimDisaggBackend, simulate_disaggregated,
                                  summarize)
from repro.core.workload import Request, WorkloadSpec, with_cancellations
from repro.models.api import build_model
from repro.serving.api import (RequestStatus, SamplingParams, ServedResult,
                               ServingBackend)
from repro.serving.cluster import ColocatedCluster, DisaggCluster

CFG = get_config("yi-6b-smoke")
LM = LatencyModel(CFG, hw.V5E)


@pytest.fixture(scope="module")
def params():
    return build_model(CFG).init(jax.random.PRNGKey(0))


def _reqs(n=6):
    return [Request(i, i * 0.01, 10 + (i % 4) * 3, 5) for i in range(n)]


def _assert_no_leaks(dc: DisaggCluster):
    """Allocator invariants after drain (the checker family from
    test_prefix_cache): every page is free xor refcounted, only the
    prefix tree may retain pages, every batch slot is back, nothing is
    parked in the transfer manager, and free lists never intersect a
    block table or the tree."""
    assert not dc.tx.parked, "parked transfers leaked"
    for e in (*dc.prefill, *dc.decode):
        assert len(e._slot_free) == e.max_batch, "batch slot leaked"
        if e._kv is None:
            continue
        kv = e._kv
        free = set(kv._free)
        assert len(free) + len(kv._refcnt) == kv.num_pages - 1
        assert free.isdisjoint(kv._refcnt)
        tree_pages = (e.prefix_cache.pages_in_tree()
                      if e.prefix_caching else [])
        assert free.isdisjoint(tree_pages)
        # all remaining references belong to the tree, not to sequences
        assert kv.used_pages == len(set(tree_pages)), \
            (kv.used_pages, len(set(tree_pages)))
        assert not kv._tables, f"block tables leaked: {kv._tables}"


# ---------------- one protocol, two worlds --------------------------------

def test_backends_satisfy_protocol(params):
    dc = DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_batch=2,
                       max_len=64, lm_tokens=48)
    cc = ColocatedCluster(CFG, params, n_engines=1, max_batch=2, max_len=64)
    sd = SimDisaggBackend(LM, InstanceConfig(Parallelism(1, 1), 1),
                          InstanceConfig(Parallelism(1, 1), 1))
    sc = SimColocatedBackend(LM, InstanceConfig(Parallelism(1, 1), 1))
    for be in (dc, cc, sd, sc):
        assert isinstance(be, ServingBackend)


def test_same_trace_same_decisions_live_and_sim(params):
    """The acceptance bar: drive the SAME arrival trace through the live
    cluster and the simulator via ServingBackend.submit/drain and assert
    identical dispatch decisions and matching per-request structure
    (token-event counts; TTFT ordering constraints)."""
    sim = SimDisaggBackend(LM, InstanceConfig(Parallelism(1, 1), 3),
                           InstanceConfig(Parallelism(1, 1), 1))
    live = DisaggCluster(CFG, params, n_prefill=3, n_decode=1, max_batch=8,
                         max_len=64, lm_tokens=48)
    in_lens = [10, 22, 13, 17, 9, 20]
    for be in (sim, live):
        handles = [be.submit(Request(i, 0.0, in_lens[i], 4))
                   for i in range(len(in_lens))]
        res = be.drain()
        assert len(res) == len(in_lens)
        for h in handles:
            assert h.status is RequestStatus.FINISHED
            # token-count structure: out_len events, first one is TTFT
            assert len(h.state.events) == 4
            assert h.state.events[0].t == h.state.request.first_token
            assert h.state.ttft > 0
            ts = h.state.token_times
            assert all(b >= a for a, b in zip(ts, ts[1:]))
    sim_pre = [d for d in sim.disp.decisions if d[0] == "prefill"]
    live_pre = [d for d in live.dispatcher.decisions if d[0] == "prefill"]
    assert sim_pre == live_pre
    assert len({i for _, _, i, _ in sim_pre}) == 3   # non-trivial spread
    assert sorted(d for d in sim.disp.decisions if d[0] == "decode") == \
        sorted(d for d in live.dispatcher.decisions if d[0] == "decode")


def test_legacy_run_shim_matches_explicit_submit_drain(params):
    """`run(requests)` is a thin submit-all-then-drain shim: identical
    ServedResults (every field, including per-token timestamps) to
    driving the open-loop API by hand — and repeated `run`s replay
    identically (fresh loop + token rng)."""
    dc = DisaggCluster(CFG, params, n_prefill=2, n_decode=1, max_batch=4,
                       max_len=64, lm_tokens=48)
    via_run = dc.run(_reqs())
    dc2 = DisaggCluster(CFG, params, n_prefill=2, n_decode=1, max_batch=4,
                        max_len=64, lm_tokens=48)
    for r in _reqs():
        dc2.submit(r)
    via_api = dc2.drain()
    assert set(via_run) == set(via_api)
    for rid in via_run:
        assert via_run[rid].tokens == via_api[rid].tokens, rid
        assert via_run[rid].finish_reason == via_api[rid].finish_reason
        assert len(via_run[rid].token_times) == \
            len(via_api[rid].token_times)
    # replay determinism of the shim itself
    again = dc.run(_reqs())
    assert {rid: r.tokens for rid, r in again.items()} == \
        {rid: r.tokens for rid, r in via_run.items()}


def test_streaming_iterator_and_result(params):
    dc = DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_batch=4,
                       max_len=64, lm_tokens=48)
    seen = []
    h = dc.submit(Request(0, 0.0, 12, 6),
                  on_token=lambda st, ev: seen.append(ev.token))
    streamed = [ev.token for ev in h.tokens()]
    assert len(streamed) == 6
    assert streamed == seen                       # callback saw the same
    res = h.result()
    assert isinstance(res, ServedResult)
    assert res.tokens[-6:] == streamed
    assert res.n_generated == 6
    assert res.tpot_max >= res.tpot_p99 >= 0.0


# ---------------- stop conditions -----------------------------------------

def test_stop_token_ends_generation_with_reason(params):
    prompt = tuple(np.random.default_rng(3).integers(
        1, CFG.vocab_size, 12).tolist())
    dc = DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_batch=4,
                       max_len=64, lm_tokens=48)
    probe = dc.run([Request(0, 0.0, 12, 8, tokens=prompt)])
    assert probe[0].finish_reason == "length"
    stop_tok = probe[0].tokens[-4]                # generated mid-stream
    h = dc.submit(Request(1, 0.0, 12, 8, tokens=prompt),
                  sampling=SamplingParams(stop=(stop_tok,)))
    r = h.result()
    assert r.finish_reason == "stop"
    assert r.tokens[-1] == stop_tok
    assert r.n_generated == 5                     # 8-token budget cut short
    # max_tokens caps below the request's out_len
    h2 = dc.submit(Request(2, 0.0, 12, 8, tokens=prompt),
                   sampling=SamplingParams(max_tokens=3))
    assert h2.result().n_generated == 3


def test_temperature_sampling_reproducible(params):
    prompt = tuple(np.random.default_rng(4).integers(
        1, CFG.vocab_size, 10).tolist())

    def gen(rid, seed):
        """Same rid + seed on a fresh cluster must replay exactly (the
        per-request rng is seeded by (seed, rid))."""
        dc = DisaggCluster(CFG, params, n_prefill=1, n_decode=1,
                           max_batch=4, max_len=64, lm_tokens=48)
        h = dc.submit(Request(rid, 0.0, 10, 6, tokens=prompt),
                      sampling=SamplingParams(temperature=1.0, seed=seed))
        out = h.result().tokens[10:]
        _assert_no_leaks(dc)
        return out

    assert gen(0, 7) == gen(0, 7)           # deterministic replay
    streams = {tuple(gen(0, 7)), tuple(gen(0, 8)), tuple(gen(1, 7))}
    assert len(streams) > 1                 # seed/rid actually matter


# ---------------- cancellation safety -------------------------------------

def test_cancel_at_each_live_stage(params):
    """Walk a request to each observable lifecycle stage (stepping the
    event loop one event at a time), cancel there, and require: no page /
    pin / parked-byte leaks, and later requests still complete."""
    stages = [RequestStatus.QUEUED, RequestStatus.MIGRATING,
              RequestStatus.PENDING_ADMIT, RequestStatus.DECODING]
    for stage in stages:
        dc = DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_batch=2,
                           max_len=64, lm_tokens=48,
                           decode_num_pages=2 * (64 // 16) + 1)
        # enough load that admission actually backs up (PENDING_ADMIT);
        # cancel whichever request is observed at the stage first — which
        # rid occupies a stage window depends on jit-compile wall time
        # charged to the virtual clock, so pinning one rid is a race
        handles = [dc.submit(r) for r in _reqs(5)]
        target = None
        while target is None:
            target = next((h for h in handles if h.status is stage), None)
            if target is not None:
                target.cancel()
                break
            if not dc.step():
                break
        assert target is not None, f"stage {stage} never observed"
        res = dc.drain()
        assert target.status is RequestStatus.CANCELLED
        assert res[target.state.request.rid].finish_reason == "cancelled"
        others = [h for h in handles if h is not target]
        assert all(h.status is RequestStatus.FINISHED for h in others)
        assert all(len(h.state.events) == 5 for h in others)
        _assert_no_leaks(dc)


def test_cancel_fuzz_random_stages_no_leaks(params):
    """Property-style fuzz: random cancel times across a bursty trace
    (hitting queued / parked-in-transfer / pinned-pending / mid-decode at
    random), with the prefix cache ON so pins and shared pages are in
    play. Allocator invariants must hold after every drain."""
    rng = np.random.default_rng(0)
    sys_p = tuple(rng.integers(1, CFG.vocab_size, 16).tolist())
    for trial in range(4):
        rr = np.random.default_rng(100 + trial)
        reqs = []
        for i in range(10):
            u = tuple(rr.integers(1, CFG.vocab_size,
                                  int(rr.integers(4, 20))).tolist())
            reqs.append(Request(i, i * 0.02, 16 + len(u), 4,
                                tokens=sys_p + u))
        reqs = with_cancellations(reqs, frac=0.5, seed=trial,
                                  mean_wait_s=0.3)
        dc = DisaggCluster(CFG, params, n_prefill=2, n_decode=1,
                           max_batch=4, max_len=64, lm_tokens=48,
                           prefix_cache=True,
                           decode_num_pages=3 * (64 // 16) + 1)
        res = dc.run(reqs)
        assert len(res) == 10
        cancelled = {rid for rid, r in res.items()
                     if r.finish_reason == "cancelled"}
        for rid, r in res.items():
            if rid not in cancelled:
                assert r.finish_reason in ("length", "stop")
                assert len(r.token_times) == 4
        _assert_no_leaks(dc)
        # the cluster stays serviceable: fresh traffic completes
        post = [Request(100 + i, 0.0, 12, 3) for i in range(3)]
        for r in post:
            dc.submit(r, t=dc.now)
        res2 = dc.drain()
        assert all(res2[100 + i].finish_reason == "length"
                   for i in range(3))
        _assert_no_leaks(dc)


def test_cancel_in_colocated_cluster(params):
    cc = ColocatedCluster(CFG, params, n_engines=1, max_batch=2, max_len=64)
    handles = [cc.submit(r) for r in _reqs(4)]
    handles[2].cancel(t=0.0)                      # cancel while queued
    h_dec = handles[0]
    while not h_dec.done and h_dec.status is not RequestStatus.DECODING:
        cc.step()
    h_dec.cancel()
    res = cc.drain()
    assert res[2].finish_reason == "cancelled"
    assert res[0].finish_reason == "cancelled"
    assert res[1].finish_reason == "length"
    for e in cc.engines:
        assert len(e._slot_free) == e.max_batch
        if e._kv is not None:
            assert e._kv.used_pages == 0 and not e._kv._tables


def test_cancel_in_simulator_frees_pool_pages():
    """Simulated cancellation at random stages: PagePool conservation +
    later requests finish; cancelled requests never count as served."""
    spec = WorkloadSpec("w", 5.0, 1.0, (4, 512), 4.0, 0.5, (4, 64),
                        slo_ttft=10.0, slo_tpot=10.0)
    rng = np.random.default_rng(1)
    reqs = [Request(i, float(i) * 0.05, int(rng.integers(16, 400)),
                    int(rng.integers(4, 40))) for i in range(60)]
    # virtual service times are milliseconds at this scale: keep the
    # abandon delay short enough to land mid-flight
    reqs = with_cancellations(reqs, frac=0.4, seed=2, mean_wait_s=0.01)
    sim = SimDisaggBackend(LM, InstanceConfig(Parallelism(1, 1), 1),
                           InstanceConfig(Parallelism(1, 1), 1))
    for r in reqs:
        sim.submit(r)
    sim.drain()
    n_cancelled = sum(r.finish_reason == "cancelled" for r in reqs)
    assert n_cancelled > 0
    for d in sim.D:
        assert d.pool.used == 0, "simulated pages leaked"
        assert not d.pool._alloc
        assert not d.running and not d.pending and not d.arrived
        assert d.in_transfer == 0
    assert not sim.tx.parked
    for r in reqs:
        if r.finish_reason != "cancelled":
            assert r.finish >= 0 and r.finish_reason == "length"
    res = summarize(reqs, spec, warmup_frac=0.0)
    assert res.n_cancelled == n_cancelled
    assert len(res.requests) == 60


# ---------------- online SLO tracking --------------------------------------

def test_slo_tracker_online_matches_summarize():
    """Feeding the tracker token-by-token while the simulator runs must
    agree with the offline summarize() pass over the same trace."""
    spec = WorkloadSpec("w", 4.0, 0.8, (4, 256), 3.0, 0.5, (4, 32),
                        slo_ttft=0.5, slo_tpot=0.05)
    rng = np.random.default_rng(3)
    reqs = [Request(i, float(i) * 0.1, int(rng.integers(16, 200)),
                    int(rng.integers(4, 24))) for i in range(40)]
    tracker = SLOTracker(spec)
    sim = SimDisaggBackend(LM, InstanceConfig(Parallelism(1, 1), 1),
                           InstanceConfig(Parallelism(1, 1), 1),
                           tracker=tracker)
    for r in reqs:
        sim.submit(r)
    sim.drain()
    res = summarize(reqs, spec, extra=sim.extras(), warmup_frac=0.0)
    rep = tracker.report()
    assert rep.finished == len(reqs)
    assert rep.ttft_attain == pytest.approx(res.ttft_attain)
    assert rep.tpot_attain == pytest.approx(res.tpot_attain)
    assert rep.attain == pytest.approx(res.attain)
    assert rep.worst_itl >= res.max_itl > 0
    assert res.p99_itl > 0
    assert res.slo is not None and res.slo.attain == res.attain


def test_served_result_itl_distribution(params):
    """TPOT is a distribution now: per-token timestamps expose the tail
    (max/p99), not just the mean the legacy field carried."""
    dc = DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_batch=4,
                       max_len=64, lm_tokens=48)
    res = dc.run(_reqs(4))
    for r in res.values():
        assert len(r.token_times) == 5
        itl = r.itl()
        assert len(itl) == 4
        assert r.tpot == pytest.approx(sum(itl) / len(itl))
        assert r.tpot_max == max(itl)
