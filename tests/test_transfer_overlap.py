"""Per-layer KV transfer/compute overlap: streaming admission starts
decode at first-layer-landed instead of blob-complete, and the live
cluster and the discrete-event simulator charge the same overlapped wire
time (identical float math on both sides)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import hw
from repro.core.kv_transfer import (TransferManager, kv_bytes, layered_times,
                                    pipelined_finish)
from repro.core.latency_model import LatencyModel, Parallelism
from repro.core.simulator import InstanceConfig, SimDisaggBackend
from repro.core.workload import Request
from repro.models.api import build_model
from repro.serving.cluster import DisaggCluster

CFG = get_config("yi-6b-smoke")
LM = LatencyModel(CFG, hw.V5E)
L = CFG.num_layers
SLOW_BW = 1e3       # B/s: wire time dwarfs any measured compute time


@pytest.fixture(scope="module")
def params():
    return build_model(CFG).init(jax.random.PRNGKey(0))


# ---------------- schedule math -------------------------------------------

def test_layered_times_schedule():
    # 4 layers ship back-to-back over 8s of wire starting at t=10
    assert layered_times(10.0, 8.0, 4) == (12.0, 18.0)
    # single layer: nothing to stream ahead of
    t1, tf = layered_times(5.0, 6.0, 1)
    assert t1 == tf == 11.0
    assert layered_times(0.0, 0.0, 16) == (0.0, 0.0)


def test_pipelined_finish_drain():
    # compute-bound: KV fully landed before the iteration ends
    assert pipelined_finish(10.0, 4.0, 9.0, 4) == 14.0
    # wire-bound: last layer lands late, drains one layer-slice after
    assert pipelined_finish(10.0, 4.0, 20.0, 4) == 21.0
    # L=1 degenerates to serial: full blob then a whole step
    assert pipelined_finish(10.0, 4.0, 20.0, 1) == 24.0


def test_kv_transfer_first_layer_time():
    full = LM.kv_transfer_time(128, 50e9)
    assert LM.kv_transfer_first_layer_time(128, 50e9) == full / L


def test_pull_layered_accounting():
    tx = TransferManager(100.0, n_layers=4)
    tx.park(0, "blob", 400, 1.0)
    blob, t_first, t_full = tx.pull_layered(0, 1.0)
    assert blob == "blob"
    assert (t_first, t_full) == (2.0, 5.0)
    assert tx.layer_overlap_s == pytest.approx(3.0)
    # the legacy pull() shim reports blob-complete
    tx.park(1, "b2", 400, 10.0)
    assert tx.pull(1, 10.0) == ("b2", 14.0)


# ---------------- live cluster realizes the overlap -----------------------

def _one_req(n=1):
    return [Request(i, i * 0.01, 12, 4) for i in range(n)]


def test_live_decode_admit_at_first_layer(params):
    dc = DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_batch=2,
                       max_len=64, lm_tokens=48, transfer_bandwidth=SLOW_BW)
    reqs = _one_req()
    res = dc.run(reqs)
    r = reqs[0]
    wire = kv_bytes(CFG, r.in_len) / SLOW_BW
    # admission at first-layer-landed: exactly wire/L after the prefill
    # parked the blob (link idle, pull starts at first_token time)
    assert r.decode_admit - r.first_token == pytest.approx(wire / L,
                                                           rel=1e-9)
    assert r.transfer_done - r.first_token == pytest.approx(wire, rel=1e-9)
    assert r.decode_admit < r.transfer_done
    # the first decode iteration drains only past the last layer's landing
    # (plus one layer-slice of compute), not a full serialized step later
    assert r.finish > r.transfer_done
    assert res[r.rid].tokens


def test_live_streaming_beats_blob_serial(params):
    def run(n_layers):
        dc = DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_batch=2,
                           max_len=64, lm_tokens=48,
                           transfer_bandwidth=SLOW_BW)
        dc.tx.n_layers = n_layers          # charge model only
        reqs = _one_req()
        res = dc.run(reqs)
        return reqs[0], res[0]
    (streamed, out_s), (serial, out_1) = run(L), run(1)
    assert out_s.tokens == out_1.tokens               # timing-only change
    wire = kv_bytes(CFG, streamed.in_len) / SLOW_BW
    d_s = streamed.decode_admit - streamed.first_token
    d_1 = serial.decode_admit - serial.first_token
    assert d_s == pytest.approx(wire / L, rel=1e-9)
    assert d_1 == pytest.approx(wire, rel=1e-9)
    assert d_s * L == pytest.approx(d_1, rel=1e-9)    # exposed stall / L


# ---------------- live == sim charge parity -------------------------------

def test_live_and_sim_charge_identical_overlap(params):
    """The realized overlap charge is the same float quantity in both
    worlds: wire seconds come from the identical kv-bytes expression, and
    both admit at start + wire/L."""
    live = DisaggCluster(CFG, params, n_prefill=1, n_decode=1, max_batch=2,
                         max_len=64, lm_tokens=48, transfer_bandwidth=SLOW_BW)
    sim = SimDisaggBackend(LM, InstanceConfig(Parallelism(1, 1), 1),
                           InstanceConfig(Parallelism(1, 1), 1),
                           transfer_bw=SLOW_BW)
    reqs_l = _one_req()
    live.run(reqs_l)
    hs = [sim.submit(r) for r in _one_req()]
    sim.drain()
    rl = reqs_l[0]
    rs = hs[0].state.request
    # both wire formulas reduce to the same float: per_tok * len / bw
    assert kv_bytes(CFG, rl.in_len) / SLOW_BW == \
        LM.kv_transfer_time(rs.in_len, SLOW_BW)
    ol_live = rl.decode_admit - rl.first_token
    ol_sim = rs.decode_admit - rs.first_token
    assert ol_live == pytest.approx(ol_sim, rel=1e-9)
    assert rl.transfer_done - rl.decode_admit == pytest.approx(
        rs.transfer_done - rs.decode_admit, rel=1e-9)


def test_sim_streaming_beats_blob_serial():
    def run(n_layers):
        sim = SimDisaggBackend(LM, InstanceConfig(Parallelism(1, 1), 1),
                               InstanceConfig(Parallelism(1, 1), 1),
                               transfer_bw=SLOW_BW)
        sim.tx.n_layers = n_layers
        hs = [sim.submit(r) for r in _one_req(3)]
        sim.drain()
        return [h.state.request for h in hs]
    streamed, serial = run(L), run(1)
    for s, b in zip(streamed, serial):
        assert s.transfer_done == pytest.approx(b.transfer_done, rel=1e-9)
        assert s.decode_admit < b.decode_admit    # admitted a blob earlier
        assert s.finish < b.finish                # and finished earlier
