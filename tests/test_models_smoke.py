"""Per-assigned-architecture smoke tests (reduced same-family configs):
one forward + one train-ish step on CPU, shape and NaN checks, and
prefill→decode parity against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.api import build_model

ARCH_NAMES = list(ARCHS)


def _batch(cfg, B, S, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(
            jax.random.PRNGKey(5), (B, 12, cfg.d_model)) * 0.3
    if cfg.frontend_tokens:
        batch["frontend_embeds"] = jax.random.normal(
            jax.random.PRNGKey(7), (B, cfg.frontend_tokens, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_shapes_no_nan(name):
    cfg = get_config(name + "-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 20
    batch = _batch(cfg, B, S, jax.random.PRNGKey(1))
    logits, aux = model.forward(params, batch, attn_blocks=(8, 8))
    S_out = S + (cfg.frontend_tokens or 0)
    assert logits.shape == (B, S_out, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_train_step(name):
    from repro.training.optimizer import AdamWConfig, adamw_init
    from repro.training.train_step import make_train_step
    cfg = get_config(name + "-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S, jax.random.PRNGKey(1))
    S_out = S + (cfg.frontend_tokens or 0)
    batch["targets"] = jax.random.randint(
        jax.random.PRNGKey(2), (B, S_out), 0, cfg.vocab_size)
    step = make_train_step(model, AdamWConfig(lr=1e-3), remat=False,
                           attn_blocks=(8, 8))
    opt = adamw_init(params)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.abs(x).sum()),
        jax.tree.map(lambda a, b: a - b, params, params2), 0.0)
    assert delta > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_prefill_decode_parity(name):
    cfg = get_config(name + "-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, extra = 2, 18, 2
    batch = _batch(cfg, B, S + extra, jax.random.PRNGKey(1))
    toks = batch["tokens"]
    logits_full, _ = model.forward(params, batch, attn_blocks=(8, 8))
    off = cfg.frontend_tokens or 0
    pre = dict(batch, tokens=toks[:, :S])
    lg, cache = model.prefill(params, pre, max_len=S + 8, attn_blocks=(8, 8))
    np.testing.assert_allclose(lg, logits_full[:, off + S - 1],
                               atol=2e-3, rtol=2e-2)
    for j in range(extra):
        lg, cache = model.decode_step(params, cache, toks[:, S + j])
        np.testing.assert_allclose(lg, logits_full[:, off + S + j],
                                   atol=2e-3, rtol=2e-2)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_remat_matches(name):
    cfg = get_config(name + "-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, 1, 16, jax.random.PRNGKey(1))
    l1, _ = model.forward(params, batch, remat=False, attn_blocks=(8, 8))
    l2, _ = model.forward(params, batch, remat=True, attn_blocks=(8, 8))
    np.testing.assert_allclose(l1, l2, atol=1e-5, rtol=1e-5)
