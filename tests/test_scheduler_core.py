"""Shared scheduler core: one batch-formation/dispatch implementation for
the simulator and the live cluster, interpolated percentiles, page pools."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import hw
from repro.core.latency_model import LatencyModel, Parallelism
from repro.core.scheduler import (DisaggDispatcher, EventLoop, FCFSQueue,
                                  PagePool, least_loaded, shortest_queue)
from repro.core.simulator import (InstanceConfig, _percentile,
                                  simulate_disaggregated)
from repro.core.workload import Request
from repro.models.api import build_model
from repro.serving.cluster import DisaggCluster


# ---------------- percentiles ---------------------------------------------

@pytest.mark.parametrize("q", [0.5, 0.9, 0.95])
def test_percentile_matches_numpy_linear(q):
    xs = [float(x) for x in range(1, 11)]          # 1..10
    assert _percentile(xs, q) == pytest.approx(np.percentile(xs, q * 100))


def test_percentile_pinned_values():
    xs = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0]
    assert _percentile(xs, 0.5) == pytest.approx(5.5)    # not 6 (truncation)
    assert _percentile(xs, 0.9) == pytest.approx(9.1)
    assert _percentile(xs, 0.95) == pytest.approx(9.55)
    assert _percentile([7.0], 0.9) == 7.0
    assert _percentile([], 0.5) == 0.0
    # unsorted input is handled
    assert _percentile([3.0, 1.0, 2.0], 0.5) == pytest.approx(2.0)


# ---------------- FCFS batch formation ------------------------------------

def _q(tokens):
    q = FCFSQueue(token_of=lambda x: x)
    for t in tokens:
        q.push(t)
    return q


def test_form_batch_budget_and_cap():
    assert _q([10, 20, 30]).form_batch(35) == [10, 20]
    assert _q([10, 20, 30]).form_batch(35, max_batch=1) == [10]
    # oversized head goes alone
    assert _q([100, 5]).form_batch(35) == [100]
    q = _q([10, 20, 30])
    q.form_batch(35)
    assert q.queued_tokens == 30 and len(q) == 1


def test_form_batch_can_take_gates_admission():
    assert _q([10, 20]).form_batch(100, can_take=lambda x: False) == []
    # stateful predicate admitting a single item
    taken = []

    def one(x):
        if taken:
            return False
        taken.append(x)
        return True

    q = _q([10, 20, 30])
    assert q.form_batch(100, can_take=one) == [10]
    assert q.queued_tokens == 50


# ---------------- event loop / policies -----------------------------------

def test_event_loop_fifo_among_ties():
    ev = EventLoop()
    ev.push(1.0, "a")
    ev.push(0.5, "b")
    ev.push(0.5, "c")
    order = [ev.pop()[1] for _ in range(3)]
    assert order == ["b", "c", "a"]


def test_policies_tie_break_low_index_and_alive_filter():
    queues = [_q([5]), _q([5]), _q([1])]
    assert shortest_queue(queues) == 2
    assert shortest_queue(queues, alive=[0, 1]) == 0
    assert least_loaded([3, 1, 1]) == 1
    assert least_loaded([3, 1, 1], alive=[0, 2]) == 2


def test_page_pool_accounting():
    pool = PagePool(10, unit=16)
    assert pool.pages_for(1) == 1 and pool.pages_for(16) == 1
    assert pool.pages_for(17) == 2
    pool.alloc(1, 6)
    assert pool.free_pages == 4
    assert not pool.can_alloc(5)
    pool.alloc(2, 4)
    assert pool.free_pages == 0 and pool.peak_used == 10
    assert pool.free(1) == 6
    assert pool.free_pages == 6


# ---------------- simulator vs live cluster -------------------------------

CFG = get_config("yi-6b-smoke")
IN_LENS = [10, 22, 13, 17, 9, 20]


def _trace():
    return [Request(i, 0.0, IN_LENS[i], 4) for i in range(len(IN_LENS))]


def test_sim_and_live_cluster_make_identical_dispatch_decisions():
    """Same burst trace through the shared scheduler core on both drivers:
    every request must land on the same prefill and decode instance."""
    lm = LatencyModel(CFG, hw.V5E)
    _, extras = simulate_disaggregated(
        _trace(), lm, InstanceConfig(Parallelism(1, 1), 3),
        InstanceConfig(Parallelism(1, 1), 1))
    sim_dec = extras["decisions"]

    params = build_model(CFG).init(jax.random.PRNGKey(0))
    dc = DisaggCluster(CFG, params, n_prefill=3, n_decode=1, max_batch=8,
                       max_len=64, lm_tokens=48)
    res = dc.run(_trace())
    live_dec = dc.dispatcher.decisions

    assert len(res) == len(IN_LENS)
    sim_pre = [d for d in sim_dec if d[0] == "prefill"]
    live_pre = [d for d in live_dec if d[0] == "prefill"]
    assert sim_pre == live_pre
    # burst in-lens spread over all instances -> decisions are non-trivial
    assert len({idx for _, _, idx, _hit in sim_pre}) == 3
    sim_dcd = sorted(d for d in sim_dec if d[0] == "decode")
    live_dcd = sorted(d for d in live_dec if d[0] == "decode")
    assert sim_dcd == live_dcd
