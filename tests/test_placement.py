"""Placement algorithms (paper Alg. 1 / Alg. 2) and goodput search."""
import pytest

from repro.configs import get_config
from repro.core import hw
from repro.core.goodput import max_goodput, min_slo_scale
from repro.core.latency_model import LatencyModel, Parallelism
from repro.core.placement import (algo1_high_affinity, algo2_low_affinity,
                                  vllm_pp_search, _fits)
from repro.core.simulator import InstanceConfig, simulate_colocated
from repro.core.workload import SHAREGPT, derive_slos

CFG = get_config("yi-6b")
LM = LatencyModel(CFG, hw.V5E)
SPEC = derive_slos(SHAREGPT, LM)


def test_algo1_returns_feasible_placement():
    pl = algo1_high_affinity(LM, SPEC, rate=20, n_node=1, m_per_node=8,
                             n_requests=200)
    assert pl.prefill.goodput_per_chip > 0
    assert pl.decode.goodput_per_chip > 0
    assert pl.n_prefill >= 1 and pl.n_decode >= 1
    assert _fits(LM, pl.prefill.par, hw.V5E)
    assert _fits(LM, pl.decode.par, hw.V5E)
    # replication sized to meet the requested rate
    assert (pl.prefill.goodput_per_chip * pl.prefill.par.num_chips
            * pl.n_prefill) >= 20 * 0.99


def test_algo2_respects_node_capacity():
    pl = algo2_low_affinity(LM, SPEC, rate=10, n_node=1, m_per_node=8,
                            n_requests=200)
    assert (pl.prefill.par.tp + pl.decode.par.tp) <= 8
    assert pl.n_prefill == pl.n_decode  # paired segments


def test_vllm_pp_search_finds_config():
    par, g = vllm_pp_search(LM, SPEC, rate=10, n_node=1, m_per_node=8,
                            n_requests=200)
    assert g > 0
    assert _fits(LM, par, hw.V5E)


def test_goodput_monotone_in_slo_scale():
    def run(reqs):
        return simulate_colocated(reqs, LM, InstanceConfig(Parallelism(2, 1), 1))
    tight = max_goodput(run, SPEC, 2, slo_scale=0.5, n_requests=200)
    loose = max_goodput(run, SPEC, 2, slo_scale=2.0, n_requests=200)
    assert loose.rate >= tight.rate


def test_min_slo_scale_bracket():
    def run(reqs):
        return simulate_colocated(reqs, LM, InstanceConfig(Parallelism(2, 1), 1))
    s = min_slo_scale(run, SPEC, rate=1.0, n_requests=200)
    assert 0.05 <= s <= 8.0
